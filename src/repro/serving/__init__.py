"""Serving substrate: batched prefill + decode engine over the consensus
model (the deployable artifact of a decentralized-FL run)."""

from repro.serving.engine import ServeEngine, GenerationResult

__all__ = ["ServeEngine", "GenerationResult"]
