"""Batched serving engine: prefill a prompt batch, then step-decode.

The engine serves the CONSENSUS model (theta_bar) produced by FL training.
Prefill populates per-layer caches by replaying the prompt through the
decode step (token-at-a-time -- simple and cache-layout-exact; a fused
prefill that reuses ``prefill_fn``'s full-sequence pass and writes caches
in one shot is the production path exercised by the dry-run).

Decode supports greedy and temperature sampling; all steps are jitted once
per (batch, cache) shape.

Hot-swap: the engine holds a **double-buffered weight slot**. A training
loop (or snapshot watcher) calls :meth:`ServeEngine.publish` from any
thread to stage new consensus weights into the PENDING slot; the decode
loop promotes pending -> active with one atomic reference swap at the
next step boundary (:meth:`decode_step`), so a new snapshot lands
without draining or corrupting in-flight decode batches -- the KV caches
carry over untouched, and every step runs against exactly one weight
set (never a torn mix). Staging (``jax.device_put``) happens in the
PUBLISHER's thread; the decode loop only ever pays the reference swap,
timed per swap in ``swap_pauses``. ``snapshot_round`` tracks the round
frontier of the ACTIVE weights, so ``staleness(frontier)`` is the
serving-side lag in training rounds.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import ModelBundle

PyTree = Any

__all__ = ["ServeEngine", "GenerationResult"]


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, prompt+generated)
    prompt_len: int
    steps: int
    #: absolute step indices (0 = first prefill step) at whose BOUNDARY a
    #: published weight set was swapped in during this call
    swap_steps: Tuple[int, ...] = ()


class ServeEngine:
    def __init__(
        self,
        bundle: ModelBundle,
        params: PyTree,
        max_seq: int,
        batch: int,
        sliding_override: bool = False,
        snapshot_round: Optional[int] = None,
    ) -> None:
        self.bundle = bundle
        self.cfg: ModelConfig = bundle.cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self.sliding = sliding_override
        self._step = jax.jit(
            functools.partial(bundle.decode_fn, sliding_override=sliding_override)
        )
        #: round frontier of the ACTIVE weights (None = unknown/seed)
        self.snapshot_round = snapshot_round
        # pending slot: (params, round, keepalive) or None. Written by
        # publisher threads, consumed by the decode loop; a single
        # reference assignment either way, atomic under the GIL.
        self._pending: Optional[Tuple[PyTree, Optional[int], Any]] = None
        # keepalive for the active weights (e.g. the mmap-backed
        # Snapshot whose views the params alias)
        self._active_ref: Any = None
        self.swap_count = 0
        self.swap_pauses: List[float] = []  # seconds per completed swap

    @classmethod
    def from_snapshot(cls, bundle: ModelBundle, snapshot, max_seq: int,
                      batch: int, sliding_override: bool = False,
                      stage: bool = True) -> "ServeEngine":
        """Serve straight from an mmap-loaded consensus snapshot
        (``repro.training.snapshot.load_snapshot``). ``stage=True``
        device-puts the views once up front (pages fault in lazily from
        the blob); ``stage=False`` keeps the raw views."""
        params = snapshot.params
        if stage:
            params = jax.device_put(params)
        eng = cls(bundle, params, max_seq, batch,
                  sliding_override=sliding_override,
                  snapshot_round=snapshot.round_frontier)
        eng._active_ref = snapshot
        return eng

    # ---------------------------------------------------------- hot swap

    def publish(self, params: PyTree, snapshot_round: Optional[int] = None,
                keepalive: Any = None, stage: bool = True) -> None:
        """Stage new weights into the pending slot (any thread).

        The decode loop promotes them at its next step boundary. With
        ``stage=True`` the (possibly mmap-view) leaves are device-put
        HERE, in the publisher's thread, so the decode loop's swap stays
        a pure reference assignment. ``keepalive`` pins whatever owns
        the leaves' memory (a Snapshot) for as long as they are active.
        """
        if stage:
            params = jax.device_put(params)
        self._pending = (params, snapshot_round, keepalive)

    def publish_snapshot(self, snapshot, stage: bool = True) -> None:
        """Publish an mmap-loaded consensus snapshot."""
        self.publish(snapshot.params, snapshot.round_frontier,
                     keepalive=snapshot, stage=stage)

    def _maybe_swap(self) -> bool:
        """Promote the pending weight slot, if any. Called by the decode
        loop between steps; never blocks on the publisher."""
        pend = self._pending
        if pend is None:
            return False
        t0 = time.perf_counter()
        params, rnd, keep = pend
        self._pending = None
        self.params = params
        self.snapshot_round = rnd
        self._active_ref = keep
        pause = time.perf_counter() - t0
        self.swap_pauses.append(pause)
        self.swap_count += 1
        return True

    def staleness(self, frontier: int) -> Optional[int]:
        """Rounds the ACTIVE weights lag the training frontier, or None
        when the engine was built from raw params with no round."""
        if self.snapshot_round is None:
            return None
        return int(frontier) - int(self.snapshot_round)

    # ------------------------------------------------------------ decode

    def decode_step(self, tokens: jnp.ndarray, caches: PyTree):
        """One decode step at a swap boundary: promote any pending
        weights, then step. Returns (logits, caches, swapped)."""
        swapped = self._maybe_swap()
        logits, caches = self._step(self.params, tokens, caches)
        return logits, caches, swapped

    def new_caches(self) -> PyTree:
        return self.bundle.init_decode_state_fn(
            self.batch, self.max_seq, sliding_override=self.sliding
        )

    def _sample(self, logits: jnp.ndarray, key, temperature: float) -> jnp.ndarray:
        # mask padded vocab
        mask = jnp.arange(logits.shape[-1]) < self.cfg.vocab_size
        logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(
        self,
        prompts: np.ndarray,
        max_new_tokens: int,
        temperature: float = 0.0,
        seed: int = 0,
        frames: Optional[np.ndarray] = None,
    ) -> GenerationResult:
        """prompts: (B, P) int32. For the audio family pass ``frames``
        (stub frontend embeddings); the engine encodes once and fills the
        cross-attention caches."""
        b, p = prompts.shape
        if b != self.batch:
            raise ValueError(f"engine built for batch {self.batch}, got {b}")
        caches = self.new_caches()
        if self.cfg.family == "audio":
            from repro.models import encdec as encdec_mod

            enc_out = encdec_mod.encode(self.params, self.cfg, jnp.asarray(frames))
            caches = encdec_mod.encdec_fill_cross_kv(self.params, self.cfg, enc_out, caches)

        toks = jnp.asarray(prompts, jnp.int32)
        out: List[np.ndarray] = [np.asarray(toks)]
        key = jax.random.key(seed)
        swap_steps: List[int] = []

        # prefill by stepping the prompt through the decode path
        logits = None
        for t in range(p):
            logits, caches, swapped = self.decode_step(toks[:, t], caches)
            if swapped:
                swap_steps.append(t)

        cur = self._sample(logits, key, temperature)
        generated = [np.asarray(cur)[:, None]]
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, caches, swapped = self.decode_step(cur, caches)
            if swapped:
                swap_steps.append(p + i)
            cur = self._sample(logits, sub, temperature)
            generated.append(np.asarray(cur)[:, None])
        tokens = np.concatenate(out + generated, axis=1)
        return GenerationResult(tokens=tokens, prompt_len=p,
                                steps=p + max_new_tokens,
                                swap_steps=tuple(swap_steps))
