"""Batched serving engine: prefill a prompt batch, then step-decode.

The engine serves the CONSENSUS model (theta_bar) produced by FL training.
Prefill populates per-layer caches by replaying the prompt through the
decode step (token-at-a-time -- simple and cache-layout-exact; a fused
prefill that reuses ``prefill_fn``'s full-sequence pass and writes caches
in one shot is the production path exercised by the dry-run).

Decode supports greedy and temperature sampling; all steps are jitted once
per (batch, cache) shape.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import ModelBundle

PyTree = Any

__all__ = ["ServeEngine", "GenerationResult"]


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, prompt+generated)
    prompt_len: int
    steps: int


class ServeEngine:
    def __init__(
        self,
        bundle: ModelBundle,
        params: PyTree,
        max_seq: int,
        batch: int,
        sliding_override: bool = False,
    ) -> None:
        self.bundle = bundle
        self.cfg: ModelConfig = bundle.cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self.sliding = sliding_override
        self._step = jax.jit(
            functools.partial(bundle.decode_fn, sliding_override=sliding_override)
        )

    def new_caches(self) -> PyTree:
        return self.bundle.init_decode_state_fn(
            self.batch, self.max_seq, sliding_override=self.sliding
        )

    def _sample(self, logits: jnp.ndarray, key, temperature: float) -> jnp.ndarray:
        # mask padded vocab
        mask = jnp.arange(logits.shape[-1]) < self.cfg.vocab_size
        logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(
        self,
        prompts: np.ndarray,
        max_new_tokens: int,
        temperature: float = 0.0,
        seed: int = 0,
        frames: Optional[np.ndarray] = None,
    ) -> GenerationResult:
        """prompts: (B, P) int32. For the audio family pass ``frames``
        (stub frontend embeddings); the engine encodes once and fills the
        cross-attention caches."""
        b, p = prompts.shape
        if b != self.batch:
            raise ValueError(f"engine built for batch {self.batch}, got {b}")
        caches = self.new_caches()
        if self.cfg.family == "audio":
            from repro.models import encdec as encdec_mod

            enc_out = encdec_mod.encode(self.params, self.cfg, jnp.asarray(frames))
            caches = encdec_mod.encdec_fill_cross_kv(self.params, self.cfg, enc_out, caches)

        toks = jnp.asarray(prompts, jnp.int32)
        out: List[np.ndarray] = [np.asarray(toks)]
        key = jax.random.key(seed)

        # prefill by stepping the prompt through the decode path
        logits = None
        for t in range(p):
            logits, caches = self._step(self.params, toks[:, t], caches)

        cur = self._sample(logits, key, temperature)
        generated = [np.asarray(cur)[:, None]]
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, caches = self._step(self.params, cur, caches)
            cur = self._sample(logits, sub, temperature)
            generated.append(np.asarray(cur)[:, None])
        tokens = np.concatenate(out + generated, axis=1)
        return GenerationResult(tokens=tokens, prompt_len=p, steps=p + max_new_tokens)
