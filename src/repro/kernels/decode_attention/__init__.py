from repro.kernels.decode_attention import ops, ref
from repro.kernels.decode_attention.decode_attention import decode_attention_bhd

__all__ = ["ops", "ref", "decode_attention_bhd"]
