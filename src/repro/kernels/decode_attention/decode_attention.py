"""Single-token decode attention against a KV cache -- Pallas TPU.

The decode-shape hot-spot (decode_32k / long_500k): one query row per
(batch x head) attends over a cache of up to seq_len keys, with a
validity horizon (contiguous cache: slots <= pos; ring buffer: all slots
once full). Memory-bound by nature -- the kernel's job is to stream K/V
through VMEM exactly once with fp32 online softmax, instead of
materializing (B, H, 1, C) scores + probs in HBM.

Grid = (batch*q_heads, cache_blocks); the cache-block axis is TPU's
sequential minor loop carrying (acc, m, l) scratch. GQA via K/V
index_map, like the prefill flash kernel. Padding rows of the final
cache block are masked via the validity horizon.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")

__all__ = ["decode_attention_bhd"]


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    nvalid_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    block_c: int,
    n_c: int,
    cache_len: int,
    scale: float,
):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (1, hd)
    k = k_ref[0].astype(jnp.float32)  # (bc, hd)
    v = v_ref[0].astype(jnp.float32)
    n_valid = nvalid_ref[0]

    cpos = cb * block_c + jax.lax.iota(jnp.int32, block_c)
    live = cpos < jnp.minimum(n_valid, cache_len)
    kz = jnp.where(live[:, None], k, 0.0)
    vz = jnp.where(live[:, None], v, 0.0)

    s = (q @ kz.T)[0] * scale  # (bc,)
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(live, jnp.exp(s - safe_m), 0.0)  # (bc,)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
    l_ref[0] = corr * l_ref[0] + jnp.sum(p)
    acc_ref[...] = corr * acc_ref[...] + (p[None, :] @ vz)
    m_ref[0] = m_new

    @pl.when(cb == n_c - 1)
    def _final():
        l = l_ref[0]
        o_ref[0] = (acc_ref[...] / jnp.where(l > 0.0, l, 1.0)).astype(o_ref.dtype)


def decode_attention_bhd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    n_valid: jnp.ndarray,
    *,
    n_q_heads: int = 1,
    n_kv_heads: int = 1,
    block_c: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B*H, 1, hd); k, v: (B*K, C, hd); n_valid: (B,) int32 populated
    slots per batch row. Returns (B*H, 1, hd)."""
    bh, _, hd = q.shape
    bkv, cache_len, _ = k.shape
    group = n_q_heads // n_kv_heads
    b = bh // n_q_heads
    block_c = min(block_c, cache_len)
    n_c = pl.cdiv(cache_len, block_c)

    def q_map(i, cb):
        return (i, 0, 0)

    def kv_map(i, cb):
        batch = i // n_q_heads
        h = i % n_q_heads
        return (batch * n_kv_heads + h // group, cb, 0)

    def nv_map(i, cb):
        return (i // n_q_heads,)

    kernel = functools.partial(
        _kernel, block_c=block_c, n_c=n_c, cache_len=cache_len, scale=hd**-0.5
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_c),
        in_specs=[
            pl.BlockSpec((1, 1, hd), q_map),
            pl.BlockSpec((1, block_c, hd), kv_map),
            pl.BlockSpec((1, block_c, hd), kv_map),
            pl.BlockSpec((1,), nv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _vmem((1, hd), jnp.float32),
            _vmem((1,), jnp.float32),
            _vmem((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, n_valid)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
