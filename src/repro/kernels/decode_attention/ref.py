"""Pure-jnp oracle for decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decode_attention_ref"]


def decode_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    n_valid: jnp.ndarray,
    *,
    n_q_heads: int = 1,
    n_kv_heads: int = 1,
) -> jnp.ndarray:
    """Same contract as decode_attention_bhd; materialized fp32 softmax."""
    bh, _, hd = q.shape
    b = bh // n_q_heads
    group = n_q_heads // n_kv_heads
    cache_len = k.shape[1]
    kk = jnp.repeat(k.reshape(b, n_kv_heads, cache_len, hd), group, axis=1).reshape(bh, cache_len, hd)
    vv = jnp.repeat(v.reshape(b, n_kv_heads, cache_len, hd), group, axis=1).reshape(bh, cache_len, hd)
    s = jnp.einsum("nqd,ncd->nqc", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    valid = jnp.arange(cache_len)[None] < jnp.repeat(n_valid, n_q_heads)[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("nqc,ncd->nqd", p, vv.astype(jnp.float32)).astype(q.dtype)
