"""jit'd dispatch for the decode attention kernel from cache layout."""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_bhd

__all__ = ["decode_attention"]


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_c",))
def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    n_valid: jnp.ndarray,
    block_c: int = 256,
) -> jnp.ndarray:
    """Model layout: q (B, 1, H, hd); caches (B, C, K, hd); n_valid (B,).
    Returns (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    c, n_kv = k_cache.shape[1], k_cache.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, 1, hd)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * n_kv, c, hd)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * n_kv, c, hd)
    out = decode_attention_bhd(
        qf, kf, vf, n_valid.astype(jnp.int32),
        n_q_heads=h, n_kv_heads=n_kv, block_c=block_c, interpret=_interpret(),
    )
    return out.reshape(b, h, 1, hd).transpose(0, 2, 1, 3)
