"""Naive sequential oracle for the RG-LRU recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rglru_ref"]


def rglru_ref(log_a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray):
    """h_t = exp(log_a_t) h_{t-1} + b_t. log_a/b: (B,S,W); h0: (B,W)."""

    def step(h, xs):
        la, bt = xs
        h = jnp.exp(la) * h + bt
        return h, h

    xs = (jnp.moveaxis(log_a, 1, 0), jnp.moveaxis(b, 1, 0))
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1).astype(log_a.dtype), h_last
