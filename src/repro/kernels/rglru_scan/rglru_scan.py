"""RG-LRU gated linear recurrence h_t = a_t h_{t-1} + b_t -- Pallas.

Grid = (batch, d_blocks, time_chunks); the time dimension is the
sequential minor loop with the (block_d,) fp32 state carried in VMEM
scratch. Within a chunk the recurrence is evaluated in CLOSED FORM via the
per-channel transition matrix

    M[t, a, c] = exp(L_t[c] - L_a[c])   for a <= t, else 0,
    h_t = exp(L_t) h_in + sum_a M[t, a] b_a,

where L_t = cumsum(log a). Every exponent is <= 0 (decays are in (0, 1]),
so the formulation is unconditionally stable -- no renormalization pass.
The M tensor is (chunk, chunk, block_d); with the default chunk=64,
block_d=128 it occupies 2 MiB fp32 of VMEM, and the contraction is VPU
multiply-adds (the recurrence has no MXU shape by nature).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rglru_scan_pallas"]


def _kernel(la_ref, b_ref, h0_ref, y_ref, hlast_ref, h_ref, *, chunk, n_chunks):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    la = la_ref[0].astype(jnp.float32)  # (C, bd), <= 0
    b = b_ref[0].astype(jnp.float32)
    h_in = h_ref[...]  # (bd,)

    cum = jnp.cumsum(la, axis=0)  # L_t (C, bd), decreasing
    # M[t, a, c] = exp(L_t - L_a) for a <= t (includes a == t: exp(0) = 1)
    diff = cum[:, None, :] - cum[None, :, :]  # (C, C, bd)
    t_idx = jax.lax.iota(jnp.int32, chunk)
    tril = (t_idx[:, None] >= t_idx[None, :])[:, :, None]
    m = jnp.where(tril, jnp.exp(jnp.where(tril, diff, 0.0)), 0.0)
    h = jnp.exp(cum) * h_in[None, :] + jnp.einsum("tac,ac->tc", m, b)
    y_ref[0] = h.astype(y_ref.dtype)
    h_ref[...] = h[-1]

    @pl.when(c == n_chunks - 1)
    def _final():
        hlast_ref[0] = h_ref[...]


def rglru_scan_pallas(
    log_a: jnp.ndarray,
    b: jnp.ndarray,
    h0: jnp.ndarray,
    *,
    block_d: int = 128,
    chunk: int = 64,
    interpret: bool = False,
):
    """log_a, b: (B, S, W) fp32; h0: (B, W). Returns (h (B,S,W), h_last)."""
    bsz, s, w = log_a.shape
    block_d = min(block_d, w)
    chunk = min(chunk, s)
    assert w % block_d == 0, (w, block_d)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    nd = w // block_d

    seq_spec = pl.BlockSpec((1, chunk, block_d), lambda i, j, c: (i, c, j))
    vec_spec = pl.BlockSpec((1, block_d), lambda i, j, c: (i, j))

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    h, h_last = pl.pallas_call(
        kernel,
        grid=(bsz, nd, n_chunks),
        in_specs=[seq_spec, seq_spec, vec_spec],
        out_specs=[seq_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, w), log_a.dtype),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        scratch_shapes=[_vmem((block_d,), jnp.float32)],
        interpret=interpret,
    )(log_a, b, h0)
    return h, h_last


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
