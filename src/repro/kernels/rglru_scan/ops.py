"""jit'd dispatch for the RG-LRU scan kernel."""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas

__all__ = ["rglru_scan"]


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_d", "chunk"))
def rglru_scan(
    log_a: jnp.ndarray,
    b: jnp.ndarray,
    h0: jnp.ndarray,
    block_d: int = 128,
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    s = log_a.shape[1]
    w = log_a.shape[2]
    ck = chunk if s % chunk == 0 else 1
    bd = block_d if w % block_d == 0 else w
    return rglru_scan_pallas(
        log_a, b, h0, block_d=bd, chunk=ck, interpret=_interpret()
    )
