from repro.kernels.rglru_scan import ops, ref
from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas

__all__ = ["ops", "ref", "rglru_scan_pallas"]
