"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref_bhsd"]


def attention_ref_bhsd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    n_q_heads: int = 1,
    n_kv_heads: int = 1,
) -> jnp.ndarray:
    """Same contract as flash_attention_bhsd, materialized softmax in fp32."""
    bh, sq, hd = q.shape
    group = n_q_heads // n_kv_heads
    b = bh // n_q_heads
    # expand kv to q heads
    kk = k.reshape(b, n_kv_heads, *k.shape[1:])
    vv = v.reshape(b, n_kv_heads, *v.shape[1:])
    kk = jnp.repeat(kk, group, axis=1).reshape(bh, *k.shape[1:])
    vv = jnp.repeat(vv, group, axis=1).reshape(bh, *v.shape[1:])
    s = jnp.einsum("nsd,ntd->nst", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -jnp.inf)
    # fully-masked rows -> zero output (matches kernel's l==0 guard)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("nst,ntd->nsd", p, vv.astype(jnp.float32)).astype(q.dtype)
