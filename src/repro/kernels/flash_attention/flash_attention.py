"""Fused flash attention (causal / sliding-window, GQA) -- Pallas TPU.

TPU-native adaptation of the flash-attention online-softmax algorithm:

  * grid = (batch*q_heads, q_blocks, kv_blocks); the LAST grid dimension is
    TPU's sequential minor loop, so fp32 accumulators (acc, row-max m,
    row-sum l) live in VMEM scratch and persist across kv blocks;
  * BlockSpec tiles (block_q x head_dim) / (block_k x head_dim) are chosen
    MXU-aligned (multiples of 128 where head_dim allows);
  * GQA is handled in the K/V index_map (q-head -> kv-head), so grouped
    K/V are streamed HBM->VMEM once per group, never materialized repeated;
  * fully-masked kv blocks (above the causal diagonal / outside the
    window) are skipped with pl.when -- the causal schedule does ~half the
    work, the windowed schedule O(window/seq).

Accumulation is fp32 regardless of input dtype.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")

__all__ = ["flash_attention_bhsd"]


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    block_q: int,
    block_k: int,
    n_k: int,
    seq_q: int,
    seq_k: int,
    causal: bool,
    window: int,
    scale: float,
):
    jq = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = jq * block_q
    k_lo = kb * block_k
    # static-shape positions, dynamic offsets
    qpos = q_lo + jax.lax.iota(jnp.int32, block_q)
    kpos = k_lo + jax.lax.iota(jnp.int32, block_k)

    # block-level skip: entirely above the diagonal or left of the window
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + block_q - 1)
    if window:
        live = jnp.logical_and(live, k_lo + block_k - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        # zero padded tail rows: p is 0 there but 0 * garbage = NaN in p @ v
        kv_valid = (kpos < seq_k)[:, None]
        k = jnp.where(kv_valid, k, 0.0)
        v = jnp.where(kv_valid, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        # tail guards (seq not divisible by block)
        mask &= (qpos[:, None] < seq_q) & (kpos[None, :] < seq_k)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # rows with no live key yet keep m = -inf; guard exp args
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(jnp.where(mask, s - safe_m[:, None], NEG_INF))
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1)
        acc_ref[...] = corr[:, None] * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kb == n_k - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    n_q_heads: int = 1,
    n_kv_heads: int = 1,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B*H, Sq, hd); k, v: (B*K, Sk, hd) with H = G*K. Returns like q.

    The (b, h) -> (b, h // G) mapping happens in the K/V index_map.
    """
    bh, seq_q, hd = q.shape
    bkv, seq_k, _ = k.shape
    group = n_q_heads // n_kv_heads
    assert bh % n_q_heads == 0 and bkv % n_kv_heads == 0
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    n_q = pl.cdiv(seq_q, block_q)
    n_k = pl.cdiv(seq_k, block_k)

    def q_map(i, jq, kb):
        return (i, jq, 0)

    def kv_map(i, jq, kb):
        b = i // n_q_heads
        h = i % n_q_heads
        return (b * n_kv_heads + h // group, kb, 0)

    kernel = functools.partial(
        _kernel,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
        seq_q=seq_q,
        seq_k=seq_k,
        causal=causal,
        window=window,
        scale=hd**-0.5,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _vmem((block_q, hd), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
