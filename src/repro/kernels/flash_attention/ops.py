"""jit'd dispatch for flash attention from model-layout tensors.

Models call with (B, S, H, hd) activations; this wrapper folds to the
kernel's (B*H, S, hd) layout, picks MXU-aligned block sizes, and selects
interpret mode automatically off-TPU (kernel-body-in-Python validation, the
only execution mode available in this CPU container).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd

__all__ = ["flash_attention"]


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, S, K, hd) (K may equal H). -> (B,S,H,hd)."""
    b, s, h, hd = q.shape
    n_kv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * n_kv, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * n_kv, v.shape[1], hd)
    out = flash_attention_bhsd(
        qf,
        kf,
        vf,
        causal=causal,
        window=window,
        n_q_heads=h,
        n_kv_heads=n_kv,
        block_q=block_q,
        block_k=block_k,
        interpret=_interpret(),
    )
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
