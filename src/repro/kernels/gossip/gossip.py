"""Fused quantize-mix-EF gossip round -- Pallas.

Grid = (total // chunk,): each program owns ONE ``(nodes, chunk)`` column
block of the flat state, which is the natural tile because compressed
gossip is columnwise-independent -- the int8 scale is per (node, chunk)
block, the W contraction runs over the nodes axis that is fully resident
in the tile, and the EF update is elementwise. Per tile the kernel
computes, entirely in VMEM with no materialized full-size intermediates:

    payload = x - recon + res            (difference coding + EF)
    s       = max|payload| / 127         per node row       <- wire scales
    q       = clip(round(payload / s))                      <- wire payload
    dq      = q * s
    recon'  = recon + dq
    res'    = payload - dq
    mixed   = W_off @ recon' + w_self * x    (MXU: (n,n) x (n,chunk))

replacing the three full-size fp32 intermediates (payload, dq, recon') of
the unfused path with one HBM read of each input and one write of each
output. With the default chunk=512 and n=64 nodes the live tile set is
~0.9 MiB fp32 -- far under VMEM; n should be a multiple of 8 (fp32
sublane) on real hardware. The jnp oracle in ``ref.py`` is bit-identical
math (interpret-mode property tests in tests/test_gossip_flat.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gossip_mix_pallas"]


def _kernel(
    x_ref,
    recon_ref,
    res_ref,
    woff_ref,
    wself_ref,
    mixed_ref,
    nrecon_ref,
    nres_ref,
    scale_ref,
    *,
    error_feedback,
    difference_coding,
):
    x = x_ref[...]  # (n, chunk) fp32
    recon = recon_ref[...]
    res = res_ref[...]

    base = recon if difference_coding else jnp.zeros_like(recon)
    payload = x - base
    if error_feedback:
        payload = payload + res

    scale = jnp.max(jnp.abs(payload), axis=1, keepdims=True) / 127.0  # (n, 1)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(payload / safe), -127, 127)
    dq = q * scale

    new_recon = base + dq
    mixed = (
        jnp.dot(woff_ref[...], new_recon, preferred_element_type=jnp.float32)
        + wself_ref[...] * x
    )

    mixed_ref[...] = mixed
    nrecon_ref[...] = new_recon
    nres_ref[...] = payload - dq if error_feedback else res
    scale_ref[...] = scale


def gossip_mix_pallas(
    x: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    *,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    interpret: bool = False,
):
    """x, recon, res: (n, t) fp32 with t % scale_chunk == 0; w_off (n, n);
    w_self (n,). Returns (mixed, new_recon, new_res, scales (n, t//chunk))."""
    n, t = x.shape
    if t % scale_chunk:
        raise ValueError(f"total {t} not a multiple of scale_chunk {scale_chunk}")
    n_chunks = t // scale_chunk

    tile = pl.BlockSpec((n, scale_chunk), lambda c: (0, c))
    whole = pl.BlockSpec((n, n), lambda c: (0, 0))
    col = pl.BlockSpec((n, 1), lambda c: (0, c))

    kernel = functools.partial(
        _kernel, error_feedback=error_feedback, difference_coding=difference_coding
    )
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[tile, tile, tile, whole, pl.BlockSpec((n, 1), lambda c: (0, 0))],
        out_specs=[tile, tile, tile, col],
        out_shape=[
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, n_chunks), jnp.float32),
        ],
        interpret=interpret,
    )(x, recon, res, w_off, w_self.reshape(n, 1))
