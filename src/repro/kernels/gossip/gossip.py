"""Fused gossip + round megakernel bodies -- Pallas.

Grid = (total // chunk,): each program owns ONE ``(nodes, chunk)`` column
block of the flat state, which is the natural tile because compressed
gossip is columnwise-independent -- the int8 scale is per (node, chunk)
block, the W contraction runs over the nodes axis that is fully resident
in the tile, and the local-update / EF arithmetic is elementwise. Per tile
the shared quantize-mix stage computes, entirely in VMEM with no
materialized full-size intermediates:

    payload = x - recon + res            (difference coding + EF)
    s       = max|payload| / 127         per node row       <- wire scales
    q       = clip(round(payload / s))                      <- wire payload
    dq      = q * s
    recon'  = recon + dq
    res'    = payload - dq
    mixed   = W_off @ recon' + w_self * x    (MXU: (n,n) x (n,chunk))

All stages take ``topk``: when set, the payload is masked to the k
largest-|.| columns of the tile before quantization (the tile IS one
scale chunk, so the mask is per (node, chunk) exactly like the scale);
the EF residual absorbs the truncated mass, and the wire drops below the
dense-int8 floor. The threshold is the k-th largest |payload| via an
in-tile ``jnp.sort`` (ties at the threshold are kept, deterministically
and identically in the jnp oracle).

Five kernels share that stage:

* :func:`gossip_mix_pallas` -- the stage alone (PR 1's fused
  quantize-mix-EF gossip round);
* :func:`fused_round_pallas` -- the DSGD **round megakernel**: the local
  update ``h = x - alpha * g`` runs in-register ahead of the stage, so one
  kernel call is a whole communication round (update + quantize + mix +
  EF) over the flat state;
* :func:`fused_round_gt_pallas` -- the DSGT round megakernel: tracker
  arithmetic ``t_half = t + g - g_prev``, parameter update
  ``h = x - alpha * t_half``, then the quantize-mix stage applied to BOTH
  buffers inside the same program (two MXU contractions against the same
  resident W tile);
* :func:`wire_stage_pallas` / :func:`wire_stage_gt_pallas` -- the
  SHARDED fused round's pre-collective half: everything above EXCEPT the
  W contraction (update + diff-code + top-k + int8 quantize + EF),
  emitting the int8 payload + fp32 scales that cross the wire; the mix
  finishes outside the kernel against the engine's running
  neighbor-reconstruction accumulator (``core.engine.ShardedFusedEngine``);
* :func:`wire_stage_compact_pallas` / :func:`wire_stage_gt_compact_pallas`
  -- the TRULY SPARSE top-k wire: the same wire stage with a
  compact-gather epilogue. Selection is EXACT-k (``jax.lax.top_k`` on
  |payload|, ties broken toward the lower index -- identically in the jnp
  oracle), and the tile emits ``(k int8 values, k in-chunk positions,
  one fp32 scale)`` per scale chunk instead of the masked-dense buffer.
  Only those compact buffers cross the collective; the receive side
  scatter-accumulates them back to dense (``ref.scatter_compact_dq``)
  before the W contraction. The EF/recon updates still use the full
  dense dequant (computed in-tile -- dq never hits the wire), so masking
  defers signal exactly as in the masked-dense path. With
  ``bitmap=True`` the tile ALSO runs the bitmap re-encode epilogue
  in-kernel (argsort the k survivors into ascending-position order +
  bit-pack the presence bitmap, ``chunk/8`` uint8 per chunk) -- the
  same math ``ref.compact_to_bitmap`` used to apply as jnp
  post-processing outside the kernel, now fused into the same program
  so the wire operands leave the kernel collective-ready (bit-identical
  buffers, same single pallas_call).

The quantize-mix kernels additionally take ``stale_mix`` (the PIPELINED
round schedule): the W contraction runs against the INPUT ``recon`` --
the reconstruction every neighbor had already advanced to at the END of
the previous round -- instead of ``new_recon``, so the mix consumes
one-round-stale neighbor information while this round's payload is still
"in flight". ``new_recon`` advances regardless (both endpoints replay
the wire), which is what makes stale mixing exactly the
sequential-with-one-round-delay dynamics.

Replacing the unfused path's full-size fp32 intermediates (the updated
parameters h, payload, dq, recon') with one HBM read of each input and one
write of each output. With the default chunk=512 and n=64 nodes the DSGT
live tile set is ~2 MiB fp32 -- far under VMEM; n should be a multiple of
8 (fp32 sublane) on real hardware. ``alpha`` rides along as a (1, 1)
operand mapped to every program (scalar on the wire, SMEM-friendly). The
jnp oracles in ``ref.py`` are bit-identical math (interpret-mode property
tests in tests/test_gossip_flat.py and tests/test_megakernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "gossip_mix_pallas",
    "fused_round_pallas",
    "fused_round_gt_pallas",
    "wire_stage_pallas",
    "wire_stage_gt_pallas",
    "wire_stage_compact_pallas",
    "wire_stage_gt_compact_pallas",
]


def _topk_mask(payload, topk):
    """Keep only the ``topk`` largest-|.| columns of each row of ONE
    (nodes, chunk) tile; everything else becomes a structural zero on the
    wire (ties at the threshold are all kept -- deterministic, and shared
    bit-for-bit with the jnp oracle which applies the same formula
    chunk-by-chunk). ``topk >= chunk`` disables the mask."""
    chunk = payload.shape[-1]
    if topk is None or topk >= chunk:
        return payload
    thr = jnp.sort(jnp.abs(payload), axis=-1)[..., chunk - topk][..., None]
    return jnp.where(jnp.abs(payload) >= thr, payload, 0.0)


def _quantize_ef(x, recon, res, *, error_feedback, difference_coding, topk):
    """Difference-code, (optionally top-k mask,) int8-quantize, and EF
    update of ONE (nodes, chunk) tile -- everything that happens BEFORE the
    wire. Returns (payload_q as fp32 ints, scale, new_recon, new_res).
    With top-k the EF residual absorbs the truncated mass (payload - dq is
    the FULL payload minus the sparse dequant), so masking never loses
    signal, it only defers it."""
    base = recon if difference_coding else jnp.zeros_like(recon)
    payload = x - base
    if error_feedback:
        payload = payload + res

    sel = _topk_mask(payload, topk)
    scale = jnp.max(jnp.abs(sel), axis=1, keepdims=True) / 127.0  # (n, 1)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(sel / safe), -127, 127)
    dq = q * scale

    new_recon = base + dq
    new_res = payload - dq if error_feedback else res
    return q, scale, new_recon, new_res


def _topk_gather(payload, topk):
    """EXACT-k selection of ONE (nodes, chunk) tile: the values and
    in-chunk positions of the k largest-|.| columns per row
    (``jax.lax.top_k`` on |payload|; ties broken toward the lower index,
    deterministically and identically in the jnp oracle). Unlike
    :func:`_topk_mask` this never keeps threshold ties beyond k -- the
    compact wire has exactly k slots per chunk."""
    _, idx = jax.lax.top_k(jnp.abs(payload), topk)  # (n, k) int32
    vals = jnp.take_along_axis(payload, idx, axis=-1)
    return vals, idx


def _quantize_ef_compact(x, recon, res, *, error_feedback, difference_coding,
                         topk):
    """Compact-gather variant of :func:`_quantize_ef`: exact-k selection,
    int8 quantization of the k SURVIVORS only, and the dense dq scattered
    back in-tile for the recon/EF updates (dq never crosses the wire).
    Returns (q (n, k) as fp32 ints, pos (n, k) int32, scale (n, 1),
    new_recon, new_res)."""
    base = recon if difference_coding else jnp.zeros_like(recon)
    payload = x - base
    if error_feedback:
        payload = payload + res

    vals, pos = _topk_gather(payload, topk)
    scale = jnp.max(jnp.abs(vals), axis=1, keepdims=True) / 127.0  # (n, 1)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(vals / safe), -127, 127)  # (n, k)

    rows = jax.lax.broadcasted_iota(jnp.int32, pos.shape, 0)
    dq = jnp.zeros_like(payload).at[rows, pos].add(q * scale)

    new_recon = base + dq
    new_res = payload - dq if error_feedback else res
    return q, pos, scale, new_recon, new_res


def _bitmap_pack(q, pos, scale_chunk):
    """In-tile bitmap re-encode of ONE compact (nodes, k) selection:
    re-sort the k survivors into ascending-position order and bit-pack
    the LSB-first presence bitmap (``scale_chunk // 8`` uint8 per chunk)
    -- the same formula as ``ref.compact_to_bitmap`` applied per tile,
    bit-identical, so the emitted buffers ARE the collective operands.
    Positions within a chunk are distinct, so the argsort order is
    unambiguous. Returns (vals (n, k) fp32 ints, bits (n, chunk//8)
    uint8)."""
    order = jnp.argsort(pos, axis=-1)
    vals = jnp.take_along_axis(q, order, axis=-1)
    n = pos.shape[0]
    one_hot = jnp.zeros((n, scale_chunk), jnp.uint8)
    r_i = jax.lax.broadcasted_iota(jnp.int32, pos.shape, 0)
    one_hot = one_hot.at[r_i, pos].set(1)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    bits = jnp.sum(
        one_hot.reshape(n, scale_chunk // 8, 8) * weights,
        axis=-1, dtype=jnp.uint8,
    )
    return vals, bits


def _quantize_mix(x, recon, res, woff, wself, *, error_feedback,
                  difference_coding, topk=None, stale_mix=False):
    """The shared in-VMEM stage: difference-code, int8-quantize (top-k
    sparsified when ``topk`` is set), W-row mix, and error-feedback update
    of ONE (nodes, chunk) tile. Returns (mixed, new_recon, new_res, scale).

    ``stale_mix`` (the pipelined round schedule) contracts W against the
    INPUT recon -- the neighbor reconstruction as of the END of the
    previous round -- instead of ``new_recon``; the recon/EF updates are
    unchanged, so the wire semantics are identical, only the mix consumes
    one-round-stale neighbor information."""
    _, scale, new_recon, new_res = _quantize_ef(
        x, recon, res, error_feedback=error_feedback,
        difference_coding=difference_coding, topk=topk,
    )
    nbr = recon if stale_mix else new_recon
    mixed = jnp.dot(woff, nbr, preferred_element_type=jnp.float32) + wself * x
    return mixed, new_recon, new_res, scale


def _kernel(
    x_ref,
    recon_ref,
    res_ref,
    woff_ref,
    wself_ref,
    mixed_ref,
    nrecon_ref,
    nres_ref,
    scale_ref,
    *,
    error_feedback,
    difference_coding,
    topk,
    stale_mix,
):
    mixed, nrecon, nres, scale = _quantize_mix(
        x_ref[...],
        recon_ref[...],
        res_ref[...],
        woff_ref[...],
        wself_ref[...],
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
    )
    mixed_ref[...] = mixed
    nrecon_ref[...] = nrecon
    nres_ref[...] = nres
    scale_ref[...] = scale


def _fused_round_kernel(
    x_ref,
    g_ref,
    recon_ref,
    res_ref,
    woff_ref,
    wself_ref,
    alpha_ref,
    mixed_ref,
    nrecon_ref,
    nres_ref,
    scale_ref,
    *,
    error_feedback,
    difference_coding,
    topk,
    stale_mix,
):
    # DSGD local update fused ahead of the gossip stage: the half-updated
    # parameters h never touch HBM.
    h = x_ref[...] - alpha_ref[0, 0] * g_ref[...]
    mixed, nrecon, nres, scale = _quantize_mix(
        h,
        recon_ref[...],
        res_ref[...],
        woff_ref[...],
        wself_ref[...],
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
    )
    mixed_ref[...] = mixed
    nrecon_ref[...] = nrecon
    nres_ref[...] = nres
    scale_ref[...] = scale


def _fused_round_gt_kernel(
    x_ref,
    t_ref,
    g_ref,
    gp_ref,
    rx_ref,
    sx_ref,
    rt_ref,
    st_ref,
    woff_ref,
    wself_ref,
    alpha_ref,
    mx_ref,
    mt_ref,
    nrx_ref,
    nsx_ref,
    nrt_ref,
    nst_ref,
    scx_ref,
    sct_ref,
    *,
    error_feedback,
    difference_coding,
    topk,
    stale_mix,
):
    # DSGT (adapt-then-combine ordering): tracker absorbs the gradient
    # innovation, parameters step against the updated tracker, and BOTH
    # half-updated buffers go through the quantize-mix stage against the
    # same resident W tile. mean_i t_half preserves the tracking invariant
    # for any doubly-stochastic W.
    woff = woff_ref[...]
    wself = wself_ref[...]
    t_half = t_ref[...] + g_ref[...] - gp_ref[...]
    h = x_ref[...] - alpha_ref[0, 0] * t_half

    mt, nrt, nst, sct = _quantize_mix(
        t_half,
        rt_ref[...],
        st_ref[...],
        woff,
        wself,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
    )
    mx, nrx, nsx, scx = _quantize_mix(
        h,
        rx_ref[...],
        sx_ref[...],
        woff,
        wself,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
    )
    mx_ref[...] = mx
    mt_ref[...] = mt
    nrx_ref[...] = nrx
    nsx_ref[...] = nsx
    nrt_ref[...] = nrt
    nst_ref[...] = nst
    scx_ref[...] = scx
    sct_ref[...] = sct


def _specs(n: int, scale_chunk: int):
    tile = pl.BlockSpec((n, scale_chunk), lambda c: (0, c))
    whole = pl.BlockSpec((n, n), lambda c: (0, 0))
    col = pl.BlockSpec((n, 1), lambda c: (0, c))
    one = pl.BlockSpec((n, 1), lambda c: (0, 0))
    scalar = pl.BlockSpec((1, 1), lambda c: (0, 0))
    return tile, whole, col, one, scalar


def _check_chunk(t: int, scale_chunk: int) -> int:
    if t % scale_chunk:
        raise ValueError(f"total {t} not a multiple of scale_chunk {scale_chunk}")
    return t // scale_chunk


def _check_topk(topk) -> None:
    if topk is not None and topk < 1:
        raise ValueError(f"topk must be >= 1 or None, got {topk}")


def gossip_mix_pallas(
    x: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    *,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    stale_mix: bool = False,
    interpret: bool = False,
):
    """x, recon, res: (n, t) fp32 with t % scale_chunk == 0; w_off (n, n);
    w_self (n,). Returns (mixed, new_recon, new_res, scales (n, t//chunk)).
    ``topk`` keeps only the k largest-|.| payload columns per scale chunk
    (EF absorbs the truncation); ``stale_mix`` mixes against the INPUT
    recon (the pipelined schedule's one-round-stale neighbor info)."""
    n, t = x.shape
    n_chunks = _check_chunk(t, scale_chunk)
    _check_topk(topk)
    tile, whole, col, one, _ = _specs(n, scale_chunk)

    kernel = functools.partial(
        _kernel, error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk, stale_mix=stale_mix,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[tile, tile, tile, whole, one],
        out_specs=[tile, tile, tile, col],
        out_shape=[
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, n_chunks), jnp.float32),
        ],
        interpret=interpret,
    )(x, recon, res, w_off, w_self.reshape(n, 1))


def fused_round_pallas(
    x: jnp.ndarray,
    g: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    stale_mix: bool = False,
    interpret: bool = False,
):
    """DSGD round megakernel: ``h = x - alpha * g`` then quantize-mix-EF of
    h (top-k sparsified when ``topk`` is set; mixed against the input
    recon when ``stale_mix``), in ONE pass. x, g, recon, res: (n, t)
    fp32; alpha: scalar. Returns (mixed, new_recon, new_res, scales)."""
    n, t = x.shape
    n_chunks = _check_chunk(t, scale_chunk)
    _check_topk(topk)
    tile, whole, col, one, scalar = _specs(n, scale_chunk)

    kernel = functools.partial(
        _fused_round_kernel,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[tile, tile, tile, tile, whole, one, scalar],
        out_specs=[tile, tile, tile, col],
        out_shape=[
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, n_chunks), jnp.float32),
        ],
        interpret=interpret,
    )(
        x,
        g,
        recon,
        res,
        w_off,
        w_self.reshape(n, 1),
        jnp.asarray(alpha, jnp.float32).reshape(1, 1),
    )


def fused_round_gt_pallas(
    x: jnp.ndarray,
    t: jnp.ndarray,
    g: jnp.ndarray,
    g_prev: jnp.ndarray,
    recon_x: jnp.ndarray,
    res_x: jnp.ndarray,
    recon_t: jnp.ndarray,
    res_t: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    stale_mix: bool = False,
    interpret: bool = False,
):
    """DSGT round megakernel: tracker arithmetic + parameter update + two
    quantize-mix-EF stages (params and tracker) in ONE pass. All array
    operands (n, tot) fp32 except w_off (n, n) / w_self (n,); alpha scalar.
    ``stale_mix`` mixes both wires against their input recons. Returns
    (mixed_x, mixed_t, new_recon_x, new_res_x, new_recon_t, new_res_t,
    scales_x, scales_t)."""
    n, tot = x.shape
    n_chunks = _check_chunk(tot, scale_chunk)
    _check_topk(topk)
    tile, whole, col, one, scalar = _specs(n, scale_chunk)

    kernel = functools.partial(
        _fused_round_gt_kernel,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
    )
    buf = jax.ShapeDtypeStruct((n, tot), jnp.float32)
    sc = jax.ShapeDtypeStruct((n, n_chunks), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[tile] * 8 + [whole, one, scalar],
        out_specs=[tile] * 6 + [col, col],
        out_shape=[buf, buf, buf, buf, buf, buf, sc, sc],
        interpret=interpret,
    )(
        x,
        t,
        g,
        g_prev,
        recon_x,
        res_x,
        recon_t,
        res_t,
        w_off,
        w_self.reshape(n, 1),
        jnp.asarray(alpha, jnp.float32).reshape(1, 1),
    )

# ---------------------------------------------------------------------------
# Wire-stage kernels: the pre-collective half of the SHARDED fused round
# ---------------------------------------------------------------------------


def _wire_stage_kernel(
    x_ref,
    g_ref,
    recon_ref,
    res_ref,
    alpha_ref,
    h_ref,
    q_ref,
    scale_ref,
    nrecon_ref,
    nres_ref,
    *,
    error_feedback,
    difference_coding,
    topk,
):
    # Everything a node computes BEFORE its payload crosses the wire:
    # local update, difference coding, (top-k,) int8 quantize, EF. The
    # int8 q + fp32 scales ARE the wire; the W contraction happens after
    # the collective (ppermute / all-gather) outside the kernel, against
    # the running neighbor-reconstruction accumulator.
    h = x_ref[...] - alpha_ref[0, 0] * g_ref[...]
    q, scale, nrecon, nres = _quantize_ef(
        h,
        recon_ref[...],
        res_ref[...],
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
    )
    h_ref[...] = h
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale
    nrecon_ref[...] = nrecon
    nres_ref[...] = nres


def _wire_stage_gt_kernel(
    x_ref,
    t_ref,
    g_ref,
    gp_ref,
    rx_ref,
    sx_ref,
    rt_ref,
    st_ref,
    alpha_ref,
    h_ref,
    th_ref,
    qx_ref,
    scx_ref,
    nrx_ref,
    nsx_ref,
    qt_ref,
    sct_ref,
    nrt_ref,
    nst_ref,
    *,
    error_feedback,
    difference_coding,
    topk,
):
    # DSGT wire stage: tracker arithmetic + parameter update + BOTH wires'
    # quantize-EF in one program (same adapt-then-combine ordering as the
    # dense megakernel).
    t_half = t_ref[...] + g_ref[...] - gp_ref[...]
    h = x_ref[...] - alpha_ref[0, 0] * t_half
    qt, sct, nrt, nst = _quantize_ef(
        t_half, rt_ref[...], st_ref[...],
        error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk,
    )
    qx, scx, nrx, nsx = _quantize_ef(
        h, rx_ref[...], sx_ref[...],
        error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk,
    )
    h_ref[...] = h
    th_ref[...] = t_half
    qx_ref[...] = qx.astype(jnp.int8)
    scx_ref[...] = scx
    nrx_ref[...] = nrx
    nsx_ref[...] = nsx
    qt_ref[...] = qt.astype(jnp.int8)
    sct_ref[...] = sct
    nrt_ref[...] = nrt
    nst_ref[...] = nst


def wire_stage_pallas(
    x: jnp.ndarray,
    g: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    interpret: bool = False,
):
    """DSGD wire stage of the SHARDED fused round: local update + difference
    coding + (top-k) int8 quantize + EF on this shard's (n_local, t) rows,
    in ONE pass. Returns (h, q int8, scales, new_recon, new_res); the
    caller moves (q, scales) over the wire and finishes the mix as
    ``w_self * h + mix_recon + sum_nbr w * dequant(q, s)``. Runs inside a
    shard_map body, so n_local is typically 1 (one node row per device;
    on real TPUs pad the sublane dim as needed)."""
    n, t = x.shape
    n_chunks = _check_chunk(t, scale_chunk)
    _check_topk(topk)
    tile, _, col, _, scalar = _specs(n, scale_chunk)

    kernel = functools.partial(
        _wire_stage_kernel,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
    )
    buf = jax.ShapeDtypeStruct((n, t), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[tile, tile, tile, tile, scalar],
        out_specs=[tile, tile, col, tile, tile],
        out_shape=[
            buf,
            jax.ShapeDtypeStruct((n, t), jnp.int8),
            jax.ShapeDtypeStruct((n, n_chunks), jnp.float32),
            buf,
            buf,
        ],
        interpret=interpret,
    )(x, g, recon, res, jnp.asarray(alpha, jnp.float32).reshape(1, 1))


def wire_stage_gt_pallas(
    x: jnp.ndarray,
    t: jnp.ndarray,
    g: jnp.ndarray,
    g_prev: jnp.ndarray,
    recon_x: jnp.ndarray,
    res_x: jnp.ndarray,
    recon_t: jnp.ndarray,
    res_t: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    interpret: bool = False,
):
    """DSGT wire stage of the SHARDED fused round: tracker arithmetic,
    parameter update, and both wires' quantize-EF in ONE pass. Returns
    (h, t_half, q_x int8, scales_x, new_recon_x, new_res_x, q_t int8,
    scales_t, new_recon_t, new_res_t)."""
    n, tot = x.shape
    n_chunks = _check_chunk(tot, scale_chunk)
    _check_topk(topk)
    tile, _, col, _, scalar = _specs(n, scale_chunk)

    kernel = functools.partial(
        _wire_stage_gt_kernel,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
    )
    buf = jax.ShapeDtypeStruct((n, tot), jnp.float32)
    qb = jax.ShapeDtypeStruct((n, tot), jnp.int8)
    sc = jax.ShapeDtypeStruct((n, n_chunks), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[tile] * 8 + [scalar],
        out_specs=[tile, tile, tile, col, tile, tile, tile, col, tile, tile],
        out_shape=[buf, buf, qb, sc, buf, buf, qb, sc, buf, buf],
        interpret=interpret,
    )(x, t, g, g_prev, recon_x, res_x, recon_t, res_t,
      jnp.asarray(alpha, jnp.float32).reshape(1, 1))


# ---------------------------------------------------------------------------
# Compact-gather wire-stage kernels: the TRULY SPARSE top-k wire
# ---------------------------------------------------------------------------


def _check_compact(topk, scale_chunk: int) -> None:
    if topk is None or not (1 <= topk < scale_chunk):
        raise ValueError(
            f"the compact wire needs 1 <= topk < scale_chunk, got "
            f"topk={topk}, scale_chunk={scale_chunk} (use the dense wire "
            "stage when the payload is not sparsified)"
        )


def _wire_stage_compact_kernel(
    x_ref,
    g_ref,
    recon_ref,
    res_ref,
    alpha_ref,
    h_ref,
    q_ref,
    pos_ref,
    scale_ref,
    nrecon_ref,
    nres_ref,
    *,
    error_feedback,
    difference_coding,
    topk,
    pos_dtype,
    bitmap=False,
):
    # The compact-gather epilogue: the tile still computes the DENSE dq for
    # its own recon/EF updates, but what it emits for the wire is exactly
    # (k int8 values, k in-chunk positions, 1 fp32 scale) per chunk -- the
    # bytes flat_wire_bytes accounts are the bytes that cross the
    # collective. With ``bitmap`` the index side leaves as the packed
    # presence bitmap instead (pos_ref is then the bits ref).
    h = x_ref[...] - alpha_ref[0, 0] * g_ref[...]
    q, pos, scale, nrecon, nres = _quantize_ef_compact(
        h,
        recon_ref[...],
        res_ref[...],
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
    )
    h_ref[...] = h
    if bitmap:
        vals, bits = _bitmap_pack(q, pos, x_ref.shape[-1])
        q_ref[...] = vals.astype(jnp.int8)
        pos_ref[...] = bits
    else:
        q_ref[...] = q.astype(jnp.int8)
        pos_ref[...] = pos.astype(pos_dtype)
    scale_ref[...] = scale
    nrecon_ref[...] = nrecon
    nres_ref[...] = nres


def _wire_stage_gt_compact_kernel(
    x_ref,
    t_ref,
    g_ref,
    gp_ref,
    rx_ref,
    sx_ref,
    rt_ref,
    st_ref,
    alpha_ref,
    h_ref,
    th_ref,
    qx_ref,
    px_ref,
    scx_ref,
    nrx_ref,
    nsx_ref,
    qt_ref,
    pt_ref,
    sct_ref,
    nrt_ref,
    nst_ref,
    *,
    error_feedback,
    difference_coding,
    topk,
    pos_dtype,
    bitmap=False,
):
    # DSGT compact wire stage: tracker arithmetic + parameter update + BOTH
    # wires' compact-gather quantize-EF in one program (both index sides
    # leave as packed bitmaps when ``bitmap``).
    t_half = t_ref[...] + g_ref[...] - gp_ref[...]
    h = x_ref[...] - alpha_ref[0, 0] * t_half
    qt, pt, sct, nrt, nst = _quantize_ef_compact(
        t_half, rt_ref[...], st_ref[...],
        error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk,
    )
    qx, px, scx, nrx, nsx = _quantize_ef_compact(
        h, rx_ref[...], sx_ref[...],
        error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk,
    )
    h_ref[...] = h
    th_ref[...] = t_half
    if bitmap:
        chunk = x_ref.shape[-1]
        vx, bx = _bitmap_pack(qx, px, chunk)
        vt, bt = _bitmap_pack(qt, pt, chunk)
        qx_ref[...] = vx.astype(jnp.int8)
        px_ref[...] = bx
        qt_ref[...] = vt.astype(jnp.int8)
        pt_ref[...] = bt
    else:
        qx_ref[...] = qx.astype(jnp.int8)
        px_ref[...] = px.astype(pos_dtype)
        qt_ref[...] = qt.astype(jnp.int8)
        pt_ref[...] = pt.astype(pos_dtype)
    scx_ref[...] = scx
    nrx_ref[...] = nrx
    nsx_ref[...] = nsx
    sct_ref[...] = sct
    nrt_ref[...] = nrt
    nst_ref[...] = nst


def wire_stage_compact_pallas(
    x: jnp.ndarray,
    g: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    bitmap: bool = False,
    interpret: bool = False,
):
    """DSGD wire stage with the compact-gather epilogue: local update +
    difference coding + EXACT-k selection + int8 quantize + EF in ONE
    pass. Returns (h, q int8 (n, n_chunks*k), pos (n, n_chunks*k)
    int16/int32, scales (n, n_chunks), new_recon, new_res); the caller
    moves (q, pos, scales) over the wire and the receiver rebuilds the
    dense dq by scatter-accumulate (``ref.scatter_compact_dq``).

    ``bitmap=True`` runs the bitmap re-encode IN-KERNEL (byte-aligned
    chunks only): the value buffer comes out in ascending-position order
    and the index buffer is the packed LSB-first presence bitmap
    (n, n_chunks * chunk // 8) uint8 -- bit-identical to
    ``ref.compact_to_bitmap`` applied to the explicit-positions output,
    decoded by ``ref.scatter_bitmap_dq``."""
    from repro.core.packing import compact_pos_dtype

    n, t = x.shape
    n_chunks = _check_chunk(t, scale_chunk)
    _check_compact(topk, scale_chunk)
    if bitmap and scale_chunk % 8:
        raise ValueError(
            f"bitmap wire needs a byte-aligned chunk, got {scale_chunk}"
        )
    tile, _, col, _, scalar = _specs(n, scale_chunk)
    kblock = pl.BlockSpec((n, topk), lambda c: (0, c))
    pos_dtype = compact_pos_dtype(scale_chunk)
    if bitmap:
        idx_width = scale_chunk // 8
        idx_shape = jax.ShapeDtypeStruct((n, n_chunks * idx_width), jnp.uint8)
    else:
        idx_width = topk
        idx_shape = jax.ShapeDtypeStruct((n, n_chunks * topk), pos_dtype)
    idx_block = pl.BlockSpec((n, idx_width), lambda c: (0, c))

    kernel = functools.partial(
        _wire_stage_compact_kernel,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        pos_dtype=pos_dtype,
        bitmap=bitmap,
    )
    buf = jax.ShapeDtypeStruct((n, t), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[tile, tile, tile, tile, scalar],
        out_specs=[tile, kblock, idx_block, col, tile, tile],
        out_shape=[
            buf,
            jax.ShapeDtypeStruct((n, n_chunks * topk), jnp.int8),
            idx_shape,
            jax.ShapeDtypeStruct((n, n_chunks), jnp.float32),
            buf,
            buf,
        ],
        interpret=interpret,
    )(x, g, recon, res, jnp.asarray(alpha, jnp.float32).reshape(1, 1))


def wire_stage_gt_compact_pallas(
    x: jnp.ndarray,
    t: jnp.ndarray,
    g: jnp.ndarray,
    g_prev: jnp.ndarray,
    recon_x: jnp.ndarray,
    res_x: jnp.ndarray,
    recon_t: jnp.ndarray,
    res_t: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    bitmap: bool = False,
    interpret: bool = False,
):
    """DSGT wire stage with the compact-gather epilogue on BOTH wires.
    Returns (h, t_half, q_x, pos_x, scales_x, new_recon_x, new_res_x,
    q_t, pos_t, scales_t, new_recon_t, new_res_t). ``bitmap=True`` runs
    the bitmap re-encode in-kernel on both wires (values in
    ascending-position order, packed presence bitmaps in place of the
    position buffers -- see :func:`wire_stage_compact_pallas`)."""
    from repro.core.packing import compact_pos_dtype

    n, tot = x.shape
    n_chunks = _check_chunk(tot, scale_chunk)
    _check_compact(topk, scale_chunk)
    if bitmap and scale_chunk % 8:
        raise ValueError(
            f"bitmap wire needs a byte-aligned chunk, got {scale_chunk}"
        )
    tile, _, col, _, scalar = _specs(n, scale_chunk)
    kblock = pl.BlockSpec((n, topk), lambda c: (0, c))
    pos_dtype = compact_pos_dtype(scale_chunk)
    if bitmap:
        idx_width = scale_chunk // 8
        pb = jax.ShapeDtypeStruct((n, n_chunks * idx_width), jnp.uint8)
    else:
        idx_width = topk
        pb = jax.ShapeDtypeStruct((n, n_chunks * topk), pos_dtype)
    idx_block = pl.BlockSpec((n, idx_width), lambda c: (0, c))

    kernel = functools.partial(
        _wire_stage_gt_compact_kernel,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        pos_dtype=pos_dtype,
        bitmap=bitmap,
    )
    buf = jax.ShapeDtypeStruct((n, tot), jnp.float32)
    qb = jax.ShapeDtypeStruct((n, n_chunks * topk), jnp.int8)
    sc = jax.ShapeDtypeStruct((n, n_chunks), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[tile] * 8 + [scalar],
        out_specs=[tile, tile, kblock, idx_block, col, tile, tile,
                   kblock, idx_block, col, tile, tile],
        out_shape=[buf, buf, qb, pb, sc, buf, buf, qb, pb, sc, buf, buf],
        interpret=interpret,
    )(x, t, g, g_prev, recon_x, res_x, recon_t, res_t,
      jnp.asarray(alpha, jnp.float32).reshape(1, 1))
