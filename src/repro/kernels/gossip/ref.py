"""Chunked jnp oracle for the fused quantize-mix-EF gossip pass.

Computes the CHOCO-gossip round on a flat ``(nodes, total)`` buffer with
per-``(node, scale_chunk)`` int8 scales -- bit-identical math to the
Pallas kernel (``gossip.py``), which tiles the same computation over
``(nodes, scale_chunk)`` VMEM blocks. This reference materializes the
full-size payload/dq/recon intermediates the kernel fuses away; it is the
interpret-mode correctness oracle and the single-device simulated path.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["gossip_mix_ref"]


def gossip_mix_ref(
    x: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    *,
    scale_chunk: int,
    error_feedback: bool = True,
    difference_coding: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One compressed gossip round on flat buffers.

    Args:
      x: (n, t) fp32 node-stacked flat parameters, t % scale_chunk == 0.
      recon: (n, t) fp32 shared reconstruction (wire-reconstructible).
      res: (n, t) fp32 error-feedback residual.
      w_off: (n, n) fp32 off-diagonal mixing weights (zero diagonal).
      w_self: (n,) fp32 self weights (the W diagonal).
      scale_chunk: columns per int8 scale block.

    Returns:
      (mixed, new_recon, new_res, scales) with scales (n, t // scale_chunk).
    """
    n, t = x.shape
    if t % scale_chunk:
        raise ValueError(f"total {t} not a multiple of scale_chunk {scale_chunk}")
    base = recon if difference_coding else jnp.zeros_like(recon)
    payload = x - base + (res if error_feedback else 0.0)

    p3 = payload.reshape(n, t // scale_chunk, scale_chunk)
    scales = jnp.max(jnp.abs(p3), axis=2) / 127.0  # (n, n_chunks)
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(p3 / safe[:, :, None]), -127, 127)
    dq = (q * scales[:, :, None]).reshape(n, t)

    new_recon = base + dq
    new_res = payload - dq if error_feedback else res
    mixed = w_off @ new_recon + w_self[:, None] * x
    return mixed, new_recon, new_res, scales
