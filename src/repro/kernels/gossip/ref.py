"""Chunked jnp oracles for the fused gossip / round megakernels.

Computes the CHOCO-gossip round on a flat ``(nodes, total)`` buffer with
per-``(node, scale_chunk)`` int8 scales -- bit-identical math to the
Pallas kernels (``gossip.py``), which tile the same computation over
``(nodes, scale_chunk)`` VMEM blocks. These references materialize the
full-size payload/dq/recon intermediates the kernels fuse away; they are
the interpret-mode correctness oracles and the single-device simulated
path.

The round oracles (:func:`fused_round_ref`, :func:`fused_round_gt_ref`)
are deliberately written as the COMPOSITION of the plain local update and
:func:`gossip_mix_ref` -- "fused == local-step-then-gossip" therefore
holds by construction on the reference side, and the megakernels are
property-tested against it (tests/test_megakernel.py).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import compact_pos_dtype

__all__ = [
    "gossip_mix_ref",
    "fused_round_ref",
    "fused_round_gt_ref",
    "wire_stage_ref",
    "wire_stage_gt_ref",
    "wire_stage_compact_ref",
    "wire_stage_gt_compact_ref",
    "scatter_compact_dq",
    "compact_to_bitmap",
    "scatter_bitmap_dq",
]


def _quantize_ef_chunks(payload, scale_chunk: int, topk):
    """Shared quantize core: per-(node, scale_chunk) int8 with optional
    top-k masking (same tie-keeping threshold formula as the kernel tile,
    applied chunk-by-chunk -- bit-identical). Returns (q, scales, dq)."""
    n, t = payload.shape
    p3 = payload.reshape(n, t // scale_chunk, scale_chunk)
    if topk is not None and topk < scale_chunk:
        thr = jnp.sort(jnp.abs(p3), axis=2)[:, :, scale_chunk - topk][:, :, None]
        p3 = jnp.where(jnp.abs(p3) >= thr, p3, 0.0)
    scales = jnp.max(jnp.abs(p3), axis=2) / 127.0  # (n, n_chunks)
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(p3 / safe[:, :, None]), -127, 127)
    dq = (q * scales[:, :, None]).reshape(n, t)
    return q, scales, dq


def _quantize_ef_compact_chunks(payload, scale_chunk: int, topk: int):
    """Compact-gather quantize core: EXACT-k selection per (node, chunk)
    via ``jax.lax.top_k`` on |payload| (ties broken toward the lower
    index -- bit-identical to the kernel's per-tile epilogue), int8
    quantization of the survivors, and the dense dq scattered back for
    the sender-side recon/EF updates. Returns (q (n, C*k) fp32 ints,
    pos (n, C*k) int32, scales (n, C), dq (n, t))."""
    n, t = payload.shape
    c = t // scale_chunk
    p2 = payload.reshape(n * c, scale_chunk)
    _, pos = jax.lax.top_k(jnp.abs(p2), topk)  # (n*c, k) int32
    vals = jnp.take_along_axis(p2, pos, axis=-1)
    scales = jnp.max(jnp.abs(vals), axis=-1, keepdims=True) / 127.0
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(vals / safe), -127, 127)
    rows = jax.lax.broadcasted_iota(jnp.int32, pos.shape, 0)
    dq = jnp.zeros_like(p2).at[rows, pos].add(q * scales).reshape(n, t)
    return (q.reshape(n, c * topk), pos.reshape(n, c * topk),
            scales.reshape(n, c), dq)


def scatter_compact_dq(
    q: jnp.ndarray,
    pos: jnp.ndarray,
    scales: jnp.ndarray,
    scale_chunk: int,
    total: int,
) -> jnp.ndarray:
    """RECEIVE-side scatter-accumulate of the compact top-k wire: rebuild
    the dense dequantized payload from exactly what crossed the
    collective.

    Args:
      q: (rows, n_chunks * k) int8 values.
      pos: (rows, n_chunks * k) int16/int32 in-chunk positions.
      scales: (rows, n_chunks) fp32 per-chunk scales.
      scale_chunk / total: the layout geometry.

    Returns the (rows, total) fp32 dense dq -- exactly equal to the
    masked-dense ``dq`` of :func:`_quantize_ef_compact_chunks` (lossless
    round trip; property-tested in tests/test_schedule.py) -- which feeds
    the running ``mix_recon`` accumulator."""
    rows, ck = q.shape
    if total % scale_chunk:
        raise ValueError(f"total {total} not a multiple of scale_chunk {scale_chunk}")
    c = total // scale_chunk
    if ck % c:
        raise ValueError(f"compact width {ck} not a multiple of n_chunks {c}")
    k = ck // c
    v3 = q.astype(jnp.float32).reshape(rows, c, k) * scales[:, :, None]
    cols = pos.astype(jnp.int32).reshape(rows, c, k) + (
        jnp.arange(c, dtype=jnp.int32) * scale_chunk)[None, :, None]
    r = jax.lax.broadcasted_iota(jnp.int32, cols.shape, 0)
    return jnp.zeros((rows, total), jnp.float32).at[r, cols].add(v3)


def compact_to_bitmap(
    q: jnp.ndarray,
    pos: jnp.ndarray,
    scale_chunk: int,
    topk: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Re-encode one compact top-k payload with a PRESENCE BITMAP index:
    explicit in-chunk positions cost ``k x 2`` bytes (int16), the bitmap
    a flat ``chunk/8`` bytes -- cheaper whenever ``k > chunk/16``
    (``packing.compact_index_bytes`` picks the same boundary, so the
    accounting is the bytes that actually cross).

    Args:
      q: (rows, n_chunks * k) int8 values in |value|-descending top_k
        order (what the compact wire-stage kernels emit).
      pos: (rows, n_chunks * k) int16/int32 in-chunk positions.
      scale_chunk / topk: the encoding geometry (chunk must be a
        multiple of 8 -- byte-aligned bitmaps only).

    Returns ``(vals, bits)``: the SAME k values per chunk re-sorted into
    ascending-position order (rows, n_chunks * k) int8 -- the order the
    bitmap decode implies -- and the packed LSB-first presence bitmap
    (rows, n_chunks * chunk // 8) uint8. Lossless:
    :func:`scatter_bitmap_dq` rebuilds exactly
    :func:`scatter_compact_dq`'s dense payload (property-tested)."""
    if scale_chunk % 8:
        raise ValueError(
            f"bitmap wire needs a byte-aligned chunk, got {scale_chunk}"
        )
    rows, ck = q.shape
    if ck % topk:
        raise ValueError(f"compact width {ck} not a multiple of k={topk}")
    c = ck // topk
    p3 = pos.astype(jnp.int32).reshape(rows, c, topk)
    v3 = q.reshape(rows, c, topk)
    order = jnp.argsort(p3, axis=-1)
    vals = jnp.take_along_axis(v3, order, axis=-1)
    # uint8 throughout: this runs on the per-round wire path inside the
    # shard_map body, and the positions of a byte's 8 bits are disjoint,
    # so the weighted sum never exceeds 255 -- a wider one-hot would move
    # 4x the dense payload's bytes just to pack k bits per chunk
    one_hot = jnp.zeros((rows, c, scale_chunk), jnp.uint8)
    r_i = jax.lax.broadcasted_iota(jnp.int32, p3.shape, 0)
    c_i = jax.lax.broadcasted_iota(jnp.int32, p3.shape, 1)
    one_hot = one_hot.at[r_i, c_i, p3].set(1)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    bits = jnp.sum(
        one_hot.reshape(rows, c, scale_chunk // 8, 8) * weights,
        axis=-1, dtype=jnp.uint8,
    )
    return vals.reshape(rows, ck), bits.reshape(rows, c * (scale_chunk // 8))


def scatter_bitmap_dq(
    vals: jnp.ndarray,
    bits: jnp.ndarray,
    scales: jnp.ndarray,
    scale_chunk: int,
    total: int,
) -> jnp.ndarray:
    """RECEIVE-side decode of the bitmap compact wire: rebuild the dense
    dequantized payload from (k ascending-position int8 values, packed
    presence bitmap, fp32 scales) -- the bitmap twin of
    :func:`scatter_compact_dq`, and exactly equal to it.

    Decode: unpack the LSB-first bits, prefix-sum them along the chunk to
    map each present column to its slot in the ascending-position value
    list, and gather."""
    rows, ck = vals.shape
    if total % scale_chunk or scale_chunk % 8:
        raise ValueError(
            f"bad geometry: total={total}, scale_chunk={scale_chunk}"
        )
    c = total // scale_chunk
    if ck % c:
        raise ValueError(f"compact width {ck} not a multiple of n_chunks {c}")
    k = ck // c
    b3 = bits.reshape(rows, c, scale_chunk // 8)
    shifts = jnp.arange(8, dtype=jnp.uint32)
    present = (
        (b3[..., None].astype(jnp.uint32) >> shifts) & jnp.uint32(1)
    ).reshape(rows, c, scale_chunk).astype(jnp.int32)
    slot = jnp.cumsum(present, axis=-1) - 1  # index into the value list
    v3 = vals.astype(jnp.float32).reshape(rows, c, k) * scales[:, :, None]
    gathered = jnp.take_along_axis(v3, jnp.clip(slot, 0, k - 1), axis=-1)
    return jnp.where(present > 0, gathered, 0.0).reshape(rows, total)


def gossip_mix_ref(
    x: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    *,
    scale_chunk: int,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    stale_mix: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One compressed gossip round on flat buffers.

    Args:
      x: (n, t) fp32 node-stacked flat parameters, t % scale_chunk == 0.
      recon: (n, t) fp32 shared reconstruction (wire-reconstructible).
      res: (n, t) fp32 error-feedback residual.
      w_off: (n, n) fp32 off-diagonal mixing weights (zero diagonal).
      w_self: (n,) fp32 self weights (the W diagonal).
      scale_chunk: columns per int8 scale block.
      topk: if set, only the k largest-|payload| columns per scale chunk
        go on the wire (ties at the threshold kept); with error feedback
        the truncated mass is absorbed by the residual, so top-k gossip
        still contracts to consensus (property-tested).
      stale_mix: mix against the INPUT recon (the neighbor reconstruction
        as of the END of the previous round) instead of ``new_recon`` --
        the pipelined round schedule's one-round-stale dynamics. recon/EF
        updates are unchanged.

    Returns:
      (mixed, new_recon, new_res, scales) with scales (n, t // scale_chunk).
    """
    n, t = x.shape
    if t % scale_chunk:
        raise ValueError(f"total {t} not a multiple of scale_chunk {scale_chunk}")
    base = recon if difference_coding else jnp.zeros_like(recon)
    payload = x - base + (res if error_feedback else 0.0)

    _, scales, dq = _quantize_ef_chunks(payload, scale_chunk, topk)

    new_recon = base + dq
    new_res = payload - dq if error_feedback else res
    nbr = recon if stale_mix else new_recon
    mixed = w_off @ nbr + w_self[:, None] * x
    return mixed, new_recon, new_res, scales


def fused_round_ref(
    x: jnp.ndarray,
    g: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    stale_mix: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """DSGD round oracle: the local update ``h = x - alpha * g`` followed
    by one compressed gossip round on h (adapt-then-combine ordering).

    Same signature contract as :func:`gossip_mix_ref` plus the flat
    gradient buffer ``g`` (n, t) and the scalar step size ``alpha``.
    """
    h = x - alpha * g
    return gossip_mix_ref(
        h,
        recon,
        res,
        w_off,
        w_self,
        scale_chunk=scale_chunk,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
    )


def fused_round_gt_ref(
    x: jnp.ndarray,
    t: jnp.ndarray,
    g: jnp.ndarray,
    g_prev: jnp.ndarray,
    recon_x: jnp.ndarray,
    res_x: jnp.ndarray,
    recon_t: jnp.ndarray,
    res_t: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    stale_mix: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """DSGT round oracle (adapt-then-combine gradient tracking):

        t_half = t + g - g_prev          (tracker absorbs the innovation)
        h      = x - alpha * t_half      (parameter update)
        t'     = quantize-mix(t_half)    (compressed gossip, tracker wire)
        x'     = quantize-mix(h)         (compressed gossip, param wire)

    ``mean_i t_half = mean_i t + mean_i (g - g_prev)`` so the tracking
    invariant ``mean_i t == mean_i g`` is preserved by any
    doubly-stochastic W up to the (vanishing, EF-corrected) quantization
    drift. Returns (mixed_x, mixed_t, new_recon_x, new_res_x, new_recon_t,
    new_res_t, scales_x, scales_t); the caller stores ``g`` as the next
    round's ``g_prev``.
    """
    t_half = t + g - g_prev
    h = x - alpha * t_half
    mt, nrt, nst, sct = gossip_mix_ref(
        t_half,
        recon_t,
        res_t,
        w_off,
        w_self,
        scale_chunk=scale_chunk,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
    )
    mx, nrx, nsx, scx = gossip_mix_ref(
        h,
        recon_x,
        res_x,
        w_off,
        w_self,
        scale_chunk=scale_chunk,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
    )
    return mx, mt, nrx, nsx, nrt, nst, scx, sct


def wire_stage_ref(
    x: jnp.ndarray,
    g: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """DSGD wire-stage oracle (the pre-collective half of the SHARDED
    fused round): local update + difference coding + (top-k) int8
    quantize + EF. Returns (h, q int8, scales, new_recon, new_res); the
    sharded engine moves (q, scales) over the wire and finishes the mix
    against its running neighbor-reconstruction accumulator."""
    n, t = x.shape
    if t % scale_chunk:
        raise ValueError(f"total {t} not a multiple of scale_chunk {scale_chunk}")
    h = x - alpha * g
    base = recon if difference_coding else jnp.zeros_like(recon)
    payload = h - base + (res if error_feedback else 0.0)
    q, scales, dq = _quantize_ef_chunks(payload, scale_chunk, topk)
    new_recon = base + dq
    new_res = payload - dq if error_feedback else res
    return h, q.reshape(n, t).astype(jnp.int8), scales, new_recon, new_res


def wire_stage_gt_ref(
    x: jnp.ndarray,
    t: jnp.ndarray,
    g: jnp.ndarray,
    g_prev: jnp.ndarray,
    recon_x: jnp.ndarray,
    res_x: jnp.ndarray,
    recon_t: jnp.ndarray,
    res_t: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """DSGT wire-stage oracle: tracker arithmetic + parameter update +
    both wires' quantize-EF. Returns (h, t_half, q_x, scales_x,
    new_recon_x, new_res_x, q_t, scales_t, new_recon_t, new_res_t)."""
    t_half = t + g - g_prev
    zeros = jnp.zeros_like(g)
    ht, qt, sct, nrt, nst = wire_stage_ref(
        t_half, zeros, recon_t, res_t, alpha, scale_chunk=scale_chunk,
        error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk,
    )
    h, qx, scx, nrx, nsx = wire_stage_ref(
        x, t_half, recon_x, res_x, alpha, scale_chunk=scale_chunk,
        error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk,
    )
    del ht  # == t_half (zero gradient)
    return h, t_half, qx, scx, nrx, nsx, qt, sct, nrt, nst


def wire_stage_compact_ref(
    x: jnp.ndarray,
    g: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """DSGD compact wire-stage oracle: local update + difference coding +
    EXACT-k selection + int8 quantize + EF. Returns (h, q int8
    (n, n_chunks*k), pos int16/int32 (n, n_chunks*k), scales
    (n, n_chunks), new_recon, new_res) -- only (q, pos, scales) cross the
    wire; :func:`scatter_compact_dq` rebuilds the dense dq on the
    receiver."""
    n, t = x.shape
    if t % scale_chunk:
        raise ValueError(f"total {t} not a multiple of scale_chunk {scale_chunk}")
    if topk is None or not (1 <= topk < scale_chunk):
        raise ValueError(
            f"the compact wire needs 1 <= topk < scale_chunk, got "
            f"topk={topk}, scale_chunk={scale_chunk}"
        )
    h = x - alpha * g
    base = recon if difference_coding else jnp.zeros_like(recon)
    payload = h - base + (res if error_feedback else 0.0)
    q, pos, scales, dq = _quantize_ef_compact_chunks(payload, scale_chunk, topk)
    new_recon = base + dq
    new_res = payload - dq if error_feedback else res
    return (h, q.astype(jnp.int8), pos.astype(compact_pos_dtype(scale_chunk)),
            scales, new_recon, new_res)


def wire_stage_gt_compact_ref(
    x: jnp.ndarray,
    t: jnp.ndarray,
    g: jnp.ndarray,
    g_prev: jnp.ndarray,
    recon_x: jnp.ndarray,
    res_x: jnp.ndarray,
    recon_t: jnp.ndarray,
    res_t: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    scale_chunk: int,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """DSGT compact wire-stage oracle: tracker arithmetic + parameter
    update + both wires' compact-gather quantize-EF. Returns (h, t_half,
    q_x, pos_x, scales_x, new_recon_x, new_res_x, q_t, pos_t, scales_t,
    new_recon_t, new_res_t)."""
    t_half = t + g - g_prev
    zeros = jnp.zeros_like(g)
    ht, qt, pt, sct, nrt, nst = wire_stage_compact_ref(
        t_half, zeros, recon_t, res_t, alpha, scale_chunk=scale_chunk,
        error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk,
    )
    h, qx, px, scx, nrx, nsx = wire_stage_compact_ref(
        x, t_half, recon_x, res_x, alpha, scale_chunk=scale_chunk,
        error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk,
    )
    del ht  # == t_half (zero gradient)
    return h, t_half, qx, px, scx, nrx, nsx, qt, pt, sct, nrt, nst
