"""jit'd dispatch for the fused gossip / round megakernels.

Every entry point resolves Pallas ``interpret`` mode OUTSIDE the jit so
the ``REPRO_PALLAS_INTERPRET`` environment variable is honored per call
(not frozen into the first compilation): interpret defaults to on
everywhere except a real TPU backend.
"""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.gossip.gossip import (
    fused_round_gt_pallas,
    fused_round_pallas,
    gossip_mix_pallas,
    wire_stage_compact_pallas,
    wire_stage_gt_compact_pallas,
    wire_stage_gt_pallas,
    wire_stage_pallas,
)

__all__ = ["gossip_mix", "fused_round", "fused_round_gt", "wire_stage",
           "wire_stage_gt", "wire_stage_compact", "wire_stage_gt_compact"]


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _dp_substitute(h, base, res, dp_clip, dp_noise):
    """Residual substitution: fold the DP clip + noise epilogue into the
    UNCHANGED Pallas kernels.

    The kernels compute their wire payload as ``(h - base) + res``. For
    DP we want them to quantize ``wire = cs * payload + noise`` instead
    (per-node L2 clip scale ``cs``, pre-scaled Gaussian ``noise`` --
    bitwise the same formula as ``ref._dp_wire``). Substituting
    ``res_sub = res + (wire - payload)`` makes the kernel's payload equal
    ``wire`` (to 1 ulp of float association), so its q / scales / recon
    outputs are the DP wire's -- ONE pallas_call per round is preserved
    and the kernel bodies never learn about privacy. The kernel's EF
    residual is then ``wire - dq``; adding the returned ``correction =
    payload - wire`` restores the true residual ``payload - dq``, i.e.
    error feedback absorbs clip + noise + quantization together.

    Requires error feedback: without it the kernel's payload is
    ``h - base`` with no residual term to substitute through, and the
    perturbation would accumulate as an uncorrected walk.
    """
    payload = (h - base) + res
    nrm = jnp.sqrt(jnp.sum(payload * payload, axis=1, keepdims=True))
    cs = jnp.minimum(
        1.0, jnp.asarray(dp_clip, jnp.float32)
        / jnp.maximum(nrm, jnp.float32(1e-12))
    )
    wire = cs * payload + dp_noise
    return res + (wire - payload), payload - wire


def _require_ef_for_dp(error_feedback: bool) -> None:
    if not error_feedback:
        raise ValueError(
            "dp needs error_feedback=True: the residual is what absorbs "
            "the clip + noise perturbation (otherwise the wire walk "
            "diverges from the parameters)"
        )


@functools.partial(
    jax.jit,
    static_argnames=("scale_chunk", "error_feedback", "difference_coding",
                     "topk", "stale_mix", "interpret"),
)
def _gossip_mix(x, recon, res, w_off, w_self, scale_chunk, error_feedback,
                difference_coding, topk, stale_mix, interpret):
    return gossip_mix_pallas(
        x,
        recon,
        res,
        w_off,
        w_self,
        scale_chunk=scale_chunk,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
        interpret=interpret,
    )


def gossip_mix(
    x: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    stale_mix: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One fused quantize -> W-row mix -> dequant + EF gossip round on the
    flat node-stacked state.

    Shapes and dtypes (n = nodes, t = flat width, c = t // scale_chunk):

      x      (n, t) fp32   node-stacked flat parameters (``core.packing``);
                           t must be a multiple of ``scale_chunk`` -- pack
                           with ``pad_to=scale_chunk`` -- else ValueError,
                           exactly like the jnp reference.
      recon  (n, t) fp32   shared reconstruction theta_hat: what every
                           neighbor can rebuild from wire traffic alone.
      res    (n, t) fp32   error-feedback residual.
      w_off  (n, n) fp32   off-diagonal mixing weights (zero diagonal).
      w_self (n,)   fp32   self weights (the W diagonal).

    Returns ``(mixed, new_recon, new_res, scales)``:

      mixed      (n, t) fp32  ``W_off @ new_recon + w_self * x`` -- the
                              gossip output; neighbors are mixed through
                              their reconstructions (what actually crossed
                              the wire), self through the exact value.
      new_recon  (n, t) fp32  ``recon + dequant(q)``; both endpoints of
                              every edge advance it identically, so it
                              never needs (re)transmission.
      new_res    (n, t) fp32  ``payload - dequant(q)``: the quantization
                              error, re-injected into the NEXT round's
                              payload (error feedback). With EF +
                              difference coding the payload magnitude --
                              and hence the int8 step -- vanishes as
                              consensus is approached, so mixing becomes
                              exact in the limit; without EF the round
                              stalls at an O(max|x|/127/gap) floor.
      scales     (n, c) fp32  per-(node, chunk) symmetric int8 scales --
                              the only fp32 values on the wire (4 bytes
                              per ``scale_chunk`` int8 payload bytes).

    Flags: ``difference_coding=False`` quantizes x itself instead of the
    delta against ``recon``; ``error_feedback=False`` passes ``res``
    through untouched; ``topk=k`` ships only the k largest-|payload|
    columns per scale chunk (EF absorbs the truncation -- sub-int8 wire
    bytes, see ``packing.flat_wire_bytes``); ``stale_mix=True`` mixes
    against the INPUT recon (the pipelined schedule's one-round-stale
    neighbor information).
    """
    return _gossip_mix(
        x, recon, res, w_off, w_self, scale_chunk, error_feedback,
        difference_coding, topk, stale_mix, _interpret(),
    )


@functools.partial(
    jax.jit,
    static_argnames=("scale_chunk", "error_feedback", "difference_coding",
                     "topk", "stale_mix", "interpret"),
)
def _fused_round(x, g, recon, res, w_off, w_self, alpha, scale_chunk,
                 error_feedback, difference_coding, topk, stale_mix,
                 interpret):
    return fused_round_pallas(
        x,
        g,
        recon,
        res,
        w_off,
        w_self,
        alpha,
        scale_chunk=scale_chunk,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
        interpret=interpret,
    )


def fused_round(
    x: jnp.ndarray,
    g: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    alpha: jnp.ndarray,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    stale_mix: bool = False,
    dp_clip: float | None = None,
    dp_noise: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """DSGD round megakernel: ``h = x - alpha * g`` fused ahead of
    :func:`gossip_mix` in ONE Pallas pass -- one kernel call is a whole
    communication round over the flat state.

    ``g`` is the flat gradient buffer (same (n, t) layout as x, packed by
    ``core.packing.pack_like``); ``alpha`` the scalar step size. Remaining
    operands, outputs, EF, ``topk`` and ``stale_mix`` semantics exactly
    as :func:`gossip_mix` applied to h. ``dp_clip``/``dp_noise`` turn on
    the differential-privacy wire epilogue via residual substitution
    (:func:`_dp_substitute`) -- still ONE pallas_call.
    """
    if dp_noise is None:
        return _fused_round(
            x, g, recon, res, w_off, w_self, alpha, scale_chunk,
            error_feedback, difference_coding, topk, stale_mix, _interpret(),
        )
    _require_ef_for_dp(error_feedback)
    h = x - alpha * g
    base = recon if difference_coding else jnp.zeros_like(recon)
    res_sub, corr = _dp_substitute(h, base, res, dp_clip, dp_noise)
    mixed, new_recon, new_res, scales = _fused_round(
        x, g, recon, res_sub, w_off, w_self, alpha, scale_chunk,
        error_feedback, difference_coding, topk, stale_mix, _interpret(),
    )
    return mixed, new_recon, new_res + corr, scales


@functools.partial(
    jax.jit,
    static_argnames=("scale_chunk", "error_feedback", "difference_coding",
                     "topk", "stale_mix", "interpret"),
)
def _fused_round_gt(x, t, g, g_prev, recon_x, res_x, recon_t, res_t, w_off,
                    w_self, alpha, scale_chunk, error_feedback,
                    difference_coding, topk, stale_mix, interpret):
    return fused_round_gt_pallas(
        x,
        t,
        g,
        g_prev,
        recon_x,
        res_x,
        recon_t,
        res_t,
        w_off,
        w_self,
        alpha,
        scale_chunk=scale_chunk,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        topk=topk,
        stale_mix=stale_mix,
        interpret=interpret,
    )


def fused_round_gt(
    x: jnp.ndarray,
    t: jnp.ndarray,
    g: jnp.ndarray,
    g_prev: jnp.ndarray,
    recon_x: jnp.ndarray,
    res_x: jnp.ndarray,
    recon_t: jnp.ndarray,
    res_t: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    alpha: jnp.ndarray,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    stale_mix: bool = False,
    dp_clip: float | None = None,
    dp_noise: jnp.ndarray | None = None,
    dp_noise_t: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """DSGT round megakernel: tracker arithmetic ``t_half = t + g - g_prev``,
    parameter update ``h = x - alpha * t_half``, and the quantize-mix-EF
    stage applied to BOTH buffers, in ONE Pallas pass.

    ``(recon_x, res_x)`` / ``(recon_t, res_t)`` are independent compression
    states for the parameter and tracker wires (both travel int8). Returns
    ``(mixed_x, mixed_t, new_recon_x, new_res_x, new_recon_t, new_res_t,
    scales_x, scales_t)``; store ``g`` as the next round's ``g_prev``. See
    ``ref.fused_round_gt_ref`` for the exact update equations;
    ``stale_mix`` mixes both wires against their input recons.
    ``dp_clip``/``dp_noise``/``dp_noise_t`` turn on the DP epilogue on
    both wires via residual substitution -- still ONE pallas_call.
    """
    if dp_noise is None:
        return _fused_round_gt(
            x, t, g, g_prev, recon_x, res_x, recon_t, res_t, w_off, w_self,
            alpha, scale_chunk, error_feedback, difference_coding, topk,
            stale_mix, _interpret(),
        )
    _require_ef_for_dp(error_feedback)
    t_half = t + g - g_prev
    h = x - alpha * t_half
    base_x = recon_x if difference_coding else jnp.zeros_like(recon_x)
    base_t = recon_t if difference_coding else jnp.zeros_like(recon_t)
    res_x_sub, corr_x = _dp_substitute(h, base_x, res_x, dp_clip, dp_noise)
    res_t_sub, corr_t = _dp_substitute(
        t_half, base_t, res_t, dp_clip, dp_noise_t
    )
    mx, mt, nrx, nsx, nrt, nst, scx, sct = _fused_round_gt(
        x, t, g, g_prev, recon_x, res_x_sub, recon_t, res_t_sub, w_off,
        w_self, alpha, scale_chunk, error_feedback, difference_coding, topk,
        stale_mix, _interpret(),
    )
    return mx, mt, nrx, nsx + corr_x, nrt, nst + corr_t, scx, sct


@functools.partial(
    jax.jit,
    static_argnames=("scale_chunk", "error_feedback", "difference_coding",
                     "topk", "interpret"),
)
def _wire_stage(x, g, recon, res, alpha, scale_chunk, error_feedback,
                difference_coding, topk, interpret):
    return wire_stage_pallas(
        x, g, recon, res, alpha, scale_chunk=scale_chunk,
        error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk, interpret=interpret,
    )


def wire_stage(
    x: jnp.ndarray,
    g: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    alpha: jnp.ndarray,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    dp_clip: float | None = None,
    dp_noise: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """DSGD wire stage of the sharded fused round (pre-collective half):
    local update + difference coding + (top-k) int8 quantize + EF in ONE
    Pallas pass on this shard's rows, with the optional DP clip+noise
    epilogue via residual substitution. Returns (h, q int8, scales,
    new_recon, new_res); see ``core.engine.ShardedFusedEngine`` for the
    post-wire mix."""
    if dp_noise is None:
        return _wire_stage(
            x, g, recon, res, alpha, scale_chunk, error_feedback,
            difference_coding, topk, _interpret(),
        )
    _require_ef_for_dp(error_feedback)
    h = x - alpha * g
    base = recon if difference_coding else jnp.zeros_like(recon)
    res_sub, corr = _dp_substitute(h, base, res, dp_clip, dp_noise)
    h_out, q, scales, new_recon, new_res = _wire_stage(
        x, g, recon, res_sub, alpha, scale_chunk, error_feedback,
        difference_coding, topk, _interpret(),
    )
    return h_out, q, scales, new_recon, new_res + corr


@functools.partial(
    jax.jit,
    static_argnames=("scale_chunk", "error_feedback", "difference_coding",
                     "topk", "interpret"),
)
def _wire_stage_gt(x, t, g, g_prev, recon_x, res_x, recon_t, res_t, alpha,
                   scale_chunk, error_feedback, difference_coding, topk,
                   interpret):
    return wire_stage_gt_pallas(
        x, t, g, g_prev, recon_x, res_x, recon_t, res_t, alpha,
        scale_chunk=scale_chunk, error_feedback=error_feedback,
        difference_coding=difference_coding, topk=topk, interpret=interpret,
    )


def wire_stage_gt(
    x: jnp.ndarray,
    t: jnp.ndarray,
    g: jnp.ndarray,
    g_prev: jnp.ndarray,
    recon_x: jnp.ndarray,
    res_x: jnp.ndarray,
    recon_t: jnp.ndarray,
    res_t: jnp.ndarray,
    alpha: jnp.ndarray,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    dp_clip: float | None = None,
    dp_noise: jnp.ndarray | None = None,
    dp_noise_t: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """DSGT wire stage of the sharded fused round: tracker arithmetic +
    parameter update + both wires' quantize-EF in ONE Pallas pass, with
    the optional DP epilogue on both wires via residual substitution.
    Returns (h, t_half, q_x, scales_x, new_recon_x, new_res_x, q_t,
    scales_t, new_recon_t, new_res_t)."""
    if dp_noise is None:
        return _wire_stage_gt(
            x, t, g, g_prev, recon_x, res_x, recon_t, res_t, alpha,
            scale_chunk, error_feedback, difference_coding, topk,
            _interpret(),
        )
    _require_ef_for_dp(error_feedback)
    t_half = t + g - g_prev
    h = x - alpha * t_half
    base_x = recon_x if difference_coding else jnp.zeros_like(recon_x)
    base_t = recon_t if difference_coding else jnp.zeros_like(recon_t)
    res_x_sub, corr_x = _dp_substitute(h, base_x, res_x, dp_clip, dp_noise)
    res_t_sub, corr_t = _dp_substitute(
        t_half, base_t, res_t, dp_clip, dp_noise_t
    )
    (h_out, th, qx, scx, nrx, nsx, qt, sct, nrt, nst) = _wire_stage_gt(
        x, t, g, g_prev, recon_x, res_x_sub, recon_t, res_t_sub, alpha,
        scale_chunk, error_feedback, difference_coding, topk, _interpret(),
    )
    return h_out, th, qx, scx, nrx, nsx + corr_x, qt, sct, nrt, nst + corr_t


@functools.partial(
    jax.jit,
    static_argnames=("scale_chunk", "error_feedback", "difference_coding",
                     "topk", "bitmap", "interpret"),
)
def _wire_stage_compact(x, g, recon, res, alpha, scale_chunk, error_feedback,
                        difference_coding, topk, bitmap, interpret):
    return wire_stage_compact_pallas(
        x, g, recon, res, alpha, scale_chunk=scale_chunk,
        error_feedback=error_feedback, difference_coding=difference_coding,
        topk=topk, bitmap=bitmap, interpret=interpret,
    )


def wire_stage_compact(
    x: jnp.ndarray,
    g: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    alpha: jnp.ndarray,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    bitmap: bool = False,
    dp_clip: float | None = None,
    dp_noise: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """DSGD wire stage with the compact-gather epilogue (the truly sparse
    top-k wire): local update + difference coding + EXACT-k selection +
    int8 quantize + EF in ONE Pallas pass, with the optional DP epilogue
    via residual substitution (selection runs on the NOISED wire -- the
    sparsity pattern itself is privatized). Returns (h, q int8
    (n, n_chunks*k), pos int16/int32, scales, new_recon, new_res); only
    (q, pos, scales) cross the collective and
    ``ref.scatter_compact_dq`` rebuilds the dense dq on the receiver.
    ``bitmap=True`` folds the bitmap re-encode into the same kernel: the
    index output is the packed presence bitmap (uint8, chunk/8 per
    chunk), decoded by ``ref.scatter_bitmap_dq``."""
    if dp_noise is None:
        return _wire_stage_compact(
            x, g, recon, res, alpha, scale_chunk, error_feedback,
            difference_coding, topk, bitmap, _interpret(),
        )
    _require_ef_for_dp(error_feedback)
    h = x - alpha * g
    base = recon if difference_coding else jnp.zeros_like(recon)
    res_sub, corr = _dp_substitute(h, base, res, dp_clip, dp_noise)
    h_out, q, pos, scales, new_recon, new_res = _wire_stage_compact(
        x, g, recon, res_sub, alpha, scale_chunk, error_feedback,
        difference_coding, topk, bitmap, _interpret(),
    )
    return h_out, q, pos, scales, new_recon, new_res + corr


@functools.partial(
    jax.jit,
    static_argnames=("scale_chunk", "error_feedback", "difference_coding",
                     "topk", "bitmap", "interpret"),
)
def _wire_stage_gt_compact(x, t, g, g_prev, recon_x, res_x, recon_t, res_t,
                           alpha, scale_chunk, error_feedback,
                           difference_coding, topk, bitmap, interpret):
    return wire_stage_gt_compact_pallas(
        x, t, g, g_prev, recon_x, res_x, recon_t, res_t, alpha,
        scale_chunk=scale_chunk, error_feedback=error_feedback,
        difference_coding=difference_coding, topk=topk, bitmap=bitmap,
        interpret=interpret,
    )


def wire_stage_gt_compact(
    x: jnp.ndarray,
    t: jnp.ndarray,
    g: jnp.ndarray,
    g_prev: jnp.ndarray,
    recon_x: jnp.ndarray,
    res_x: jnp.ndarray,
    recon_t: jnp.ndarray,
    res_t: jnp.ndarray,
    alpha: jnp.ndarray,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
    topk: int | None = None,
    bitmap: bool = False,
    dp_clip: float | None = None,
    dp_noise: jnp.ndarray | None = None,
    dp_noise_t: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, ...]:
    """DSGT wire stage with the compact-gather epilogue on BOTH wires, in
    ONE Pallas pass, with the optional DP epilogue via residual
    substitution. Returns (h, t_half, q_x, pos_x, scales_x, new_recon_x,
    new_res_x, q_t, pos_t, scales_t, new_recon_t, new_res_t).
    ``bitmap=True`` folds the bitmap re-encode into the kernel on both
    wires (index outputs become packed presence bitmaps)."""
    if dp_noise is None:
        return _wire_stage_gt_compact(
            x, t, g, g_prev, recon_x, res_x, recon_t, res_t, alpha,
            scale_chunk, error_feedback, difference_coding, topk, bitmap,
            _interpret(),
        )
    _require_ef_for_dp(error_feedback)
    t_half = t + g - g_prev
    h = x - alpha * t_half
    base_x = recon_x if difference_coding else jnp.zeros_like(recon_x)
    base_t = recon_t if difference_coding else jnp.zeros_like(recon_t)
    res_x_sub, corr_x = _dp_substitute(h, base_x, res_x, dp_clip, dp_noise)
    res_t_sub, corr_t = _dp_substitute(
        t_half, base_t, res_t, dp_clip, dp_noise_t
    )
    (h_out, th, qx, px, scx, nrx, nsx,
     qt, pt, sct, nrt, nst) = _wire_stage_gt_compact(
        x, t, g, g_prev, recon_x, res_x_sub, recon_t, res_t_sub, alpha,
        scale_chunk, error_feedback, difference_coding, topk, bitmap,
        _interpret(),
    )
    return (h_out, th, qx, px, scx, nrx, nsx + corr_x,
            qt, pt, sct, nrt, nst + corr_t)
