"""jit'd dispatch for the fused quantize-mix-EF gossip kernel."""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.gossip.gossip import gossip_mix_pallas

__all__ = ["gossip_mix"]


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("scale_chunk", "error_feedback", "difference_coding", "interpret"),
)
def _gossip_mix(x, recon, res, w_off, w_self, scale_chunk, error_feedback,
                difference_coding, interpret):
    return gossip_mix_pallas(
        x,
        recon,
        res,
        w_off,
        w_self,
        scale_chunk=scale_chunk,
        error_feedback=error_feedback,
        difference_coding=difference_coding,
        interpret=interpret,
    )


def gossip_mix(
    x: jnp.ndarray,
    recon: jnp.ndarray,
    res: jnp.ndarray,
    w_off: jnp.ndarray,
    w_self: jnp.ndarray,
    scale_chunk: int = 512,
    error_feedback: bool = True,
    difference_coding: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused pass on a flat buffer whose width is a multiple of
    ``scale_chunk`` (pack with ``pad_to=scale_chunk``); raises ValueError
    otherwise, exactly like the jnp reference. ``interpret`` is resolved
    OUTSIDE the jit so REPRO_PALLAS_INTERPRET is honored per call, not
    frozen into the first compilation."""
    return _gossip_mix(
        x, recon, res, w_off, w_self, scale_chunk, error_feedback,
        difference_coding, _interpret(),
    )
