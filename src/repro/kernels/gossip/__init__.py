"""Fused gossip kernels: int8 quantize -> W-row mix -> dequant + EF residual
in one VMEM-tiled pass over the flat (nodes, total) state, plus the round
megakernels that fuse the DSGD/DSGT local update into the same pass."""

from repro.kernels.gossip.ops import fused_round, fused_round_gt, gossip_mix
from repro.kernels.gossip.ref import (
    fused_round_gt_ref,
    fused_round_ref,
    gossip_mix_ref,
)

__all__ = [
    "gossip_mix",
    "gossip_mix_ref",
    "fused_round",
    "fused_round_ref",
    "fused_round_gt",
    "fused_round_gt_ref",
]
