"""Fused gossip kernel: int8 quantize -> W-row mix -> dequant + EF residual
in one VMEM-tiled pass over the flat (nodes, total) state."""

from repro.kernels.gossip.ops import gossip_mix
from repro.kernels.gossip.ref import gossip_mix_ref

__all__ = ["gossip_mix", "gossip_mix_ref"]
