"""Fused gossip kernels: int8 quantize -> W-row mix -> dequant + EF residual
in one VMEM-tiled pass over the flat (nodes, total) state, the round
megakernels that fuse the DSGD/DSGT local update into the same pass, and
the wire-stage kernels (pre-collective half of the SHARDED fused round:
update + top-k + quantize + EF, with the W mix finished after the
ppermute / all-gather wire). All entry points take ``topk=`` for top-k
sparsified payloads (EF absorbs the truncation); the ``*_compact``
variants emit the truly sparse (k values, k positions, scales) wire
buffers, and the mix kernels take ``stale_mix=`` for the pipelined round
schedule's one-round-stale neighbor mixing."""

from repro.kernels.gossip.ops import (
    fused_round,
    fused_round_gt,
    gossip_mix,
    wire_stage,
    wire_stage_compact,
    wire_stage_gt,
    wire_stage_gt_compact,
)
from repro.kernels.gossip.ref import (
    fused_round_gt_ref,
    fused_round_ref,
    gossip_mix_ref,
    scatter_compact_dq,
    wire_stage_compact_ref,
    wire_stage_gt_compact_ref,
    wire_stage_gt_ref,
    wire_stage_ref,
)

__all__ = [
    "gossip_mix",
    "gossip_mix_ref",
    "fused_round",
    "fused_round_ref",
    "fused_round_gt",
    "fused_round_gt_ref",
    "wire_stage",
    "wire_stage_ref",
    "wire_stage_gt",
    "wire_stage_gt_ref",
    "wire_stage_compact",
    "wire_stage_compact_ref",
    "wire_stage_gt_compact",
    "wire_stage_gt_compact_ref",
    "scatter_compact_dq",
]
