from repro.kernels.rwkv6_scan import ops, ref
from repro.kernels.rwkv6_scan.rwkv6_scan import wkv6_chunked_pallas

__all__ = ["ops", "ref", "wkv6_chunked_pallas"]
