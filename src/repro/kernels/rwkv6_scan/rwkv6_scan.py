"""Chunked WKV-6 recurrence (RWKV "Finch" data-dependent decay) -- Pallas.

Per (batch x head) the recurrence over the 64x64 kv-state S is

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

TPU mapping: grid = (B*H, n_chunks); the chunk dimension is the sequential
minor loop, the fp32 state S persists in a (64, 64) VMEM scratch across
chunks. Within a chunk (C time steps) the work is three MXU-shaped
einsums (C x C x 64) built from log-space decay ratios -- exactly the
chunked form of models/rwkv6.wkv6_chunked, tiled so one chunk's operands
(5 x C x 64 fp32) sit in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


__all__ = ["wkv6_chunked_pallas"]


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sout_ref, s_ref, *, chunk, n_chunks, head_size):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = s0_ref[0]

    rc = r_ref[0].astype(jnp.float32)  # (C, hd)
    kc = k_ref[0].astype(jnp.float32)
    vc = v_ref[0].astype(jnp.float32)
    lwc = lw_ref[0].astype(jnp.float32)  # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)  # (1, hd) -> broadcast
    s_in = s_ref[...]

    cum = jnp.cumsum(lwc, axis=0)  # inclusive
    total = cum[-1]  # (hd,)
    cum_excl = cum - lwc  # exclusive

    r_dec = rc * jnp.exp(cum_excl)  # r_t * P_{t-1}; exp <= 1, stable
    y_carry = jax.lax.dot_general(
        r_dec, s_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, hd_v)

    # intra-chunk attention-like term, PAIRWISE decay (exponents bounded by
    # -lw_t; the factored e^{cum} * e^{-cum} form overflows at strong decay)
    t_idx = jax.lax.iota(jnp.int32, chunk)
    tri = t_idx[:, None] > t_idx[None, :]  # strict lower triangle (a < t)
    diff = cum_excl[:, None, :] - cum[None, :, :]  # (t, a, hd)
    decay = jnp.exp(jnp.where(tri[:, :, None], diff, 0.0))
    att = jnp.sum(rc[:, None, :] * kc[None, :, :] * decay, axis=-1)  # (t, a)
    att = jnp.where(tri, att, 0.0)
    y_intra = jax.lax.dot_general(
        att, vc, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    bonus = jnp.sum(rc * u * kc, axis=-1, keepdims=True)  # (C, 1)
    y = y_carry + y_intra + bonus * vc
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S_out = e^total * S_in + sum_a (e^{total - cum_a} k_a) v_a^T
    k_rem = kc * jnp.exp(total[None, :] - cum)
    s_ref[...] = jnp.exp(total)[:, None] * s_in + jax.lax.dot_general(
        k_rem, vc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(c == n_chunks - 1)
    def _final():
        sout_ref[0] = s_ref[...]


def wkv6_chunked_pallas(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_w: jnp.ndarray,
    u: jnp.ndarray,
    s0: jnp.ndarray,
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """r/k/v/log_w: (BH, S, hd) fp32; u: (BH, hd); s0: (BH, hd, hd).

    Returns (y (BH, S, hd), s_final (BH, hd, hd)). S must divide by chunk.
    """
    bh, s, hd = r.shape
    assert s % chunk == 0, f"seq {s} % chunk {chunk}"
    n_chunks = s // chunk

    seq_spec = pl.BlockSpec((1, chunk, hd), lambda i, c: (i, c, 0))
    head_spec = pl.BlockSpec((1, hd), lambda i, c: (i, 0))
    state_spec = pl.BlockSpec((1, hd, hd), lambda i, c: (i, 0, 0))

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks, head_size=hd)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, head_spec, state_spec],
        out_specs=[seq_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, hd), r.dtype),
            jax.ShapeDtypeStruct((bh, hd, hd), jnp.float32),
        ],
        scratch_shapes=[_vmem((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u, s0)
    return y, s_out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
