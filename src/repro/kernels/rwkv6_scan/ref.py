"""Naive O(T) sequential oracle for the WKV-6 recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv6_ref"]


def wkv6_ref(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_w: jnp.ndarray,
    u: jnp.ndarray,
    s0: jnp.ndarray,
):
    """r/k/v/log_w: (BH, S, hd) fp32; u: (BH, hd); s0: (BH, hd, hd).

        y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """

    def step(s, xs):
        rt, kt, vt, lwt = xs  # (BH, hd) each
        kv = kt[..., :, None] * vt[..., None, :]  # (BH, hd, hd)
        y = jnp.einsum("bi,bij->bj", rt, s + u[..., :, None] * kv)
        s_new = jnp.exp(lwt)[..., :, None] * s + kv
        return s_new, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, log_w))
    s_fin, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), s_fin
