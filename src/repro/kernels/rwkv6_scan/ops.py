"""jit'd dispatch for the WKV-6 kernel from model-layout tensors."""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan.rwkv6_scan import wkv6_chunked_pallas

__all__ = ["wkv6"]


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_w: jnp.ndarray,
    u: jnp.ndarray,
    s0: jnp.ndarray,
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Model layout: r/k/v/log_w (B, S, H, hd); u (H, hd); s0 (B, H, hd, hd).

    Returns (y (B,S,H,hd), s_final (B,H,hd,hd)).
    """
    b, s, h, hd = r.shape
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    uu = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, hd)
    ss = s0.reshape(b * h, hd, hd).astype(jnp.float32)
    ck = chunk if s % chunk == 0 else 1
    y, s_fin = wkv6_chunked_pallas(
        fold(r), fold(k), fold(v), fold(log_w), uu, ss, chunk=ck, interpret=_interpret()
    )
    return (
        y.reshape(b, h, s, hd).transpose(0, 2, 1, 3),
        s_fin.reshape(b, h, hd, hd),
    )
