"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package ships: <name>.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd model-layout wrapper, auto interpret off-TPU), ref.py
(pure-jnp oracle used by the allclose test sweeps).
"""
