"""Non-IID partitioners + heterogeneity diagnostics.

The paper's datasets are *naturally* partitioned (each hospital's patients
are its own). For ablations on synthetic corpora we also provide the
standard Dirichlet(alpha) label-skew partitioner used across the FL
literature (alpha -> 0: one-class nodes; alpha -> inf: IID).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

__all__ = ["dirichlet_partition", "label_shift_stats", "cohort_label_stats"]


def dirichlet_partition(
    labels: np.ndarray, n_nodes: int, alpha: float, seed: int = 0
) -> List[np.ndarray]:
    """Index lists per node with Dirichlet(alpha) class proportions."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    node_indices: List[List[int]] = [[] for _ in range(n_nodes)]
    for c in classes:
        idx = np.nonzero(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_nodes, alpha))
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for node, part in enumerate(np.split(idx, cuts)):
            node_indices[node].extend(part.tolist())
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in node_indices]


def label_shift_stats(
    labels: np.ndarray, parts: List[np.ndarray]
) -> Dict[str, float]:
    """Quantify heterogeneity: mean/max total-variation distance between
    per-node label distributions and the global one."""
    classes = np.unique(labels)
    global_p = np.array([(labels == c).mean() for c in classes])
    tvs = []
    for ix in parts:
        if len(ix) == 0:
            continue
        local = labels[ix]
        p = np.array([(local == c).mean() for c in classes])
        tvs.append(0.5 * np.abs(p - global_p).sum())
    return {
        "tv_mean": float(np.mean(tvs)),
        "tv_max": float(np.max(tvs)),
        "nodes": float(len(tvs)),
    }


def cohort_label_stats(labels_per_node) -> Dict[str, float]:
    """Label-shift diagnostics for a NATURALLY partitioned cohort (a
    sequence of per-node label arrays, e.g. ``EHRDataset.labels``):
    the TV-distance stats of :func:`label_shift_stats` plus the spread
    of per-node positive-class prevalence -- the number the harder
    cohort knobs (``label_shift`` / ``minority_concentration``) move."""
    labels_per_node = [np.asarray(l) for l in labels_per_node]
    y = np.concatenate(labels_per_node)
    parts, off = [], 0
    for l in labels_per_node:
        parts.append(np.arange(off, off + len(l), dtype=np.int64))
        off += len(l)
    stats = label_shift_stats(y, parts)
    prev = [float(l.mean()) if len(l) else 0.0 for l in labels_per_node]
    stats["prevalence_min"] = float(min(prev))
    stats["prevalence_max"] = float(max(prev))
    stats["prevalence_mean"] = float(np.mean(prev))
    return stats
