"""Synthetic EHR cohort matched to the paper's published statistics.

Section 2.1: 2,103 Alzheimer's Disease (AD) + 7,919 mild-cognitive-
impairment (MCI) patients across 20 hospitals (~500 records each),
42 engineered features. The real IQVIA dataset is proprietary; this
generator reproduces the *structure* that drives the paper's algorithmic
claims:

  * non-identical per-hospital distributions (Fig. 1 right: t-SNE clusters
    separate by hospital) -- each hospital gets its own feature-mean offset
    and covariance rotation, so the local optima f_i* genuinely disagree;
  * class imbalance (AD ~21% overall) varying per hospital;
  * a shared global signal (a true separating direction) so the consensus
    model is learnable.

Generation is pure numpy with a fixed seed: deterministic, no I/O.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import numpy as np

__all__ = ["EHRDataset", "generate_ehr_cohort", "make_node_batcher"]

N_HOSPITALS = 20
N_FEATURES = 42
N_AD = 2103
N_MCI = 7919


@dataclasses.dataclass(frozen=True)
class EHRDataset:
    """Per-hospital arrays: features[i] (n_i, 42) float32, labels[i] (n_i,)
    int32 (1 = AD, 0 = MCI)."""

    features: Tuple[np.ndarray, ...]
    labels: Tuple[np.ndarray, ...]

    @property
    def n_nodes(self) -> int:
        return len(self.features)

    def node_sizes(self) -> List[int]:
        return [len(x) for x in self.features]

    def totals(self) -> Dict[str, int]:
        y = np.concatenate(self.labels)
        return {"n": len(y), "ad": int(y.sum()), "mci": int((1 - y).sum())}


def generate_ehr_cohort(
    seed: int = 0,
    n_hospitals: int = N_HOSPITALS,
    n_features: int = N_FEATURES,
    n_ad: int = N_AD,
    n_mci: int = N_MCI,
    heterogeneity: float = 1.5,
    label_shift: float = 0.0,
    minority_concentration: float = 0.0,
    conditional_shift: float = 0.0,
) -> EHRDataset:
    """Build the cohort. ``heterogeneity`` scales the per-hospital
    distribution shift (0 = IID across hospitals).

    The three extra knobs harden the cohort for personalization-vs-
    consensus experiments (all default 0, which reproduces the legacy
    cohort BIT-IDENTICALLY -- their draws come from separate, gated RNG
    streams):

    * ``label_shift``: per-hospital AD-prevalence tilt. Each hospital
      gets a tilt in [-1, 1]; AD mass is reweighted by
      ``exp(label_shift * tilt)`` (MCI by the inverse), so hospitals
      range from AD-poor to AD-rich while the cohort totals stay exact.
    * ``minority_concentration``: concentrates the minority (AD) class
      into few hospitals -- AD mass is further multiplied by a per-
      hospital factor in [0.05, 1] raised to this power, so at 1-2 most
      hospitals see only a handful of AD cases.
    * ``conditional_shift``: per-hospital CLASS-CONDITIONAL drift -- the
      AD cluster's mean moves along a hospital-specific direction
      orthogonal to the global signal, so the Bayes-optimal classifier
      genuinely differs per hospital (a shared head cannot be optimal
      everywhere; a personalized head can).
    """
    rng = np.random.default_rng(seed)

    # global class-separating structure
    w_true = rng.normal(size=(n_features,))
    w_true /= np.linalg.norm(w_true)

    # per-hospital distribution shift: mean offset + random rotation mix
    offsets = heterogeneity * rng.normal(size=(n_hospitals, n_features))
    mixes = []
    for _ in range(n_hospitals):
        a = rng.normal(size=(n_features, n_features)) * 0.15
        mixes.append(np.eye(n_features) + a)

    # allocate patients to hospitals (~500 each, Dirichlet jitter);
    # ``weight`` reweights a hospital's share AFTER the base Dirichlet
    # draw, so the rng stream (and the default cohort) is unchanged
    def alloc(total: int, weight=None) -> np.ndarray:
        p = rng.dirichlet(np.full(n_hospitals, 20.0))
        if weight is not None:
            p = p * weight
            p = p / p.sum()
        counts = np.floor(p * total).astype(int)
        counts[: total - counts.sum()] += 1
        return counts

    ad_w = mci_w = None
    if label_shift or minority_concentration:
        rng_shift = np.random.default_rng((seed, 104729))
        tilt = rng_shift.permutation(np.linspace(-1.0, 1.0, n_hospitals))
        ad_w = np.exp(label_shift * tilt)
        mci_w = np.exp(-label_shift * tilt)
        if minority_concentration:
            conc = rng_shift.permutation(
                np.linspace(1.0, 0.05, n_hospitals))
            ad_w = ad_w * conc ** minority_concentration
    ad_counts = alloc(n_ad, ad_w)
    mci_counts = alloc(n_mci, mci_w)

    cond_dirs = None
    if conditional_shift:
        rng_cond = np.random.default_rng((seed, 1299709))
        cond_dirs = rng_cond.normal(size=(n_hospitals, n_features))
        # orthogonal to the global signal: the drift moves the AD
        # cluster WITHOUT strengthening or weakening the shared
        # separating direction
        cond_dirs -= (cond_dirs @ w_true)[:, None] * w_true
        cond_dirs /= np.linalg.norm(cond_dirs, axis=1, keepdims=True)

    feats, labs = [], []
    for h in range(n_hospitals):
        n_pos, n_neg = int(ad_counts[h]), int(mci_counts[h])
        z_pos = rng.normal(size=(n_pos, n_features)) + 1.2 * w_true
        z_neg = rng.normal(size=(n_neg, n_features)) - 0.3 * w_true
        if cond_dirs is not None:
            z_pos = z_pos + conditional_shift * cond_dirs[h]
        z = np.concatenate([z_pos, z_neg], axis=0)
        y = np.concatenate([np.ones(n_pos), np.zeros(n_neg)]).astype(np.int32)
        x = (z @ mixes[h].T + offsets[h]).astype(np.float32)
        perm = rng.permutation(len(y))
        feats.append(x[perm])
        labs.append(y[perm])

    # standardize with GLOBAL statistics (each hospital could compute these
    # privately via secure aggregation; offsets keep the per-node shift)
    allx = np.concatenate(feats)
    mu, sd = allx.mean(0), allx.std(0) + 1e-6
    feats = [((x - mu) / sd).astype(np.float32) for x in feats]
    return EHRDataset(features=tuple(feats), labels=tuple(labs))


def make_node_batcher(
    data: EHRDataset, m: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of FL round batches shaped for ``make_fl_round``:
    each call yields {"x": (Q?, nodes, m, 42), ...} -- here per-STEP batches
    (nodes, m, 42); the trainer stacks Q of them.

    Samples WITH replacement per node (the paper's stochastic gradient
    ``m``-sample estimate, m=20).
    """
    rng = np.random.default_rng(seed)
    n = data.n_nodes
    while True:
        xs = np.empty((n, m, data.features[0].shape[1]), np.float32)
        ys = np.empty((n, m), np.int32)
        for i in range(n):
            idx = rng.integers(0, len(data.labels[i]), size=m)
            xs[i] = data.features[i][idx]
            ys[i] = data.labels[i][idx]
        yield {"x": xs, "y": ys}
