"""Synthetic LM token pipeline for the transformer architectures.

Deterministic, infinite, per-node sharded streams. The generator is a
node-seeded Markov-ish process over the vocabulary so that (a) streams are
reproducible given (seed, node, step), (b) per-node distributions are
non-identical (each node has its own transition bias -- the FL non-IID
regime the paper targets), and (c) the next-token task is learnable
(loss decreases measurably within a few hundred steps at 100M scale).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["TokenStream", "make_fl_token_batches"]


@dataclasses.dataclass
class TokenStream:
    """Per-node reproducible token sampler.

    Each node draws from a mixture: with prob ``struct_p`` the next token is
    a deterministic function of the previous one (node-specific affine map
    mod vocab -- the learnable structure), else uniform noise.
    """

    vocab_size: int
    node: int
    seed: int = 0
    struct_p: float = 0.8

    def sample(self, batch: int, seq_len: int, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.node, step])
        )
        v = self.vocab_size
        a = 3 + 2 * (self.node % 8)  # node-specific affine map (odd => bijective-ish)
        b = 17 * (self.node + 1)
        toks = np.empty((batch, seq_len), np.int64)
        toks[:, 0] = rng.integers(0, v, size=batch)
        structured = rng.random((batch, seq_len)) < self.struct_p
        noise = rng.integers(0, v, size=(batch, seq_len))
        for t in range(1, seq_len):
            nxt = (a * toks[:, t - 1] + b) % v
            toks[:, t] = np.where(structured[:, t], nxt, noise[:, t])
        return toks.astype(np.int32)


def make_fl_token_batches(
    vocab_size: int,
    n_nodes: int,
    per_node_batch: int,
    seq_len: int,
    q: int,
    seed: int = 0,
    extras: Optional[Dict[str, tuple]] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of FL-round batches {"tokens": (Q, nodes, pnb,
    seq_len+1)} (+1 because the loss shifts labels). ``extras`` maps key ->
    trailing shape for stubbed frontend embeddings, filled with seeded
    gaussians, e.g. {"prefix_embeds": (16, 256)}.
    """
    streams = [TokenStream(vocab_size, node=i, seed=seed) for i in range(n_nodes)]
    step = 0
    while True:
        toks = np.stack(
            [
                np.stack(
                    [s.sample(per_node_batch, seq_len + 1, step * q + j) for s in streams]
                )
                for j in range(q)
            ]
        )
        out: Dict[str, np.ndarray] = {"tokens": toks}
        if extras:
            rng = np.random.default_rng(np.random.SeedSequence([seed + 7, step]))
            for name, trail in extras.items():
                out[name] = rng.normal(
                    size=(q, n_nodes, per_node_batch) + tuple(trail)
                ).astype(np.float32)
        step += 1
        yield out
