"""Data substrate: synthetic EHR cohort (paper Section 2.1 statistics),
LM token pipeline, and non-IID partitioners."""

from repro.data.ehr import EHRDataset, generate_ehr_cohort, make_node_batcher
from repro.data.tokens import TokenStream, make_fl_token_batches
from repro.data.partition import dirichlet_partition, label_shift_stats

__all__ = [
    "EHRDataset",
    "generate_ehr_cohort",
    "make_node_batcher",
    "TokenStream",
    "make_fl_token_batches",
    "dirichlet_partition",
    "label_shift_stats",
]
