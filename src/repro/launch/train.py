"""Training launcher: decentralized FL training of any registered arch.

Two modes:
  * ``--smoke`` (default): reduced config of the same family, real training
    on the host devices (CPU in this container) with the simulated node
    axis -- this is the end-to-end driver the examples use;
  * full configs with ``--mesh single|multi``: builds the sharded FL round
    (node-stacked state over (pod, data), ppermute gossip, Megatron TP) --
    on TPU this trains; on CPU use launch/dryrun.py, which lowers the very
    same round function.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --rounds 20 --q 4 --algorithm dsgt --nodes 8
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FLRunConfig, get_config
from repro.core.dynamics import program_names
from repro.core.engine import engine_names, schedule_names
from repro.core.heterogeneity import node_program_names
from repro.data.tokens import make_fl_token_batches
from repro.models import build_model
from repro.training.checkpoint import save_fl_state
from repro.training.trainer import train_decentralized


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--algorithm", default="dsgt", choices=("dsgd", "dsgt"))
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--batch-per-node", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--alpha0", type=float, default=0.5)
    ap.add_argument("--fl-engine", default="tree", choices=engine_names(),
                    help="round engine, resolved through the GossipEngine "
                         "registry (sharded_fused needs a mesh -- use "
                         "launch/dryrun.py for that path)")
    ap.add_argument("--scale-chunk", type=int, default=512,
                    help="fused engines: int8 scale block width")
    ap.add_argument("--topk", type=int, default=None,
                    help="fused engines: k largest payload columns per "
                         "scale chunk on the wire")
    ap.add_argument("--fl-schedule", default="sequential",
                    help="round time layout (RoundSchedule registry: "
                         f"{', '.join(schedule_names())}): pipelined "
                         "overlaps the collective with the next round's "
                         "local steps, mixing one-round stale; spec "
                         "syntax name:k=v e.g. 'bounded_staleness:k=3' "
                         "keeps k payloads in flight (fused engines only)")
    ap.add_argument("--fl-staleness-depth", type=int, default=None,
                    help="sugar for --fl-schedule bounded_staleness:k=K "
                         "(0 = sequential); mutually exclusive with "
                         "--fl-schedule")
    ap.add_argument("--storage-dtype", default=None,
                    help="flat engine: buffer storage dtype (e.g. "
                         "bfloat16); fp32 stays in the mix accumulator")
    ap.add_argument("--fl-topology-program", default=None,
                    help="per-round graph dynamics (TopologyProgram "
                         f"registry: {', '.join(program_names())}); spec "
                         "syntax name:k=v,... e.g. "
                         "'edge_failure:p=0.2,seed=0' -- flat/fused "
                         "engines; metrics gain edge_fraction")
    ap.add_argument("--fl-node-program", default=None,
                    help="per-node heterogeneity (NodeProgram registry: "
                         f"{', '.join(node_program_names())}); spec syntax "
                         "name:k=v,... e.g. "
                         "'stragglers:frac=0.25,rate=0.5' gates local-step "
                         "budgets and payload delivery per round; metrics "
                         "gain payload_fraction / compute_fraction")
    ap.add_argument("--fl-privacy", default=None,
                    help="wire privacy epilogue (PrivacySpec): "
                         "'+'-separated tokens, e.g. 'secure_agg' "
                         "(pairwise antisymmetric masks -- no single "
                         "neighbor payload readable, cancels exactly "
                         "under the symmetric mix), "
                         "'dp:sigma=0.5,clip=1.0' (per-node clip + "
                         "Gaussian noise riding the EF residual; metrics "
                         "gain dp_epsilon), or both joined with '+' -- "
                         "fused engines; tree rejects")
    ap.add_argument("--fl-scope", default=None,
                    help="federation scope (FederationScope registry: "
                         "which flat-buffer columns gossip touches): "
                         "'full' (default), 'backbone' (share all but "
                         "the classifier head -- per-node personalized "
                         "heads stay bit-untouched, wire shrinks to the "
                         "shared slice), 'backbone:private=PAT', "
                         "'ranges:a-b,c-d', or 'layerwise:freq=R' (head "
                         "joins the mix every R rounds; fused engine "
                         "only) -- fused/sharded_fused; tree/flat "
                         "reject")
    ap.add_argument("--fl-robust-alpha", action="store_true",
                    help="shrink the step-size schedule by the "
                         "staleness/churn controller "
                         "(robust_alpha_scale(uptime, k))")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    bundle = build_model(cfg)
    run = FLRunConfig(
        algorithm=args.algorithm,
        q=args.q,
        topology=args.topology,
        n_nodes=args.nodes,
        batch_per_node=args.batch_per_node,
        alpha0=args.alpha0,
        seed=args.seed,
    )
    params = bundle.init_fn(jax.random.key(args.seed))

    extras: Dict[str, tuple] = {}
    if cfg.family == "vlm":
        extras["prefix_embeds"] = (cfg.frontend_seq, cfg.d_model)
    if cfg.family == "audio":
        extras["frames"] = (cfg.encoder.seq_len, cfg.encoder.d_model)

    fl_rounds = make_fl_token_batches(
        cfg.vocab_size, args.nodes, args.batch_per_node, args.seq_len,
        q=1, seed=args.seed, extras=extras or None,
    )

    def step_batches():
        while True:
            b = next(fl_rounds)
            yield {k: v[0] for k, v in b.items()}  # (nodes, pnb, ...)

    t0 = time.time()
    fl_schedule = args.fl_schedule
    if args.fl_staleness_depth is not None:
        if fl_schedule != "sequential":
            raise SystemExit(
                "--fl-staleness-depth is sugar for --fl-schedule "
                "bounded_staleness:k=K; pass one or the other"
            )
        fl_schedule = None  # trainer derives it from staleness_depth
    result = train_decentralized(
        bundle.loss_fn, params, run, step_batches(), rounds=args.rounds,
        log_every=args.log_every, engine=args.fl_engine,
        scale_chunk=args.scale_chunk, topk=args.topk,
        round_schedule=fl_schedule, storage_dtype=args.storage_dtype,
        topology_program=args.fl_topology_program,
        node_program=args.fl_node_program,
        staleness_depth=args.fl_staleness_depth,
        robust_alpha=args.fl_robust_alpha,
        privacy=args.fl_privacy,
        scope=args.fl_scope,
    )
    hist = result.history
    first, last = hist.rows()[0], hist.last()
    print(
        json.dumps(
            {
                "arch": cfg.name,
                "fl_engine": args.fl_engine,
                "fl_schedule": result.engine.round_schedule.spec(),
                "fl_topology_program": args.fl_topology_program,
                "fl_node_program": args.fl_node_program,
                "fl_privacy": result.engine.privacy.spec(),
                "fl_scope": result.engine.scope.spec(),
                "algorithm": args.algorithm,
                "q": args.q,
                "rounds": args.rounds,
                "iterations": int(last["iteration"]),
                "loss_first": first["loss"],
                "loss_last": last["loss"],
                "consensus_err_last": last["consensus_err"],
                "dp_epsilon": last.get("dp_epsilon"),
                "wall_s": round(time.time() - t0, 1),
            },
            indent=2,
        )
    )
    if args.checkpoint:
        save_fl_state(args.checkpoint, result.state, extra={"arch": cfg.name},
                      engine=result.engine)
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
