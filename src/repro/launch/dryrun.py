"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) pair.

Proves the distribution config is coherent without TPU hardware:
  * 512 placeholder host devices stand in for 2 pods x 256 chips;
  * every combination must .lower().compile() under its production
    sharding; failures (sharding mismatch, unsupported collective) are
    bugs in the system, not in the environment;
  * memory_analysis() / cost_analysis() + the collective ops parsed from
    the compiled HLO feed EXPERIMENTS.md (§Dry-run, §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
      --shape train_4k --mesh single --q 4 --out experiments/dryrun
  (run_all: benchmarks/run_dryruns.py drives every pair with caching)
"""

# The VERY FIRST lines, before ANY other import: jax locks the device
# count at first initialization.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (  # noqa: E402
    SHAPES,
    decode_sliding_override,
    get_config,
    serve_input_specs,
    supports_shape,
    train_input_specs,
)
from repro.core.dynamics import program_names  # noqa: E402
from repro.core.engine import engine_names, get_engine, schedule_names  # noqa: E402
from repro.core.fl import FLConfig, FLState, make_fl_round  # noqa: E402
from repro.core.schedules import inv_sqrt  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    HW,
    make_production_mesh,
    model_axis,
    n_fl_nodes,
    node_axes,
)
from repro.models import build_model  # noqa: E402
from repro.models.sharding import model_param_specs, node_stack_specs  # noqa: E402


def _sds_tree(tree):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree
    )


def _stack_nodes_sds(tree, n_nodes: int):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((n_nodes,) + l.shape, l.dtype), tree
    )


def build_train_lowering(arch: str, shape_name: str, mesh, q: int, algorithm: str = "dsgt",
                         wire_dtype=None, pod_gossip_every: int = 1, impl: str = "ref",
                         pad_heads: int = 0, fl_engine: str = "tree",
                         scale_chunk: int = 512, topk=None,
                         fl_schedule: str = "sequential",
                         fl_topology_program: Optional[str] = None,
                         fl_node_program: Optional[str] = None,
                         fl_privacy: Optional[str] = None,
                         fl_scope: Optional[str] = None,
                         fl_shard_model: bool = False):
    """Lower one FL round (Q local steps + gossip) for the given mesh.

    ``fl_engine`` names a registered GossipEngine (the registry in
    ``repro.core.engine`` is the one source of truth; no string dispatch
    here), built against the mesh with its ``from_mesh`` constructor:

      * "tree"          -- node-stacked pytree state, per-leaf model
                           sharding, ppermute gossip inside shard_map;
      * "flat"          -- the state lives as ONE packed (nodes, total)
                           buffer end to end; local steps, metrics, and
                           gossip are all single-buffer ops;
      * "fused"         -- the round megakernel against the dense
                           equivalent of the mesh's circulant W. The
                           dry-run lowers the kernel's jnp oracle
                           (bit-identical math) because GSPMD can
                           partition it over the node axes;
      * "sharded_fused" -- the shard_map-native fused round: wire-stage
                           Pallas kernel per shard (interpret off-TPU) +
                           int8 ppermute wire; the one-kernel-per-round
                           property survives the mesh.

    ``topk`` masks the fused engines' payload to k columns per scale
    chunk; on the sharded engine it also turns on the COMPACT wire (the
    collective moves k int8 values + k positions + scales per chunk
    instead of the masked-dense buffer). ``fl_schedule`` selects the
    round's time layout through the RoundSchedule registry:
    "sequential" (produce -> collective -> mix) or "pipelined" (the
    collective for round r's payload is issued before round r+1's
    local-step scan and the mix consumes one-round-stale neighbor
    information; fused engines only). ``fl_topology_program`` selects the
    per-round graph dynamics through the TopologyProgram registry
    (``repro.core.dynamics``; e.g. "node_churn:p_down=0.2"): the round's
    mixing weights become traced operands of the one compiled round --
    churn adds zero recompiles and zero collectives (fused engines; the
    sharded engine gates its circulant ppermute wire).
    ``fl_node_program`` adds per-node heterogeneity the same way
    (``repro.core.heterogeneity``; e.g. "stragglers:frac=0.25"): compute
    and payload gates are traced operands, so slow/faulty nodes change
    nothing about the lowering. ``fl_schedule`` also accepts depth-k
    specs ("bounded_staleness:k=3"): the comm state grows a k-slot wire
    ring but the collective still moves ONE slot per round. ``fl_privacy``
    adds the wire's privacy epilogue the same way (``repro.core.privacy``;
    e.g. "secure_agg+dp:sigma=0.5,clip=1.0"): pads and noise are generated
    from comm-state counters inside the round, so the lowering keeps the
    identical collective count and operand bytes as the plaintext wire.
    """
    import dataclasses as _dc

    engine_cls = get_engine(fl_engine)  # raises with the registry listing
    cfg = get_config(arch)
    if pad_heads:
        cfg = _dc.replace(cfg, tp_head_pad=pad_heads)
    bundle = build_model(cfg, impl=impl, remat=True)
    shape = SHAPES[shape_name]
    nodes = n_fl_nodes(mesh)
    naxes = node_axes(mesh)

    params_sds = jax.eval_shape(bundle.init_fn, jax.random.key(0))
    stacked_sds = _stack_nodes_sds(params_sds, nodes)
    pspecs = node_stack_specs(model_param_specs(params_sds), naxes)

    fl_cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=nodes)
    # Hierarchical gossip (pod_gossip_every > 1): the driver alternates two
    # jitted rounds; this lowering is the COMMON-CASE round whose gossip
    # mixes only the intra-pod ("data") axis. The every-k-th full round is
    # the pod_gossip_every == 1 lowering; amortized cost =
    # ((k-1) * data_only + full) / k (EXPERIMENTS.md §Perf).
    hier = pod_gossip_every > 1 and "pod" in naxes

    extra = {}
    if fl_shard_model:
        # the two-axis (gossip_node, model_shard) round: each node's flat
        # buffer tiles over the model axis; gossip stays node-axis-only
        if fl_engine != "sharded_fused":
            raise ValueError(
                "--fl-shard-model needs the sharded_fused engine (the "
                f"two-axis wire is its contract); got fl_engine={fl_engine!r}"
            )
        maxis = model_axis(mesh)
        if maxis is None:
            raise ValueError(
                "--fl-shard-model needs a mesh with a 'model' axis; "
                f"this mesh has {mesh.axis_names!r}"
            )
        extra["model_axis"] = maxis
    engine = engine_cls.from_mesh(
        mesh, naxes, stacked_sds, specs=pspecs, wire_dtype=wire_dtype,
        axes_subset=("data",) if hier else None, scale_chunk=scale_chunk,
        topk=topk, round_schedule=fl_schedule,
        topology_program=fl_topology_program,
        node_program=fl_node_program,
        privacy=fl_privacy, scope=fl_scope, **extra,
    )
    round_fn = make_fl_round(
        bundle.loss_fn, None, inv_sqrt(0.02), fl_cfg, engine=engine
    )

    int_sds = jax.ShapeDtypeStruct((), jnp.int32)
    if engine.layout is None:
        buf_sds, buf_specs = stacked_sds, pspecs
    else:
        buf_sds = jax.ShapeDtypeStruct(
            (nodes, engine.layout.total),
            jnp.dtype(engine.layout.storage_dtype),
        )
        # the engine owns its partition spec: the two-axis sharded engine
        # tiles the flat buffer's columns over the model axis
        buf_specs = (engine.params_spec() if hasattr(engine, "params_spec")
                     else P(tuple(naxes), None))
    # comm buffers from the engine's own contract (shapes/dtypes differ
    # per schedule and wire: in-flight int8 payloads, positions, scales).
    # Node-stacked (rank >= 2) buffers shard over the LEADING node axes
    # only -- depth-k rings are (n, k, width) and the dense-W neighbor
    # replica is (n, n, t), both sharded by receiver row; the topology
    # program's scalar counters (topo_round, topo_key) replicate. Engines
    # exposing comm_state_specs (the two-axis sharded engine) decide for
    # themselves which trailing axes tile over the model axis.
    comm_sds = engine.comm_state_sds(fl_cfg)
    if comm_sds is None:
        comm_specs = None
    elif hasattr(engine, "comm_state_specs"):
        comm_specs = engine.comm_state_specs(fl_cfg)
    else:
        comm_specs = {
            k: (P(tuple(naxes), *(None,) * (len(s.shape) - 1))
                if len(s.shape) >= 2 else P())
            for k, s in comm_sds.items()
        }
    if algorithm == "dsgt":
        state_sds = FLState(int_sds, buf_sds, buf_sds, buf_sds, comm_sds)
        state_specs = FLState(P(), buf_specs, buf_specs, buf_specs, comm_specs)
    else:
        state_sds = FLState(int_sds, buf_sds, None, None, comm_sds)
        state_specs = FLState(P(), buf_specs, None, None, comm_specs)

    batch_sds = train_input_specs(cfg, shape, nodes, q)
    batch_specs = jax.tree_util.tree_map(
        lambda l: P(None, naxes, *(None,) * (l.ndim - 2)), batch_sds
    )

    def shardings(spec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    jitted = jax.jit(
        round_fn, in_shardings=(shardings(state_specs), shardings(batch_specs))
    )
    aux = {"engine": engine, "round_fn": round_fn, "fl_cfg": fl_cfg,
           "mesh": mesh}
    return jitted, (state_sds, batch_sds), cfg, aux


def _serve_param_shardings(mesh, params_sds):
    specs = model_param_specs(params_sds)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def build_prefill_lowering(arch: str, shape_name: str, mesh):
    cfg = get_config(arch)
    bundle = build_model(cfg, impl="ref", remat=False)
    shape = SHAPES[shape_name]
    naxes = node_axes(mesh)
    params_sds = jax.eval_shape(bundle.init_fn, jax.random.key(0))
    batch_sds = serve_input_specs(cfg, shape)
    nodes = n_fl_nodes(mesh)
    bdim = naxes if shape.global_batch % nodes == 0 else (
        ("data",) if shape.global_batch % mesh.shape["data"] == 0 else None
    )
    batch_specs = jax.tree_util.tree_map(
        lambda l: P(bdim, *(None,) * (l.ndim - 1)), batch_sds
    )
    jitted = jax.jit(
        bundle.prefill_fn,
        in_shardings=(
            _serve_param_shardings(mesh, params_sds),
            jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), batch_specs,
                is_leaf=lambda x: isinstance(x, P),
            ),
        ),
    )
    return jitted, (params_sds, batch_sds), cfg


def _cache_specs(cache_sds, batch: int, naxes, divisible: bool):
    """Shard the batch dim of every decode-cache leaf over the node axes."""

    def f(l):
        spec = [None] * l.ndim
        if divisible:
            for i, d in enumerate(l.shape):
                if d == batch and i <= 1:
                    spec[i] = naxes
                    break
        return P(*spec)

    return jax.tree_util.tree_map(f, cache_sds)


def build_decode_lowering(arch: str, shape_name: str, mesh):
    cfg = get_config(arch)
    bundle = build_model(cfg, impl="ref", remat=False)
    shape = SHAPES[shape_name]
    naxes = node_axes(mesh)
    nodes = n_fl_nodes(mesh)
    sliding = decode_sliding_override(cfg, shape)
    b = shape.global_batch
    params_sds = jax.eval_shape(bundle.init_fn, jax.random.key(0))
    cache_sds = jax.eval_shape(
        lambda: bundle.init_decode_state_fn(b, shape.seq_len, sliding_override=sliding)
    )
    divisible = b % nodes == 0
    cache_specs = _cache_specs(cache_sds, b, naxes, divisible)
    tok_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    tok_spec = P(naxes) if divisible else P()

    def step(params, tokens, caches):
        return bundle.decode_fn(params, tokens, caches, sliding_override=sliding)

    jitted = jax.jit(
        step,
        in_shardings=(
            _serve_param_shardings(mesh, params_sds),
            NamedSharding(mesh, tok_spec),
            jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), cache_specs,
                is_leaf=lambda x: isinstance(x, P),
            ),
        ),
    )
    return jitted, (params_sds, tok_sds, cache_sds), cfg


def _walk_jaxpr(jaxpr, name, found):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            found.append(eqn)
        for v in eqn.params.values():
            subs = v if isinstance(v, (list, tuple)) else [v]
            for sub in subs:
                if hasattr(sub, "jaxpr"):
                    _walk_jaxpr(sub.jaxpr, name, found)
                elif hasattr(sub, "eqns"):
                    _walk_jaxpr(sub, name, found)
    return found


def two_axis_record(engine, round_fn, state_sds, batch_sds, fl_cfg) -> Dict[str, Any]:
    """Jaxpr proof obligations for the two-axis (node, shard) round:

      * ONE pallas_call per (node, shard) wire-stage tile -- the shard_map
        body traces once with per-device-tile (local) shapes, so one
        pallas_call eqn IS one kernel launch per tile;
      * every gossip collective (ppermute / all_gather) binds node axes
        ONLY -- nothing moves over the model axis;
      * the collective operands of one wire direction are EXACTLY the
        per-shard compact encoding: flat_wire_bytes_per_shard bytes.

    Returns the record fields; raises AssertionError when the lowering
    breaks the contract (a bug, not an environment problem)."""
    from repro.core.packing import flat_wire_bytes_per_shard

    jx = jax.make_jaxpr(round_fn)(state_sds, batch_sds)
    pallas = _walk_jaxpr(jx.jaxpr, "pallas_call", [])
    assert len(pallas) == 1, (
        f"two-axis round must stay ONE wire-stage kernel per (node, shard) "
        f"tile; found {len(pallas)} pallas_call eqns"
    )
    node_axes_set = set(engine.node_axes)
    coll = (_walk_jaxpr(jx.jaxpr, "ppermute", [])
            + _walk_jaxpr(jx.jaxpr, "all_gather", []))
    axes_seen = set()
    for eqn in coll:
        ax = eqn.params.get("axis_name")
        for a in (ax if isinstance(ax, (list, tuple)) else (ax,)):
            axes_seen.add(a)
    assert axes_seen and axes_seen <= node_axes_set, (
        f"gossip collectives must bind node axes only; saw {axes_seen!r} "
        f"vs node axes {node_axes_set!r}"
    )
    # one wire direction = one group of per-buffer ppermutes (compact
    # bitmap wire: values + bitmap + scales = 3; dense int8 wire: q +
    # scales = 2). Inside shard_map the jaxpr's shapes are LOCAL
    # per-device tiles: one node row x one model shard.
    pp = _walk_jaxpr(jx.jaxpr, "ppermute", [])
    n_buffers = 3 if engine.compact_wire else 2
    per_shard = None
    if pp:
        one_dir = pp[:n_buffers]
        moved = sum(int(np.prod(e.invars[0].aval.shape))
                    * e.invars[0].aval.dtype.itemsize for e in one_dir)
        # the wire moves the SCOPED layout: under a partial federation
        # scope the collectives carry only the shared slice's columns
        per_shard = flat_wire_bytes_per_shard(
            getattr(engine, "wire_layout", engine.layout), 1,
            engine.scale_chunk,
            engine.topk if engine.compact_wire else None)
        assert moved == per_shard, (
            f"per-shard collective operand bytes {moved} != "
            f"flat_wire_bytes_per_shard {per_shard}"
        )
    return {
        "model_axis": engine.model_axis,
        "model_shards": int(engine.model_shards),
        "shard_width": int(engine.layout.shard_width),
        "pallas_calls": len(pallas),
        "collective_axes": sorted(axes_seen),
        "wire_bytes_per_shard_one_edge": per_shard,
        "wire_bytes_per_shard_per_round": float(
            engine.wire_bytes_per_shard(fl_cfg)),
    }


def run_pair(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    q: int = 4,
    algorithm: str = "dsgt",
    wire_dtype: Optional[str] = None,
    pod_gossip_every: int = 1,
    remat: bool = True,
    impl: str = "ref",
    pad_heads: int = 0,
    fl_engine: str = "tree",
    topk=None,
    fl_schedule: str = "sequential",
    fl_topology_program: Optional[str] = None,
    fl_node_program: Optional[str] = None,
    fl_privacy: Optional[str] = None,
    fl_scope: Optional[str] = None,
    fl_shard_model: bool = False,
) -> Dict[str, Any]:
    """Lower + compile one pair; return the dry-run record."""
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if not supports_shape(cfg, shape):
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "skipped", "reason": "whisper x long_500k: enc-dec full-attention decoder (DESIGN.md §4)",
        }
    wd = jnp.dtype(wire_dtype) if wire_dtype else None
    t0 = time.time()
    aux = None
    with mesh:
        if shape.kind == "train":
            jitted, args, cfg, aux = build_train_lowering(
                arch, shape_name, mesh, q, algorithm, wd, pod_gossip_every, impl,
                pad_heads, fl_engine, topk=topk, fl_schedule=fl_schedule,
                fl_topology_program=fl_topology_program,
                fl_node_program=fl_node_program,
                fl_privacy=fl_privacy, fl_scope=fl_scope,
                fl_shard_model=fl_shard_model,
            )
            lowered = jitted.lower(*args)
        elif shape.kind == "prefill":
            jitted, args, cfg = build_prefill_lowering(arch, shape_name, mesh)
            lowered = jitted.lower(*args)
        else:
            jitted, args, cfg = build_decode_lowering(arch, shape_name, mesh)
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns a 1-list of dicts
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    # while-aware accounting (cost_analysis counts scan bodies once)
    hlo = analyze_hlo(compiled.as_text())
    n_chips = int(np.prod(list(mesh.shape.values())))
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": shape.kind,
        "status": "ok",
        "q": q if shape.kind == "train" else None,
        "algorithm": algorithm if shape.kind == "train" else None,
        "impl": impl,
        "fl_engine": fl_engine if shape.kind == "train" else None,
        "fl_schedule": fl_schedule if shape.kind == "train" else None,
        "fl_topology_program": (
            fl_topology_program if shape.kind == "train" else None
        ),
        "fl_node_program": (
            fl_node_program if shape.kind == "train" else None
        ),
        "fl_privacy": fl_privacy if shape.kind == "train" else None,
        "fl_scope": fl_scope if shape.kind == "train" else None,
        "topk": topk if shape.kind == "train" else None,
        "wire_dtype": wire_dtype,
        "pod_gossip_every": pod_gossip_every,
        "n_chips": n_chips,
        "n_nodes": n_fl_nodes(mesh),
        "flops": float(hlo.flops),
        "traffic_bytes": float(hlo.traffic_bytes),
        "raw_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": {
            "per_kind": hlo.collectives,
            "total_bytes": float(hlo.collective_bytes),
            "cross_node_bytes": float(hlo.cross_node_bytes),
            "cross_pod_bytes": float(hlo.cross_pod_bytes),
        },
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        },
        "model_params": cfg.param_count() if cfg.family != "mlp" else None,
        "active_params": cfg.active_param_count() if cfg.family != "mlp" else None,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    if fl_shard_model and aux is not None:
        with mesh:
            record["two_axis"] = two_axis_record(
                aux["engine"], aux["round_fn"], args[0], args[1],
                aux["fl_cfg"])
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--algorithm", default="dsgt", choices=("dsgd", "dsgt"))
    ap.add_argument("--wire-dtype", default=None)
    ap.add_argument("--pod-gossip-every", type=int, default=1)
    ap.add_argument("--impl", default="ref", choices=("ref", "blocked"))
    ap.add_argument("--fl-engine", default="tree", choices=engine_names(),
                    help="round engine, resolved through the GossipEngine "
                         "registry (repro.core.engine; see "
                         "docs/ARCHITECTURE.md)")
    ap.add_argument("--topk", type=int, default=None,
                    help="fused engines: ship only the k largest payload "
                         "columns per scale chunk (compact sparse wire on "
                         "the sharded engine)")
    ap.add_argument("--fl-schedule", default="sequential",
                    help="round time layout, resolved through the "
                         f"RoundSchedule registry ({', '.join(schedule_names())}): "
                         "pipelined overlaps the collective with the next "
                         "round's local steps; spec syntax "
                         "'bounded_staleness:k=3' keeps k payloads in "
                         "flight (fused engines only)")
    ap.add_argument("--fl-topology-program", default=None,
                    help="per-round graph dynamics, resolved through the "
                         "TopologyProgram registry "
                         f"({', '.join(program_names())}); spec syntax "
                         "name:k=v,... e.g. "
                         "'node_churn:p_down=0.2,mean_downtime=5' -- "
                         "fused engines take any W, the sharded engine "
                         "gates its circulant ppermute wire")
    ap.add_argument("--fl-node-program", default=None,
                    help="per-node heterogeneity, resolved through the "
                         "NodeProgram registry (repro.core.heterogeneity); "
                         "spec syntax name:k=v,... e.g. "
                         "'stragglers:frac=0.25,rate=0.5' -- compute and "
                         "payload gates are traced operands of the one "
                         "compiled round")
    ap.add_argument("--fl-privacy", default=None,
                    help="wire privacy epilogue (repro.core.privacy); "
                         "'+'-separated spec e.g. "
                         "'secure_agg+dp:sigma=0.5,clip=1.0' -- pads and "
                         "noise ride comm-state counters, so the lowering "
                         "keeps the plaintext wire's collective count and "
                         "operand bytes")
    ap.add_argument("--fl-scope", default=None,
                    help="federation scope (repro.core.scope): which "
                         "flat-buffer columns gossip touches -- 'full', "
                         "'backbone[:private=PAT]', 'ranges:a-b,...', "
                         "'layerwise:freq=R' (fused only); partial scopes "
                         "shrink every collective operand to the shared "
                         "slice (asserted on the jaxpr)")
    ap.add_argument("--fl-shard-model", action="store_true",
                    help="two-axis (gossip_node, model_shard) round: each "
                         "node's flat parameter buffer tiles over the mesh's "
                         "'model' axis; the wire stage runs one Pallas pass "
                         "per (node, shard) tile and the gossip collective "
                         "stays node-axis-only (sharded_fused engine only; "
                         "jaxpr-asserted, recorded under 'two_axis')")
    ap.add_argument("--pad-heads", type=int, default=0,
                    help="pad q heads to a multiple of this (16 = TP degree)")
    ap.add_argument("--out", default=None, help="directory for the JSON record")
    args = ap.parse_args()

    rec = run_pair(
        args.arch, args.shape, args.mesh, q=args.q, algorithm=args.algorithm,
        wire_dtype=args.wire_dtype, pod_gossip_every=args.pod_gossip_every,
        impl=args.impl, pad_heads=args.pad_heads, fl_engine=args.fl_engine,
        topk=args.topk, fl_schedule=args.fl_schedule,
        fl_topology_program=args.fl_topology_program,
        fl_node_program=args.fl_node_program,
        fl_privacy=args.fl_privacy,
        fl_scope=args.fl_scope,
        fl_shard_model=args.fl_shard_model,
    )
    print(json.dumps(rec, indent=2))
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        suffix = ""
        if args.impl != "ref":
            suffix += f"_{args.impl}"
        if args.fl_engine != "tree":
            suffix += f"_{args.fl_engine}"
        if args.topk:
            suffix += f"_topk{args.topk}"
        if args.fl_shard_model:
            suffix += "_shardmodel"
        if args.fl_schedule != "sequential":
            suffix += "_" + args.fl_schedule.replace(":", "-").replace("=", "")
        if args.fl_topology_program:
            suffix += "_" + args.fl_topology_program.split(":")[0]
        if args.fl_node_program:
            suffix += "_" + args.fl_node_program.split(":")[0]
        if args.fl_privacy:
            suffix += "_" + args.fl_privacy.split(":")[0].replace("+", "-")
        if args.fl_scope:
            suffix += "_scope-" + args.fl_scope.split(":")[0]
        if args.pad_heads:
            suffix += f"_hpad{args.pad_heads}"
        if args.wire_dtype:
            suffix += f"_wire-{args.wire_dtype}"
        if args.pod_gossip_every > 1:
            suffix += f"_podq{args.pod_gossip_every}"
        if args.q != 4 and args.shape in ("train_4k",):
            suffix += f"_q{args.q}"
        if args.algorithm != "dsgt":
            suffix += f"_{args.algorithm}"
        fname = f"{args.arch}_{args.shape}_{args.mesh}{suffix}.json"
        with open(os.path.join(args.out, fname), "w") as f:
            json.dump(rec, f, indent=2)


if __name__ == "__main__":
    main()
