"""Serving launcher: batched generation from a (smoke) model or checkpoint.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --batch 4 --prompt-len 16 --max-new 32 --temperature 0.7
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_fn(jax.random.key(args.seed))
    engine = ServeEngine(bundle, params, max_seq=args.max_seq, batch=args.batch)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)).astype(np.int32)
    frames = None
    if cfg.family == "audio":
        frames = rng.normal(size=(args.batch, cfg.encoder.seq_len, cfg.encoder.d_model)).astype(np.float32)

    t0 = time.time()
    out = engine.generate(
        prompts, max_new_tokens=args.max_new, temperature=args.temperature,
        seed=args.seed, frames=frames,
    )
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name,
        "batch": args.batch,
        "steps": out.steps,
        "tokens_generated": int(args.batch * args.max_new),
        "wall_s": round(dt, 2),
        "tok_per_s": round(args.batch * args.max_new / dt, 1),
        "sample_continuation": out.tokens[0, args.prompt_len:args.prompt_len + 16].tolist(),
    }, indent=2))


if __name__ == "__main__":
    main()
