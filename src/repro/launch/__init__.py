"""Launchers: production meshes, multi-pod dry-run, train/serve CLIs.

NOTE: import repro.launch.dryrun only as __main__ (it forces 512 host
devices before jax init). mesh/hlo_analysis are import-safe.
"""
