"""While-aware accounting over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
step built on ``lax.scan`` (layer stacks, Q local steps) under-reports
FLOPs/bytes by the trip count, and collective bytes are not reported at
all. This module parses the compiled HLO text into its computation graph
and aggregates, multiplying loop bodies by their ``known_trip_count``.
When the annotation is absent (older XLA, or a ``while`` whose bound the
trip-count pass did not stamp), the multiplier is recovered from the
loop-condition computation itself: a ``lax.scan``/``fori_loop`` lowers
to the canonical ``counter < N`` compare against an integer constant,
and that ``N`` is the trip count (counters start at 0). Without this
fallback, every un-annotated scanned body silently counted ONCE -- the
exact under-reporting this module exists to fix:

  * ``flops``        -- 2*M*N*K per dot (shapes resolved through a
                        per-computation symbol table) + 1 flop/output
                        element per fusion (elementwise estimate, matters
                        for the SSM recurrences);
  * ``traffic_bytes``-- HBM traffic proxy: operand+result bytes of every
                        top-level fusion/dot/collective (post-fusion HLO,
                        so fused elementwise chains count once);
  * ``collectives``  -- per-kind {count, bytes} with bytes = the largest
                        shape on the instruction (all-gather: output;
                        reduce-scatter: input), x trip multipliers.

Validated in tests against an UNROLLED lowering of the same program
(tests/test_hlo_analysis.py): unrolled cost_analysis flops == scanned
flops from this module within the elementwise estimate's tolerance.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCosts"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)

# one shape token: f32[1,2,3]{...}  (layout suffix optional)
_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# instruction: %name = <shape-or-tuple> opcode(...)
_INSTR = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[\'"]?\s*:\s*\{\s*[\'"]n[\'"]\s*:\s*[\'"]?(\d+)')
_OPERAND = re.compile(r"%([\w.\-]+)")
_DIRECTION = re.compile(r"direction=(\w+)")
_CONST_INT = re.compile(r"constant\((-?\d+)\)")


def _shape_elems_bytes(dtype: str, dims: str) -> Tuple[int, int]:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dtype, 0)


def _first_shapes(text: str) -> List[Tuple[str, str]]:
    return _SHAPE_TOKEN.findall(text)


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, str]]  # [(dtype, dims)]
    operands: List[str]
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: List[_Instr]


@dataclasses.dataclass
class HloCosts:
    flops: float
    traffic_bytes: float
    collective_bytes: float
    collectives: Dict[str, Dict[str, float]]
    cross_node_bytes: float = 0.0  # collectives crossing the model-axis block
    cross_pod_bytes: float = 0.0  # collectives crossing pod blocks (DCI)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


_GROUPS_EXPLICIT = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)
_PAIRS = re.compile(r"source_target_pairs=\{\{(\d+),(\d+)\}")


def _comm_level(line: str, block: int, pod_block: int) -> int:
    """0 = stays within a tensor-parallel group (contiguous ``block`` ids);
    1 = crosses nodes within a pod; 2 = crosses pods (``pod_block`` ids).

    Device ids are row-major over (pod, data, model)."""

    def level(ids) -> int:
        if len({i // pod_block for i in ids}) > 1:
            return 2
        if len({i // block for i in ids}) > 1:
            return 1
        return 0

    m = _PAIRS.search(line)
    if m:
        return level([int(m.group(1)), int(m.group(2))])
    m = _GROUPS_EXPLICIT.search(line)
    if m:
        ids = [int(v) for v in m.group(1).replace(" ", "").split(",") if v]
        return level(ids)
    m = _GROUPS_IOTA.search(line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(v) for v in m.group(3).split(",")]
        perm = [int(v) for v in m.group(4).split(",")] if m.group(4) else None
        try:
            import numpy as _np

            order = _np.arange(int(_np.prod(dims))).reshape(dims)
            if perm is not None:
                order = order.transpose(perm)
            first = order.reshape(-1)[:group_size]
            return level([int(i) for i in first])
        except Exception:
            return 2
    return 2  # unknown format: assume the expensive case


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "iota", "after-all", "partition-id", "replica-id",
}


def _parse_computations(hlo: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            # computation header: `%name (args) -> type {` or `ENTRY %name ...{`
            if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
                m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
                if m:
                    cur = _Computation(m.group(1), [])
            continue
        if stripped == "}" or stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        rest = m.group(3)
        # opcode = first word after the shape spec: `<shape> opcode(...)`.
        # tuple types may contain `/*index=N*/` comments but never parens.
        op_m = re.match(
            r"(?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\(", rest
        )
        opcode = op_m.group(1) if op_m else ""
        # result shapes: tokens before the opcode
        head = rest.split("(", 1)[0] if "(" in rest else rest
        result_shapes = _first_shapes(head)
        paren = rest[rest.find("(") :] if "(" in rest else ""
        operand_names = _OPERAND.findall(paren.split(")", 1)[0]) if paren else []
        cur.instrs.append(_Instr(m.group(2), opcode, result_shapes, operand_names, stripped))
    return comps


def _dot_flops(instr: _Instr, symbols: Dict[str, List[Tuple[str, str]]]) -> float:
    """2 * result_elems * K. K from lhs shape + lhs_contracting_dims."""
    res_elems = sum(_shape_elems_bytes(d, s)[0] for d, s in instr.result_shapes)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    if not mc or not instr.operands:
        return 2.0 * res_elems  # fallback
    lhs_shapes = symbols.get(instr.operands[0])
    if not lhs_shapes:
        return 2.0 * res_elems
    dims = lhs_shapes[0][1].split(",") if lhs_shapes[0][1] else []
    k = 1
    for ax in (int(a) for a in mc.group(1).split(",") if a):
        if ax < len(dims):
            k *= int(dims[ax])
    return 2.0 * res_elems * k


class _Analyzer:
    def __init__(self, comps: Dict[str, _Computation], model_block: int = 16,
                 pod_block: int = 256):
        self.comps = comps
        self.block = model_block
        self.pod_block = pod_block
        self._memo: Dict[str, HloCosts] = {}

    def cost(self, comp_name: str) -> HloCosts:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return HloCosts(0.0, 0.0, 0.0, {})
        symbols = {i.name: i.result_shapes for i in comp.instrs}
        flops = 0.0
        traffic = 0.0
        coll_bytes = 0.0
        cross_bytes = 0.0
        pod_bytes = 0.0
        coll: Dict[str, Dict[str, float]] = {}
        for instr in comp.instrs:
            op = instr.opcode
            base = op.replace("-start", "")
            if base in _COLLECTIVE_KINDS:
                toks = _first_shapes(instr.line)
                size = max((_shape_elems_bytes(d, s)[1] for d, s in toks), default=0)
                lvl = _comm_level(instr.line, self.block, self.pod_block)
                d = coll.setdefault(base, {"count": 0.0, "bytes": 0.0, "cross_bytes": 0.0})
                d["count"] += 1
                d["bytes"] += size
                if lvl >= 1:
                    d["cross_bytes"] += size
                    cross_bytes += size
                if lvl >= 2:
                    pod_bytes += size
                coll_bytes += size
                traffic += size
                continue
            if op == "while":
                body_m = _CALLED.search(instr.line)
                cond_m = _COND.search(instr.line)
                trips = None
                tm = _TRIP.search(instr.line)
                if tm:
                    trips = int(tm.group(1))
                elif cond_m:
                    # no known_trip_count annotation: recover the trip
                    # count from the canonical `counter < N` condition,
                    # else scanned bodies would count ONCE.
                    trips = self._infer_trips(cond_m.group(1))
                known = trips is not None
                if trips is None:
                    trips = 1
                if body_m:
                    sub = self.cost(body_m.group(1))
                    flops += trips * sub.flops
                    traffic += trips * sub.traffic_bytes
                    coll_bytes += trips * sub.collective_bytes
                    cross_bytes += trips * sub.cross_node_bytes
                    pod_bytes += trips * sub.cross_pod_bytes
                    _merge(coll, sub.collectives, trips)
                if cond_m:
                    sub = self.cost(cond_m.group(1))
                    # the condition runs once more than the body
                    flops += ((trips + 1) if known else 1) * sub.flops
                continue
            if op in ("call", "conditional", "async-start"):
                cm = _CALLED.search(instr.line)
                if cm:
                    sub = self.cost(cm.group(1))
                    flops += sub.flops
                    traffic += sub.traffic_bytes
                    coll_bytes += sub.collective_bytes
                    cross_bytes += sub.cross_node_bytes
                    pod_bytes += sub.cross_pod_bytes
                    _merge(coll, sub.collectives, 1)
                continue
            if op == "dot":
                flops += _dot_flops(instr, symbols)
                traffic += _io_bytes(instr, symbols)
                continue
            if op == "fusion":
                cm = _CALLED.search(instr.line)
                if cm:
                    sub = self.cost(cm.group(1))
                    flops += sub.flops  # dots nested inside fusions
                    coll_bytes += sub.collective_bytes
                    cross_bytes += sub.cross_node_bytes
                    pod_bytes += sub.cross_pod_bytes
                    _merge(coll, sub.collectives, 1)
                # elementwise estimate: 1 flop per output element
                flops += sum(_shape_elems_bytes(d, s)[0] for d, s in instr.result_shapes)
                traffic += _io_bytes(instr, symbols)
                continue
            if op in _SKIP_BYTES_OPS or not op:
                continue
            # other real ops (dynamic-slice, scatter, convert at top level...)
            traffic += _io_bytes(instr, symbols)
        out = HloCosts(flops, traffic, coll_bytes, coll, cross_bytes, pod_bytes)
        self._memo[comp_name] = out
        return out

    def _infer_trips(self, cond_name: str) -> Optional[int]:
        """Trip count from a scan-style loop condition: ``counter < N``.

        ``lax.scan`` / ``fori_loop`` lower to a while whose condition is
        a single ``compare`` of a tuple-carried s32 counter (init 0,
        step 1) against an integer constant bound, ``direction=LT`` (or
        the mirrored constant-first ``GT``). Returns that bound, or
        ``None`` when the condition is anything else (dynamic bound,
        non-unit stride -- caller falls back to counting the body once).
        """
        comp = self.comps.get(cond_name)
        if comp is None:
            return None
        by_name = {i.name: i for i in comp.instrs}
        compares = [i for i in comp.instrs if i.opcode == "compare"]
        if len(compares) != 1:
            return None
        cmp_i = compares[0]
        dm = _DIRECTION.search(cmp_i.line)
        if dm is None or dm.group(1) not in ("LT", "GT"):
            return None
        consts = []
        for opn in cmp_i.operands:
            src = by_name.get(opn)
            if src is not None and src.opcode == "constant":
                cm = _CONST_INT.search(src.line)
                if cm:
                    consts.append(int(cm.group(1)))
        if len(consts) != 1:  # need exactly one constant side
            return None
        bound = consts[0]
        return bound if bound > 0 else None


def _io_bytes(instr: _Instr, symbols: Dict[str, List[Tuple[str, str]]]) -> float:
    total = sum(_shape_elems_bytes(d, s)[1] for d, s in instr.result_shapes)
    for op in instr.operands:
        shapes = symbols.get(op)
        if shapes:
            total += sum(_shape_elems_bytes(d, s)[1] for d, s in shapes)
    return float(total)


def _merge(dst: Dict[str, Dict[str, float]], src: Dict[str, Dict[str, float]], mult: int) -> None:
    for k, v in src.items():
        d = dst.setdefault(k, {"count": 0.0, "bytes": 0.0, "cross_bytes": 0.0})
        d["count"] += mult * v["count"]
        d["bytes"] += mult * v["bytes"]
        d["cross_bytes"] += mult * v.get("cross_bytes", 0.0)


def analyze_hlo(hlo_text: str, entry: Optional[str] = None, model_block: int = 16,
                pod_block: int = 256) -> HloCosts:
    comps = _parse_computations(hlo_text)
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
        entry = m.group(1) if m else next(iter(comps))
    return _Analyzer(comps, model_block, pod_block).cost(entry)
