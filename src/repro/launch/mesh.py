"""Production meshes + FL node-axis helpers.

TPU v5e target: 256 chips/pod. Single-pod mesh (16, 16) over
("data", "model"): 16 FL nodes x 16-way tensor parallel. Multi-pod
(2, 16, 16) over ("pod", "data", "model"): 32 FL nodes on a 2 x 16 node
torus whose inter-pod edges ride DCI.

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any initialization).
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType

    def _axis_kw(n: int):
        return {"axis_types": (AxisType.Auto,) * n}

except ImportError:  # jax 0.4.x: meshes are Auto-typed implicitly

    def _axis_kw(n: int):
        return {}

__all__ = [
    "make_production_mesh",
    "make_test_mesh",
    "node_axes",
    "model_axis",
    "n_fl_nodes",
    "n_model_shards",
    "HW",
]


# TPU v5e hardware constants (per chip) used by the roofline analysis
HW = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw": 50e9,  # B/s per link (intra-pod)
    "dci_bw": 9e9,  # B/s per link (inter-pod; hierarchical-gossip motivation)
}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_test_mesh(shape: Tuple[int, ...] = (2, 2, 2)) -> Mesh:
    """Small mesh for CPU tests (requires XLA host-device override)."""
    axes = ("pod", "data", "model")[-len(shape) :] if len(shape) < 3 else ("pod", "data", "model")
    if len(shape) == 2:
        axes = ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(shape)))


def node_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes enumerating FL nodes (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def model_axis(mesh: Mesh):
    """The tensor/FSDP shard axis name, or None on node-only meshes.
    Engines that accept ``model_axis=`` shard each node's flat parameter
    buffer across it (the two-axis ``(gossip_node, model_shard)`` round:
    gossip collectives stay on the node axes; the model axis only tiles
    the columns)."""
    return "model" if "model" in mesh.axis_names else None


def n_fl_nodes(mesh: Mesh) -> int:
    n = 1
    for a in node_axes(mesh):
        n *= mesh.shape[a]
    return n


def n_model_shards(mesh: Mesh) -> int:
    """Size of the 'model' axis (1 when the mesh has none)."""
    return int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
