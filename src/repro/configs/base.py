"""Model / run configuration dataclasses.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact published shape) and ``smoke_config()`` (a reduced
variant: <=2 layers, d_model<=512, <=4 experts) used by the CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "EncoderConfig", "FLRunConfig"]

VOCAB_PAD = 256  # pad vocab to a multiple of this (standard TP practice)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder--decoder (whisper) architectures."""

    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    seq_len: int  # fixed encoder positions (whisper: 1500 frames)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int  # query heads (attention blocks); 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    shared_expert: bool = False  # llama4-style always-on expert
    router_aux_coef: float = 0.01  # load-balance auxiliary loss

    # SSM / hybrid
    block_pattern: Tuple[str, ...] = ()  # e.g. ("recurrent","recurrent","attention")
    rnn_width: int = 0  # RG-LRU recurrence width (0 => d_model)
    conv_width: int = 4  # temporal conv width in recurrent blocks
    window: int = 0  # local/sliding attention window (0 = full causal)

    # modality frontend (STUB per task spec: embeddings come from input_specs)
    frontend: str = "none"  # none | vision_stub | audio_stub
    frontend_seq: int = 0  # number of frontend tokens (patches / frames)
    encoder: Optional[EncoderConfig] = None  # whisper enc-dec

    # tensor-parallel head padding: pad q heads up to a multiple of this
    # (0 = off). Padded heads are zero-init + statically masked -> exact
    # logical-head semantics; avoids GSPMD re-sharding all-reduces of the
    # score tensors when the TP degree does not divide n_heads (§Perf).
    tp_head_pad: int = 0

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # provenance
    source: str = ""  # citation (arXiv / model card), from the assignment

    def __post_init__(self) -> None:
        if self.family not in ("dense", "moe", "ssm", "hybrid", "vlm", "audio", "mlp"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.n_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads (GQA)")
        if self.family == "moe" and (self.n_experts < 2 or self.experts_per_token < 1):
            raise ValueError("moe family needs n_experts>=2 and experts_per_token>=1")

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD

    @property
    def effective_pattern(self) -> Tuple[str, ...]:
        """Per-layer block types of length n_layers."""
        if not self.block_pattern:
            default = {
                "dense": "attention",
                "vlm": "attention",
                "audio": "attention",
                "moe": "moe",
                "ssm": "rwkv",
                "hybrid": "recurrent",
            }[self.family]
            return (default,) * self.n_layers
        reps = -(-self.n_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.n_layers]

    @property
    def is_homogeneous(self) -> bool:
        pat = self.effective_pattern
        return all(p == pat[0] for p in pat)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head), used for
        MODEL_FLOPS = 6*N*D in the roofline and sanity-checked in tests."""
        d, v = self.d_model, self.padded_vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # output head
        total += d  # final norm
        hd = self.head_dim
        for kind in self.effective_pattern:
            total += d  # pre-norm scale
            if kind in ("attention", "local_attention"):
                qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                if self.qkv_bias:
                    qkv += (self.n_heads + 2 * self.n_kv_heads) * hd
                total += qkv + (self.n_heads * hd) * d
                total += d + 3 * d * self.d_ff  # mlp norm + swiglu
            elif kind == "moe":
                qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                if self.qkv_bias:
                    qkv += (self.n_heads + 2 * self.n_kv_heads) * hd
                total += qkv + (self.n_heads * hd) * d
                total += d + d * self.n_experts  # mlp norm + router
                total += self.n_experts * 3 * d * self.d_ff
                if self.shared_expert:
                    total += 3 * d * self.d_ff
            elif kind == "rwkv":
                n_h = d // 64
                # r,k,v,g,o projections + data-dependent decay lora + ffn
                total += 5 * d * d + 2 * (d * 64 + 64 * d) + n_h * 64
                total += d + 2 * d * self.d_ff  # rwkv channel-mix (k,v)
            elif kind == "recurrent":
                w = self.rnn_width or d
                total += d * w * 2 + w * self.conv_width + w * 2  # in-proj x2, conv, gates' lora approx
                total += 2 * w * w // 8  # gate projections (block-diagonal, 8 blocks)
                total += w * d  # out proj
                total += d + 3 * d * self.d_ff
            else:
                raise ValueError(f"unknown block kind {kind}")
        if self.encoder is not None:
            e = self.encoder
            total += e.n_layers * (2 * e.d_model + 4 * e.d_model * e.d_model + 2 * e.d_model * e.d_ff)
            total += e.seq_len * e.d_model  # learned positions
            # decoder cross-attention (added per decoder layer)
            total += self.n_layers * (d + 4 * d * d)
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        inactive = (self.n_experts - self.experts_per_token) * 3 * d * self.d_ff
        return int(self.param_count() - len(self.effective_pattern) * inactive)


@dataclasses.dataclass(frozen=True)
class FLRunConfig:
    """One decentralized-FL training run (paper Algorithm 1 hyperparams)."""

    algorithm: str = "dsgt"  # dsgd | dsgt
    q: int = 1  # local steps per comm round (paper: 100)
    topology: str = "ring"  # ring | torus | complete | star | hospital20 | mesh
    n_nodes: int = 16
    batch_per_node: int = 16  # m in the paper (samples per local step)
    alpha0: float = 0.02  # paper: alpha^r = 0.02/sqrt(r)
    schedule: str = "inv_sqrt"  # inv_sqrt | constant | theorem1
    seed: int = 0
    wire_dtype: Optional[str] = None  # e.g. "bfloat16" for the bf16-wire opt
    pod_gossip_every: int = 1  # hierarchical gossip cadence (multi-pod)
