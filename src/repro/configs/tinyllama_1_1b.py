"""TinyLlama-1.1B [dense] — 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000 — llama2-arch small [arXiv:2401.02385]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    head_dim=64,
    window=4096,
    source="arXiv:2401.02385",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b-smoke",
        family="dense",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        window=64,
        source="arXiv:2401.02385",
    )
