"""The paper's own experimental model: shallow NN over 42 EHR features,
20 hospitals, AD vs MCI classification (Section 3).

The cohort is heavily imbalanced (2,103 AD vs 7,919 MCI ~ 79% majority),
so the unweighted cross-entropy saturates balanced accuracy near 0.6:
the majority class dominates the gradient and the minority decision
boundary barely moves. ``class_weights`` is the knob: ``"balanced"``
gives the standard inverse-frequency weights ``n / (n_classes * n_c)``
(mean 1 over samples, so the loss scale and usable alpha range are
unchanged), an explicit pair overrides them, and ``None`` recovers the
paper-faithful unweighted loss. Feed the result to
``models.mlp.make_mlp_loss``.
"""

import numpy as np

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="ehr-mlp",
    family="mlp",
    n_layers=2,
    d_model=42,  # feature dim ("problem dimension of 42")
    n_heads=0,
    n_kv_heads=0,
    d_ff=32,  # hidden width
    vocab_size=2,  # AD vs MCI
    source="this paper, Section 3",
)

# default for the EHR experiments; None = the paper's unweighted loss
CLASS_WEIGHT = "balanced"

# Adaptive top-k wire (the error-triggered refresh of the ROADMAP):
# (k_sparse, k_dense, densify_high[, resparsify_low]). Rounds ship the
# sparse k until the EF-residual RMS -- the mass the wire is deferring --
# crosses densify_high, then densify to k_dense until it drains BELOW
# resparsify_low (default densify_high / 2). The two-threshold
# hysteresis band keeps k from duty-cycling around a single line
# (training.trainer.AdaptiveTopK). k_dense >= scale_chunk means
# "temporarily dense int8". Calibrated on the 20-hospital cohort: the
# first rounds (recon cold, payload = full params) sit well above the
# high threshold, steady-state EF residuals well below the low one, so
# both wire widths are exercised in the e2e run.
TOPK_SCHEDULE = (64, 512, 3e-3)


def topk_schedule(spec=TOPK_SCHEDULE):
    """Validate an adaptive-k spec to (k_sparse, k_dense, high[, low]),
    or pass None through (fixed-k wire). Feed the result to
    ``training.trainer.train_decentralized(topk_schedule=...)``."""
    if spec is None:
        return None
    if len(spec) not in (3, 4):
        raise ValueError(
            f"topk_schedule needs (k_sparse, k_dense, high[, low]), got "
            f"{spec!r}"
        )
    k_sparse, k_dense = int(spec[0]), int(spec[1])
    thresholds = tuple(float(v) for v in spec[2:])
    low = thresholds[1] if len(thresholds) == 2 else thresholds[0] / 2.0
    if (not (1 <= k_sparse <= k_dense) or thresholds[0] <= 0
            or not (0 < low <= thresholds[0])):
        raise ValueError(
            f"topk_schedule needs 1 <= k_sparse <= k_dense and a "
            f"positive densify_high >= resparsify_low > 0, got {spec!r}"
        )
    return (k_sparse, k_dense) + thresholds


def class_weights(class_weight=CLASS_WEIGHT):
    """Resolve the ``class_weight`` knob to a (2,) array or None.

    ``"balanced"`` computes inverse-frequency weights from the published
    cohort statistics (labels: 0 = MCI majority, 1 = AD minority);
    a sequence passes through; None disables weighting.
    """
    if class_weight is None:
        return None
    if class_weight == "balanced":
        from repro.data.ehr import N_AD, N_MCI

        counts = np.asarray([N_MCI, N_AD], np.float64)
        return counts.sum() / (len(counts) * counts)
    w = np.asarray(class_weight, np.float64)
    if w.shape != (2,) or (w <= 0).any():
        raise ValueError(
            f"class_weight must be 'balanced', None, or 2 positive "
            f"weights; got {class_weight!r}"
        )
    return w


def smoke_config() -> ModelConfig:
    return CONFIG  # already CPU-scale
