"""The paper's own experimental model: shallow NN over 42 EHR features,
20 hospitals, AD vs MCI classification (Section 3)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="ehr-mlp",
    family="mlp",
    n_layers=2,
    d_model=42,  # feature dim ("problem dimension of 42")
    n_heads=0,
    n_kv_heads=0,
    d_ff=32,  # hidden width
    vocab_size=2,  # AD vs MCI
    source="this paper, Section 3",
)


def smoke_config() -> ModelConfig:
    return CONFIG  # already CPU-scale
