"""Config registry: ``--arch <id>`` name -> (full CONFIG, smoke_config)."""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import EncoderConfig, FLRunConfig, ModelConfig
from repro.configs.shapes import (
    SHAPES,
    InputShape,
    decode_sliding_override,
    serve_input_specs,
    supports_shape,
    train_input_specs,
)

# arch id -> module name
ARCH_MODULES: Dict[str, str] = {
    "phi3-medium-14b": "phi3_medium_14b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-26b": "internvl2_26b",
    "smollm-360m": "smollm_360m",
    "rwkv6-7b": "rwkv6_7b",
    "qwen2.5-32b": "qwen2_5_32b",
    "dbrx-132b": "dbrx_132b",
    "whisper-medium": "whisper_medium",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "ehr-mlp": "ehr_mlp",
}

ASSIGNED_ARCHS = tuple(a for a in ARCH_MODULES if a != "ehr-mlp")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.smoke_config() if smoke else mod.CONFIG


__all__ = [
    "ARCH_MODULES",
    "ASSIGNED_ARCHS",
    "EncoderConfig",
    "FLRunConfig",
    "InputShape",
    "ModelConfig",
    "SHAPES",
    "decode_sliding_override",
    "get_config",
    "serve_input_specs",
    "supports_shape",
    "train_input_specs",
]
