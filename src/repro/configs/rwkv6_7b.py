"""RWKV-6 "Finch" 7B [ssm] — 32L d_model=4096 (attention-free)
d_ff=14336 vocab=65536 — data-dependent decay WKV [arXiv:2404.05892]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,  # attention-free; WKV heads = d_model/64 = 64
    n_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    source="arXiv:2404.05892",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-smoke",
        family="ssm",
        n_layers=2,
        d_model=256,
        n_heads=0,
        n_kv_heads=0,
        d_ff=512,
        vocab_size=512,
        source="arXiv:2404.05892",
    )
