"""The four assigned input shapes + ShapeDtypeStruct input builders.

  train_4k     seq_len=  4,096  global_batch=256   (training, train_step)
  prefill_32k  seq_len= 32,768  global_batch= 32   (inference prefill)
  decode_32k   seq_len= 32,768  global_batch=128   (decode: 1 token vs cache)
  long_500k    seq_len=524,288  global_batch=  1   (long-context decode)

``input_specs`` returns abstract ShapeDtypeStructs (never allocates), the
same stand-in pattern the dry-run lowers with. Training batches follow the
FL layout: every leaf is (Q, nodes, per_node_batch, ...) -- Q microbatches
per communication round, node axis sharded over (pod, data).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any

__all__ = ["InputShape", "SHAPES", "train_input_specs", "serve_input_specs", "decode_sliding_override"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def _frontend_specs(cfg: ModelConfig, lead: tuple) -> Dict[str, jax.ShapeDtypeStruct]:
    """Stubbed modality-frontend embeddings (per task spec)."""
    if cfg.family == "vlm":
        return {
            "prefix_embeds": jax.ShapeDtypeStruct(
                lead + (cfg.frontend_seq, cfg.d_model), jnp.float32
            )
        }
    if cfg.family == "audio":
        e = cfg.encoder
        return {"frames": jax.ShapeDtypeStruct(lead + (e.seq_len, e.d_model), jnp.float32)}
    return {}


def train_input_specs(
    cfg: ModelConfig, shape: InputShape, n_nodes: int, q: int
) -> Dict[str, jax.ShapeDtypeStruct]:
    """FL round batch: (Q, nodes, per_node_batch, ...)."""
    if shape.global_batch % n_nodes:
        raise ValueError(f"global_batch {shape.global_batch} % nodes {n_nodes} != 0")
    pnb = shape.global_batch // n_nodes
    lead = (q, n_nodes, pnb)
    text_len = shape.seq_len
    if cfg.family == "vlm":
        text_len = shape.seq_len - cfg.frontend_seq  # image patches + text = seq
    specs = {"tokens": jax.ShapeDtypeStruct(lead + (text_len + 1,), jnp.int32)}
    specs.update(_frontend_specs(cfg, lead))
    return specs


def serve_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Prefill request batch (decode state specs come from the bundle)."""
    b = shape.global_batch
    text_len = shape.seq_len
    if cfg.family == "vlm":
        text_len = shape.seq_len - cfg.frontend_seq
    if cfg.family == "audio":
        text_len = min(text_len, 448)  # whisper prefill prompt is short
    specs = {"tokens": jax.ShapeDtypeStruct((b, text_len), jnp.int32)}
    specs.update(_frontend_specs(cfg, (b,)))
    return specs


def decode_sliding_override(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k policy (DESIGN.md §4): dense/full-attention archs decode
    with the sliding-window ring-buffer cache; SSM/hybrid run natively."""
    if shape.name != "long_500k":
        return False
    return cfg.family in ("dense", "moe", "vlm")


def supports_shape(cfg: ModelConfig, shape: InputShape) -> bool:
    """whisper x long_500k is the single documented skip (DESIGN.md §4)."""
    if cfg.family == "audio" and shape.name == "long_500k":
        return False
    return True
