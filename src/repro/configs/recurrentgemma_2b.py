"""RecurrentGemma-2B [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, pattern 1 attn : 2 recurrent
[arXiv:2402.19427]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("recurrent", "recurrent", "local_attention"),
    rnn_width=2560,
    conv_width=4,
    window=2048,
    source="arXiv:2402.19427",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke",
        family="hybrid",
        n_layers=2,  # one recurrent + ... pattern truncated to 2 layers
        d_model=256,
        n_heads=4,
        n_kv_heads=1,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        block_pattern=("recurrent", "local_attention"),
        rnn_width=256,
        conv_width=4,
        window=64,
        source="arXiv:2402.19427",
    )
