"""SmolLM-360M [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    window=4096,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-smoke",
        family="dense",
        n_layers=2,
        d_model=192,
        n_heads=3,
        n_kv_heads=1,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        window=64,
        tie_embeddings=True,
        source="hf:HuggingFaceTB/SmolLM-135M",
    )
