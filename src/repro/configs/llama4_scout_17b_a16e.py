"""Llama-4-Scout-17B-16E [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192,
MoE 16 experts top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    n_experts=16,
    experts_per_token=1,
    shared_expert=True,
    rope_theta=500000.0,
    window=4096,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke",
        family="moe",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        n_experts=4,
        experts_per_token=1,
        shared_expert=True,
        window=64,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
