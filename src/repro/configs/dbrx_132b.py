"""DBRX-132B [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, 16 experts top-4 (fine-grained) [hf:databricks/dbrx-base]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    n_experts=16,
    experts_per_token=4,
    window=4096,
    source="hf:databricks/dbrx-base",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke",
        family="moe",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        n_experts=4,
        experts_per_token=2,
        window=64,
        source="hf:databricks/dbrx-base",
    )
