"""InternVL2-26B [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT (STUB frontend) + InternLM2-20B language backbone
[arXiv:2404.16821]. input_specs() supplies 1024 patch embeddings."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    window=4096,
    frontend="vision_stub",
    frontend_seq=1024,
    source="arXiv:2404.16821",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke",
        family="vlm",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        window=64,
        frontend="vision_stub",
        frontend_seq=16,
        source="arXiv:2404.16821",
    )
