"""Qwen2.5-32B [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1000000.0,
    window=4096,
    source="hf:Qwen/Qwen2.5-0.5B",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b-smoke",
        family="dense",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        qkv_bias=True,
        window=64,
        source="hf:Qwen/Qwen2.5-0.5B",
    )
