"""Phi-3-medium-14B [dense] — 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE + SwiGLU + GQA [arXiv:2404.14219]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    head_dim=128,
    rope_theta=10000.0,
    window=4096,  # used only by the long_500k sliding-window decode policy
    source="arXiv:2404.14219",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b-smoke",
        family="dense",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        window=64,
        source="arXiv:2404.14219",
    )
