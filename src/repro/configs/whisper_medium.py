"""Whisper-medium [audio] — enc-dec, 24L decoder d_model=1024 16H d_ff=4096
vocab=51865; 24L encoder over 1500 stubbed conv-frontend frames
[arXiv:2212.04356]."""

from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    frontend="audio_stub",
    encoder=EncoderConfig(n_layers=24, d_model=1024, n_heads=16, d_ff=4096, seq_len=1500),
    source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke",
        family="audio",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        frontend="audio_stub",
        encoder=EncoderConfig(n_layers=2, d_model=256, n_heads=4, d_ff=512, seq_len=32),
        source="arXiv:2212.04356",
    )
