"""Checkpointing: FLState <-> sharded .npz + JSON manifest.

Pure numpy/JSON (no orbax dependency): leaves are flattened by tree path,
saved in one compressed npz per call, with a manifest recording step,
engine, and tree structure for restore-time validation. Restoring
requires a template state (from ``init_fl_state``) whose structure must
match -- shape/dtype mismatches fail loudly.

Engine awareness: ``save_fl_state(..., engine=...)`` records the engine's
registry name in the manifest; ``load_fl_state`` validates a recorded
name against the GossipEngine registry (catching checkpoints written by
a renamed/removed engine before shape errors obscure the cause) and
refuses to silently drop wire state: a comm-carrying checkpoint cannot
land on a comm-less template, and a template may not discard buffers the
checkpoint saved. Restoring onto a template with MORE comm buffers than
the checkpoint saved (e.g. a fused checkpoint onto a sharded template)
requires ``engine=`` so the engine's ``restore_comm`` hook can rebuild
the DERIVED buffers consistently (the sharded engine's invariant is
``mix_recon == W_off @ recon``; zero-filling would silently corrupt the
mix). Pre-comm checkpoints (no comm saved at all) still restore onto any
template with the zero-initialized comm buffers -- self-consistent:
every node retransmits in full next round.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

from repro.core.dynamics import parse_program
from repro.core.engine import (
    GossipEngine,
    engine_names,
    get_engine,
    resolve_schedule,
    schedule_names,
)
from repro.core.heterogeneity import parse_node_program
from repro.core.fl import FLState

PyTree = Any

__all__ = ["save_fl_state", "load_fl_state", "engine_manifest"]


def engine_manifest(engine: GossipEngine) -> dict:
    """The six-axis round spec (engine x schedule x topology x node
    program x privacy x scope, plus mesh geometry) as a
    JSON-serializable dict.

    One codepath feeds BOTH durable formats: checkpoint manifests
    (``save_fl_state``) and consensus snapshot headers
    (``repro.training.snapshot.write_snapshot``), so the recorded
    round provenance can never drift between them.
    """
    manifest = {"engine": engine.name}
    # the schedule is part of the comm-state contract: a PIPELINED
    # checkpoint carries the in-flight wire_* payload buffers, and a
    # restore must rebuild mix_recon against them (engine.restore_comm)
    schedule = getattr(engine, "round_schedule", None)
    if schedule is not None:
        # spec(), not name: "bounded_staleness:k=3" carries a
        # 3-deep wire ring a k=2 restore could not consume
        manifest["round_schedule"] = schedule.spec()
    # so is the topology program: the comm counters (topo_round /
    # topo_key) only mean something under the SAME program -- the
    # recorded spec lets a mid-churn restore rebuild the engine and
    # replay the identical graph sequence
    program = getattr(engine, "topology_program", None)
    if program is not None:
        manifest["topology_program"] = program.spec()
    # and the node program: node_key (and any Markov fault state)
    # replays the identical straggler/outage sequence only under it
    node_prog = getattr(engine, "node_program", None)
    if node_prog is not None:
        manifest["node_program"] = node_prog.spec()
    # and the privacy spec: priv_key + the pad/noise round counter
    # regenerate the identical mask and noise streams only under the
    # SAME spec, and a restored run's epsilon accounting is only
    # truthful if sigma/clip/delta match what actually trained
    privacy = getattr(engine, "privacy", None)
    if privacy is not None:
        manifest["privacy"] = privacy.spec()
    # and the federation scope: the wire-state buffers are sized to the
    # SCOPED wire width and the private columns carry per-node state
    # gossip never touched -- a restore under a different scope would
    # feed shared state into columns trained private (or vice versa)
    scope = getattr(engine, "scope", None)
    if scope is not None:
        manifest["scope"] = scope.spec()
    # and the mesh: a two-axis (gossip_node, model_shard) engine pads
    # the flat layout per shard, so buffers written under one shard
    # count are not byte-compatible with another -- record the full
    # mesh geometry so restore can refuse with a migration hint
    mesh = getattr(engine, "mesh", None)
    if mesh is not None:
        layout = getattr(engine, "layout", None)
        manifest["mesh"] = {
            "axis_names": [str(a) for a in mesh.axis_names],
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
            "node_axes": [str(a) for a in
                          (getattr(engine, "node_axes", ()) or ())],
            "model_axis": getattr(engine, "model_axis", None),
            "model_shards": int(getattr(engine, "model_shards", 1)),
            "layout_shards": int(getattr(layout, "shards", 1)),
        }
    return manifest


def _flat_dict(tree: PyTree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_fl_state(path: str, state: FLState, extra: Optional[dict] = None,
                  engine: Optional[GossipEngine] = None) -> None:
    os.makedirs(path, exist_ok=True)
    arrays = {}
    manifest = {
        "step": int(state.step),
        "has_tracker": state.tracker is not None,
        "has_comm": state.comm is not None,
    }
    if engine is not None:
        manifest.update(engine_manifest(engine))
    if state.comm is not None:
        manifest["comm_keys"] = sorted(state.comm)
    if extra:
        manifest["extra"] = extra
    for name, tree in (("params", state.params), ("tracker", state.tracker),
                       ("prev_grad", state.prev_grad), ("comm", state.comm)):
        if tree is None:
            continue
        for k, v in _flat_dict(tree).items():
            arrays[f"{name}::{k}"] = v
    np.savez_compressed(os.path.join(path, "state.npz"), **arrays)
    manifest["n_arrays"] = len(arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_fl_state(path: str, template: FLState,
                  engine: Optional[GossipEngine] = None) -> FLState:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    saved_engine = manifest.get("engine")
    if saved_engine is not None:
        if saved_engine not in engine_names():
            raise ValueError(
                f"checkpoint was written by engine {saved_engine!r}, which "
                f"is not in the registry {engine_names()}"
            )
        get_engine(saved_engine)  # resolvable, not just named
    saved_schedule = manifest.get("round_schedule")
    if saved_schedule is not None:
        try:
            saved_sched = resolve_schedule(saved_schedule)
        except (ValueError, KeyError):
            raise ValueError(
                f"checkpoint was written under round schedule "
                f"{saved_schedule!r}, which no schedule in the registry "
                f"{schedule_names()} can rebuild"
            ) from None
        if engine is not None:
            eng_sched = getattr(engine, "round_schedule", None)
            if (eng_sched is not None
                    and eng_sched.depth != saved_sched.depth):
                raise ValueError(
                    f"checkpoint was written at staleness depth "
                    f"{saved_sched.depth} ({saved_schedule!r}) but the "
                    f"restore engine runs depth {eng_sched.depth} "
                    f"({eng_sched.spec()!r}); the in-flight wire ring is "
                    "part of the comm-state contract -- rebuild the "
                    f"engine with round_schedule={saved_schedule!r}"
                )
    saved_program = manifest.get("topology_program")
    if saved_program is not None:
        try:
            parse_program(saved_program)
        except ValueError as e:
            raise ValueError(
                f"checkpoint was written under topology program "
                f"{saved_program!r}, which no registered program can "
                f"rebuild: {e}"
            ) from None
        if engine is not None and saved_program != "static":
            # a STATIC checkpoint may seed a dynamic run (the program
            # starts from round 0); a DYNAMIC checkpoint's counters are
            # meaningless under any other program
            engine_program = getattr(engine, "topology_program", None)
            if (engine_program is not None
                    and engine_program.spec() != saved_program):
                raise ValueError(
                    f"checkpoint was written under topology program "
                    f"{saved_program!r} but the restore engine runs "
                    f"{engine_program.spec()!r}; the topo_round/topo_key "
                    "counters only replay the identical graph sequence "
                    "under the same program -- rebuild the engine with "
                    f"topology_program={saved_program!r}"
                )
    saved_privacy = manifest.get("privacy")
    if saved_privacy is not None:
        from repro.core.privacy import parse_privacy

        try:
            parse_privacy(saved_privacy)
        except ValueError as e:
            raise ValueError(
                f"checkpoint was written under privacy spec "
                f"{saved_privacy!r}, which cannot be rebuilt: {e}"
            ) from None
        if engine is not None and saved_privacy != "none":
            engine_privacy = getattr(engine, "privacy", None)
            if (engine_privacy is not None
                    and engine_privacy.spec() != saved_privacy):
                raise ValueError(
                    f"checkpoint was written under privacy spec "
                    f"{saved_privacy!r} but the restore engine runs "
                    f"{engine_privacy.spec()!r}; priv_key and the round "
                    "counter only regenerate the identical mask/noise "
                    "streams -- and the epsilon accounting is only "
                    "truthful -- under the same spec; rebuild the engine "
                    f"with privacy={saved_privacy!r}"
                )
    saved_scope = manifest.get("scope")
    if saved_scope is not None:
        from repro.core.scope import parse_scope

        try:
            parse_scope(saved_scope)
        except ValueError as e:
            raise ValueError(
                f"checkpoint was written under federation scope "
                f"{saved_scope!r}, which cannot be rebuilt: {e}"
            ) from None
        if engine is not None and saved_scope != "full":
            engine_scope = getattr(engine, "scope", None)
            if (engine_scope is not None
                    and engine_scope.spec() != saved_scope):
                raise ValueError(
                    f"checkpoint was written under federation scope "
                    f"{saved_scope!r} but the restore engine runs "
                    f"{engine_scope.spec()!r}; the private columns carry "
                    "per-node state gossip never touched and the wire "
                    "buffers are sized to the scoped slice -- both only "
                    "stay meaningful under the same scope; rebuild the "
                    f"engine with scope={saved_scope!r}"
                )
    saved_node = manifest.get("node_program")
    if saved_node is not None:
        try:
            parse_node_program(saved_node)
        except ValueError as e:
            raise ValueError(
                f"checkpoint was written under node program "
                f"{saved_node!r}, which no registered program can "
                f"rebuild: {e}"
            ) from None
        if engine is not None and saved_node != "homogeneous":
            engine_node = getattr(engine, "node_program", None)
            if engine_node is not None and engine_node.spec() != saved_node:
                raise ValueError(
                    f"checkpoint was written under node program "
                    f"{saved_node!r} but the restore engine runs "
                    f"{engine_node.spec()!r}; node_key only replays the "
                    "identical straggler/outage sequence under the same "
                    "program -- rebuild the engine with "
                    f"node_program={saved_node!r}"
                )
    saved_mesh = manifest.get("mesh")
    if saved_mesh is not None and engine is not None:
        eng_shards = int(getattr(engine, "model_shards", 1))
        ckpt_shards = int(saved_mesh.get("model_shards", 1))
        if eng_shards != ckpt_shards:
            raise ValueError(
                f"checkpoint was written on a mesh with "
                f"model_shards={ckpt_shards} (axes "
                f"{saved_mesh.get('axis_names')!r}, shape "
                f"{saved_mesh.get('shape')!r}, model_axis="
                f"{saved_mesh.get('model_axis')!r}) but the restore engine "
                f"runs model_shards={eng_shards}; the flat layout is padded "
                "per shard, so the saved buffers are not byte-compatible -- "
                "rebuild the engine on a mesh whose model axis has "
                f"{ckpt_shards} devices, or migrate the checkpoint by "
                "unpacking params with the saved layout and repacking with "
                f"pack(..., shards={eng_shards}) before resuming"
            )
    data = np.load(os.path.join(path, "state.npz"))
    saved_comm_keys = set(manifest.get("comm_keys") or ())
    if not saved_comm_keys:  # legacy manifest: derive from the npz contents
        saved_comm_keys = {
            k.split("::", 1)[1] for k in data.files if k.startswith("comm::")
        }
    if template.comm is None and saved_comm_keys:
        raise ValueError(
            f"checkpoint carries wire state {sorted(saved_comm_keys)} but "
            "the restore template has none; build the template with the "
            "matching engine (init_fl_state(..., engine=...))"
        )

    def restore(name: str, tree: PyTree) -> PyTree:
        if tree is None:
            return None
        flat_template = _flat_dict(tree)
        out = {}
        for k, t in flat_template.items():
            key = f"{name}::{k}"
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if arr.shape != t.shape:
                raise ValueError(f"{key}: shape {arr.shape} != template {t.shape}")
            out[k] = arr.astype(t.dtype)
        # unflatten back onto the template structure
        leaves_paths = jax.tree_util.tree_flatten_with_path(tree)
        keys = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in leaves_paths[0]
        ]
        new_leaves = [out[k] for k in keys]
        return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves)

    # pre-comm checkpoints -- and checkpoints from engines with FEWER comm
    # buffers -- restore onto richer templates with the extra buffers kept
    # zero-initialized (self-consistent: every node retransmits in full
    # next round). Buffers present in both are restored exactly.
    comm = template.comm
    if comm is not None and manifest.get("has_comm", False):
        saved_keys = saved_comm_keys
        extra = saved_keys - set(comm)
        if extra and engine is not None:
            # DERIVED buffers (the engine's restore_comm rebuilds them
            # from recon) may be dropped when the template's comm
            # contract no longer carries them -- e.g. a static sharded
            # checkpoint's mix_recon seeding a dynamic-topology run whose
            # contract replaced it with per-direction accumulators
            is_derived = getattr(engine, "is_derived_comm_key", None)
            if is_derived is not None:
                droppable = {k for k in extra if is_derived(k)}
                extra -= droppable
                saved_keys = saved_keys - droppable
        if extra:  # refuse to silently drop wire state (engine= or not)
            raise ValueError(
                f"checkpoint carries wire state {sorted(extra)} that the "
                "restore template does not use; build the template with the "
                "matching engine"
            )
        if saved_keys and saved_keys < set(comm):
            # the template carries buffers this checkpoint never saved --
            # they may be DERIVED from the restored ones (e.g. the sharded
            # engine's mix_recon == W_off @ recon), so the owning engine
            # must rebuild them; zero-filling is only safe pre-comm
            if engine is None:
                raise ValueError(
                    "restore template carries engine-specific wire state "
                    f"{sorted(set(comm) - saved_keys)} the checkpoint did "
                    "not save; pass engine= so it can be rebuilt "
                    "consistently"
                )
            partial = restore("comm", {k: comm[k] for k in sorted(saved_keys)})
            comm = dict(comm)
            comm.update(partial)
        else:
            comm = restore("comm", template.comm)
        rebuild = getattr(engine, "restore_comm", None)
        if rebuild is not None:
            comm = rebuild(comm)
    return FLState(
        step=np.int32(manifest["step"]),
        params=restore("params", template.params),
        tracker=restore("tracker", template.tracker),
        prev_grad=restore("prev_grad", template.prev_grad),
        comm=comm,
    )
