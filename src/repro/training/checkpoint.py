"""Checkpointing: FLState <-> sharded .npz + JSON manifest.

Pure numpy/JSON (no orbax dependency): leaves are flattened by tree path,
saved in one compressed npz per call, with a manifest recording step,
algorithm, and tree structure for restore-time validation. Restoring
requires a template state (from ``init_fl_state``) whose structure must
match -- shape/dtype mismatches fail loudly.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

from repro.core.fl import FLState

PyTree = Any

__all__ = ["save_fl_state", "load_fl_state"]


def _flat_dict(tree: PyTree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_fl_state(path: str, state: FLState, extra: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    arrays = {}
    manifest = {
        "step": int(state.step),
        "has_tracker": state.tracker is not None,
        "has_comm": state.comm is not None,
    }
    if extra:
        manifest["extra"] = extra
    for name, tree in (("params", state.params), ("tracker", state.tracker),
                       ("prev_grad", state.prev_grad), ("comm", state.comm)):
        if tree is None:
            continue
        for k, v in _flat_dict(tree).items():
            arrays[f"{name}::{k}"] = v
    np.savez_compressed(os.path.join(path, "state.npz"), **arrays)
    manifest["n_arrays"] = len(arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_fl_state(path: str, template: FLState) -> FLState:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "state.npz"))

    def restore(name: str, tree: PyTree) -> PyTree:
        if tree is None:
            return None
        flat_template = _flat_dict(tree)
        out = {}
        for k, t in flat_template.items():
            key = f"{name}::{k}"
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if arr.shape != t.shape:
                raise ValueError(f"{key}: shape {arr.shape} != template {t.shape}")
            out[k] = arr.astype(t.dtype)
        # unflatten back onto the template structure
        leaves_paths = jax.tree_util.tree_flatten_with_path(tree)
        keys = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in leaves_paths[0]
        ]
        new_leaves = [out[k] for k in keys]
        return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves)

    # pre-comm checkpoints restore onto fused templates with zeroed wire
    # state (self-consistent: every node retransmits in full next round)
    comm = template.comm
    if comm is not None and manifest.get("has_comm", False):
        comm = restore("comm", template.comm)
    return FLState(
        step=np.int32(manifest["step"]),
        params=restore("params", template.params),
        tracker=restore("tracker", template.tracker),
        prev_grad=restore("prev_grad", template.prev_grad),
        comm=comm,
    )
