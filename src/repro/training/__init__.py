"""Training substrate: FL trainer driver, metrics, checkpointing."""

from repro.training.trainer import TrainResult, train_decentralized
from repro.training.metrics import comm_bytes_per_gossip, allreduce_bytes, param_bytes
from repro.training.checkpoint import load_fl_state, save_fl_state

__all__ = [
    "TrainResult",
    "train_decentralized",
    "comm_bytes_per_gossip",
    "allreduce_bytes",
    "param_bytes",
    "load_fl_state",
    "save_fl_state",
]
