"""Communication accounting + run metrics.

The paper's headline metric is *communication rounds*; production deploys
care about *bytes on the wire*. Both are derived here from the parameter
pytree and the topology, and both appear in EXPERIMENTS.md.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np

PyTree = Any

__all__ = ["param_bytes", "comm_bytes_per_gossip", "allreduce_bytes", "MetricHistory"]


def param_bytes(params: PyTree, wire_dtype: str | None = None) -> int:
    """Bytes of ONE node's parameters as sent on the wire."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        itemsize = np.dtype(wire_dtype).itemsize if wire_dtype else leaf.dtype.itemsize
        total += leaf.size * itemsize
    return total


def comm_bytes_per_gossip(
    params: PyTree, topology: str, n_nodes: int, wire_dtype: str | None = None
) -> int:
    """Per-NODE egress bytes for one gossip round.

    ring/torus: one parameter copy per outgoing direction (ppermute).
    complete/allgather: N-1 copies. star: 1 (upload) + broadcast share.
    """
    p = param_bytes(params, wire_dtype)
    if topology.startswith("torus"):
        return 4 * p
    if topology == "ring":
        return 2 * p
    if topology == "complete":
        return (n_nodes - 1) * p
    if topology == "star":
        return 2 * p  # up to server + down
    # arbitrary graph: mean degree from the mixing matrix
    from repro.core.topology import mixing_matrix

    w = mixing_matrix(topology, n_nodes)
    mean_deg = float((np.abs(w) > 1e-12).sum(1).mean() - 1.0)
    return int(mean_deg * p)


def allreduce_bytes(params: PyTree, n_nodes: int, wire_dtype: str | None = None) -> int:
    """Per-node bytes of a ring all-reduce: 2 (N-1)/N x payload."""
    p = param_bytes(params, wire_dtype)
    return int(2 * (n_nodes - 1) / n_nodes * p)


class MetricHistory:
    """Append-only metric recorder with numpy export."""

    def __init__(self) -> None:
        self._rows: list[Dict[str, float]] = []

    def append(self, **kv: float) -> None:
        self._rows.append({k: float(v) for k, v in kv.items()})

    def __len__(self) -> int:
        return len(self._rows)

    def column(self, key: str) -> np.ndarray:
        return np.array([r[key] for r in self._rows if key in r])

    def last(self) -> Dict[str, float]:
        return dict(self._rows[-1]) if self._rows else {}

    def rows(self) -> list[Dict[str, float]]:
        return [dict(r) for r in self._rows]
