"""End-to-end decentralized-FL training driver (simulated node axis).

Runs the paper's Algorithm 1 on a single host: nodes live on the leading
array axis (vmap), mixing through whichever GossipEngine is selected
(``engine=`` accepts a registry name -- tree / flat / fused -- or a
prebuilt engine; the default tree engine gossips through the dense-W
backend). This is the driver behind the EHR reproduction and the
CPU-scale LM examples; the sharded multi-pod variant reuses the same
``make_fl_round`` with a mesh-built engine (see launch/train.py and
launch/dryrun.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLRunConfig
from repro.core.engine import GossipEngine, get_engine
from repro.core.fl import FLConfig, FLState, init_fl_state, make_fl_round
from repro.core.schedules import constant, inv_sqrt, theorem1_schedule
from repro.core.topology import check_assumption1, mixing_matrix
from repro.training.metrics import MetricHistory, comm_bytes_per_gossip

PyTree = Any

__all__ = ["TrainResult", "train_decentralized", "make_schedule", "stack_for_nodes"]


@dataclasses.dataclass
class TrainResult:
    state: FLState
    history: MetricHistory
    consensus: PyTree
    w: np.ndarray
    engine: GossipEngine = None  # the engine the run trained with


def make_schedule(run: FLRunConfig):
    if run.schedule == "inv_sqrt":
        return inv_sqrt(run.alpha0)
    if run.schedule == "constant":
        return constant(run.alpha0)
    if run.schedule == "theorem1":
        return theorem1_schedule(run.n_nodes, run.alpha0)
    raise ValueError(f"unknown schedule {run.schedule!r}")


def stack_for_nodes(params: PyTree, n_nodes: int, perturb: float = 0.0, key=None) -> PyTree:
    """Replicate one node's params across the node axis (identical init;
    optional per-node perturbation for consensus-dynamics experiments)."""

    def f(p):
        stacked = jnp.broadcast_to(p[None], (n_nodes,) + p.shape)
        return jnp.array(stacked)

    stacked = jax.tree_util.tree_map(f, params)
    if perturb > 0.0:
        if key is None:
            key = jax.random.key(0)
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        keys = jax.random.split(key, len(leaves))
        leaves = [
            l + perturb * jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype)
            for l, k in zip(leaves, keys)
        ]
        stacked = jax.tree_util.tree_unflatten(treedef, leaves)
    return stacked


def train_decentralized(
    loss_fn: Callable[[PyTree, Dict], jnp.ndarray],
    params_single: PyTree,
    run: FLRunConfig,
    step_batches: Iterator[Dict[str, np.ndarray]],
    rounds: int,
    eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
    eval_every: int = 50,
    log_every: int = 0,
    wire_dtype=None,
    engine="tree",
    scale_chunk: Optional[int] = None,
    topk: Optional[int] = None,
) -> TrainResult:
    """Train for ``rounds`` communication rounds.

    ``step_batches`` yields PER-STEP node-stacked batches (nodes, ...);
    the driver groups Q of them per round (paper: Q local updates, then
    one communication).

    ``engine`` selects the round engine: a registry name (resolved via
    ``repro.core.engine.get_engine`` and built with its ``simulated``
    constructor against the run topology's W) or a prebuilt
    :class:`GossipEngine`. Flat/fused engines pack the state; the tree
    view is restored at the eval/consensus boundary via
    ``engine.params_view``. ``scale_chunk`` / ``topk`` configure the
    fused engines' int8 / top-k wire.
    """
    w = mixing_matrix(run.topology, run.n_nodes)
    check_assumption1(w)
    cfg = FLConfig(algorithm=run.algorithm, q=run.q, n_nodes=run.n_nodes)
    stacked = (
        params_single
        if _is_stacked(params_single, run.n_nodes)
        else stack_for_nodes(params_single, run.n_nodes)
    )
    if isinstance(engine, GossipEngine):
        knobs = {"wire_dtype": wire_dtype, "scale_chunk": scale_chunk,
                 "topk": topk}
        set_knobs = sorted(k for k, v in knobs.items() if v is not None)
        if set_knobs:
            raise ValueError(
                f"{set_knobs} configure an engine BUILD; the prebuilt "
                f"{engine.name!r} engine already fixed its wire -- pass a "
                "registry name instead, or bake the knobs into the engine"
            )
        params0 = stacked if engine.layout is None else engine_pack(engine, stacked)
    else:
        engine, params0 = get_engine(engine).simulated(
            w, stacked, wire_dtype=wire_dtype,
            scale_chunk=512 if scale_chunk is None else scale_chunk,
            topk=topk,
        )
    schedule = make_schedule(run)
    round_fn = jax.jit(make_fl_round(loss_fn, None, schedule, cfg, engine=engine))
    state = init_fl_state(cfg, params0, engine=engine)

    bytes_per_round = engine.wire_bytes(cfg)
    if bytes_per_round is None:
        bytes_per_round = comm_bytes_per_gossip(
            params_single, run.topology, run.n_nodes,
            wire_dtype=str(np.dtype(wire_dtype)) if wire_dtype else None,
        )
    history = MetricHistory()
    t0 = time.time()
    for rnd in range(1, rounds + 1):
        qs = [next(step_batches) for _ in range(run.q)]
        batches = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *qs)
        state, m = round_fn(state, batches)
        row = {
            "round": rnd,
            "iteration": int(state.step),
            "comm_rounds": rnd,
            "comm_bytes": rnd * bytes_per_round,
            "loss": float(m["loss"]),
            "local_loss": float(m["local_loss"]),
            "grad_norm_sq": float(m["grad_norm_sq"]),
            "consensus_err": float(m["consensus_err"]),
            "alpha": float(m["alpha"]),
            "wall_s": time.time() - t0,
        }
        if eval_fn is not None and (rnd % eval_every == 0 or rnd == rounds):
            row.update({f"eval_{k}": v for k, v in eval_fn(_consensus(engine, state)).items()})
        history.append(**row)
        if log_every and rnd % log_every == 0:
            print(
                f"[round {rnd:5d}] it={row['iteration']:6d} loss={row['loss']:.4f} "
                f"cons={row['consensus_err']:.3e} gnorm2={row['grad_norm_sq']:.3e}"
            )
    return TrainResult(state=state, history=history,
                       consensus=_consensus(engine, state), w=w, engine=engine)


def _consensus(engine: GossipEngine, state: FLState) -> PyTree:
    """theta_bar on the TREE view, whatever the engine's representation."""
    return jax.tree_util.tree_map(
        lambda p: jnp.mean(p, axis=0), engine.params_view(state.params)
    )


def engine_pack(engine: GossipEngine, stacked: PyTree):
    """Pack tree params into a prebuilt flat engine's layout."""
    from repro.core.packing import pack_like

    return pack_like(stacked, engine.layout)


def _is_stacked(params: PyTree, n_nodes: int) -> bool:
    leaves = jax.tree_util.tree_leaves(params)
    return bool(leaves) and all(l.ndim >= 1 and l.shape[0] == n_nodes for l in leaves)
