"""End-to-end decentralized-FL training driver (simulated node axis).

Runs the paper's Algorithm 1 on a single host: nodes live on the leading
array axis (vmap), gossip through the dense-W backend. This is the driver
behind the EHR reproduction and the CPU-scale LM examples; the sharded
multi-pod variant reuses the same ``make_fl_round`` with mesh gossip
(see launch/train.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLRunConfig
from repro.core.fl import FLConfig, FLState, consensus_params, init_fl_state, make_fl_round
from repro.core.mixing import make_dense_gossip
from repro.core.schedules import constant, inv_sqrt, theorem1_schedule
from repro.core.topology import check_assumption1, mixing_matrix
from repro.training.metrics import MetricHistory, comm_bytes_per_gossip

PyTree = Any

__all__ = ["TrainResult", "train_decentralized", "make_schedule", "stack_for_nodes"]


@dataclasses.dataclass
class TrainResult:
    state: FLState
    history: MetricHistory
    consensus: PyTree
    w: np.ndarray


def make_schedule(run: FLRunConfig):
    if run.schedule == "inv_sqrt":
        return inv_sqrt(run.alpha0)
    if run.schedule == "constant":
        return constant(run.alpha0)
    if run.schedule == "theorem1":
        return theorem1_schedule(run.n_nodes, run.alpha0)
    raise ValueError(f"unknown schedule {run.schedule!r}")


def stack_for_nodes(params: PyTree, n_nodes: int, perturb: float = 0.0, key=None) -> PyTree:
    """Replicate one node's params across the node axis (identical init;
    optional per-node perturbation for consensus-dynamics experiments)."""

    def f(p):
        stacked = jnp.broadcast_to(p[None], (n_nodes,) + p.shape)
        return jnp.array(stacked)

    stacked = jax.tree_util.tree_map(f, params)
    if perturb > 0.0:
        if key is None:
            key = jax.random.key(0)
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        keys = jax.random.split(key, len(leaves))
        leaves = [
            l + perturb * jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype)
            for l, k in zip(leaves, keys)
        ]
        stacked = jax.tree_util.tree_unflatten(treedef, leaves)
    return stacked


def train_decentralized(
    loss_fn: Callable[[PyTree, Dict], jnp.ndarray],
    params_single: PyTree,
    run: FLRunConfig,
    step_batches: Iterator[Dict[str, np.ndarray]],
    rounds: int,
    eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
    eval_every: int = 50,
    log_every: int = 0,
    wire_dtype=None,
) -> TrainResult:
    """Train for ``rounds`` communication rounds.

    ``step_batches`` yields PER-STEP node-stacked batches (nodes, ...);
    the driver groups Q of them per round (paper: Q local updates, then
    one communication).
    """
    w = mixing_matrix(run.topology, run.n_nodes)
    check_assumption1(w)
    gossip = make_dense_gossip(w, wire_dtype=wire_dtype)
    cfg = FLConfig(algorithm=run.algorithm, q=run.q, n_nodes=run.n_nodes)
    schedule = make_schedule(run)
    round_fn = jax.jit(make_fl_round(loss_fn, gossip, schedule, cfg))
    state = init_fl_state(cfg, params_single if _is_stacked(params_single, run.n_nodes) else stack_for_nodes(params_single, run.n_nodes))

    bytes_per_round = comm_bytes_per_gossip(
        params_single, run.topology, run.n_nodes,
        wire_dtype=str(np.dtype(wire_dtype)) if wire_dtype else None,
    )
    history = MetricHistory()
    t0 = time.time()
    for rnd in range(1, rounds + 1):
        qs = [next(step_batches) for _ in range(run.q)]
        batches = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *qs)
        state, m = round_fn(state, batches)
        row = {
            "round": rnd,
            "iteration": int(state.step),
            "comm_rounds": rnd,
            "comm_bytes": rnd * bytes_per_round,
            "loss": float(m["loss"]),
            "local_loss": float(m["local_loss"]),
            "grad_norm_sq": float(m["grad_norm_sq"]),
            "consensus_err": float(m["consensus_err"]),
            "alpha": float(m["alpha"]),
            "wall_s": time.time() - t0,
        }
        if eval_fn is not None and (rnd % eval_every == 0 or rnd == rounds):
            row.update({f"eval_{k}": v for k, v in eval_fn(consensus_params(state)).items()})
        history.append(**row)
        if log_every and rnd % log_every == 0:
            print(
                f"[round {rnd:5d}] it={row['iteration']:6d} loss={row['loss']:.4f} "
                f"cons={row['consensus_err']:.3e} gnorm2={row['grad_norm_sq']:.3e}"
            )
    return TrainResult(state=state, history=history, consensus=consensus_params(state), w=w)


def _is_stacked(params: PyTree, n_nodes: int) -> bool:
    leaves = jax.tree_util.tree_leaves(params)
    return bool(leaves) and all(l.ndim >= 1 and l.shape[0] == n_nodes for l in leaves)
