"""End-to-end decentralized-FL training driver (simulated node axis).

Runs the paper's Algorithm 1 on a single host: nodes live on the leading
array axis (vmap), mixing through whichever GossipEngine is selected
(``engine=`` accepts a registry name -- tree / flat / fused -- or a
prebuilt engine; the default tree engine gossips through the dense-W
backend). This is the driver behind the EHR reproduction and the
CPU-scale LM examples; the sharded multi-pod variant reuses the same
``make_fl_round`` with a mesh-built engine (see launch/train.py and
launch/dryrun.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLRunConfig
from repro.core.engine import GossipEngine, get_engine
from repro.core.fl import FLConfig, FLState, init_fl_state, make_fl_round
from repro.core.schedules import constant, inv_sqrt, theorem1_schedule
from repro.core.topology import check_assumption1, mixing_matrix
from repro.training.metrics import MetricHistory, comm_bytes_per_gossip

PyTree = Any

__all__ = ["AdaptiveTopK", "TrainResult", "train_decentralized",
           "make_schedule", "stack_for_nodes"]


class AdaptiveTopK:
    """Error-triggered wire densification: the ONE owner of the adaptive-k
    round-to-round logic (used by ``train_decentralized`` and the EHR
    example -- do not hand-roll the switch).

    Spec ``(k_sparse, k_dense, densify_high[, resparsify_low])``: rounds
    run the sparse wire until the ``ef_residual_rms`` metric (the mass
    the wire is deferring) crosses ``densify_high``; then the densified
    twin runs (``dense_topk`` collapses to None -- plain dense int8 --
    when k_dense covers the whole scale chunk) until the residual drains
    BELOW ``resparsify_low`` (default ``densify_high / 2``).

    The two thresholds are a HYSTERESIS band: a single threshold
    duty-cycles -- densifying drains the residual just under the line,
    re-sparsifying pushes it back over, so k flaps every round or two
    around regime changes (observed on the EHR cohort trace;
    regression-tested in tests/test_schedule.py). With the band, the
    wire stays dense until the residual is genuinely drained and stays
    sparse until it genuinely builds back up.

    Build BOTH engines/round functions up front (identical comm-state
    contract, so they advance the same state; k is a compile-time kernel
    constant, so adapting is a function switch, never a recompile), then
    per round:

        fn = ctl.pick(sparse_fn, dense_fn)
        state, m = fn(state, batches)        # ctl.current_k ran this round
        ctl.update(float(m["ef_residual_rms"]))
    """

    def __init__(self, spec, scale_chunk: int):
        if len(spec) == 3:
            k_sparse, k_dense, high = spec
            low = float(high) / 2.0
        else:
            k_sparse, k_dense, high, low = spec
        self.k_sparse = int(k_sparse)
        self.k_dense = int(k_dense)
        self.threshold = float(high)  #: densify when rms exceeds this
        self.low = float(low)  #: re-sparsify only when rms drains below
        if not (0.0 < self.low <= self.threshold):
            raise ValueError(
                f"hysteresis band needs 0 < low <= high, got "
                f"low={self.low}, high={self.threshold}"
            )
        #: topk= for the densified twin engine (None = dense int8)
        self.dense_topk = None if self.k_dense >= scale_chunk else self.k_dense
        self._use_dense = False
        self.rounds = 0
        self.dense_rounds = 0
        self.switches = 0

    @property
    def current_k(self) -> int:
        """The k THIS round ships (valid until :meth:`update` is called)."""
        return self.k_dense if self._use_dense else self.k_sparse

    def pick(self, sparse_fn, dense_fn):
        return dense_fn if self._use_dense else sparse_fn

    def update(self, ef_residual_rms: float) -> None:
        """Account the round just run and arm the next one: densify-high
        / re-sparsify-low, holding the current wire inside the band."""
        self.rounds += 1
        self.dense_rounds += int(self._use_dense)
        if self._use_dense:
            use_dense = ef_residual_rms >= self.low
        else:
            use_dense = ef_residual_rms > self.threshold
        self.switches += int(use_dense != self._use_dense)
        self._use_dense = use_dense


@dataclasses.dataclass
class TrainResult:
    state: FLState
    history: MetricHistory
    consensus: PyTree
    w: np.ndarray
    engine: GossipEngine = None  # the engine the run trained with


def make_schedule(run: FLRunConfig):
    if run.schedule == "inv_sqrt":
        return inv_sqrt(run.alpha0)
    if run.schedule == "constant":
        return constant(run.alpha0)
    if run.schedule == "theorem1":
        return theorem1_schedule(run.n_nodes, run.alpha0)
    raise ValueError(f"unknown schedule {run.schedule!r}")


def stack_for_nodes(params: PyTree, n_nodes: int, perturb: float = 0.0, key=None) -> PyTree:
    """Replicate one node's params across the node axis (identical init;
    optional per-node perturbation for consensus-dynamics experiments)."""

    def f(p):
        stacked = jnp.broadcast_to(p[None], (n_nodes,) + p.shape)
        return jnp.array(stacked)

    stacked = jax.tree_util.tree_map(f, params)
    if perturb > 0.0:
        if key is None:
            key = jax.random.key(0)
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        keys = jax.random.split(key, len(leaves))
        leaves = [
            l + perturb * jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype)
            for l, k in zip(leaves, keys)
        ]
        stacked = jax.tree_util.tree_unflatten(treedef, leaves)
    return stacked


def train_decentralized(
    loss_fn: Callable[[PyTree, Dict], jnp.ndarray],
    params_single: PyTree,
    run: FLRunConfig,
    step_batches: Iterator[Dict[str, np.ndarray]],
    rounds: int,
    eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
    eval_every: int = 50,
    log_every: int = 0,
    wire_dtype=None,
    engine="tree",
    scale_chunk: Optional[int] = None,
    topk: Optional[int] = None,
    round_schedule: Optional[str] = None,
    storage_dtype=None,
    topk_schedule: Optional[Tuple[int, ...]] = None,
    topology_program: Optional[str] = None,
    node_program: Optional[str] = None,
    staleness_depth: Optional[int] = None,
    robust_alpha: bool = False,
    privacy: Optional[str] = None,
    scope: Optional[str] = None,
) -> TrainResult:
    """Train for ``rounds`` communication rounds.

    ``step_batches`` yields PER-STEP node-stacked batches (nodes, ...);
    the driver groups Q of them per round (paper: Q local updates, then
    one communication).

    ``engine`` selects the round engine: a registry name (resolved via
    ``repro.core.engine.get_engine`` and built with its ``simulated``
    constructor against the run topology's W) or a prebuilt
    :class:`GossipEngine`. Flat/fused engines pack the state; the tree
    view is restored at the eval/consensus boundary via
    ``engine.params_view``. ``scale_chunk`` / ``topk`` configure the
    fused engines' int8 / top-k wire; ``round_schedule``
    ("sequential" | "pipelined") selects the round's time layout
    (pipelined overlaps the collective with the next round's local
    steps, mixing one-round stale); ``storage_dtype`` keeps the flat
    engine's packed buffer in bf16 (fp32 stays only in the mix
    accumulator).

    ``topk_schedule = (k_sparse, k_dense, densify_high[, resparsify_low])``
    is the adaptive-k hook: rounds run with the sparse wire until the
    EF-residual RMS (the ``ef_residual_rms`` metric) crosses
    ``densify_high``, then densify to ``k_dense`` (>= the scale chunk
    disables masking entirely) until the residual drains below
    ``resparsify_low`` (default ``densify_high / 2`` -- the hysteresis
    band that keeps k from duty-cycling; see
    :class:`AdaptiveTopK`). Both variants are built once and jitted once
    -- k is a compile-time kernel constant, so adapting means switching
    between two round functions over the SAME state, not recompiling.

    ``topology_program`` selects the per-round graph dynamics (the THIRD
    round axis, ``repro.core.dynamics``): a registry spec string like
    ``"node_churn:p_down=0.2,mean_downtime=5"`` -- the run's base W is
    gated per round with dropped-edge weight folded into the self-loops,
    inside the ONE compiled round function (metrics gain
    ``edge_fraction``). None (or ``"static"``) keeps the compile-time
    constant W.

    ``node_program`` selects per-NODE heterogeneity (the FOURTH round
    axis, ``repro.core.heterogeneity``): a spec string like
    ``"stragglers:frac=0.25,rate=0.5"`` gating each node's local-step
    budget and payload delivery per round -- still traced operands of
    the one compiled round (metrics gain ``payload_fraction`` /
    ``compute_fraction``). ``staleness_depth=k`` is sugar for
    ``round_schedule="bounded_staleness:k=k"`` (k-round-stale mixing
    with k payloads in flight; 0 = sequential). ``robust_alpha=True``
    shrinks the step-size schedule by
    ``robust_alpha_scale(expected_uptime, k)`` -- the staleness/churn
    controller keeping the effective alpha/spectral-gap ratio of the
    fault-free tuning.

    ``privacy`` selects the wire's privacy epilogue (the FIFTH round
    axis, ``repro.core.privacy``): a spec string like
    ``"secure_agg+dp:sigma=0.5,clip=1.0"`` -- pairwise antisymmetric
    masks that cancel under the symmetric mix (no single neighbor
    payload is readable) and/or per-node clip + Gaussian noise riding
    the EF residual, with the ``dp_epsilon`` moments bound as a metric.

    ``scope`` selects the federation scope (the SIXTH round axis,
    ``repro.core.scope``): which columns of the flat buffer gossip
    touches at all. A spec string like ``"backbone"`` (share everything
    but the classifier head -- each hospital keeps a personalized head
    trained purely on local gradients, bit-untouched by the wire) /
    ``"ranges:0-1376"`` / ``"layerwise:freq=4"`` (head columns join the
    mix only every 4th round). Partial scopes shrink the wire
    proportionally: every collective, top-k, EF residual and
    quantization scale operates on the shared slice only.
    """
    w = mixing_matrix(run.topology, run.n_nodes)
    check_assumption1(w)
    if staleness_depth is not None:
        if round_schedule is not None:
            raise ValueError(
                "pass either round_schedule or staleness_depth, not both "
                "(staleness_depth=k is sugar for "
                "round_schedule='bounded_staleness:k=k')"
            )
        k = int(staleness_depth)
        round_schedule = "sequential" if k == 0 else f"bounded_staleness:k={k}"
    cfg = FLConfig(algorithm=run.algorithm, q=run.q, n_nodes=run.n_nodes)
    stacked = (
        params_single
        if _is_stacked(params_single, run.n_nodes)
        else stack_for_nodes(params_single, run.n_nodes)
    )
    if isinstance(engine, GossipEngine):
        knobs = {"wire_dtype": wire_dtype, "scale_chunk": scale_chunk,
                 "topk": topk, "round_schedule": round_schedule,
                 "storage_dtype": storage_dtype,
                 "topk_schedule": topk_schedule,
                 "topology_program": topology_program,
                 "node_program": node_program,
                 "privacy": privacy,
                 "scope": scope}
        set_knobs = sorted(k for k, v in knobs.items() if v is not None)
        if set_knobs:
            raise ValueError(
                f"{set_knobs} configure an engine BUILD; the prebuilt "
                f"{engine.name!r} engine already fixed its wire -- pass a "
                "registry name instead, or bake the knobs into the engine"
            )
        params0 = stacked if engine.layout is None else engine_pack(engine, stacked)
    else:
        if topk_schedule is not None:
            if topk is not None:
                raise ValueError("pass either topk or topk_schedule, not both")
            topk = int(topk_schedule[0])  # start on the sparse wire
        build = get_engine(engine).simulated
        kw = dict(
            wire_dtype=wire_dtype,
            scale_chunk=512 if scale_chunk is None else scale_chunk,
            round_schedule=round_schedule, storage_dtype=storage_dtype,
            topology_program=topology_program, node_program=node_program,
            privacy=privacy, scope=scope,
        )
        engine, params0 = build(w, stacked, topk=topk, **kw)
    schedule = make_schedule(run)
    if robust_alpha:
        from repro.core.schedules import robust_alpha_scale, scaled

        uptime = (engine.topology_program.expected_uptime()
                  * engine.node_program.expected_uptime())
        schedule = scaled(
            schedule,
            robust_alpha_scale(uptime, engine.round_schedule.depth),
        )
    round_fn = jax.jit(make_fl_round(loss_fn, None, schedule, cfg, engine=engine))
    adaptive, dense_fn = None, None
    if topk_schedule is not None:
        adaptive = AdaptiveTopK(topk_schedule, engine.scale_chunk)
        # the densified twin: same comm-state contract (comm_keys do not
        # depend on k), so both round functions advance the SAME state
        dense_engine, _ = build(w, stacked, topk=adaptive.dense_topk, **kw)
        dense_fn = jax.jit(
            make_fl_round(loss_fn, None, schedule, cfg, engine=dense_engine)
        )
    state = init_fl_state(cfg, params0, engine=engine)

    fallback_bytes = engine.wire_bytes(cfg)
    if fallback_bytes is None:
        fallback_bytes = comm_bytes_per_gossip(
            params_single, run.topology, run.n_nodes,
            wire_dtype=str(np.dtype(wire_dtype)) if wire_dtype else None,
        )
    history = MetricHistory()
    t0 = time.time()
    cum_bytes = 0.0
    for rnd in range(1, rounds + 1):
        qs = [next(step_batches) for _ in range(run.q)]
        batches = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *qs)
        fn = adaptive.pick(round_fn, dense_fn) if adaptive else round_fn
        state, m = fn(state, batches)
        cum_bytes += float(m.get("wire_bytes", fallback_bytes))
        row = {
            "round": rnd,
            "iteration": int(state.step),
            "comm_rounds": rnd,
            "comm_bytes": cum_bytes,
            "loss": float(m["loss"]),
            "local_loss": float(m["local_loss"]),
            "grad_norm_sq": float(m["grad_norm_sq"]),
            "consensus_err": float(m["consensus_err"]),
            "alpha": float(m["alpha"]),
            "wall_s": time.time() - t0,
        }
        for k in ("edge_fraction", "payload_fraction", "compute_fraction",
                  "dp_epsilon"):
            if k in m:
                row[k] = float(m[k])
        if adaptive is not None:
            row["topk"] = float(adaptive.current_k)
            row["ef_residual_rms"] = float(m["ef_residual_rms"])
            adaptive.update(float(m["ef_residual_rms"]))
        if eval_fn is not None and (rnd % eval_every == 0 or rnd == rounds):
            row.update({f"eval_{k}": v for k, v in eval_fn(_consensus(engine, state)).items()})
        history.append(**row)
        if log_every and rnd % log_every == 0:
            print(
                f"[round {rnd:5d}] it={row['iteration']:6d} loss={row['loss']:.4f} "
                f"cons={row['consensus_err']:.3e} gnorm2={row['grad_norm_sq']:.3e}"
            )
    return TrainResult(state=state, history=history,
                       consensus=_consensus(engine, state), w=w, engine=engine)


def _consensus(engine: GossipEngine, state: FLState) -> PyTree:
    """theta_bar on the TREE view, whatever the engine's representation."""
    return jax.tree_util.tree_map(
        lambda p: jnp.mean(p, axis=0), engine.params_view(state.params)
    )


def engine_pack(engine: GossipEngine, stacked: PyTree):
    """Pack tree params into a prebuilt flat engine's layout."""
    from repro.core.packing import pack_like

    return pack_like(stacked, engine.layout)


def _is_stacked(params: PyTree, n_nodes: int) -> bool:
    leaves = jax.tree_util.tree_leaves(params)
    return bool(leaves) and all(l.ndim >= 1 and l.shape[0] == n_nodes for l in leaves)
