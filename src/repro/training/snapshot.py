"""Consensus snapshots: the training->serving fast path.

A checkpoint (``checkpoint.py``) is for RESUMING training: it carries
every node's parameters plus tracker/comm wire state, compressed, and
restores through a pytree round trip. A **snapshot** is for SERVING: the
consensus model (the node-axis mean of the flat ``(nodes, total)``
buffer -- the iterate the paper deploys, not any single node) written as
one aligned raw-bytes blob plus a JSON header, so a server can
``mmap``-load it **zero-copy**:

* the blob is the consensus row in ``layout.storage_dtype``, padded to
  :data:`BLOB_ALIGN` bytes;
* the header records the :class:`~repro.core.packing.FlatLayout`
  geometry (per-leaf path/offset/shape/dtype, ``total``/``used``/
  ``storage_dtype``), the six-axis round spec (engine x schedule x
  topology x node program x privacy x scope, same record a checkpoint
  manifest carries -- see
  :func:`repro.training.checkpoint.engine_manifest`), and a
  ``round_frontier`` counter (how many training rounds produced it);
* under a partial federation scope the blob also carries each node's
  private columns after the consensus row, so
  ``load_snapshot(..., node=i)`` serves hospital ``i``'s personalized
  model (consensus backbone + its own head);
* :func:`load_snapshot` memory-maps the blob and slices each leaf as a
  numpy VIEW (``blob[offset:offset+size].reshape(shape)``) -- no pytree
  unflatten of materialized arrays, no host staging copy; bytes fault in
  lazily as the server first touches them. Only a leaf whose dtype
  differs from the storage dtype pays a convert.

Publication protocol (safe under a concurrently-reading server):
snapshot files are immutable once named -- the writer stages to a
``.tmp`` name and ``os.replace``s into place (blob first, then header),
then atomically rewrites ``LATEST`` to point at the new round. A reader
that follows ``LATEST`` therefore never observes a torn snapshot, and an
in-flight reader of round k keeps its mmap alive even after round k+1
lands (POSIX keeps the inode until unmapped).
"""

from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Any, Optional

import jax
import numpy as np

from repro.core.packing import FlatLayout, pack, pack_like

PyTree = Any

__all__ = [
    "Snapshot",
    "write_snapshot",
    "load_snapshot",
    "latest_round",
    "snapshot_paths",
]

SNAPSHOT_MAGIC = "repro-consensus-snapshot"
SNAPSHOT_VERSION = 1
#: blob files are padded to this many bytes so mmap'd leaf views stay
#: safely vector-loadable past the used tail
BLOB_ALIGN = 64
_LATEST = "LATEST"


def snapshot_paths(dirpath: str, round_frontier: int) -> tuple:
    """(blob, header) filenames for a given training round."""
    stem = f"snapshot-{int(round_frontier):08d}"
    return (os.path.join(dirpath, stem + ".bin"),
            os.path.join(dirpath, stem + ".json"))


def _atomic_write(path: str, data: bytes) -> None:
    tmp = path + f".tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _leaf_paths(layout: FlatLayout) -> list:
    """Tree-path strings for each leaf, in ``layout.leaves`` order (the
    ``tree_flatten`` order ``pack`` stored them in)."""
    dummy = jax.tree_util.tree_unflatten(
        layout.treedef, list(range(len(layout.leaves))))
    pairs = jax.tree_util.tree_flatten_with_path(dummy)[0]
    paths = [None] * len(layout.leaves)
    for path, idx in pairs:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        paths[idx] = key
    return paths


def write_snapshot(dirpath: str, params: PyTree, layout: Optional[FlatLayout]
                   = None, *, round_frontier: int, engine=None,
                   step: Optional[int] = None,
                   extra: Optional[dict] = None) -> str:
    """Publish the consensus model as an mmap-able snapshot.

    Args:
      params: either the node-stacked flat ``(nodes, total)`` buffer
        (requires ``layout``), an already-reduced ``(total,)`` consensus
        row (requires ``layout``), or a node-stacked pytree (packed
        through ``layout`` when given, else with a fresh layout).
      layout: the :class:`FlatLayout` describing the buffer columns.
      round_frontier: training rounds completed when this consensus was
        taken -- the server's staleness metric is
        ``frontier_now - header["round_frontier"]``.
      engine: optional GossipEngine; records the six-axis round spec in
        the header (same record as a checkpoint manifest), and supplies
        the federation scope whose private columns get the per-node
        block below.
      step: optional optimizer step counter, recorded verbatim.
      extra: optional JSON-serializable dict, recorded verbatim.

    When the engine runs a partial federation scope ('backbone' /
    'ranges:') and ``params`` is the node-stacked 2-D buffer, the blob
    additionally carries every node's PRIVATE columns (captured before
    the consensus mean -- gossip never mixed them, so the mean would
    destroy exactly the personalized state) at an aligned offset after
    the consensus row; ``load_snapshot(..., node=i)`` overlays them to
    serve hospital ``i``'s personalized model.

    Returns the header path. The write is atomic: blob, then header,
    then the ``LATEST`` pointer, each staged + ``os.replace``d.
    """
    if isinstance(params, (np.ndarray, jax.Array)):
        if layout is None:
            raise ValueError("writing from a flat buffer requires layout=")
        flat = params
    else:
        if layout is None:
            flat, layout = pack(params)
        else:
            flat = pack_like(params, layout)
    scope = getattr(engine, "scope", None)
    private_ranges = ()
    if scope is not None and not scope.is_full:
        private_ranges = tuple(scope.private_ranges(layout))
    private_block = None
    if flat.ndim == 2:
        if private_ranges:
            # per-node private columns, captured BEFORE the consensus
            # mean: gossip left them bit-untouched per hospital, and the
            # node-axis mean is precisely the reduction that would lose
            # that personalization
            stacked = np.asarray(jax.device_get(flat),
                                 dtype=np.dtype(layout.storage_dtype))
            private_block = np.concatenate(
                [stacked[:, a:b] for a, b in private_ranges], axis=1)
        # THE consensus reduction: one mean over the node axis of the
        # flat buffer -- no per-leaf traversal
        flat = flat.mean(axis=0)
    if flat.shape != (layout.total,):
        raise ValueError(
            f"flat buffer {flat.shape} does not match layout total "
            f"({layout.total},)")
    consensus = np.asarray(jax.device_get(flat),
                           dtype=np.dtype(layout.storage_dtype))
    blob = consensus.tobytes()
    if len(blob) % BLOB_ALIGN:
        blob += b"\x00" * (BLOB_ALIGN - len(blob) % BLOB_ALIGN)
    private_offset = None
    if private_block is not None:
        private_offset = len(blob)
        blob += private_block.tobytes()
        if len(blob) % BLOB_ALIGN:
            blob += b"\x00" * (BLOB_ALIGN - len(blob) % BLOB_ALIGN)

    os.makedirs(dirpath, exist_ok=True)
    blob_path, header_path = snapshot_paths(dirpath, round_frontier)
    header = {
        "magic": SNAPSHOT_MAGIC,
        "version": SNAPSHOT_VERSION,
        "round_frontier": int(round_frontier),
        "blob": os.path.basename(blob_path),
        "blob_bytes": len(blob),
        "payload_bytes": consensus.nbytes,
        "crc32": zlib.crc32(blob) & 0xFFFFFFFF,
        "total": int(layout.total),
        "used": int(layout.used),
        "storage_dtype": str(layout.storage_dtype),
        "source_n_nodes": int(layout.n_nodes),
        "leaves": [
            {"path": p, "offset": int(s.offset), "shape": list(s.shape),
             "dtype": str(s.dtype)}
            for p, s in zip(_leaf_paths(layout), layout.leaves)
        ],
    }
    if private_block is not None:
        header["scope"] = {
            "spec": scope.spec(),
            "private_ranges": [[int(a), int(b)] for a, b in private_ranges],
            "private_offset_bytes": int(private_offset),
            "private_bytes": int(private_block.nbytes),
            "n_nodes": int(private_block.shape[0]),
        }
    if step is not None:
        header["step"] = int(step)
    if extra:
        header["extra"] = extra
    if engine is not None:
        from repro.training.checkpoint import engine_manifest

        header["round_spec"] = engine_manifest(engine)
    _atomic_write(blob_path, blob)
    _atomic_write(header_path,
                  json.dumps(header, indent=2).encode("utf-8"))
    _atomic_write(os.path.join(dirpath, _LATEST),
                  f"{int(round_frontier)}\n".encode("ascii"))
    return header_path


def latest_round(dirpath: str) -> Optional[int]:
    """Round of the newest published snapshot, or None before the first
    publish. Follows the atomically-replaced ``LATEST`` pointer, so a
    concurrent writer can never make this return a torn snapshot."""
    try:
        with open(os.path.join(dirpath, _LATEST)) as f:
            return int(f.read().strip())
    except (FileNotFoundError, ValueError):
        return None


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """An mmap-loaded consensus snapshot.

    ``params`` leaves are numpy views into ``flat`` (itself a read-only
    ``np.memmap``) whenever the leaf dtype equals the storage dtype --
    zero-copy, lazily faulted. Keep the snapshot object alive as long as
    the views are in use.
    """

    params: PyTree
    flat: np.ndarray  # (total,) read-only memmap of the consensus row
    round_frontier: int
    header: dict
    path: str  # header path

    @property
    def step(self) -> Optional[int]:
        return self.header.get("step")


def load_snapshot(dirpath: str, round_frontier: Optional[int] = None,
                  template: Optional[PyTree] = None,
                  verify: bool = False,
                  node: Optional[int] = None) -> Snapshot:
    """mmap-load a snapshot zero-copy into its FlatLayout geometry.

    Args:
      dirpath: snapshot directory.
      round_frontier: which round to load; default = ``LATEST``.
      template: optional pytree (arrays or ShapeDtypeStructs) giving the
        exact container structure to unflatten into; leaves are matched
        by tree path and validated against the header's shapes/dtypes.
        Without a template, containers restore as nested dicts keyed by
        path component (sufficient for the models' dict param trees).
      verify: recompute the blob crc32 (reads every byte -- defeats
        laziness; leave False on the serving path).
      node: serve hospital ``node``'s PERSONALIZED model: the consensus
        backbone with that node's private columns overlaid from the
        snapshot's per-node private block. Requires a snapshot written
        from the node-stacked buffer under a partial federation scope;
        raises ``ValueError`` otherwise. The overlay materializes one
        writable ``(total,)`` copy -- the zero-copy mmap path is the
        ``node=None`` consensus load.

    Returns a :class:`Snapshot` whose ``params`` leaves are views into
    the mapped blob (a leaf pays a copy only when its dtype differs from
    the storage dtype).
    """
    if round_frontier is None:
        round_frontier = latest_round(dirpath)
        if round_frontier is None:
            raise FileNotFoundError(f"no snapshot published in {dirpath!r}")
    blob_path, header_path = snapshot_paths(dirpath, round_frontier)
    with open(header_path) as f:
        header = json.load(f)
    if header.get("magic") != SNAPSHOT_MAGIC:
        raise ValueError(f"{header_path!r} is not a consensus snapshot")
    if header.get("version", 0) > SNAPSHOT_VERSION:
        raise ValueError(
            f"snapshot version {header['version']} is newer than this "
            f"reader ({SNAPSHOT_VERSION})")
    storage = np.dtype(header["storage_dtype"])
    total = int(header["total"])
    mm = np.memmap(blob_path, dtype=storage, mode="r",
                   shape=(int(header["blob_bytes"]) // storage.itemsize,))
    if verify:
        crc = zlib.crc32(mm.tobytes()) & 0xFFFFFFFF
        if crc != header["crc32"]:
            raise ValueError(
                f"snapshot {blob_path!r} failed crc32 verification")
    flat = mm[:total]
    if node is not None:
        sec = header.get("scope")
        if sec is None:
            raise ValueError(
                f"snapshot {header_path!r} carries no per-node private "
                "columns (written under scope 'full', or from an "
                "already-reduced consensus row); node= needs a snapshot "
                "written from the node-stacked buffer under a partial "
                "federation scope")
        n = int(sec["n_nodes"])
        node = int(node)
        if not 0 <= node < n:
            raise ValueError(
                f"node={node} out of range for a {n}-node snapshot")
        off = int(sec["private_offset_bytes"]) // storage.itemsize
        width = sum(b - a for a, b in sec["private_ranges"])
        priv = mm[off:off + n * width].reshape(n, width)
        flat = np.array(flat)  # writable: consensus + this node's head
        pos = 0
        for a, b in sec["private_ranges"]:
            flat[a:b] = priv[node, pos:pos + (b - a)]
            pos += b - a

    leaves = {}
    for spec in header["leaves"]:
        size = int(np.prod(spec["shape"])) if spec["shape"] else 1
        off = int(spec["offset"])
        view = flat[off:off + size].reshape(tuple(spec["shape"]))
        if np.dtype(spec["dtype"]) != storage:
            view = view.astype(spec["dtype"])  # the only copying path
        leaves[spec["path"]] = view

    if template is not None:
        pairs, treedef = jax.tree_util.tree_flatten_with_path(template)
        ordered = []
        for path, t in pairs:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            if key not in leaves:
                raise KeyError(f"snapshot missing leaf {key!r}")
            v = leaves[key]
            tshape = tuple(t.shape)
            if tshape != v.shape:
                raise ValueError(
                    f"{key}: snapshot shape {v.shape} != template {tshape}")
            ordered.append(v)
        params = jax.tree_util.tree_unflatten(treedef, ordered)
    else:
        params = {}
        for key, v in leaves.items():
            node = params
            parts = key.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = v
    return Snapshot(params=params, flat=flat,
                    round_frontier=int(header["round_frontier"]),
                    header=header, path=header_path)
