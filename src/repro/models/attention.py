"""GQA attention: full-causal, sliding-window, bidirectional, cross, decode.

Parameters use FUSED head dims -- wq: (d, H*hd), wk/wv: (d, K*hd),
wo: (H*hd, d) -- because fused dims are divisible by the tensor-parallel
degree (16) for every assigned architecture even when head counts (40, 15,
10) are not. GSPMD shards the fused dims; the per-head einsums below leave
the head axis unconstrained.

``impl`` selects the ref (pure jnp, runs everywhere) or the Pallas flash
kernel path (TPU target; interpret=True on CPU for tests).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, linear

PyTree = Any

__all__ = [
    "attn_init",
    "attn_apply",
    "init_kv_cache",
    "attn_decode",
    "cross_attn_init",
    "cross_attn_apply",
    "precompute_cross_kv",
    "NEG_INF",
]

NEG_INF = -1e30


def layout_heads(n_heads: int, pad_to: int) -> int:
    """Physical head count: logical heads padded up to a multiple of
    ``pad_to`` (the TP degree). 16 does not divide 40/15/10-head configs;
    without padding GSPMD factors the model axis and ALL-REDUCES the
    (B, H/8, S, S) fp32 score tensors -- the dominant collective in the
    baseline dry-runs. Padded heads are zero-initialized and their output
    is statically masked, so the model is EXACTLY the logical-head model
    (padded parameters receive zero gradient and never train)."""
    if pad_to <= 0 or n_heads % pad_to == 0:
        return n_heads
    return ((n_heads + pad_to - 1) // pad_to) * pad_to


def _pad_heads(x: jnp.ndarray, n_layout: int) -> jnp.ndarray:
    """(B, T, H, hd) -> (B, T, n_layout, hd) with zero pad heads."""
    h = x.shape[-2]
    if h == n_layout:
        return x
    pad = [(0, 0)] * x.ndim
    pad[-2] = (0, n_layout - h)
    return jnp.pad(x, pad)


def _head_mask(n_heads: int, n_layout: int, dtype) -> Optional[jnp.ndarray]:
    if n_layout == n_heads:
        return None
    return (jnp.arange(n_layout) < n_heads).astype(dtype)[None, None, :, None]


def attn_init(
    key,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    dtype,
    qkv_bias: bool = False,
    n_heads_layout: Optional[int] = None,
) -> Dict:
    hl = n_heads_layout or n_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, hl * head_dim, dtype, bias=qkv_bias),
        "wk": dense_init(kk, d_model, n_kv_heads * head_dim, dtype, bias=qkv_bias),
        "wv": dense_init(kv, d_model, n_kv_heads * head_dim, dtype, bias=qkv_bias),
        "wo": dense_init(ko, hl * head_dim, d_model, dtype),
    }


def _split_heads(x: jnp.ndarray, n: int, hd: int) -> jnp.ndarray:
    return x.reshape(*x.shape[:-1], n, hd)


def _repeat_kv(kv: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B,T,K,hd) -> (B,T,H,hd) by repeating each kv head H/K times."""
    n_kv = kv.shape[-2]
    if n_kv == n_heads:
        return kv
    return jnp.repeat(kv, n_heads // n_kv, axis=-2)


def _sdpa(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    window: int,
    q_offset: jnp.ndarray | int = 0,
    kv_valid: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Reference scaled-dot-product attention, fp32 softmax.

    q: (B,S,H,hd); k,v: (B,T,H,hd). ``q_offset`` is the absolute position
    of q[0] minus that of k[0] (nonzero during decode). ``kv_valid``:
    (B,T) bool mask of populated cache slots.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qpos = jnp.arange(s)[:, None] + q_offset  # absolute q positions
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    if kv_valid is not None:
        scores = jnp.where(kv_valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _sdpa_blocked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    window: int,
    q_chunk: int = 512,
) -> jnp.ndarray:
    """Flash-style q-blocked attention in pure jnp (EXACT, differentiable).

    Scans over query chunks; each chunk takes a full-row softmax against
    all keys, so peak score memory is (B, H, q_chunk, T) instead of
    (B, H, S, T) -- the S/q_chunk x traffic reduction that the Pallas
    flash kernel realizes on TPU, in a form XLA can compile on any
    backend. This is the §Perf "blocked attention" lever.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    if s % q_chunk:
        return _sdpa(q, k, v, causal=causal, window=window)
    n_chunks = s // q_chunk
    qc = q.reshape(b, n_chunks, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(t)

    def per_chunk(_, xs):
        qi, idx = xs  # (B, cq, H, hd), scalar chunk index
        scores = jnp.einsum("bshd,bthd->bhst", qi, k).astype(jnp.float32)
        scores = scores / jnp.sqrt(jnp.float32(hd))
        qpos = idx * q_chunk + jnp.arange(q_chunk)
        mask = jnp.ones((q_chunk, t), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return None, jnp.einsum("bhst,bthd->bshd", probs, v)

    _, out = jax.lax.scan(per_chunk, None, (qc, jnp.arange(n_chunks)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def attn_apply(
    p: Dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float],
    causal: bool = True,
    window: int = 0,
    impl: str = "ref",
    compute_dtype=jnp.bfloat16,
    n_heads_layout: Optional[int] = None,
) -> jnp.ndarray:
    """Self-attention over a full sequence (training / prefill)."""
    hl = n_heads_layout or n_heads
    q = _split_heads(linear(p["wq"], x, compute_dtype), hl, head_dim)
    k = _split_heads(linear(p["wk"], x, compute_dtype), n_kv_heads, head_dim)
    v = _split_heads(linear(p["wv"], x, compute_dtype), n_kv_heads, head_dim)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    kk = _pad_heads(_repeat_kv(k, n_heads), hl)
    vv = _pad_heads(_repeat_kv(v, n_heads), hl)
    if impl == "flash":
        from repro.kernels.flash_attention import ops as flash_ops

        out = flash_ops.flash_attention(q, kk, vv, causal=causal, window=window)
    elif impl == "blocked":
        out = _sdpa_blocked(q, kk, vv, causal=causal, window=window)
    else:
        out = _sdpa(q, kk, vv, causal=causal, window=window)
    mask = _head_mask(n_heads, hl, out.dtype)
    if mask is not None:
        out = out * mask
    return linear(p["wo"], out.reshape(*x.shape[:-1], hl * head_dim), compute_dtype)


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(
    batch: int, length: int, n_kv_heads: int, head_dim: int, dtype=jnp.bfloat16
) -> Dict:
    """Contiguous cache (full attention) or ring buffer (window attention --
    pass length=window). ``pos`` is the absolute next-token position."""
    return {
        "k": jnp.zeros((batch, length, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, length, n_kv_heads, head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def attn_decode(
    p: Dict,
    x: jnp.ndarray,
    cache: Dict,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float],
    ring: bool = False,
    compute_dtype=jnp.bfloat16,
    n_heads_layout: Optional[int] = None,
    impl: str = "ref",
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode: x (B,1,d) against the cache.

    ``ring=True`` treats the cache as a sliding-window ring buffer of size
    ``cache_len`` (keys stay rope'd at absolute positions, so relative
    geometry is preserved regardless of buffer rotation).
    """
    b = x.shape[0]
    cache_len = cache["k"].shape[1]
    pos = cache["pos"]
    positions = jnp.full((b, 1), pos, jnp.int32)

    hl = n_heads_layout or n_heads
    q = _split_heads(linear(p["wq"], x, compute_dtype), hl, head_dim)
    k = _split_heads(linear(p["wk"], x, compute_dtype), n_kv_heads, head_dim)
    v = _split_heads(linear(p["wv"], x, compute_dtype), n_kv_heads, head_dim)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    slot = pos % cache_len if ring else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    n_valid = jnp.minimum(pos + 1, cache_len)
    if impl == "decode_kernel":
        # fused Pallas path: K/V stream through VMEM once (TPU target;
        # interpret mode on CPU). The kernel works on LOGICAL heads (its
        # GQA index_map needs n_heads % n_kv == 0); padded layout heads
        # are zero anyway, so slice in and pad back out.
        from repro.kernels.decode_attention import ops as dec_ops

        out = dec_ops.decode_attention(
            q[:, :, :n_heads],
            ck.astype(compute_dtype),
            cv.astype(compute_dtype),
            jnp.broadcast_to(n_valid, (b,)),
        )
        out = _pad_heads(out, hl)
    else:
        if ring:
            valid = jnp.broadcast_to(jnp.arange(cache_len)[None] < n_valid, (b, cache_len))
        else:
            valid = jnp.broadcast_to(jnp.arange(cache_len)[None] <= pos, (b, cache_len))
        out = _sdpa(
            q,
            _pad_heads(_repeat_kv(ck.astype(compute_dtype), n_heads), hl),
            _pad_heads(_repeat_kv(cv.astype(compute_dtype), n_heads), hl),
            causal=False,  # validity mask already encodes the horizon
            window=0,
            kv_valid=valid,
        )
    mask = _head_mask(n_heads, hl, out.dtype)
    if mask is not None:
        out = out * mask
    out = linear(p["wo"], out.reshape(b, 1, hl * head_dim), compute_dtype)
    return out, {"k": ck, "v": cv, "pos": pos + 1}


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_init(key, d_model: int, n_heads: int, head_dim: int, dtype) -> Dict:
    return attn_init(key, d_model, n_heads, n_heads, head_dim, dtype, qkv_bias=True)


def precompute_cross_kv(
    p: Dict, enc_out: jnp.ndarray, n_heads: int, head_dim: int, compute_dtype=jnp.bfloat16
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = _split_heads(linear(p["wk"], enc_out, compute_dtype), n_heads, head_dim)
    v = _split_heads(linear(p["wv"], enc_out, compute_dtype), n_heads, head_dim)
    return k, v


def cross_attn_apply(
    p: Dict,
    x: jnp.ndarray,
    kv: Tuple[jnp.ndarray, jnp.ndarray],
    *,
    n_heads: int,
    head_dim: int,
    compute_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Decoder queries attend (unmasked) over precomputed encoder K/V."""
    q = _split_heads(linear(p["wq"], x, compute_dtype), n_heads, head_dim)
    k, v = kv
    out = _sdpa(q, k, v, causal=False, window=0)
    return linear(p["wo"], out.reshape(*x.shape[:-1], n_heads * head_dim), compute_dtype)
