"""RG-LRU recurrent block (RecurrentGemma / Griffin [arXiv:2402.19427]).

Block structure (the "recurrent block" of Griffin):

    x -> in_proj -> branch1 -> conv1d(width 4) -> RG-LRU -> *gelu(branch2) -> out_proj

RG-LRU recurrence (per channel):

    r_t = sigmoid(x_t W_a + b_a)          recurrence gate
    i_t = sigmoid(x_t W_x + b_x)          input gate
    log a_t = -c * softplus(lambda) * r_t (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The training/prefill path uses ``jax.lax.associative_scan`` (O(log T)
parallel depth -- the TPU-friendly formulation); the naive scan oracle
lives in kernels/rglru_scan/ref.py and the blocked Pallas kernel in
kernels/rglru_scan/.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, linear, normal_init

PyTree = Any
RGLRU_C = 8.0

__all__ = ["rglru_block_init", "rglru_block_apply", "rglru_decode_state", "rglru_scan_assoc"]


def rglru_block_init(key, d_model: int, width: int, conv_width: int, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    # lambda init so that a^c = sigmoid(lambda)^c is spread in (0.9, 0.999)
    lam = jax.random.uniform(ks[4], (width,), jnp.float32, 2.0, 6.0)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * width, dtype, bias=True),
        "conv_w": normal_init(ks[1], (conv_width, width), conv_width**-0.5, dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "gate_a": dense_init(ks[2], width, width, dtype, bias=True),
        "gate_x": dense_init(ks[3], width, width, dtype, bias=True),
        "lam": lam,
        "out_proj": dense_init(ks[5], width, d_model, dtype, bias=True),
    }


def _causal_conv1d(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, state: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x: (B,S,W); w: (K,W); state: (B,K-1,W) holds
    the trailing inputs of the previous segment."""
    k = w.shape[0]
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+K-1, W)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k)
    ) + b.astype(x.dtype)
    return out, xp[:, -(k - 1) :].astype(state.dtype)


def rglru_scan_assoc(
    log_a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t h_{t-1} + bx_t via associative_scan over the time axis.

    log_a, bx: (B, S, W) fp32; h0: (B, W). Returns (h (B,S,W), h_last).
    The initial state is folded in as a virtual step with a=1? No --
    we prepend it as bx_0 scaled appropriately by composing after the scan:
    h_t = (prod a_{1..t}) h0 + scan_t.
    """

    def combine(c1, c2):
        la1, b1 = c1
        la2, b2 = c2
        return la1 + la2, jnp.exp(la2) * b1 + b2

    la_cum, b_cum = jax.lax.associative_scan(combine, (log_a, bx), axis=1)
    h = b_cum + jnp.exp(la_cum) * h0[:, None]
    return h, h[:, -1]


def rglru_block_apply(
    p: Dict,
    x: jnp.ndarray,
    state: Dict,
    compute_dtype=jnp.bfloat16,
    impl: str = "ref",
) -> Tuple[jnp.ndarray, Dict]:
    """x: (B,S,d) -> (out (B,S,d), new_state {h, conv})."""
    width = p["lam"].shape[0]
    xw = linear(p["in_proj"], x, compute_dtype)
    u, gate_branch = jnp.split(xw, 2, axis=-1)
    u, conv_state = _causal_conv1d(u, p["conv_w"], p["conv_b"], state["conv"])

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(linear(p["gate_a"], u, compute_dtype).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(p["gate_x"], u, compute_dtype).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"])[None, None] * r  # (B,S,W) <= 0
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = beta * (i * uf)

    if impl == "pallas":
        from repro.kernels.rglru_scan import ops as rglru_ops

        h, h_last = rglru_ops.rglru_scan(log_a, bx, state["h"])
    else:
        h, h_last = rglru_scan_assoc(log_a, bx, state["h"])

    y = h.astype(compute_dtype) * jax.nn.gelu(gate_branch)
    out = linear(p["out_proj"], y, compute_dtype)
    return out, {"h": h_last, "conv": conv_state}


def rglru_decode_state(batch: int, width: int, conv_width: int) -> Dict:
    return {
        "h": jnp.zeros((batch, width), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, width), jnp.float32),
    }
