"""The paper's own model: a shallow neural network over 42-dim EHR features.

Section 3: "we train a shallow neural network at each node with a problem
dimension of 42" -- a 2-layer tanh MLP classifying AD vs MCI from the
42 engineered EHR features. This is the model the Fig. 2 reproduction
trains with DSGD / DSGT / FD variants.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

import numpy as np

from repro.models.layers import dense_init, linear

PyTree = Any

__all__ = [
    "mlp_init",
    "mlp_logits",
    "mlp_loss",
    "make_mlp_loss",
    "mlp_accuracy",
    "mlp_balanced_accuracy",
]


def mlp_init(key, d_in: int = 42, d_hidden: int = 32, n_classes: int = 2) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "fc1": dense_init(k1, d_in, d_hidden, jnp.float32, bias=True),
        "fc2": dense_init(k2, d_hidden, n_classes, jnp.float32, bias=True),
    }


def mlp_logits(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.tanh(linear(params["fc1"], x, jnp.float32))
    return linear(params["fc2"], h, jnp.float32)


def make_mlp_loss(class_weight=None):
    """Build the per-node loss, optionally class-weighted.

    ``class_weight``: a length-``n_classes`` array of per-class weights
    (e.g. inverse-frequency from ``configs.ehr_mlp.class_weights``), or
    None for the plain unweighted cross-entropy. The weighted loss is the
    weight-normalized mean ``sum_i w_{y_i} ce_i / sum_i w_{y_i}`` so its
    scale -- and hence the usable alpha range -- matches the unweighted
    loss. On the 79%-MCI synthetic cohort the unweighted optimum barely
    moves the AD (minority) decision boundary, saturating balanced
    accuracy near 0.6; inverse-frequency weighting makes both classes
    carry equal gradient mass (asserted in tests/test_training_e2e.py).
    """
    weights = None if class_weight is None else jnp.asarray(
        np.asarray(class_weight), jnp.float32
    )

    def loss(params: Dict, batch: Dict) -> jnp.ndarray:
        """batch: {"x": (m, 42), "y": (m,) int32} -> mean cross-entropy."""
        logits = mlp_logits(params, batch["x"]).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
        ce = logz - gold
        if weights is None:
            return jnp.mean(ce)
        w = weights[batch["y"]]
        return jnp.sum(w * ce) / jnp.maximum(jnp.sum(w), 1e-6)

    return loss


mlp_loss = make_mlp_loss()  # the paper-faithful unweighted loss


def mlp_accuracy(params: Dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(mlp_logits(params, x), axis=-1) == y).astype(jnp.float32))


def mlp_balanced_accuracy(params: Dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean per-class recall (chance = 0.5 for the 2-class cohort) -- the
    metric the class-imbalance work targets; plain accuracy saturates at
    the 79% majority rate."""
    pred = jnp.argmax(mlp_logits(params, x), axis=-1)
    accs = []
    for k in (0, 1):
        mask = (y == k).astype(jnp.float32)
        hit = ((pred == k).astype(jnp.float32) * mask).sum()
        accs.append(hit / jnp.maximum(mask.sum(), 1.0))
    return (accs[0] + accs[1]) / 2.0
