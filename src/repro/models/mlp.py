"""The paper's own model: a shallow neural network over 42-dim EHR features.

Section 3: "we train a shallow neural network at each node with a problem
dimension of 42" -- a 2-layer tanh MLP classifying AD vs MCI from the
42 engineered EHR features. This is the model the Fig. 2 reproduction
trains with DSGD / DSGT / FD variants.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, linear

PyTree = Any

__all__ = ["mlp_init", "mlp_logits", "mlp_loss", "mlp_accuracy"]


def mlp_init(key, d_in: int = 42, d_hidden: int = 32, n_classes: int = 2) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "fc1": dense_init(k1, d_in, d_hidden, jnp.float32, bias=True),
        "fc2": dense_init(k2, d_hidden, n_classes, jnp.float32, bias=True),
    }


def mlp_logits(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.tanh(linear(params["fc1"], x, jnp.float32))
    return linear(params["fc2"], h, jnp.float32)


def mlp_loss(params: Dict, batch: Dict) -> jnp.ndarray:
    """batch: {"x": (m, 42), "y": (m,) int32} -> mean cross-entropy."""
    logits = mlp_logits(params, batch["x"]).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def mlp_accuracy(params: Dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(mlp_logits(params, x), axis=-1) == y).astype(jnp.float32))
