"""Decoder-stack assembly for all decoder-only families.

Composition rules:
  * homogeneous stacks (dense / moe / ssm) scan over layer-stacked params
    (HLO size O(1) in depth -- essential for the 64-layer dry-runs) with an
    optional remat (activation-checkpoint) policy;
  * patterned stacks (hybrid: RecurrentGemma's recurrent/recurrent/local-
    attention) unroll with per-layer param trees.

Functions are pure; parameters are nested dicts. Each block kind implements
(train, decode) pairs and a decode-state initializer. ``impl`` routes the
attention / recurrence inner loops to "ref" (pure jnp) or Pallas kernels.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import (
    chunked_softmax_xent,
    embed_init,
    embed_lookup,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
    unembed_logits,
)

PyTree = Any

__all__ = [
    "init_params",
    "forward_hidden",
    "lm_loss",
    "prefill",
    "decode_step",
    "init_decode_state",
]


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, kind: str) -> Dict:
    dt = _pdtype(cfg)
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind in ("attention", "local_attention"):
        return {
            "ln1": rmsnorm_init(d, dt),
            "attn": attn.attn_init(
                k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt, cfg.qkv_bias,
                n_heads_layout=attn.layout_heads(cfg.n_heads, cfg.tp_head_pad),
            ),
            "ln2": rmsnorm_init(d, dt),
            "mlp": swiglu_init(k2, d, cfg.d_ff, dt),
        }
    if kind == "moe":
        return {
            "ln1": rmsnorm_init(d, dt),
            "attn": attn.attn_init(
                k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt, cfg.qkv_bias,
                n_heads_layout=attn.layout_heads(cfg.n_heads, cfg.tp_head_pad),
            ),
            "ln2": rmsnorm_init(d, dt),
            "moe": moe_mod.moe_init(k2, d, cfg.d_ff, cfg.n_experts, dt, cfg.shared_expert),
        }
    if kind == "rwkv":
        return {
            "ln1": rmsnorm_init(d, dt),
            "ln2": rmsnorm_init(d, dt),
            "rwkv": rwkv_mod.rwkv_block_init(k1, d, cfg.d_ff, dt),
        }
    if kind == "recurrent":
        width = cfg.rnn_width or d
        return {
            "ln1": rmsnorm_init(d, dt),
            "rglru": rglru_mod.rglru_block_init(k1, d, width, cfg.conv_width, dt),
            "ln2": rmsnorm_init(d, dt),
            "mlp": swiglu_init(k2, d, cfg.d_ff, dt),
        }
    raise ValueError(f"unknown block kind {kind}")


def apply_block_train(
    p: Dict,
    kind: str,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    impl: str,
    carry_state: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence (train / prefill) block. Returns (x, aux_loss)."""
    cd = _cdtype(cfg)
    aux = jnp.float32(0.0)
    eps = cfg.norm_eps
    if kind in ("attention", "local_attention", "moe"):
        window = cfg.window if kind == "local_attention" else 0
        h = attn.attn_apply(
            p["attn"],
            rmsnorm(p["ln1"], x, eps),
            positions,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
            causal=True,
            window=window,
            impl=impl,
            compute_dtype=cd,
            n_heads_layout=attn.layout_heads(cfg.n_heads, cfg.tp_head_pad),
        )
        x = x + h
        if kind == "moe":
            m, aux = moe_mod.moe_apply(
                p["moe"],
                rmsnorm(p["ln2"], x, eps),
                n_experts=cfg.n_experts,
                k=cfg.experts_per_token,
                capacity_factor=cfg.moe_capacity_factor,
                compute_dtype=cd,
            )
        else:
            m = swiglu(p["mlp"], rmsnorm(p["ln2"], x, eps), cd)
        return x + m, aux
    if kind == "rwkv":
        b, s, d = x.shape
        st = carry_state or rwkv_mod.rwkv_decode_states(b, d)
        h, _, _ = rwkv_mod.rwkv_time_mix(
            p["rwkv"]["time"], rmsnorm(p["ln1"], x, eps), st["tm_prev"], st["s"], cd, impl=impl
        )
        x = x + h
        c, _ = rwkv_mod.rwkv_channel_mix(
            p["rwkv"]["channel"], rmsnorm(p["ln2"], x, eps), st["cm_prev"], cd
        )
        return x + c, aux
    if kind == "recurrent":
        b = x.shape[0]
        width = cfg.rnn_width or cfg.d_model
        st = carry_state or rglru_mod.rglru_decode_state(b, width, cfg.conv_width)
        h, _ = rglru_mod.rglru_block_apply(p["rglru"], rmsnorm(p["ln1"], x, eps), st, cd, impl=impl)
        x = x + h
        m = swiglu(p["mlp"], rmsnorm(p["ln2"], x, eps), cd)
        return x + m, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# whole-stack init / forward
# ---------------------------------------------------------------------------


def _period_split(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(period, n_periods, n_tail) for patterned stacks. Periods are scanned
    when n_periods >= 2 (compile-time O(1) in depth); the tail unrolls."""
    period = len(cfg.block_pattern) or 1
    n_periods = cfg.n_layers // period
    if n_periods < 2:
        return period, 0, cfg.n_layers
    return period, n_periods, cfg.n_layers - n_periods * period


def init_params(cfg: ModelConfig, key) -> Dict:
    dt = _pdtype(cfg)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dt),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(k_head, cfg.padded_vocab, cfg.d_model, dt)
    pattern = cfg.effective_pattern
    keys = jax.random.split(k_blocks, cfg.n_layers)
    if cfg.is_homogeneous:
        params["blocks"] = jax.vmap(lambda k: init_block(k, cfg, pattern[0]))(keys)
    else:
        period, n_periods, n_tail = _period_split(cfg)
        if n_periods:
            # one layer-stacked tree per position in the repeating pattern
            params["pblocks"] = [
                jax.vmap(lambda k, pos=pos: init_block(k, cfg, pattern[pos]))(
                    jnp.stack([keys[p * period + pos] for p in range(n_periods)])
                )
                for pos in range(period)
            ]
            params["tail"] = [
                init_block(keys[n_periods * period + i], cfg, pattern[n_periods * period + i])
                for i in range(n_tail)
            ]
        else:
            params["blocks"] = [init_block(keys[i], cfg, pattern[i]) for i in range(cfg.n_layers)]
    return params


def _layer_params(params: Dict, cfg: ModelConfig, i: int) -> Dict:
    """Per-layer param tree regardless of storage layout (used by decode)."""
    if "blocks" in params and cfg.is_homogeneous:
        return jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
    if "pblocks" in params:
        period, n_periods, _ = _period_split(cfg)
        if i < n_periods * period:
            p, pos = divmod(i, period)
            return jax.tree_util.tree_map(lambda a: a[p], params["pblocks"][pos])
        return params["tail"][i - n_periods * period]
    return params["blocks"][i]


def forward_hidden(
    params: Dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    impl: str = "ref",
    remat: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Embedded inputs (B,S,d) -> final hidden (B,S,d), total aux loss."""
    pattern = cfg.effective_pattern
    if cfg.is_homogeneous:
        kind = pattern[0]

        def body(carry, layer_params):
            h, aux = carry
            h2, a = apply_block_train(layer_params, kind, cfg, h, positions, impl)
            return (h2, aux + a), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    else:
        aux = jnp.float32(0.0)
        period, n_periods, n_tail = _period_split(cfg)

        def one_layer(blk_, h, pos, kind):
            return apply_block_train(blk_, kind, cfg, h, pos, impl)

        if "pblocks" in params and n_periods:

            def period_body(carry, stacked_blks):
                h, a = carry
                for pos in range(period):
                    fn = functools.partial(one_layer, kind=pattern[pos])
                    if remat:
                        fn = jax.checkpoint(fn, prevent_cse=False)
                    h, ai = fn(stacked_blks[pos], h, positions)
                    a = a + ai
                return (h, a), None

            (x, aux), _ = jax.lax.scan(
                period_body, (x, aux), tuple(params["pblocks"])
            )
            tail_blocks = params.get("tail", [])
            tail_kinds = pattern[n_periods * period :]
        else:
            tail_blocks = params["blocks"]
            tail_kinds = pattern
        for blk, kind in zip(tail_blocks, tail_kinds):
            fn = functools.partial(one_layer, kind=kind)
            if remat:
                fn = jax.checkpoint(fn, prevent_cse=False)
            x, a = fn(blk, x, positions)
            aux = aux + a
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def _embed_inputs(
    params: Dict, cfg: ModelConfig, batch: Dict
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (embedded (B,S,d), positions (B,S), labels (B,S))."""
    cd = _cdtype(cfg)
    tokens = batch["tokens"]  # (B, S+1): inputs + shifted labels
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    emb = embed_lookup(params["embed"], inputs, cd)
    if cfg.frontend != "none" and "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(cd)  # (B, P, d) stubbed frontend
        emb = jnp.concatenate([pre, emb], axis=1)
        labels = jnp.concatenate(
            [jnp.full(pre.shape[:2], -1, labels.dtype), labels], axis=1
        )
    b, s, _ = emb.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return emb, positions, labels


def lm_loss(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict,
    impl: str = "ref",
    remat: bool = True,
    loss_chunk: int = 512,
) -> jnp.ndarray:
    """Next-token cross-entropy (mean over valid tokens) + MoE aux."""
    emb, positions, labels = _embed_inputs(params, cfg, batch)
    h, aux = forward_hidden(params, cfg, emb, positions, impl, remat)
    table = params["embed" if cfg.tie_embeddings else "head"]["table"]
    loss = chunked_softmax_xent(
        table, h, labels, cfg.vocab_size, chunk=loss_chunk, compute_dtype=_cdtype(cfg)
    )
    return loss + cfg.router_aux_coef * aux


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------


def _decode_kinds(cfg: ModelConfig, max_seq: int, sliding_override: bool) -> Tuple[Tuple[str, int], ...]:
    """(kind, cache_len) per layer. ``sliding_override`` replaces full
    attention with a window ring buffer (the long_500k policy for dense
    archs -- see DESIGN.md)."""
    out = []
    for kind in cfg.effective_pattern:
        if kind in ("attention", "moe"):
            if sliding_override:
                out.append((kind, min(cfg.window or 4096, max_seq)))
            else:
                out.append((kind, max_seq))
        elif kind == "local_attention":
            out.append((kind, min(cfg.window or max_seq, max_seq)))
        else:
            out.append((kind, 0))
    return tuple(out)


def init_decode_state(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    sliding_override: bool = False,
    cache_dtype=jnp.bfloat16,
) -> Any:
    """Per-layer decode caches. Homogeneous stacks get layer-stacked caches
    (scanned decode); patterned stacks get a list."""
    kinds = _decode_kinds(cfg, max_seq, sliding_override)

    def one(kind: str, cache_len: int):
        if kind in ("attention", "moe", "local_attention"):
            return attn.init_kv_cache(batch, cache_len, cfg.n_kv_heads, cfg.head_dim, cache_dtype)
        if kind == "rwkv":
            return rwkv_mod.rwkv_decode_states(batch, cfg.d_model)
        if kind == "recurrent":
            return rglru_mod.rglru_decode_state(batch, cfg.rnn_width or cfg.d_model, cfg.conv_width)
        raise ValueError(kind)

    if cfg.is_homogeneous:
        single = one(*kinds[0])
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), single
        )
    return [one(k, c) for k, c in kinds]


def apply_block_decode(
    p: Dict, kind: str, cfg: ModelConfig, x: jnp.ndarray, state: Any, ring: bool
) -> Tuple[jnp.ndarray, Any]:
    cd = _cdtype(cfg)
    eps = cfg.norm_eps
    if kind in ("attention", "local_attention", "moe"):
        h, state = attn.attn_decode(
            p["attn"],
            rmsnorm(p["ln1"], x, eps),
            state,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
            ring=ring or kind == "local_attention",
            compute_dtype=cd,
            n_heads_layout=attn.layout_heads(cfg.n_heads, cfg.tp_head_pad),
        )
        x = x + h
        if kind == "moe":
            m, _ = moe_mod.moe_apply(
                p["moe"],
                rmsnorm(p["ln2"], x, eps),
                n_experts=cfg.n_experts,
                k=cfg.experts_per_token,
                capacity_factor=cfg.moe_capacity_factor,
                compute_dtype=cd,
            )
        else:
            m = swiglu(p["mlp"], rmsnorm(p["ln2"], x, eps), cd)
        return x + m, state
    if kind == "rwkv":
        h, tm_prev, s_new = rwkv_mod.rwkv_time_mix(
            p["rwkv"]["time"], rmsnorm(p["ln1"], x, eps), state["tm_prev"], state["s"], cd, chunk=1
        )
        x = x + h
        c, cm_prev = rwkv_mod.rwkv_channel_mix(
            p["rwkv"]["channel"], rmsnorm(p["ln2"], x, eps), state["cm_prev"], cd
        )
        return x + c, {"tm_prev": tm_prev, "cm_prev": cm_prev, "s": s_new}
    if kind == "recurrent":
        h, state2 = rglru_mod.rglru_block_apply(p["rglru"], rmsnorm(p["ln1"], x, eps), state, cd)
        x = x + h
        m = swiglu(p["mlp"], rmsnorm(p["ln2"], x, eps), cd)
        return x + m, state2
    raise ValueError(kind)


def decode_step(
    params: Dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    caches: Any,
    sliding_override: bool = False,
) -> Tuple[jnp.ndarray, Any]:
    """One decode step: tokens (B,) -> (logits (B, padded_vocab), caches)."""
    cd = _cdtype(cfg)
    x = embed_lookup(params["embed"], tokens[:, None], cd)  # (B,1,d)
    pattern = cfg.effective_pattern
    if cfg.is_homogeneous:
        kind = pattern[0]

        def body(h, xs):
            layer_params, layer_cache = xs
            h2, new_cache = apply_block_decode(
                layer_params, kind, cfg, h, layer_cache, ring=sliding_override
            )
            return h2, new_cache

        x, caches = jax.lax.scan(body, x, (params["blocks"], caches))
    else:
        new_caches = []
        for i, kind in enumerate(pattern):
            x, c = apply_block_decode(
                _layer_params(params, cfg, i), kind, cfg, x, caches[i], ring=sliding_override
            )
            new_caches.append(c)
        caches = new_caches
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed" if cfg.tie_embeddings else "head"]["table"]
    logits = unembed_logits(table, x[:, 0], cd)
    return logits, caches


def prefill(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict,
    impl: str = "ref",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward returning last-position logits (B, padded_vocab).

    (The production engine would also materialize KV caches; for the
    dry-run roofline the compute/collective profile of prefill is what
    matters, and cache writes are pure stores.)
    """
    cd = _cdtype(cfg)
    tokens = batch["tokens"]
    emb = embed_lookup(params["embed"], tokens, cd)
    if cfg.frontend != "none" and "prefix_embeds" in batch:
        emb = jnp.concatenate([batch["prefix_embeds"].astype(cd), emb], axis=1)
    b, s, _ = emb.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    h, _ = forward_hidden(params, cfg, emb, positions, impl, remat=False)
    table = params["embed" if cfg.tie_embeddings else "head"]["table"]
    return unembed_logits(table, h[:, -1], cd), h[:, -1]
