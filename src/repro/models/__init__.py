"""Model substrate: composable decoder stacks in pure functional JAX.

Families: dense (llama/phi/qwen-style GQA+RoPE+SwiGLU), moe (dbrx/llama4
expert-parallel), ssm (RWKV-6), hybrid (RecurrentGemma RG-LRU + local
attention), audio (whisper enc-dec, conv frontend stubbed), vlm (InternVL2
LM backbone, ViT stubbed).
"""

from repro.models.model import build_model, ModelBundle

__all__ = ["build_model", "ModelBundle"]
