"""Whisper-style encoder--decoder backbone [arXiv:2212.04356].

Per the task spec the mel-spectrogram + conv feature extractor is a STUB:
``input_specs()`` supplies precomputed frame embeddings (B, 1500, d_model);
this module implements the transformer that consumes them.

Encoder: learned positions, bidirectional attention, GELU MLP, pre-LN.
Decoder: token + learned positional embeddings, causal self-attention,
cross-attention over encoder output, GELU MLP. Whisper's published decoder
context is 448; the generic decode_32k stress shape uses a 32k learned
position table (recorded as an adaptation in DESIGN.md).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    chunked_softmax_xent,
    embed_init,
    embed_lookup,
    gelu_mlp,
    gelu_mlp_init,
    layernorm,
    layernorm_init,
    normal_init,
    unembed_logits,
)

PyTree = Any

__all__ = [
    "encdec_init",
    "encode",
    "encdec_loss",
    "encdec_prefill",
    "encdec_decode_step",
    "encdec_init_decode_state",
    "DEC_POS_LEN",
]

DEC_POS_LEN = 32768  # decode_32k stress shape (whisper native: 448)


def _enc_block_init(key, d: int, n_heads: int, d_ff: int, dt) -> Dict:
    k1, k2 = jax.random.split(key)
    hd = d // n_heads
    return {
        "ln1": layernorm_init(d, dt),
        "attn": attn.attn_init(k1, d, n_heads, n_heads, hd, dt, qkv_bias=True),
        "ln2": layernorm_init(d, dt),
        "mlp": gelu_mlp_init(k2, d, d_ff, dt),
    }


def _dec_block_init(key, cfg: ModelConfig, dt) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": layernorm_init(d, dt),
        "self_attn": attn.attn_init(
            k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt, qkv_bias=True
        ),
        "ln2": layernorm_init(d, dt),
        "cross_attn": attn.cross_attn_init(k2, d, cfg.n_heads, cfg.head_dim, dt),
        "ln3": layernorm_init(d, dt),
        "mlp": gelu_mlp_init(k3, d, cfg.d_ff, dt),
    }


def encdec_init(cfg: ModelConfig, key) -> Dict:
    assert cfg.encoder is not None
    dt = jnp.dtype(cfg.param_dtype)
    e = cfg.encoder
    k_ep, k_eb, k_de, k_dp, k_db = jax.random.split(key, 5)
    enc_keys = jax.random.split(k_eb, e.n_layers)
    dec_keys = jax.random.split(k_db, cfg.n_layers)
    return {
        "enc": {
            "pos": normal_init(k_ep, (e.seq_len, e.d_model), 0.02, dt),
            "blocks": jax.vmap(
                lambda k: _enc_block_init(k, e.d_model, e.n_heads, e.d_ff, dt)
            )(enc_keys),
            "final_ln": layernorm_init(e.d_model, dt),
        },
        "dec": {
            "embed": embed_init(k_de, cfg.padded_vocab, cfg.d_model, dt),
            "pos": normal_init(k_dp, (DEC_POS_LEN, cfg.d_model), 0.02, dt),
            "blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, dt))(dec_keys),
            "final_ln": layernorm_init(cfg.d_model, dt),
        },
    }


def encode(params: Dict, cfg: ModelConfig, frames: jnp.ndarray, impl: str = "ref") -> jnp.ndarray:
    """frames: stubbed conv-frontend embeddings (B, T_enc, d)."""
    cd = jnp.dtype(cfg.compute_dtype)
    e = cfg.encoder
    x = frames.astype(cd) + params["enc"]["pos"].astype(cd)[None, : frames.shape[1]]
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(h, blk):
        a = attn.attn_apply(
            blk["attn"],
            layernorm(blk["ln1"], h, cfg.norm_eps),
            positions,
            n_heads=e.n_heads,
            n_kv_heads=e.n_heads,
            head_dim=e.d_model // e.n_heads,
            rope_theta=None,
            causal=False,
            impl=impl,
            compute_dtype=cd,
        )
        h = h + a
        h = h + gelu_mlp(blk["mlp"], layernorm(blk["ln2"], h, cfg.norm_eps), cd)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc"]["blocks"])
    return layernorm(params["enc"]["final_ln"], x, cfg.norm_eps)


def _decode_hidden(
    params: Dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    enc_out: jnp.ndarray,
    impl: str,
    remat: bool,
) -> jnp.ndarray:
    cd = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    x = embed_lookup(params["dec"]["embed"], tokens, cd)
    x = x + params["dec"]["pos"].astype(cd)[None, :s]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, blk):
        a = attn.attn_apply(
            blk["self_attn"],
            layernorm(blk["ln1"], h, cfg.norm_eps),
            positions,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            rope_theta=None,
            causal=True,
            impl=impl,
            compute_dtype=cd,
        )
        h = h + a
        kv = attn.precompute_cross_kv(
            blk["cross_attn"], enc_out, cfg.n_heads, cfg.head_dim, cd
        )
        c = attn.cross_attn_apply(
            blk["cross_attn"],
            layernorm(blk["ln2"], h, cfg.norm_eps),
            kv,
            n_heads=cfg.n_heads,
            head_dim=cfg.head_dim,
            compute_dtype=cd,
        )
        h = h + c
        h = h + gelu_mlp(blk["mlp"], layernorm(blk["ln3"], h, cfg.norm_eps), cd)
        return h, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec"]["blocks"])
    return layernorm(params["dec"]["final_ln"], x, cfg.norm_eps)


def encdec_loss(
    params: Dict, cfg: ModelConfig, batch: Dict, impl: str = "ref", remat: bool = True
) -> jnp.ndarray:
    """batch: {"frames": (B,T_enc,d_enc), "tokens": (B,S+1)}."""
    enc_out = encode(params, cfg, batch["frames"], impl)
    tokens = batch["tokens"]
    h = _decode_hidden(params, cfg, tokens[:, :-1], enc_out, impl, remat)
    return chunked_softmax_xent(
        params["dec"]["embed"]["table"],
        h,
        tokens[:, 1:],
        cfg.vocab_size,
        compute_dtype=jnp.dtype(cfg.compute_dtype),
    )


def encdec_prefill(
    params: Dict, cfg: ModelConfig, batch: Dict, impl: str = "ref"
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    enc_out = encode(params, cfg, batch["frames"], impl)
    h = _decode_hidden(params, cfg, batch["tokens"], enc_out, impl, remat=False)
    logits = unembed_logits(
        params["dec"]["embed"]["table"], h[:, -1], jnp.dtype(cfg.compute_dtype)
    )
    return logits, enc_out


def encdec_init_decode_state(
    cfg: ModelConfig, batch: int, max_seq: int, cache_dtype=jnp.bfloat16
) -> Dict:
    """Self-attn KV caches (layer-stacked) + per-layer precomputed cross KV
    placeholders (filled by the engine after encode())."""
    e = cfg.encoder
    kv = attn.init_kv_cache(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, cache_dtype)
    stacked = jax.tree_util.tree_map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), kv
    )
    cross = jnp.zeros(
        (cfg.n_layers, batch, e.seq_len, cfg.n_heads, cfg.head_dim), cache_dtype
    )
    return {"self": stacked, "cross_k": cross, "cross_v": cross}


def encdec_fill_cross_kv(params: Dict, cfg: ModelConfig, enc_out: jnp.ndarray, state: Dict) -> Dict:
    cd = jnp.dtype(cfg.compute_dtype)

    def per_layer(blk):
        k, v = attn.precompute_cross_kv(blk, enc_out, cfg.n_heads, cfg.head_dim, cd)
        return k.astype(state["cross_k"].dtype), v.astype(state["cross_v"].dtype)

    ks, vs = jax.vmap(per_layer)(
        jax.tree_util.tree_map(lambda a: a, params["dec"]["blocks"]["cross_attn"])
    )
    return {**state, "cross_k": ks, "cross_v": vs}


def encdec_decode_step(
    params: Dict, cfg: ModelConfig, tokens: jnp.ndarray, state: Dict
) -> Tuple[jnp.ndarray, Dict]:
    """One decoder token against self-cache + cross KV. tokens: (B,)."""
    cd = jnp.dtype(cfg.compute_dtype)
    pos = state["self"]["pos"][0]
    x = embed_lookup(params["dec"]["embed"], tokens[:, None], cd)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec"]["pos"].astype(cd), pos, 1, axis=0
    )[None]

    def body(h, xs):
        blk, self_cache, ck, cv = xs
        a, self_cache = attn.attn_decode(
            blk["self_attn"],
            layernorm(blk["ln1"], h, cfg.norm_eps),
            self_cache,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            rope_theta=None,
            compute_dtype=cd,
        )
        h = h + a
        c = attn.cross_attn_apply(
            blk["cross_attn"],
            layernorm(blk["ln2"], h, cfg.norm_eps),
            (ck.astype(cd), cv.astype(cd)),
            n_heads=cfg.n_heads,
            head_dim=cfg.head_dim,
            compute_dtype=cd,
        )
        h = h + c
        h = h + gelu_mlp(blk["mlp"], layernorm(blk["ln3"], h, cfg.norm_eps), cd)
        return h, self_cache

    x, new_self = jax.lax.scan(
        body, x, (params["dec"]["blocks"], state["self"], state["cross_k"], state["cross_v"])
    )
    x = layernorm(params["dec"]["final_ln"], x, cfg.norm_eps)
    logits = unembed_logits(params["dec"]["embed"]["table"], x[:, 0], cd)
    return logits, {**state, "self": new_self}
