"""Mixture-of-Experts FFN: top-k router + capacity-based sorted dispatch.

Expert-parallel layout: expert weights carry a leading E axis sharded over
the ``model`` mesh axis (E=16 experts over 16-way TP -> one expert per
device group); the dispatch scatter/gather becomes the all-to-all the MoE
literature expects, inserted by GSPMD around the sharded expert einsum.

Dispatch is the TPU-standard sort-free capacity scheme WITHOUT the O(N*E*C)
one-hot of GShard: assignments are ranked per expert via a stable sort of
expert ids, tokens beyond capacity C = ceil(N*k/E * capacity_factor) are
DROPPED (their combine weight contributes nothing -- the residual stream
carries them), and scatter/gather use a +1 padded row as the drop sink.

FLOP count therefore matches the paper-table expectation:
experts_per_token x N x (3 d d_ff) x capacity_factor, not n_experts x.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, normal_init

PyTree = Any

__all__ = ["moe_init", "moe_apply", "moe_capacity"]


def moe_capacity(n_tokens: int, n_experts: int, k: int, factor: float) -> int:
    cap = int(-(-(n_tokens * k * factor) // n_experts))  # ceil
    # round to a lane-friendly multiple of 8 and keep >= k
    return max(8, ((cap + 7) // 8) * 8)


def moe_init(
    key,
    d_model: int,
    d_ff: int,
    n_experts: int,
    dtype,
    shared_expert: bool = False,
) -> Dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    scale = d_model**-0.5
    p = {
        "router": {"w": normal_init(kr, (d_model, n_experts), scale, jnp.float32)},
        "gate": normal_init(kg, (n_experts, d_model, d_ff), scale, dtype),
        "up": normal_init(ku, (n_experts, d_model, d_ff), scale, dtype),
        "down": normal_init(kd, (n_experts, d_ff, d_model), d_ff**-0.5, dtype),
    }
    if shared_expert:
        from repro.models.layers import swiglu_init

        p["shared"] = swiglu_init(ks, d_model, d_ff, dtype)
    return p


def moe_apply(
    p: Dict,
    x: jnp.ndarray,
    *,
    n_experts: int,
    k: int,
    capacity_factor: float = 1.25,
    compute_dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).

    aux_loss is the standard load-balance term E * sum_e f_e * p_e
    (Switch/GShard), which the trainer scales by ``router_aux_coef``.
    """
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    cap = moe_capacity(n, n_experts, k, capacity_factor)

    # --- routing (fp32) ---
    logits = xf.astype(jnp.float32) @ p["router"]["w"]  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (N, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    # load-balance auxiliary loss
    frac = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], n_experts, dtype=jnp.float32), axis=0
    )
    mean_p = jnp.mean(probs, axis=0)
    aux = jnp.float32(n_experts) * jnp.sum(frac * mean_p)

    # --- rank assignments within each expert (stable sort by expert id) ---
    flat_e = top_e.reshape(-1)  # (N*k,)
    flat_w = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(sorted_e, length=n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(n * k) - starts[sorted_e]
    rank = jnp.zeros((n * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

    keep = rank < cap
    slot = jnp.where(keep, flat_e * cap + rank, n_experts * cap)  # drop sink row

    # --- dispatch: scatter tokens into the (E*C [+1 sink], d) buffer ---
    buf = jnp.zeros((n_experts * cap + 1, d), compute_dtype)
    buf = buf.at[slot].set(xf[flat_tok].astype(compute_dtype))
    buf = buf[: n_experts * cap].reshape(n_experts, cap, d)

    # --- expert computation (expert-parallel einsums) ---
    g = jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(compute_dtype))

    # --- combine: gather back and weight ---
    y_flat = jnp.concatenate(
        [y.reshape(n_experts * cap, d), jnp.zeros((1, d), y.dtype)], axis=0
    )
    contrib = y_flat[slot] * flat_w[:, None].astype(y.dtype)  # dropped rows hit the zero sink
    out = jnp.zeros((n, d), jnp.float32).at[flat_tok].add(contrib.astype(jnp.float32))
    out = out.astype(compute_dtype)

    if "shared" in p:
        from repro.models.layers import swiglu

        out = out + swiglu(p["shared"], xf, compute_dtype)
    return out.reshape(b, s, d), aux
