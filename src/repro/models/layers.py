"""Primitive layers: norms, RoPE, MLPs, embeddings, chunked cross-entropy.

All layers are pure functions over nested-dict parameter pytrees. Matmul
inputs are cast to ``compute_dtype`` (bf16 on TPU) while parameters are
stored in ``param_dtype`` (fp32 for the FL optimizer state); reductions
(norm statistics, softmax, loss) run in fp32.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = [
    "uniform_init",
    "normal_init",
    "dense_init",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "linear",
    "swiglu_init",
    "swiglu",
    "gelu_mlp_init",
    "gelu_mlp",
    "rope_freqs",
    "apply_rope",
    "embed_init",
    "embed_lookup",
    "unembed_logits",
    "chunked_softmax_xent",
    "softmax_xent",
]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def normal_init(key, shape, scale: float, dtype) -> jnp.ndarray:
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def uniform_init(key, shape, scale: float, dtype) -> jnp.ndarray:
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Dict:
    """Fan-in scaled normal init (1/sqrt(d_in)), the llama convention."""
    p = {"w": normal_init(key, (d_in, d_out), d_in**-0.5, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Dict, x: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    y = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_init(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d: int, d_ff: int, dtype) -> Dict:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "gate": dense_init(kg, d, d_ff, dtype),
        "up": dense_init(ku, d, d_ff, dtype),
        "down": dense_init(kd, d_ff, d, dtype),
    }


def swiglu(p: Dict, x: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    g = linear(p["gate"], x, compute_dtype)
    u = linear(p["up"], x, compute_dtype)
    return linear(p["down"], jax.nn.silu(g) * u, compute_dtype)


def gelu_mlp_init(key, d: int, d_ff: int, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "up": dense_init(k1, d, d_ff, dtype, bias=True),
        "down": dense_init(k2, d_ff, d, dtype, bias=True),
    }


def gelu_mlp(p: Dict, x: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    return linear(p["down"], jax.nn.gelu(linear(p["up"], x, compute_dtype)), compute_dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies (head_dim/2,), fp32."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """Rotate (..., seq, heads, head_dim) by per-position angles.

    positions: (..., seq) int32 absolute positions (supports KV-cache decode
    by passing the absolute write position).
    """
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., seq, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads: (..., seq, 1, hd/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / logits / loss
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype, scale: float = 0.02) -> Dict:
    return {"table": normal_init(key, (vocab, d), scale, dtype)}


def embed_lookup(p: Dict, tokens: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    return p["table"].astype(compute_dtype)[tokens]


def unembed_logits(
    table: jnp.ndarray, h: jnp.ndarray, compute_dtype=jnp.bfloat16
) -> jnp.ndarray:
    """h (..., d) @ table^T (v, d) -> (..., v)."""
    return h.astype(compute_dtype) @ table.astype(compute_dtype).T


def softmax_xent(
    logits: jnp.ndarray, labels: jnp.ndarray, valid_vocab: Optional[int] = None
) -> jnp.ndarray:
    """Mean token cross-entropy, fp32. Padded vocab ids are masked out."""
    lf = logits.astype(jnp.float32)
    if valid_vocab is not None and valid_vocab < lf.shape[-1]:
        mask = jnp.arange(lf.shape[-1]) < valid_vocab
        lf = jnp.where(mask, lf, -1e30)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def chunked_softmax_xent(
    table: jnp.ndarray,
    h: jnp.ndarray,
    labels: jnp.ndarray,
    valid_vocab: int,
    chunk: int = 512,
    compute_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Cross-entropy WITHOUT materializing (B, S, V) logits.

    Scans over sequence chunks; peak logits memory is (B, chunk, V) --
    ~2 orders of magnitude smaller at train_4k x 152k vocab. This is the
    memory-term optimization used by the large-vocab configs.
    """
    b, s, d = h.shape
    if s % chunk:
        pad = chunk - s % chunk
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        s = s + pad
    n_chunks = s // chunk
    h = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    labels = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        hc, lc = xs
        logits = unembed_logits(table, hc, compute_dtype).astype(jnp.float32)
        vocab_iota = jnp.arange(logits.shape[-1])
        logits = jnp.where(vocab_iota < valid_vocab, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via mask+sum instead of take_along_axis: with the
        # vocab dim SHARDED a gather forces an all-gather of the full
        # (B, chunk, V) logits; the masked sum reduces locally and
        # all-reduces only (B, chunk) scalars.
        onehot = (vocab_iota == lc[..., None]).astype(jnp.float32)
        gold = jnp.sum(jnp.where(onehot > 0, logits, 0.0), axis=-1)
        valid = (lc >= 0).astype(jnp.float32)
        loss_sum, count = acc
        return (loss_sum + jnp.sum((logz - gold) * valid), count + jnp.sum(valid)), None

    (loss_sum, count), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (h, labels))
    return loss_sum / jnp.maximum(count, 1.0)
