"""Tensor-parallel PartitionSpec rules for model parameters.

Megatron-style layout over the ``model`` mesh axis, applied to FUSED dims
(always divisible by 16 for the assigned architectures -- see DESIGN.md §5):

  embed/head tables (V, d)      -> P("model", None)        vocab-sharded
  attn wq/wk/wv     (d, H*hd)   -> P(None, "model")        column-parallel
  attn wo           (H*hd, d)   -> P("model", None)        row-parallel
  mlp gate/up       (d, ff)     -> P(None, "model")
  mlp down          (ff, d)     -> P("model", None)
  moe experts       (E, d, ff)  -> P("model", None, None)  expert-parallel
  rglru in_proj     (d, 2W)     -> P(None, "model")        etc.
  norms, lerp coefficients, decay vectors -> replicated

``stacked`` leaves (under a scanned "blocks" dict) get a leading None for
the layer axis. ``node_stack_specs`` prepends the FL node axes for the
node-stacked optimizer state.
"""

from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

PyTree = Any

__all__ = ["model_param_specs", "node_stack_specs", "batch_specs"]

_COL = {"wq", "wk", "wv", "wg", "wr", "gate", "up", "in_proj", "gate_a", "gate_x"}
_ROW = {"wo", "down", "out_proj"}


def _leaf_spec(path: Tuple, leaf) -> P:
    keys = [k.key for k in path if isinstance(k, DictKey)]
    name = keys[-1] if keys else ""
    parent = keys[-2] if len(keys) > 1 else ""
    gparent = keys[-3] if len(keys) > 2 else ""
    stacked = ("blocks" in keys and not any(isinstance(k, SequenceKey) for k in path)) or (
        "pblocks" in keys  # pattern-period stacks: list index + layer-stacked leaves
    )
    nd = leaf.ndim - (1 if stacked else 0)

    def wrap(*spec) -> P:
        spec = tuple(spec) + (None,) * (nd - len(spec))
        if stacked:
            spec = (None,) + spec
        return P(*spec)

    # embedding / unembedding tables
    if name == "table":
        return wrap("model", None)
    # learned positional tables / norms / scalars / gates' vectors
    if name in ("pos", "scale", "bias", "w0", "u", "ln_scale", "lam", "conv_b") or name.startswith("mu_"):
        return wrap(*([None] * nd))
    # MoE expert stacks (E, d, ff) / (E, ff, d) and router
    if parent == "moe" and name in ("gate", "up", "down"):
        return wrap("model", None, None)
    if parent == "router" or gparent == "router":
        return wrap(*([None] * nd))
    # dense kernels: match on the dict that OWNS the w/b leaf
    owner = parent if name in ("w", "b") else name
    # rwkv channel-mix down projection (ff -> d) is row-parallel, unlike
    # the attention/time-mix "wv" which is column-parallel
    if owner == "wv" and gparent == "channel":
        owner = "down"
    if owner in _COL:
        if name == "b":
            return wrap("model")
        return wrap(None, "model")
    if owner in _ROW:
        if name == "b":
            return wrap(*([None] * nd))
        return wrap("model", None)
    if owner == "conv_w":
        return wrap(None, "model")
    # rwkv decay lora (wa: d->64, wb: 64->d) and anything small: replicate
    return wrap(*([None] * nd))


def model_param_specs(params: PyTree) -> PyTree:
    """PartitionSpec pytree (model/TP axes only) matching ``params``."""
    return jax.tree_util.tree_map_with_path(_leaf_spec, params)


def node_stack_specs(specs: PyTree, node_axes: Sequence[str]) -> PyTree:
    """Prepend the FL node axes to every spec (node-stacked state layout)."""
    na = tuple(node_axes)

    def f(s: P) -> P:
        return P(na, *tuple(s))

    return jax.tree_util.tree_map(f, specs, is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_tree: PyTree, node_axes: Sequence[str], leading_scan: bool = True) -> PyTree:
    """Specs for FL batches: (Q, nodes, per_node, ...) -> P(None, nodes, ...)."""
    na = tuple(node_axes)

    def f(leaf) -> P:
        extra = (None,) * (leaf.ndim - (2 if leading_scan else 1))
        if leading_scan:
            return P(None, na, *extra)
        return P(na, *extra)

    return jax.tree_util.tree_map(f, batch_tree)
