"""RWKV-6 "Finch" block: token-shift time mixing with DATA-DEPENDENT decay.

Per head (size 64) the WKV recurrence over kv-state S in R^{64x64} is

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

with per-channel decay w_t = exp(-exp(w0 + lora_w(x~_t))) in (0,1) -- the
data dependence of w_t is the Finch contribution [arXiv:2404.05892].

The training/prefill path here is the CHUNKED parallel form (log-space
decay ratios; within-chunk attention-like einsums + cross-chunk carried
state), which is both the TPU-friendly formulation and what the Pallas
kernel (kernels/rwkv6_scan) tiles. The naive O(T) scan lives in
kernels/rwkv6_scan/ref.py as the oracle.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, linear, normal_init

PyTree = Any
HEAD_SIZE = 64

__all__ = ["rwkv_block_init", "rwkv_time_mix", "rwkv_channel_mix", "rwkv_decode_states", "HEAD_SIZE"]


def rwkv_block_init(key, d_model: int, d_ff: int, dtype) -> Dict:
    if d_model % HEAD_SIZE:
        raise ValueError(f"d_model={d_model} not a multiple of head size {HEAD_SIZE}")
    n_heads = d_model // HEAD_SIZE
    keys = jax.random.split(key, 12)
    lora = 64  # decay LoRA width
    return {
        "time": {
            # learned token-shift lerp coefficients per projection
            "mu_r": jnp.full((d_model,), 0.5, dtype),
            "mu_k": jnp.full((d_model,), 0.5, dtype),
            "mu_v": jnp.full((d_model,), 0.5, dtype),
            "mu_g": jnp.full((d_model,), 0.5, dtype),
            "mu_w": jnp.full((d_model,), 0.5, dtype),
            "wr": dense_init(keys[0], d_model, d_model, dtype),
            "wk": dense_init(keys[1], d_model, d_model, dtype),
            "wv": dense_init(keys[2], d_model, d_model, dtype),
            "wg": dense_init(keys[3], d_model, d_model, dtype),
            "wo": dense_init(keys[4], d_model, d_model, dtype),
            # data-dependent decay: w0 + B_w tanh(A_w x~)
            "w0": normal_init(keys[5], (d_model,), 0.3, jnp.float32) - 6.0,
            "wa": dense_init(keys[6], d_model, lora, dtype),
            "wb": dense_init(keys[7], lora, d_model, dtype),
            "u": normal_init(keys[8], (n_heads, HEAD_SIZE), 0.3, jnp.float32),
            "ln_scale": jnp.ones((n_heads, HEAD_SIZE), dtype),
        },
        "channel": {
            "mu_k": jnp.full((d_model,), 0.5, dtype),
            "wk": dense_init(keys[9], d_model, d_ff, dtype),
            "wv": dense_init(keys[10], d_ff, d_model, dtype),
        },
    }


def _token_shift(x: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
    """x_{t-1} with ``prev`` = last token of the previous segment (B, d)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _lerp(x: jnp.ndarray, xs: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    return x + (xs - x) * mu.astype(x.dtype)


def _group_norm(y: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Per-head RMS normalization of (B, S, H, hd)."""
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def wkv6_chunked(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_w: jnp.ndarray,
    u: jnp.ndarray,
    s0: jnp.ndarray,
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked WKV-6. r,k,v,log_w: (B,S,H,hd) fp32; u: (H,hd); s0: (B,H,hd,hd).

    Returns (y (B,S,H,hd), s_final). log_w <= 0 (log of decay in (0,1]);
    all decay ratios are exp of non-positive numbers -> numerically safe.
    """
    b, s, h, hd = r.shape
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    n_ch = s // chunk
    rs = r.reshape(b, n_ch, chunk, h, hd)
    ks = k.reshape(b, n_ch, chunk, h, hd)
    vs = v.reshape(b, n_ch, chunk, h, hd)
    lw = log_w.reshape(b, n_ch, chunk, h, hd)

    def per_chunk(s_in, xs):
        rc, kc, vc, lwc = xs  # (B, C, H, hd)
        cum = jnp.cumsum(lwc, axis=1)  # inclusive cumulative log decay
        total = cum[:, -1]  # (B, H, hd)
        # decay from chunk start to just BEFORE t: P_{t-1} (exclusive cumsum)
        cum_excl = cum - lwc
        # carry term: r_t . (P_{t-1} * S_in); exp(cum_excl) <= 1, stable
        r_dec = rc * jnp.exp(cum_excl)
        y_carry = jnp.einsum("bchi,bhij->bchj", r_dec, s_in)
        # intra-chunk: A[t,a] = sum_i r_t[i] k_a[i] e^{cum_excl_t[i]-cum_a[i]}
        # computed PAIRWISE (a < t => exponent <= -lw_t, bounded) -- the
        # factored e^{cum}*e^{-cum} form overflows for strong decays.
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # (t, a), a < t
        diff = cum_excl[:, :, None] - cum[:, None]  # (B, t, a, H, hd)
        decay = jnp.exp(jnp.where(tri[None, :, :, None, None], diff, 0.0))
        att = jnp.einsum("bchi,bahi,bcahi->bhca", rc, kc, decay)
        att = jnp.where(tri[None, None], att, 0.0)
        y_intra = jnp.einsum("bhca,bahj->bchj", att, vc)
        # current-token bonus: (r_t . (u*k_t)) v_t
        bonus = jnp.einsum("bchi,bchi->bch", rc, u[None, None] * kc)
        y_bonus = bonus[..., None] * vc
        y = y_carry + y_intra + y_bonus
        # state update: S_out = e^{total} * S_in + sum_a e^{total-cum_a} k_a v_a^T
        k_rem = kc * jnp.exp(total[:, None] - cum)
        s_out = jnp.exp(total)[..., None] * s_in + jnp.einsum(
            "bahi,bahj->bhij", k_rem, vc
        )
        return s_out, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rs, ks, vs, lw))
    s_fin, ys = jax.lax.scan(per_chunk, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, hd)
    return y, s_fin


def rwkv_time_mix(
    p: Dict,
    x: jnp.ndarray,
    prev_x: jnp.ndarray,
    s0: jnp.ndarray,
    compute_dtype=jnp.bfloat16,
    chunk: int = 64,
    impl: str = "ref",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(out, new_prev_x, new_state). x: (B,S,d); prev_x: (B,d);
    s0: (B,H,hd,hd) fp32."""
    b, s, d = x.shape
    h = d // HEAD_SIZE
    xs = _token_shift(x, prev_x)
    r = linear(p["wr"], _lerp(x, xs, p["mu_r"]), compute_dtype)
    k = linear(p["wk"], _lerp(x, xs, p["mu_k"]), compute_dtype)
    v = linear(p["wv"], _lerp(x, xs, p["mu_v"]), compute_dtype)
    g = linear(p["wg"], _lerp(x, xs, p["mu_g"]), compute_dtype)
    xw = _lerp(x, xs, p["mu_w"])
    dd = linear({"w": p["wb"]["w"]}, jnp.tanh(linear(p["wa"], xw, compute_dtype)), compute_dtype)
    log_w = -jnp.exp(
        jnp.clip(p["w0"].astype(jnp.float32) + dd.astype(jnp.float32), -20.0, 10.0)
    )  # (B,S,d), <= 0

    shape4 = (b, s, h, HEAD_SIZE)
    rf, kf, vf = (a.astype(jnp.float32).reshape(shape4) for a in (r, k, v))
    lwf = log_w.reshape(shape4)
    if s % chunk:
        chunk = 1  # fallback for irregular lengths (decode, odd prefixes)
    if impl == "pallas":
        from repro.kernels.rwkv6_scan import ops as wkv_ops

        y, s_fin = wkv_ops.wkv6(rf, kf, vf, lwf, p["u"].astype(jnp.float32), s0)
    else:
        y, s_fin = wkv6_chunked(rf, kf, vf, lwf, p["u"].astype(jnp.float32), s0, chunk=chunk)
    y = _group_norm(y, p["ln_scale"]).reshape(b, s, d)
    out = linear(p["wo"], y.astype(compute_dtype) * jax.nn.silu(g), compute_dtype)
    return out, x[:, -1], s_fin


def rwkv_channel_mix(
    p: Dict, x: jnp.ndarray, prev_x: jnp.ndarray, compute_dtype=jnp.bfloat16
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xs = _token_shift(x, prev_x)
    kx = _lerp(x, xs, p["mu_k"])
    hdn = jnp.square(jax.nn.relu(linear(p["wk"], kx, compute_dtype)))
    return linear(p["wv"], hdn, compute_dtype), x[:, -1]


def rwkv_decode_states(batch: int, d_model: int, dtype=jnp.float32) -> Dict:
    h = d_model // HEAD_SIZE
    return {
        "tm_prev": jnp.zeros((batch, d_model), dtype),
        "cm_prev": jnp.zeros((batch, d_model), dtype),
        "s": jnp.zeros((batch, h, HEAD_SIZE, HEAD_SIZE), jnp.float32),
    }
