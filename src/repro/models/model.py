"""Model registry: ModelConfig -> ModelBundle (init / loss / serve fns).

The bundle is the single integration surface consumed by the FL trainer,
the serving engine, and the dry-run launcher. All functions are pure and
jit-able; ``init_fn`` is also ``jax.eval_shape``-able (the dry-run builds
parameter ShapeDtypeStructs without allocating 132B parameters).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.models.sharding import model_param_specs

PyTree = Any

__all__ = ["ModelBundle", "build_model"]


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init_fn: Callable[[jax.Array], PyTree]
    loss_fn: Callable[[PyTree, Dict], jnp.ndarray]
    prefill_fn: Callable[[PyTree, Dict], Tuple[jnp.ndarray, jnp.ndarray]]
    decode_fn: Callable[[PyTree, jnp.ndarray, PyTree], Tuple[jnp.ndarray, PyTree]]
    init_decode_state_fn: Callable[..., PyTree]
    param_specs_fn: Callable[[PyTree], PyTree]

    def param_shapes(self) -> PyTree:
        return jax.eval_shape(self.init_fn, jax.random.key(0))


def build_model(cfg: ModelConfig, impl: str = "ref", remat: bool = True) -> ModelBundle:
    if cfg.family == "audio":
        return _build_encdec(cfg, impl, remat)
    return _build_decoder_only(cfg, impl, remat)


def _build_decoder_only(cfg: ModelConfig, impl: str, remat: bool) -> ModelBundle:
    def init_fn(key):
        return tfm.init_params(cfg, key)

    def loss_fn(params, batch):
        return tfm.lm_loss(params, cfg, batch, impl=impl, remat=remat)

    def prefill_fn(params, batch):
        return tfm.prefill(params, cfg, batch, impl=impl)

    def decode_fn(params, tokens, caches, sliding_override: bool = False):
        return tfm.decode_step(params, cfg, tokens, caches, sliding_override)

    def init_decode_state_fn(batch: int, max_seq: int, sliding_override: bool = False):
        return tfm.init_decode_state(cfg, batch, max_seq, sliding_override)

    return ModelBundle(
        cfg=cfg,
        init_fn=init_fn,
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        init_decode_state_fn=init_decode_state_fn,
        param_specs_fn=model_param_specs,
    )


def _build_encdec(cfg: ModelConfig, impl: str, remat: bool) -> ModelBundle:
    def init_fn(key):
        return encdec_mod.encdec_init(cfg, key)

    def loss_fn(params, batch):
        return encdec_mod.encdec_loss(params, cfg, batch, impl=impl, remat=remat)

    def prefill_fn(params, batch):
        return encdec_mod.encdec_prefill(params, cfg, batch, impl=impl)

    def decode_fn(params, tokens, caches, sliding_override: bool = False):
        del sliding_override  # whisper decoder: contiguous self-cache only
        return encdec_mod.encdec_decode_step(params, cfg, tokens, caches)

    def init_decode_state_fn(batch: int, max_seq: int, sliding_override: bool = False):
        del sliding_override
        return encdec_mod.encdec_init_decode_state(cfg, batch, max_seq)

    return ModelBundle(
        cfg=cfg,
        init_fn=init_fn,
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        init_decode_state_fn=init_decode_state_fn,
        param_specs_fn=model_param_specs,
    )
