"""Learning-rate schedules.

The paper's experiments use alpha^r = 0.02 / sqrt(r) and Theorem 1 assumes
alpha^r ~ O(sqrt(N / r)). Schedules are functions of the *global iteration
counter* r (1-indexed, as in the paper) returning a float32 scalar, and are
safe to call with traced integers inside jit/scan.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]

__all__ = [
    "inv_sqrt",
    "paper_schedule",
    "theorem1_schedule",
    "constant",
    "cosine",
    "warmup_linear",
    "scaled",
    "robust_alpha_scale",
]


def inv_sqrt(alpha0: float) -> Schedule:
    """alpha^r = alpha0 / sqrt(r), r >= 1."""

    def f(step: jnp.ndarray) -> jnp.ndarray:
        r = jnp.maximum(step, 1).astype(jnp.float32)
        return jnp.float32(alpha0) / jnp.sqrt(r)

    return f


def paper_schedule() -> Schedule:
    """The paper's exact experimental schedule: 0.02 / sqrt(r)."""
    return inv_sqrt(0.02)


def theorem1_schedule(n_nodes: int, c: float = 0.02) -> Schedule:
    """alpha^r = c * sqrt(N / r) -- the Theorem 1 rate showing linear
    speedup in N."""

    def f(step: jnp.ndarray) -> jnp.ndarray:
        r = jnp.maximum(step, 1).astype(jnp.float32)
        return jnp.float32(c) * jnp.sqrt(jnp.float32(n_nodes) / r)

    return f


def constant(alpha: float) -> Schedule:
    return lambda step: jnp.float32(alpha)


def cosine(alpha0: float, total_steps: int, alpha_min: float = 0.0) -> Schedule:
    def f(step: jnp.ndarray) -> jnp.ndarray:
        t = jnp.clip(step.astype(jnp.float32) / float(total_steps), 0.0, 1.0)
        return jnp.float32(alpha_min) + 0.5 * jnp.float32(alpha0 - alpha_min) * (
            1.0 + jnp.cos(jnp.pi * t)
        )

    return f


def scaled(schedule: Schedule, factor: float) -> Schedule:
    """Pointwise-scaled schedule: ``factor * schedule(r)``. The
    combinator the robustness controller uses -- the base schedule's
    shape (inv-sqrt decay etc.) is preserved, only the level shrinks."""
    f32 = jnp.float32(factor)
    return lambda step: f32 * schedule(step)


def robust_alpha_scale(uptime: float = 1.0, staleness_depth: int = 0) -> float:
    """Staleness/churn-aware step-size shrink factor in (0, 1].

    The decentralized convergence rates trade step size against the
    mixing matrix's spectral gap. Under faults the EFFECTIVE gap shrinks:
    with per-node payload availability ``uptime`` an edge of E[W_r]
    survives with probability ~uptime**2 (both endpoints must deliver),
    scaling ``1 - lambda_2`` by the same factor; depth-k bounded-stale
    mixing turns gossip into an order-(k+1) recurrence whose
    disagreement modes contract roughly ``(k/2 + 1)``-times slower (the
    k=1 root analysis in benchmarks/staleness_ehr.py, extended). Both
    effects multiply:

        scale = uptime**2 * 2 / (2 + k)

    Heuristic, not a bound -- but it keeps the effective
    ``alpha / gap_eff`` ratio of the fault-free tuning, which is what the
    sweep in benchmarks/straggler_ehr.py shows matters."""
    uptime = float(uptime)
    if not (0.0 < uptime <= 1.0):
        raise ValueError(f"uptime={uptime} not in (0, 1]")
    k = int(staleness_depth)
    if k < 0:
        raise ValueError(f"staleness_depth={staleness_depth} must be >= 0")
    return uptime ** 2 * 2.0 / (2.0 + k)


def warmup_linear(alpha0: float, warmup: int, total_steps: int) -> Schedule:
    def f(step: jnp.ndarray) -> jnp.ndarray:
        s = step.astype(jnp.float32)
        wu = s / jnp.maximum(1.0, float(warmup))
        decay = (float(total_steps) - s) / jnp.maximum(1.0, float(total_steps - warmup))
        return jnp.float32(alpha0) * jnp.clip(jnp.minimum(wu, decay), 0.0, 1.0)

    return f
