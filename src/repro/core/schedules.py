"""Learning-rate schedules.

The paper's experiments use alpha^r = 0.02 / sqrt(r) and Theorem 1 assumes
alpha^r ~ O(sqrt(N / r)). Schedules are functions of the *global iteration
counter* r (1-indexed, as in the paper) returning a float32 scalar, and are
safe to call with traced integers inside jit/scan.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]

__all__ = [
    "inv_sqrt",
    "paper_schedule",
    "theorem1_schedule",
    "constant",
    "cosine",
    "warmup_linear",
]


def inv_sqrt(alpha0: float) -> Schedule:
    """alpha^r = alpha0 / sqrt(r), r >= 1."""

    def f(step: jnp.ndarray) -> jnp.ndarray:
        r = jnp.maximum(step, 1).astype(jnp.float32)
        return jnp.float32(alpha0) / jnp.sqrt(r)

    return f


def paper_schedule() -> Schedule:
    """The paper's exact experimental schedule: 0.02 / sqrt(r)."""
    return inv_sqrt(0.02)


def theorem1_schedule(n_nodes: int, c: float = 0.02) -> Schedule:
    """alpha^r = c * sqrt(N / r) -- the Theorem 1 rate showing linear
    speedup in N."""

    def f(step: jnp.ndarray) -> jnp.ndarray:
        r = jnp.maximum(step, 1).astype(jnp.float32)
        return jnp.float32(c) * jnp.sqrt(jnp.float32(n_nodes) / r)

    return f


def constant(alpha: float) -> Schedule:
    return lambda step: jnp.float32(alpha)


def cosine(alpha0: float, total_steps: int, alpha_min: float = 0.0) -> Schedule:
    def f(step: jnp.ndarray) -> jnp.ndarray:
        t = jnp.clip(step.astype(jnp.float32) / float(total_steps), 0.0, 1.0)
        return jnp.float32(alpha_min) + 0.5 * jnp.float32(alpha0 - alpha_min) * (
            1.0 + jnp.cos(jnp.pi * t)
        )

    return f


def warmup_linear(alpha0: float, warmup: int, total_steps: int) -> Schedule:
    def f(step: jnp.ndarray) -> jnp.ndarray:
        s = step.astype(jnp.float32)
        wu = s / jnp.maximum(1.0, float(warmup))
        decay = (float(total_steps) - s) / jnp.maximum(1.0, float(total_steps - warmup))
        return jnp.float32(alpha0) * jnp.clip(jnp.minimum(wu, decay), 0.0, 1.0)

    return f
