"""Fully decentralized federated learning: DSGD / DSGT with Q local steps.

Implements the paper's Algorithm 1 and both base optimizers as pure JAX
step builders operating on **node-stacked** state (every parameter leaf
carries a leading ``nodes`` axis). All state representation, mixing, and
wire concerns live behind the :class:`repro.core.engine.GossipEngine`
protocol -- ``make_fl_round`` builds ONE round function for whichever
engine it is handed:

* ``tree``          -- nodes as a vmap axis over the parameter pytree,
  mixing via any tree-level gossip backend (dense-W simulated, ppermute
  mesh, all-gather); the EHR experiments and all CPU tests;
* ``flat``          -- the state packed into a single ``(nodes,
  total_params)`` buffer (``core.packing``): optimizer update, metrics,
  and mixing are single-buffer ops instead of per-leaf traversals;
* ``fused``         -- the flat state with the round megakernel: the
  whole communication step (local update + int8 quantize + W mix + EF
  residual, optionally top-k sparsified, for DSGD and DSGT alike) is ONE
  Pallas call (``repro.kernels.gossip``), with the compression state in
  ``FLState.comm``;
* ``sharded_fused`` -- the shard_map-native fused round for real meshes:
  one wire-stage kernel per round per shard, int8 payload moved by
  ppermute (circulant W) or all-gather (dense W).

Update equations (r is the global iteration counter, 1-indexed):

  local (Eq. 4):  theta_i <- theta_i - alpha^r * grad g_i(theta_i)

  DSGD comm (Eq. 2):
      theta_i <- sum_j W_ij theta_j - alpha^r * grad g_i(theta_i)

  DSGT comm (Eq. 3, GNSD ordering of [14]):
      g_new   = grad g_i(theta_i^r)
      vtheta  <- sum_j W_ij vtheta_j + (g_new - g_prev)
      theta_i <- sum_j W_ij theta_j - alpha^r * vtheta_i
      g_prev  <- g_new

  where for the federated variant (Q > 1) ``g_prev`` is the gradient from
  the *previous communication round* (local rounds use Eq. 4 only, exactly
  as Algorithm 1 prescribes). The gradient-tracking invariant

      mean_i vtheta_i^k == mean_i g_i^k        (at every comm round k)

  is preserved by any doubly-stochastic W and is property-tested.

  The FUSED engines use the adapt-then-combine ordering (update first,
  then mix the half-updated state) so the megakernel quantizes exactly
  what goes on the wire:

      DSGD:  theta_i <- sum_j W_ij Q[theta_j - alpha^r g_j]
      DSGT:  vtheta_half = vtheta + (g_new - g_prev)
             vtheta <- sum_j W_ij Q[vtheta_half_j]
             theta  <- sum_j W_ij Q[theta_j - alpha^r vtheta_half_j]

  with Q[.] the difference-coded int8 quantizer with error feedback
  (CHOCO-style; exact in the consensus limit; ``topk`` ships only the k
  largest payload columns per scale chunk, EF absorbing the truncation).
  Both orderings satisfy the same Theorem 1 style guarantees; the fused
  one is what a bandwidth-bound deployment runs.

Baselines expressed in the same machinery:
  * centralized SGD ("fusion center"):  W = (1/N) 1 1^T, Q = 1
  * FedAvg (star network, McMahan et al.): W = (1/N) 1 1^T, Q > 1
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mixing import GossipFn
from repro.core.schedules import Schedule

PyTree = Any
LossFn = Callable[[PyTree, Any], jnp.ndarray]  # (params_one_node, batch_one_node) -> scalar

__all__ = [
    "FLState",
    "FLConfig",
    "init_fl_state",
    "make_fl_round",
    "consensus_params",
]

_MIGRATION_HINT = (
    "was replaced by the GossipEngine protocol (repro.core.engine). "
    "Build an engine -- TreeEngine(gossip_fn), FlatEngine(mix_fn, layout), "
    "FusedEngine(w, layout, topk=...), or ShardedFusedEngine(mesh, "
    "node_axes, layout, ...) -- and pass it as engine=...; CLI surfaces "
    "resolve names through repro.core.engine.get_engine()."
)


class FLState(NamedTuple):
    """Node-stacked optimizer state. ``tracker``/``prev_grad`` are None for
    DSGD (keeps DSGD memory at 1x params, DSGT at 3x -- inherent to GT).
    ``comm`` is None except in the fused engines, where it holds the int8
    wire state (``engine.comm_keys``): ``{"recon", "residual"}`` (n, total)
    fp32 buffers for the parameter wire, ``{"recon_t", "residual_t"}`` for
    DSGT's tracker wire, and the sharded engine's running neighbor-mix
    accumulators ``{"mix_recon", "mix_recon_t"}`` (per-direction
    ``nbr_recon_{d}`` twins under a dynamic topology program). A dynamic
    :class:`~repro.core.dynamics.TopologyProgram` additionally carries its
    round counter and base RNG key here (``topo_round``, ``topo_key``), so
    checkpointed restores replay the identical graph sequence. An active
    :class:`~repro.core.privacy.PrivacySpec` rides the same counter
    discipline: ``priv_key`` (the spec's base key) plus ``topo_round``
    (reused as the pad/noise round counter even under a static topology),
    so restored runs regenerate the identical mask and noise streams."""

    step: jnp.ndarray  # () int32, global iteration r (counts local steps too)
    params: PyTree  # each leaf (nodes, ...)
    tracker: Optional[PyTree]  # DSGT vtheta, same layout
    prev_grad: Optional[PyTree]  # DSGT g at the last comm round
    #: fused-engine wire state (engine.comm_keys / comm_state_sds). Under
    #: the PIPELINED round schedule the sharded engine also double-buffers
    #: the in-flight wire payload here: ``wire_q`` (int8), ``wire_pos``
    #: (compact wire positions), ``wire_scales`` (+ ``_t`` twins for DSGT)
    comm: Optional[Dict[str, jnp.ndarray]] = None


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algorithm: str = "dsgt"  # "dsgd" | "dsgt"
    q: int = 1  # local steps per communication round (Q in Alg. 1)
    n_nodes: int = 1

    def __post_init__(self) -> None:
        if self.algorithm not in ("dsgd", "dsgt"):
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        if self.q < 1:
            raise ValueError("q must be >= 1")
        if self.n_nodes < 1:
            raise ValueError("n_nodes must be >= 1")


def _tm(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def init_fl_state(
    cfg: FLConfig, stacked_params: PyTree, engine=None, **legacy
) -> FLState:
    """Initial state. DSGT's tracker is initialized to zeros; the first
    comm round's ``g_new - g_prev`` then loads the first gradient into the
    tracker (the standard GNSD cold start with g^0 := 0).

    ``engine``: the :class:`~repro.core.engine.GossipEngine` the state
    will be trained with. Engines validate their representation (the
    fused engines require the packed ``(nodes, total)`` flat buffer from
    ``core.packing.pack``) and contribute zero-initialized wire-state
    buffers to ``FLState.comm``. ``engine=None`` builds plain tree-state
    (no comm buffers) -- valid for the tree and flat exact-wire engines.
    """
    if legacy:
        raise TypeError(
            f"init_fl_state() got {sorted(legacy)}: the fused= flag "
            + _MIGRATION_HINT
        )
    if engine is not None and not hasattr(engine, "init_comm_state"):
        # e.g. the historical positional fused: bool landing on engine=
        raise TypeError(
            f"init_fl_state() engine must be a GossipEngine, got "
            f"{engine!r}: the fused= flag " + _MIGRATION_HINT
        )
    comm = None
    if engine is not None:
        engine.check_params(cfg, stacked_params)
        comm = engine.init_comm_state(cfg, stacked_params)
    else:
        leaves = jax.tree_util.tree_leaves(stacked_params)
        if not leaves:
            raise ValueError("empty parameter pytree")
        for leaf in leaves:
            if leaf.shape[:1] != (cfg.n_nodes,):
                raise ValueError(
                    f"param leaf {leaf.shape} is not node-stacked for "
                    f"n={cfg.n_nodes}"
                )
    zeros = _tm(jnp.zeros_like, stacked_params)
    if cfg.algorithm == "dsgt":
        return FLState(
            jnp.int32(0), stacked_params, zeros, _tm(jnp.zeros_like, zeros), comm
        )
    return FLState(jnp.int32(0), stacked_params, None, None, comm)


def consensus_params(state: FLState) -> PyTree:
    """theta_bar = (1/N) sum_i theta_i -- the model you deploy/serve."""
    return _tm(lambda p: jnp.mean(p, axis=0), state.params)


def make_fl_round(
    loss_fn: LossFn,
    gossip_fn: Optional[GossipFn] = None,
    schedule: Schedule = None,
    cfg: FLConfig = None,
    engine=None,
    **legacy,
) -> Callable[[FLState, PyTree], Tuple[FLState, Dict[str, jnp.ndarray]]]:
    """Build one *communication round*: (Q-1) local steps + 1 comm step.

    Args:
      loss_fn: per-node loss ``(params, batch) -> scalar`` (unstacked).
      gossip_fn: convenience shorthand -- a tree-level mixing backend
        (theta <- W theta); wrapped in a
        :class:`~repro.core.engine.TreeEngine`. Mutually exclusive with
        ``engine``.
      schedule: alpha^r.
      cfg: algorithm + Q + N.
      engine: a :class:`~repro.core.engine.GossipEngine` -- THE dispatch
        path. The engine owns the state representation (tree pytree vs
        packed flat buffer), the wire (exact fp32/bf16 vs difference-coded
        int8 vs top-k sparsified int8), and the mixing implementation
        (dense matmul, ppermute, all-gather, round megakernel, sharded
        megakernel) -- and, via its ``round_schedule`` attribute, the
        round's TIME layout: ``sequential`` (the paper's blocking round)
        or ``pipelined`` (the collective for round r's payload in flight
        across round r+1's local steps, one-round-stale mixing; see
        ``repro.core.engine.RoundSchedule``). Build the matching state
        with ``init_fl_state(cfg, params, engine=engine)``. The
        historical ``layout=`` / ``fused=`` kwargs raise with a
        migration hint.

    Hierarchical (multi-pod) gossip is built by ALTERNATING two round
    functions at the driver level -- one whose engine mixes only the cheap
    intra-pod axis (``axes_subset=("data",)``), one that also crosses pods
    -- rather than branching inside the jitted program (a data-dependent
    `where` would execute both collectives every round; verified in the
    dry-run HLO).

    Returns ``round_fn(state, batches) -> (state, metrics)`` where each
    ``batches`` leaf is shaped (Q, nodes, ...) -- one microbatch per local
    iteration per node. Metrics: mean loss, ||mean_i grad_i||^2 (the
    stationarity term of Theorem 1), consensus error
    (1/N) sum_i ||theta_i - theta_bar||^2, comm_rounds (=1), alpha, and --
    for engines that account their wire -- ``wire_bytes`` (summed
    per-round egress of all nodes).
    """
    if legacy:
        raise TypeError(
            f"make_fl_round() got {sorted(legacy)}: the layout=/fused= "
            "kwarg maze " + _MIGRATION_HINT
        )
    if schedule is None or cfg is None:
        raise TypeError(
            "make_fl_round requires schedule and cfg (they default to None "
            "only so engine= can be passed by keyword)"
        )
    if engine is not None and not hasattr(engine, "make_comm_step"):
        # e.g. a historical positional layout= landing on engine=
        raise TypeError(
            f"make_fl_round() engine must be a GossipEngine, got "
            f"{engine!r}: the layout=/fused= kwarg maze " + _MIGRATION_HINT
        )
    if engine is None:
        if gossip_fn is None:
            raise ValueError(
                "make_fl_round needs either a tree-level gossip_fn or an "
                "engine=GossipEngine"
            )
        from repro.core.engine import TreeEngine

        engine = TreeEngine(gossip_fn)
    elif gossip_fn is not None:
        raise ValueError(
            "pass the mixing backend inside the engine, not as gossip_fn"
        )

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))
    eval_grads = engine.make_eval_grads(grad_fn)

    def local_step(state: FLState, batch: PyTree,
                   mask=None) -> Tuple[FLState, jnp.ndarray]:
        # ``mask``: the node program's (n,) per-iteration compute gate
        # (straggling nodes sit masked iterations out -- traced, so the
        # ONE compiled scan serves every heterogeneity pattern).
        step = state.step + 1
        alpha = schedule(step)
        losses, grads = eval_grads(state.params, batch)
        params = engine.local_step(state.params, grads, alpha, mask=mask)
        return state._replace(step=step, params=params), jnp.mean(losses)

    # The engine's RoundSchedule owns the round's TIME layout: sequential
    # (Q-1 local steps, then produce -> collective -> mix) or pipelined
    # (ingest the in-flight collective BEFORE the scan, mix one-round
    # stale). The schedule is fixed at engine construction because it is
    # part of the comm-state contract (repro.core.engine.RoundSchedule).
    from repro.core.engine import resolve_schedule

    round_schedule = resolve_schedule(getattr(engine, "round_schedule", None))
    return round_schedule.build_round(engine, eval_grads, schedule, cfg,
                                      local_step)


def _mean_grad_norm_sq(stacked_grads: PyTree) -> jnp.ndarray:
    """|| (1/N) sum_i grad_i ||^2 -- the first term of Theorem 1's LHS."""
    sq = 0.0
    for g in jax.tree_util.tree_leaves(stacked_grads):
        mean_g = jnp.mean(g.astype(jnp.float32), axis=0)
        sq = sq + jnp.sum(mean_g * mean_g)
    return sq


def _consensus_error(stacked_params: PyTree) -> jnp.ndarray:
    """(1/N) sum_i ||theta_i - theta_bar||^2 -- Theorem 1's second term."""
    err = 0.0
    for p in jax.tree_util.tree_leaves(stacked_params):
        pf = p.astype(jnp.float32)
        dev = pf - jnp.mean(pf, axis=0, keepdims=True)
        err = err + jnp.sum(dev * dev) / pf.shape[0]
    return err
