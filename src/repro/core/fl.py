"""Fully decentralized federated learning: DSGD / DSGT with Q local steps.

Implements the paper's Algorithm 1 and both base optimizers as pure JAX
step builders operating on **node-stacked** state (every parameter leaf
carries a leading ``nodes`` axis). The same code runs:

* *simulated*  -- single device, nodes as a vmap axis (the EHR experiments
  and all CPU tests), with a dense-W gossip backend;
* *sharded*    -- nodes sharded over the (pod, data) mesh axes, gossip via
  the ppermute backend; the node axis is a pure map dimension so local
  steps lower with ZERO cross-node collectives (verified in the dry-run);
* *flat*       -- either of the above with the state packed into a single
  ``(nodes, total_params)`` buffer (``core.packing``): pass ``layout=`` to
  ``make_fl_round`` and a flat-native gossip backend, and the optimizer
  update, metrics, and mixing all become single-buffer ops instead of
  per-leaf traversals (benchmarks/gossip_bench.py);
* *fused*      -- the flat mode with ``fused=FusedRoundSpec(...)``: the
  whole communication step (local update + int8 quantize + W mix + EF
  residual, for DSGD and DSGT alike) is ONE round-megakernel call on the
  flat buffers (``repro.kernels.gossip``), and the int8 compression state
  rides along in ``FLState.comm``.

Update equations (r is the global iteration counter, 1-indexed):

  local (Eq. 4):  theta_i <- theta_i - alpha^r * grad g_i(theta_i)

  DSGD comm (Eq. 2):
      theta_i <- sum_j W_ij theta_j - alpha^r * grad g_i(theta_i)

  DSGT comm (Eq. 3, GNSD ordering of [14]):
      g_new   = grad g_i(theta_i^r)
      vtheta  <- sum_j W_ij vtheta_j + (g_new - g_prev)
      theta_i <- sum_j W_ij theta_j - alpha^r * vtheta_i
      g_prev  <- g_new

  where for the federated variant (Q > 1) ``g_prev`` is the gradient from
  the *previous communication round* (local rounds use Eq. 4 only, exactly
  as Algorithm 1 prescribes). The gradient-tracking invariant

      mean_i vtheta_i^k == mean_i g_i^k        (at every comm round k)

  is preserved by any doubly-stochastic W and is property-tested.

  The FUSED comm step uses the adapt-then-combine ordering (update first,
  then mix the half-updated state) so the megakernel quantizes exactly
  what goes on the wire:

      DSGD:  theta_i <- sum_j W_ij Q[theta_j - alpha^r g_j]
      DSGT:  vtheta_half = vtheta + (g_new - g_prev)
             vtheta <- sum_j W_ij Q[vtheta_half_j]
             theta  <- sum_j W_ij Q[theta_j - alpha^r vtheta_half_j]

  with Q[.] the difference-coded int8 quantizer with error feedback
  (CHOCO-style; exact in the consensus limit). Both orderings satisfy the
  same Theorem 1 style guarantees; the fused one is what a bandwidth-bound
  deployment runs.

Baselines expressed in the same machinery:
  * centralized SGD ("fusion center"):  W = (1/N) 1 1^T, Q = 1
  * FedAvg (star network, McMahan et al.): W = (1/N) 1 1^T, Q > 1
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixing import GossipFn
from repro.core.packing import FlatLayout, pack_like, unpack
from repro.core.schedules import Schedule

PyTree = Any
LossFn = Callable[[PyTree, Any], jnp.ndarray]  # (params_one_node, batch_one_node) -> scalar

__all__ = [
    "FLState",
    "FLConfig",
    "FusedRoundSpec",
    "init_fl_state",
    "make_fl_round",
    "consensus_params",
]


class FLState(NamedTuple):
    """Node-stacked optimizer state. ``tracker``/``prev_grad`` are None for
    DSGD (keeps DSGD memory at 1x params, DSGT at 3x -- inherent to GT).
    ``comm`` is None except in the fused engine, where it holds the int8
    wire state: ``{"recon", "residual"}`` (n, total) fp32 buffers for the
    parameter wire, plus ``{"recon_t", "residual_t"}`` for DSGT's tracker
    wire."""

    step: jnp.ndarray  # () int32, global iteration r (counts local steps too)
    params: PyTree  # each leaf (nodes, ...)
    tracker: Optional[PyTree]  # DSGT vtheta, same layout
    prev_grad: Optional[PyTree]  # DSGT g at the last comm round
    comm: Optional[Dict[str, jnp.ndarray]] = None  # fused engine wire state


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algorithm: str = "dsgt"  # "dsgd" | "dsgt"
    q: int = 1  # local steps per communication round (Q in Alg. 1)
    n_nodes: int = 1

    def __post_init__(self) -> None:
        if self.algorithm not in ("dsgd", "dsgt"):
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        if self.q < 1:
            raise ValueError("q must be >= 1")
        if self.n_nodes < 1:
            raise ValueError("n_nodes must be >= 1")


@dataclasses.dataclass(frozen=True)
class FusedRoundSpec:
    """Configuration of the fused round megakernel (``make_fl_round``'s
    ``fused=`` argument).

    Attributes:
      w: (n, n) doubly-stochastic mixing matrix (numpy, compile-time
        constant; split into diagonal + off-diagonal for the kernel).
      scale_chunk: columns per int8 scale block == the kernel's VMEM tile
        width; ``layout.total`` must be a multiple (pack with
        ``pad_to=scale_chunk``).
      error_feedback / difference_coding: the CHOCO wire semantics (see
        ``kernels.gossip.ops.gossip_mix``); defaults give exact-in-the-
        limit mixing.
      impl: "pallas" runs the Pallas megakernel (interpret mode off-TPU);
        "jnp" the chunked oracle -- bit-identical math, GSPMD-partitionable
        (what the sharded dry-run lowers).
    """

    w: Any
    scale_chunk: int = 512
    error_feedback: bool = True
    difference_coding: bool = True
    impl: str = "pallas"

    def __post_init__(self) -> None:
        if self.impl not in ("pallas", "jnp"):
            raise ValueError(f"unknown impl {self.impl!r}")
        if self.scale_chunk < 1:
            raise ValueError("scale_chunk must be >= 1")


def _tm(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def init_fl_state(
    cfg: FLConfig, stacked_params: PyTree, fused: bool = False
) -> FLState:
    """Initial state. DSGT's tracker is initialized to zeros; the first
    comm round's ``g_new - g_prev`` then loads the first gradient into the
    tracker (the standard GNSD cold start with g^0 := 0).

    With ``fused=True``, ``stacked_params`` must be the packed
    ``(nodes, total)`` flat buffer (``core.packing.pack``) and the state
    additionally carries zero-initialized int8 wire buffers in ``comm``
    (zeros mean the first round effectively transmits the full state).
    """
    leaves = jax.tree_util.tree_leaves(stacked_params)
    if not leaves:
        raise ValueError("empty parameter pytree")
    for leaf in leaves:
        if leaf.shape[:1] != (cfg.n_nodes,):
            raise ValueError(
                f"param leaf {leaf.shape} is not node-stacked for n={cfg.n_nodes}"
            )
    comm = None
    if fused:
        if len(leaves) != 1 or leaves[0].ndim != 2:
            raise ValueError(
                "fused=True requires the packed (nodes, total) flat buffer"
            )
        z = jnp.zeros(leaves[0].shape, jnp.float32)
        comm = {"recon": z, "residual": z}
        if cfg.algorithm == "dsgt":
            comm.update({"recon_t": z, "residual_t": z})
    zeros = _tm(jnp.zeros_like, stacked_params)
    if cfg.algorithm == "dsgt":
        return FLState(
            jnp.int32(0), stacked_params, zeros, _tm(jnp.zeros_like, zeros), comm
        )
    return FLState(jnp.int32(0), stacked_params, None, None, comm)


def consensus_params(state: FLState) -> PyTree:
    """theta_bar = (1/N) sum_i theta_i -- the model you deploy/serve."""
    return _tm(lambda p: jnp.mean(p, axis=0), state.params)


def make_fl_round(
    loss_fn: LossFn,
    gossip_fn: Optional[GossipFn],
    schedule: Schedule,
    cfg: FLConfig,
    layout: Optional[FlatLayout] = None,
    fused: Optional[FusedRoundSpec] = None,
) -> Callable[[FLState, PyTree], Tuple[FLState, Dict[str, jnp.ndarray]]]:
    """Build one *communication round*: (Q-1) local steps + 1 comm step.

    Args:
      loss_fn: per-node loss ``(params, batch) -> scalar`` (unstacked).
      gossip_fn: mixing backend (theta <- W theta). Operates on
        node-stacked pytrees, or directly on the flat buffer when
        ``layout`` is given (e.g. ``make_dense_flat_mix`` /
        ``make_mesh_flat_mix``). Ignored (may be None) when ``fused`` is
        given -- the megakernel carries its own W.
      schedule: alpha^r.
      cfg: algorithm + Q + N.
      layout: when a ``core.packing.FlatLayout`` is passed, the round runs
        the **flat-buffer engine**: ``FLState.params`` (and the DSGT
        tracker/prev_grad) are single ``(nodes, total)`` fp32 buffers, the
        pytree is materialized only transiently inside the per-node loss,
        and every optimizer update / metric / gossip step is ONE fused op
        on the contiguous buffer instead of a pytree traversal -- the
        local ``scan`` body stops re-traversing the state leaf-by-leaf.
        Build the state with ``pack(stacked_params, pad_to=...)`` and read
        results back with ``unpack``.
      fused: a :class:`FusedRoundSpec` (requires ``layout``): the comm
        step becomes ONE round-megakernel call -- local update, int8
        quantize, W-row mix, and error-feedback residual fused over
        ``(nodes, scale_chunk)`` tiles with no materialized full-size
        intermediates. The wire is the CHOCO difference-coded int8
        payload, so build the state with ``init_fl_state(..., fused=True)``
        (adds the ``comm`` buffers) and pack with
        ``pad_to=fused.scale_chunk``. Metrics gain ``wire_bytes``: the
        summed per-round egress of all nodes (int8 payload + fp32 scales,
        doubled for DSGT's tracker wire).

    Hierarchical (multi-pod) gossip is built by ALTERNATING two round
    functions at the driver level -- one whose gossip mixes only the cheap
    intra-pod axis, one that also crosses pods -- rather than branching
    inside the jitted program (a data-dependent `where` would execute both
    collectives every round; verified in the dry-run HLO).

    Returns ``round_fn(state, batches) -> (state, metrics)`` where each
    ``batches`` leaf is shaped (Q, nodes, ...) -- one microbatch per local
    iteration per node. Metrics: mean loss, ||mean_i grad_i||^2 (the
    stationarity term of Theorem 1), consensus error
    (1/N) sum_i ||theta_i - theta_bar||^2, comm_rounds (=1), and alpha.
    """
    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

    if layout is None:
        if fused is not None:
            raise ValueError("fused rounds require the flat engine (layout=...)")
        eval_grads = grad_fn
    else:

        def eval_grads(params: jnp.ndarray, batch: PyTree):
            # The tree view exists only inside this call; XLA lowers the
            # unpack/pack pair to slices/concat and fuses them away.
            losses, grads = grad_fn(unpack(params, layout), batch)
            return losses, pack_like(grads, layout)

    if fused is not None:
        comm_step = _make_fused_comm_step(eval_grads, schedule, cfg, layout, fused)
    else:
        comm_step = _make_comm_step(eval_grads, gossip_fn, schedule, cfg)

    def local_step(state: FLState, batch: PyTree) -> Tuple[FLState, jnp.ndarray]:
        step = state.step + 1
        alpha = schedule(step)
        losses, grads = eval_grads(state.params, batch)
        params = _tm(lambda p, g: p - alpha * g.astype(p.dtype), state.params, grads)
        return state._replace(step=step, params=params), jnp.mean(losses)

    def round_fn(
        state: FLState, batches: PyTree
    ) -> Tuple[FLState, Dict[str, jnp.ndarray]]:
        q = cfg.q
        if q > 1:
            local_batches = _tm(lambda b: b[: q - 1], batches)
            state, local_losses = jax.lax.scan(local_step, state, local_batches)
        else:
            local_losses = jnp.zeros((0,), jnp.float32)
        comm_batch = _tm(lambda b: b[q - 1], batches)
        state, metrics = comm_step(state, comm_batch)
        metrics["local_loss"] = jnp.where(
            q > 1, jnp.sum(local_losses) / jnp.maximum(1, q - 1), metrics["loss"]
        )
        return state, metrics

    return round_fn


def _make_comm_step(eval_grads, gossip_fn, schedule: Schedule, cfg: FLConfig):
    """The exact-wire comm step: gossip_fn mixes, then the optimizer update
    (mix-then-adapt, Eqs. 2/3)."""

    def comm_step(
        state: FLState, batch: PyTree
    ) -> Tuple[FLState, Dict[str, jnp.ndarray]]:
        step = state.step + 1
        alpha = schedule(step)
        losses, grads = eval_grads(state.params, batch)
        mix = gossip_fn

        if cfg.algorithm == "dsgd":
            # Eq. (2): theta <- W theta - alpha * g
            params = _tm(
                lambda wp, g: wp - alpha * g.astype(wp.dtype), mix(state.params), grads
            )
            new_state = state._replace(step=step, params=params)
        else:
            # Eq. (3): tracker <- W tracker + (g_new - g_prev); theta <- W theta - alpha*tracker
            tracker = _tm(
                lambda wt, gn, gp: wt + gn.astype(wt.dtype) - gp,
                mix(state.tracker),
                grads,
                state.prev_grad,
            )
            params = _tm(
                lambda wp, t: wp - alpha * t, mix(state.params), tracker
            )
            new_state = state._replace(
                step=step,
                params=params,
                tracker=tracker,
                prev_grad=_tm(lambda g, p: g.astype(p.dtype), grads, state.prev_grad),
            )

        metrics = {
            "loss": jnp.mean(losses),
            "alpha": alpha,
            "grad_norm_sq": _mean_grad_norm_sq(grads),
            "consensus_err": _consensus_error(new_state.params),
            "comm_rounds": jnp.float32(1.0),
        }
        return new_state, metrics

    return comm_step


def _make_fused_comm_step(
    eval_grads, schedule: Schedule, cfg: FLConfig, layout: FlatLayout,
    spec: FusedRoundSpec,
):
    """The megakernel comm step: ONE fused update+quantize+mix+EF kernel
    call on the flat buffers (two mixed wires for DSGT, still one call)."""
    if layout.total % spec.scale_chunk:
        raise ValueError(
            f"layout.total {layout.total} not a multiple of scale_chunk "
            f"{spec.scale_chunk}; pack with pad_to={spec.scale_chunk}"
        )
    w = np.asarray(spec.w, dtype=np.float64)
    if w.shape != (cfg.n_nodes, cfg.n_nodes):
        raise ValueError(f"W shape {w.shape} != ({cfg.n_nodes},) * 2")
    w_self = jnp.asarray(np.diag(w), jnp.float32)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)

    if spec.impl == "pallas":
        from repro.kernels.gossip.ops import fused_round, fused_round_gt
    else:
        from repro.kernels.gossip.ref import (
            fused_round_gt_ref as fused_round_gt,
            fused_round_ref as fused_round,
        )

    # Per-round egress, summed over nodes: every off-diagonal edge carries
    # 1 B/param + 4 B per scale chunk; DSGT ships params AND tracker.
    degrees = (np.abs(w - np.diag(np.diag(w))) > 0).sum(axis=1)
    n_scales = layout.total // spec.scale_chunk
    wires = 2 if cfg.algorithm == "dsgt" else 1
    egress = float(wires * degrees.sum() * (layout.total + 4 * n_scales))

    kw = dict(
        scale_chunk=spec.scale_chunk,
        error_feedback=spec.error_feedback,
        difference_coding=spec.difference_coding,
    )

    def comm_step(
        state: FLState, batch: PyTree
    ) -> Tuple[FLState, Dict[str, jnp.ndarray]]:
        if state.comm is None:
            raise ValueError("fused rounds need init_fl_state(..., fused=True)")
        step = state.step + 1
        alpha = schedule(step)
        losses, grads = eval_grads(state.params, batch)
        grads = grads.astype(jnp.float32)

        if cfg.algorithm == "dsgd":
            mixed, recon, res, _ = fused_round(
                state.params, grads, state.comm["recon"], state.comm["residual"],
                w_off, w_self, alpha, **kw,
            )
            new_state = state._replace(
                step=step, params=mixed, comm={"recon": recon, "residual": res}
            )
        else:
            mx, mt, nrx, nsx, nrt, nst, _, _ = fused_round_gt(
                state.params, state.tracker, grads, state.prev_grad,
                state.comm["recon"], state.comm["residual"],
                state.comm["recon_t"], state.comm["residual_t"],
                w_off, w_self, alpha, **kw,
            )
            new_state = FLState(
                step=step,
                params=mx,
                tracker=mt,
                prev_grad=grads,
                comm={
                    "recon": nrx, "residual": nsx,
                    "recon_t": nrt, "residual_t": nst,
                },
            )

        metrics = {
            "loss": jnp.mean(losses),
            "alpha": alpha,
            "grad_norm_sq": _mean_grad_norm_sq(grads),
            "consensus_err": _consensus_error(new_state.params),
            "comm_rounds": jnp.float32(1.0),
            "wire_bytes": jnp.float32(egress),
        }
        return new_state, metrics

    return comm_step


def _mean_grad_norm_sq(stacked_grads: PyTree) -> jnp.ndarray:
    """|| (1/N) sum_i grad_i ||^2 -- the first term of Theorem 1's LHS."""
    sq = 0.0
    for g in jax.tree_util.tree_leaves(stacked_grads):
        mean_g = jnp.mean(g.astype(jnp.float32), axis=0)
        sq = sq + jnp.sum(mean_g * mean_g)
    return sq


def _consensus_error(stacked_params: PyTree) -> jnp.ndarray:
    """(1/N) sum_i ||theta_i - theta_bar||^2 -- Theorem 1's second term."""
    err = 0.0
    for p in jax.tree_util.tree_leaves(stacked_params):
        pf = p.astype(jnp.float32)
        dev = pf - jnp.mean(pf, axis=0, keepdims=True)
        err = err + jnp.sum(dev * dev) / pf.shape[0]
    return err
