"""GossipEngine protocol: ONE pluggable layer behind ``make_fl_round``.

Historically the round machinery grew three divergent call paths -- the
node-stacked pytree path, the flat ``(nodes, total)`` buffer path
(``layout=``), and the fused round megakernel (``fused=``) -- selected by
a kwarg maze in ``core.fl`` and string-dispatched if-chains in the
launchers. This module replaces all of that with a small protocol:

    init_comm_state(cfg, params)  extra wire state carried in FLState.comm
    local_step(params, grads, a)  the SGD update in the engine's own
                                  state representation
    mix(buf)                      exact-wire W application (tree/flat
                                  engines; fused engines mix inside their
                                  comm step instead)
    wire_bytes(cfg)               per-round egress accounting (all nodes)

plus two build hooks ``make_eval_grads`` (representation adapter around
the vmapped grad fn) and ``make_comm_step`` (the whole communication
step; the base class provides the paper's exact-wire mix-then-adapt
Eqs. 2/3, fused engines override it with adapt-then-combine kernels).

Shipped engines (the registry keys are what ``--fl-engine`` accepts
everywhere -- launch/dryrun.py, launch/train.py, examples -- so names
cannot drift):

    tree           node-stacked pytree state + any tree-level gossip
                   backend (dense-W simulated, mesh ppermute, all-gather)
    flat           the state IS one packed (nodes, total) fp32 buffer;
                   mixing is one matmul / ppermute / all-gather on it
    fused          the round megakernel: local update + int8 quantize +
                   W mix + error feedback in ONE Pallas call
                   (``kernels.gossip``), CHOCO difference-coded wire
    sharded_fused  the shard_map-native fused round: every device owns
                   its node's W row and its rows of the flat buffer, the
                   wire stage (update + top-k + int8 quantize + EF) is
                   ONE Pallas call per round, and the int8 payload moves
                   via ppermute (circulant torus/ring W) or all-gather
                   (arbitrary dense W)

``topk=`` on the fused engines masks the payload to the k largest-|.|
columns per scale chunk inside the kernel; the EF residual absorbs the
truncation, and wire bytes drop below the dense-int8 floor
(``packing.flat_wire_bytes``). On the SHARDED engine, ``topk`` also
turns on the COMPACT wire by default: the wire-stage kernel's
compact-gather epilogue emits exactly (k int8 values, k int16/int32
in-chunk positions, fp32 scales) per chunk, those buffers -- and nothing
masked-dense -- are the collective's operands, and the receive side
scatter-accumulates them into the running ``mix_recon`` term, so
``flat_wire_bytes`` accounts the bytes that actually cross.

Orthogonally to WHAT moves, a :class:`RoundSchedule` fixes WHEN: the
``sequential`` schedule is the paper's produce -> collective -> mix
round; the ``pipelined`` schedule double-buffers the wire payload in
``FLState.comm`` (``wire_*`` keys), issues the collective for round r's
payload BEFORE round r+1's local-step scan (no data dependency -- the
overlap window an async-collective backend exploits), and mixes with
one-round-STALE neighbor information -- exactly
sequential-with-one-round-delay, proven against a hand-written delayed
oracle in tests/test_schedule.py. Engines carry their schedule
(``round_schedule=`` at build time) because it is part of the comm-state
contract; ``--fl-schedule`` resolves through the schedule registry the
same way ``--fl-engine`` resolves through the engine registry.

How the sharded engine stays O(params/node) per device: a CHOCO node
needs ``sum_j W_ij recon_j`` over its neighbors' reconstructions, but
``recon_j`` only ever advances by the dequantized wire payload
``dq_j``, so each node carries a running accumulator

    mix_recon_i  <-  mix_recon_i + sum_j W_ij dq_j        (one buffer)
    mixed_i       =  w_ii * h_i + mix_recon_i'

which equals the dense megakernel's ``W_off @ recon' + w_self * h`` row
exactly (up to summation order) without ever materializing neighbor
state. ``mix_recon`` rides in ``FLState.comm`` next to recon/residual.
"""

from __future__ import annotations

import abc
from typing import Any, Callable, ClassVar, Dict, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.dynamics import (
    STATIC,
    TopologyProgram,
    resolve_program,
)
from repro.core.heterogeneity import (
    HOMOGENEOUS,
    NodeProgram,
    compose_node_gate,
    resolve_node_program,
)
from repro.core.fl import (
    FLConfig,
    FLState,
    _consensus_error,
    _mean_grad_norm_sq,
)
from repro.core.mixing import (
    GossipFn,
    _allgather_row,
    _mesh_dirs,
    _shard_map,
    _split_w,
    make_dense_flat_mix,
    make_dense_gossip,
    make_mesh_flat_mix,
    make_mesh_gossip,
    mesh_gossip_dense_equivalent,
)
from repro.core.packing import (
    FlatLayout,
    bitmap_bytes_per_chunk,
    compact_index_bytes,
    compact_pos_dtype,
    flat_wire_bytes,
    flat_wire_bytes_per_shard,
    pack,
    pack_layout,
    pack_like,
    scoped_layout,
    unpack,
)
from repro.core.privacy import (
    NONE as PRIVACY_NONE,
    PAD_STREAM,
    TRACKER_STREAM_OFFSET,
    PrivacySpec,
    dp_noise,
    epsilon_traced,
    mask_wire,
    pair_index,
    resolve_privacy,
)
from repro.core.scope import (
    FULL as SCOPE_FULL,
    FederationScope,
    LayerwiseScope,
    resolve_scope,
)

PyTree = Any

__all__ = [
    "GossipEngine",
    "TreeEngine",
    "FlatEngine",
    "FusedEngine",
    "ShardedFusedEngine",
    "register_engine",
    "get_engine",
    "engine_names",
    "RoundSchedule",
    "SequentialSchedule",
    "PipelinedSchedule",
    "BoundedStalenessSchedule",
    "register_schedule",
    "get_schedule",
    "schedule_names",
    "resolve_schedule",
]


def _tm(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


# ---------------------------------------------------------------------------
# Round schedules: how a communication round is laid out in TIME
# ---------------------------------------------------------------------------


class RoundSchedule(abc.ABC):
    """How one communication round is laid out in time.

    The :class:`GossipEngine` owns WHAT moves (state representation, wire
    encoding, mixing math); the RoundSchedule owns WHEN: whether the
    collective for a round's payload blocks that round's mix
    (:class:`SequentialSchedule`) or is issued while the NEXT round's
    local steps compute, the mix consuming one-round-stale neighbor
    information (:class:`PipelinedSchedule`). An engine carries its
    schedule as ``engine.round_schedule`` (fixed at construction -- the
    schedule is part of the engine's comm-state contract, so
    ``init_fl_state`` / checkpoints see one consistent answer), and
    ``make_fl_round`` delegates the round layout here.

    Schedules register by name exactly like engines -- the registry is
    what ``--fl-schedule`` accepts everywhere.
    """

    name: ClassVar[str] = "abstract"
    #: staleness depth of the mixed neighbor information: 0 for the
    #: blocking sequential round, 1 for the double-buffered pipelined
    #: round, k for :class:`BoundedStalenessSchedule` (k in-flight
    #: payloads, mix against the k-round-stale one)
    depth: int = 0

    @abc.abstractmethod
    def build_round(self, engine: "GossipEngine", eval_grads, schedule,
                    cfg: FLConfig, local_step):
        """Assemble ``round_fn(state, batches) -> (state, metrics)`` from
        the engine's comm machinery and the per-iteration ``local_step``."""

    def spec(self) -> str:
        """The round-trippable string form (``resolve_schedule(spec)``
        reconstructs an equivalent schedule) -- what checkpoint manifests
        record and ``--fl-schedule`` accepts."""
        return self.name


_SCHEDULES: Dict[str, "RoundSchedule"] = {}


def register_schedule(cls: Type[RoundSchedule]) -> Type[RoundSchedule]:
    """Class decorator: make the schedule resolvable by name. Schedules
    are stateless, so the registry holds singleton instances -- the ONE
    list every ``--fl-schedule`` CLI and checkpoint manifest consults."""
    if cls.name in _SCHEDULES:
        raise ValueError(f"duplicate schedule name {cls.name!r}")
    _SCHEDULES[cls.name] = cls()
    return cls


def get_schedule(name: str) -> RoundSchedule:
    try:
        return _SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown round schedule {name!r}; registered: {schedule_names()}"
        ) from None


def schedule_names() -> Tuple[str, ...]:
    return tuple(sorted(_SCHEDULES))


def resolve_schedule(rs) -> RoundSchedule:
    """Accept a registry name, a parameterized spec string
    (``"bounded_staleness:k=4"``), a RoundSchedule instance, or None
    (the sequential default)."""
    if rs is None:
        return _SCHEDULES["sequential"]
    if isinstance(rs, RoundSchedule):
        return rs
    name, _, argstr = str(rs).partition(":")
    base = get_schedule(name)
    if not argstr:
        return base
    kwargs: Dict[str, int] = {}
    for item in argstr.split(","):
        k, sep, v = item.partition("=")
        if not sep:
            raise ValueError(
                f"bad schedule spec {rs!r}: expected name:key=value[,...]"
            )
        try:
            kwargs[k.strip()] = int(v)
        except ValueError:
            raise ValueError(
                f"bad schedule spec {rs!r}: {v!r} is not an integer"
            ) from None
    try:
        return type(base)(**kwargs)
    except TypeError:
        raise ValueError(
            f"schedule {name!r} takes no parameters {tuple(kwargs)!r}"
        ) from None


def _require_sequential(round_schedule, name: str) -> RoundSchedule:
    rs = resolve_schedule(round_schedule)
    if rs.name != "sequential":
        raise ValueError(
            f"round schedule {rs.name!r} needs the split produce/collective "
            f"comm step of the fused engines; the {name!r} engine is "
            "sequential-only -- use 'fused' or 'sharded_fused'"
        )
    return rs


def _assemble_round(cfg, local_step, comm_call, pre_scan=None,
                    step_mask=None):
    """The shared round body: optional pre-scan hook (the pipelined
    ingest -- traced FIRST so its collective precedes the scan in the
    jaxpr), (Q-1) local steps under ONE lax.scan, then the comm call.
    ``comm_call(state, batch, aux)`` receives whatever ``pre_scan``
    returned (None without one). ``step_mask(state) -> (q-1, n)`` is the
    heterogeneous-compute hook (:meth:`GossipEngine.make_step_mask`): a
    traced per-node mask over the local-step scan -- straggling nodes
    run fewer EFFECTIVE iterations as masked updates of the ONE compiled
    scan, never as a recompile."""

    def round_fn(state: FLState, batches: PyTree):
        aux = pre_scan(state) if pre_scan is not None else None
        q = cfg.q
        mask = step_mask(state) if step_mask is not None else None
        if q > 1:
            local_batches = _tm(lambda b: b[: q - 1], batches)
            if mask is None:
                state, local_losses = jax.lax.scan(
                    local_step, state, local_batches
                )
            else:
                state, local_losses = jax.lax.scan(
                    lambda c, xs: local_step(c, xs[0], mask=xs[1]),
                    state, (local_batches, mask),
                )
        else:
            local_losses = jnp.zeros((0,), jnp.float32)
        comm_batch = _tm(lambda b: b[q - 1], batches)
        state, metrics = comm_call(state, comm_batch, aux)
        metrics["local_loss"] = jnp.where(
            q > 1,
            jnp.sum(local_losses) / jnp.maximum(1, q - 1),
            metrics["loss"],
        )
        if mask is not None:
            # realized local-step work: masked scan iterations + the comm
            # step's own update, as a fraction of the homogeneous q * n
            metrics["compute_fraction"] = (
                jnp.sum(mask.astype(jnp.float32)) + cfg.n_nodes
            ) / jnp.float32(q * cfg.n_nodes)
        return state, metrics

    return round_fn


@register_schedule
class SequentialSchedule(RoundSchedule):
    """The paper's round layout: (Q-1) local steps, then ONE comm step in
    which the payload is produced, crosses the wire, and is mixed before
    the round returns -- every engine supports it."""

    name = "sequential"
    depth = 0

    def build_round(self, engine, eval_grads, schedule, cfg, local_step):
        comm_step = engine.make_comm_step(eval_grads, schedule, cfg)
        return _assemble_round(
            cfg, local_step,
            lambda state, batch, aux: comm_step(state, batch),
            step_mask=engine.make_step_mask(cfg),
        )


@register_schedule
class PipelinedSchedule(RoundSchedule):
    """Overlap the collective with the local steps: round r's payload is
    double-buffered in ``FLState.comm`` (``wire_*``), its ppermute /
    all-gather is ISSUED at the top of round r+1 -- before the local-step
    scan, with no data dependency on it, so an async-collective backend
    overlaps the wire with the Q local steps -- and round r+1's mix
    consumes that one-round-stale neighbor information:

        sequential round r:   mixed_r = w_self*h_r + S_j W_ij recon_j^(r)
        pipelined  round r:   mixed_r = w_self*h_r + S_j W_ij recon_j^(r-1)

    i.e. exactly sequential-with-one-round-delay (tests/test_schedule.py
    proves equality against a hand-written delayed oracle). The first
    round mixes nothing (zero in-flight payload), the staleness price is
    quantified in experiments/staleness_ehr.json.

    Supported by the fused engines (their comm step already separates
    payload production from the collective); exact-wire engines raise at
    build time.
    """

    name = "pipelined"
    depth = 1

    def build_round(self, engine, eval_grads, schedule, cfg, local_step):
        # The ingest collective on the IN-FLIGHT payload is the pre-scan
        # hook: traced first, so it precedes the local-step scan in the
        # jaxpr and depends on nothing the scan computes -- that is the
        # overlap window.
        ingest, comm_step = engine.make_pipelined_round(
            eval_grads, schedule, cfg
        )
        return _assemble_round(cfg, local_step, comm_step, pre_scan=ingest,
                               step_mask=engine.make_step_mask(cfg))


@register_schedule
class BoundedStalenessSchedule(RoundSchedule):
    """Depth-k generalization of the pipelined round: k wire payloads
    ride in flight in ``FLState.comm`` (a ring buffer of
    ``wire_q`` / ``wire_pos`` / ``wire_scales``), the collective consumes
    the OLDEST one, and the mix uses k-round-stale neighbor information:

        round r:   mixed_r = w_self*h_r + S_j W_ij recon_j^(r-k)

    -- exactly sequential-with-k-round-delay (tests/test_bounded_staleness
    proves equality against a hand-written k-delayed oracle), a straggler
    budget of k rounds before a late payload must be dropped. ``k=1`` IS
    the pipelined schedule (bit-identical trajectories, same comm-state
    contract). The staleness price is swept in
    experiments/straggler_ehr.json; the alpha controller
    (``core.schedules.robust_alpha_scale``) compensates the slower
    mixing. Fused engines only, like the pipelined schedule.
    """

    name = "bounded_staleness"

    def __init__(self, k: int = 1):
        k = int(k)
        if k < 1:
            raise ValueError(f"bounded staleness depth k={k} must be >= 1")
        self.depth = k

    def spec(self) -> str:
        return f"{self.name}:k={self.depth}"

    def build_round(self, engine, eval_grads, schedule, cfg, local_step):
        ingest, comm_step = engine.make_pipelined_round(
            eval_grads, schedule, cfg
        )
        return _assemble_round(cfg, local_step, comm_step, pre_scan=ingest,
                               step_mask=engine.make_step_mask(cfg))


def _check_flat_params(cfg: FLConfig, params: PyTree, name: str) -> None:
    leaves = jax.tree_util.tree_leaves(params)
    if not leaves:
        raise ValueError("empty parameter pytree")
    for leaf in leaves:
        if leaf.shape[:1] != (cfg.n_nodes,):
            raise ValueError(
                f"param leaf {leaf.shape} is not node-stacked for n={cfg.n_nodes}"
            )
    if len(leaves) != 1 or leaves[0].ndim != 2:
        raise ValueError(
            f"{name} engine state must be the packed (nodes, total) flat "
            "buffer (core.packing.pack)"
        )


def _make_flat_eval_grads(layout: FlatLayout, grad_fn):
    def eval_grads(params: jnp.ndarray, batch: PyTree):
        # The tree view exists only inside this call; XLA lowers the
        # unpack/pack pair to slices/concat and fuses them away.
        losses, grads = grad_fn(unpack(params, layout), batch)
        return losses, pack_like(grads, layout)

    return eval_grads


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------


class GossipEngine(abc.ABC):
    """One round engine: state representation + wire + mixing semantics.

    Subclasses set ``name`` (the registry key) and ``layout`` (the
    :class:`FlatLayout` for flat-state engines, None for tree state), and
    either implement :meth:`mix` (exact-wire engines; the base
    :meth:`make_comm_step` then runs the paper's mix-then-adapt Eqs. 2/3)
    or override :meth:`make_comm_step` entirely (fused engines).
    """

    name: ClassVar[str] = "abstract"
    #: True for engines that only run on a device mesh (no ``simulated``)
    needs_mesh: ClassVar[bool] = False
    layout: Optional[FlatLayout] = None
    #: the engine's :class:`RoundSchedule` (sequential unless the engine
    #: was built pipelined -- the schedule is part of the comm-state
    #: contract, so it is fixed at construction)
    round_schedule: RoundSchedule = _SCHEDULES["sequential"]
    #: the engine's :class:`~repro.core.dynamics.TopologyProgram` -- the
    #: THIRD round axis (engine = WHAT moves, schedule = WHEN, program =
    #: over WHICH graph). Fixed at construction like the schedule: a
    #: dynamic program adds the ``topo_round`` / ``topo_key`` counters to
    #: the comm-state contract and turns the mixing weights into traced
    #: per-round operands of the ONE compiled round function.
    topology_program: TopologyProgram = STATIC
    #: the engine's :class:`~repro.core.heterogeneity.NodeProgram` -- the
    #: FOURTH round axis (over WHICH nodes, at WHAT speed): per-round
    #: traced compute-rate masks for the local-step scan and payload
    #: drop gates folded into the realized W_r
    #: (:func:`~repro.core.heterogeneity.compose_node_gate` renormalizes
    #: the missing weight into the self-loop, so every realized round
    #: stays symmetric doubly stochastic). Same zero-recompile discipline
    #: as the topology program: one ``node_key`` in ``FLState.comm``,
    #: everything per-round is a traced operand of the ONE compiled round.
    node_program: NodeProgram = HOMOGENEOUS
    #: the engine's :class:`~repro.core.privacy.PrivacySpec` -- the FIFTH
    #: round axis (what the wire does to the PAYLOAD: pairwise transport
    #: pads and/or clip + Gaussian DP noise). Engines that realize it
    #: override :attr:`_priv_rng`; the base engines carry the spec only
    #: so the checkpoint manifest can record/refuse it uniformly.
    privacy: PrivacySpec = PRIVACY_NONE
    #: the engine's :class:`~repro.core.scope.FederationScope` -- the
    #: SIXTH round axis (which bytes EXIST on the wire: the shared
    #: sub-ranges of the flat buffer that gossip mixes; everything else
    #: is a per-node private slice that stays bit-untouched). Engines
    #: that realize it slice the wire stage to the shared columns; the
    #: base engines carry the spec only so the checkpoint manifest can
    #: record/refuse it uniformly.
    scope: FederationScope = SCOPE_FULL

    # -- dynamic-round contract (topology + node programs) -----------------

    @property
    def dynamic_topology(self) -> bool:
        return not self.topology_program.is_static

    @property
    def dynamic_nodes(self) -> bool:
        return not self.node_program.is_static

    @property
    def dynamic_round(self) -> bool:
        """True when ANY per-round traced operand exists (dynamic graph
        or heterogeneous/faulty nodes) -- the condition that selects the
        traced-W round layout."""
        return self.dynamic_topology or self.dynamic_nodes

    @property
    def _priv_rng(self) -> bool:
        """True when the engine REALIZES a privacy transform that
        consumes round-time RNG (pads / DP noise) -- it then carries
        ``priv_key`` + the shared ``topo_round`` counter in
        ``FLState.comm`` so masked/noised rounds are checkpoint-exact.
        Base engines never do; the fused engines override."""
        return False

    @property
    def _scope_round(self) -> bool:
        """True when the scope gates per-round behaviour on the round
        counter (``layerwise:freq=``) -- the engine then carries the
        shared ``topo_round`` counter in ``FLState.comm`` even under a
        static topology, so restores replay the identical gate phase."""
        return self.scope.needs_round

    def _topo_keys(self) -> Tuple[str, ...]:
        """Comm keys the dynamic programs contribute: the shared round
        counter (round index the NEXT comm step will mix under), the
        topology program's base RNG key + Markov state buffers, the
        node program's base RNG key, and the privacy base key -- all
        checkpointed, so a mid-churn / mid-outage / mid-noise restore
        replays the identical round sequence."""
        keys: Tuple[str, ...] = ()
        if self.dynamic_round or self._priv_rng or self._scope_round:
            keys += ("topo_round",)
        if self.dynamic_topology:
            keys += ("topo_key",) + self.topology_program.state_keys()
        if self.dynamic_nodes:
            keys += ("node_key",)
        if self._priv_rng:
            keys += ("priv_key",)
        return keys

    def _topo_sds(self) -> Dict[str, jax.ShapeDtypeStruct]:
        sds = {
            "topo_round": jax.ShapeDtypeStruct((), jnp.int32),
            "topo_key": jax.ShapeDtypeStruct((2,), jnp.uint32),
            "node_key": jax.ShapeDtypeStruct((2,), jnp.uint32),
            "priv_key": jax.ShapeDtypeStruct((2,), jnp.uint32),
        }
        sds.update(self.topology_program.state_sds())
        return sds

    def _topo_init(self) -> Dict[str, jnp.ndarray]:
        init = {
            "topo_round": jnp.int32(0),
            "topo_key": jnp.asarray(self.topology_program.init_key()),
            "node_key": jnp.asarray(self.node_program.init_key()),
            "priv_key": jnp.asarray(self.privacy.init_key()),
        }
        # jnp.asarray: program init states are eager numpy (jit-safe); a
        # raw ndarray leaf would cost one extra executable on round 1.
        init.update({k: jnp.asarray(v)
                     for k, v in self.topology_program.init_state().items()})
        return init

    def _static_round_w(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """The engine's compile-time ``(w_off, w_diag)`` as jnp constants
        -- what :meth:`_round_gates` starts from when the topology is
        static but a node program gates payloads. Engines that never
        materialize a dense W reject node programs at build time
        instead."""
        raise NotImplementedError(
            f"the {self.name!r} engine does not expose its static W; "
            "node programs are unsupported on this build"
        )

    def _round_gates(self, comm: Dict[str, jnp.ndarray]):
        """ONE derivation of the round's realized mixing weights from
        BOTH dynamic axes: the topology program's per-round W (stateful
        Markov churn advances its up/down state here), then the node
        program's payload gate folded in by
        :func:`~repro.core.heterogeneity.compose_node_gate`. Returns
        ``(w_off_r, w_diag_r, new_comm_entries, metrics)`` -- the per-
        round W is a traced OPERAND of the one compiled round, the
        counter/state advance rides in the returned comm entries, and
        the metrics report the realized edge/payload fractions."""
        r = comm["topo_round"]
        new_comm: Dict[str, jnp.ndarray] = {"topo_round": r + 1}
        metrics: Dict[str, jnp.ndarray] = {}
        topo = self.topology_program
        if self.dynamic_topology:
            key = comm["topo_key"]
            tstate = {k: comm[k] for k in topo.state_keys()}
            w_off_r, w_diag_r, tnew = topo.round_weights_state(r, key, tstate)
            new_comm["topo_key"] = key
            new_comm.update(tnew)
            metrics["edge_fraction"] = topo.edge_fraction(w_off_r)
        else:
            w_off_r, w_diag_r = self._static_round_w()
        if self.dynamic_nodes:
            nkey = comm["node_key"]
            up = self.node_program.wire_gate(r, nkey)
            w_off_r, w_diag_r = compose_node_gate(w_off_r, w_diag_r, up)
            new_comm["node_key"] = nkey
            metrics["payload_fraction"] = jnp.mean(up.astype(jnp.float32))
        if self._priv_rng:
            new_comm["priv_key"] = comm["priv_key"]
        return w_off_r, w_diag_r, new_comm, metrics

    def _priv_comm(self, comm: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """The advanced privacy/scope counter entries for STATIC rounds
        (a dynamic round advances ``topo_round`` in :meth:`_round_gates`,
        which also passes ``priv_key`` through)."""
        if self.dynamic_round or not (self._priv_rng or self._scope_round):
            return {}
        out: Dict[str, jnp.ndarray] = {"topo_round": comm["topo_round"] + 1}
        if self._priv_rng:
            out["priv_key"] = comm["priv_key"]
        return out

    def make_step_mask(self, cfg: FLConfig):
        """The heterogeneous-compute hook for ``_assemble_round``: None
        for homogeneous programs (the scan runs unmasked, zero overhead),
        else ``step_mask(state) -> (q-1, n)`` traced from the round
        counter + node key in ``FLState.comm`` -- stragglers run fewer
        effective local steps as MASKED iterations of the one compiled
        scan."""
        prog = self.node_program
        if getattr(prog, "heterogeneous_wire_k", False) and not getattr(
            self, "supports_wire_k", False
        ):
            raise ValueError(
                f"node program {prog.spec()!r} modulates per-node wire k, "
                f"which the {self.name!r} engine does not support -- use "
                "engine='sharded_fused' (top-k wire with an EF residual)"
            )
        if not prog.heterogeneous_compute or cfg.q <= 1:
            return None

        def step_mask(state: FLState) -> jnp.ndarray:
            return prog.step_gate(
                state.comm["topo_round"], state.comm["node_key"], cfg.q
            )

        return step_mask

    def mix_dynamic(self, buf: PyTree, w_off_r: jnp.ndarray,
                    w_diag_r: jnp.ndarray) -> PyTree:
        """Exact-wire mixing against a TRACED per-round W (engines that
        support dynamic programs on the exact-wire path override this;
        the fused engines take the per-round W as kernel operands
        instead)."""
        raise NotImplementedError(
            f"the {self.name!r} engine does not support dynamic topology "
            "programs on this build"
        )

    # -- protocol ----------------------------------------------------------

    def comm_keys(self, cfg: FLConfig) -> Tuple[str, ...]:
        """Names of the engine's extra wire-state buffers in
        ``FLState.comm`` (shapes/dtypes per :meth:`comm_state_sds`)."""
        return self._topo_keys()

    def comm_state_sds(
        self, cfg: FLConfig
    ) -> Optional[Dict[str, jax.ShapeDtypeStruct]]:
        """Shape/dtype of every comm buffer (trace-time safe -- the
        lowering-only dry runs build their state specs from this)."""
        keys = self.comm_keys(cfg)
        if not keys:
            return None
        topo = self._topo_sds()
        buf_keys = [k for k in keys if k not in topo]
        if buf_keys and self.layout is None:
            raise NotImplementedError(
                f"{type(self).__name__} declares comm buffers but no layout"
            )
        sds = (
            jax.ShapeDtypeStruct((cfg.n_nodes, self.layout.total), jnp.float32)
            if self.layout is not None else None
        )
        return {k: topo[k] if k in topo else sds for k in keys}

    def init_comm_state(
        self, cfg: FLConfig, params: PyTree
    ) -> Optional[Dict[str, jnp.ndarray]]:
        """Zero-initialized wire state (zeros = the first round
        effectively transmits the full parameters, and a pipelined
        engine's first in-flight payload dequantizes to nothing); a
        dynamic program's counter starts at round 0 with its base key."""
        sds = self.comm_state_sds(cfg)
        if sds is None:
            return None
        comm = {k: jnp.zeros(s.shape, s.dtype) for k, s in sds.items()}
        comm.update({k: v for k, v in self._topo_init().items() if k in comm})
        return comm

    def local_step(self, params: PyTree, grads: PyTree, alpha,
                   mask=None) -> PyTree:
        """Eq. 4 in the engine's state representation (works unchanged for
        tree state and for the single-leaf flat buffer). The update is
        computed at the wider of (leaf, fp32) and stored back at the
        leaf's dtype -- bf16 flat storage keeps fp32 only in transient
        arithmetic, never in the stored buffer. ``mask`` is the node
        program's (n,) compute gate for this scan iteration: a masked
        node's update is zeroed (it sits the iteration out) without
        touching the compiled scan shape."""
        a = alpha if mask is None else alpha * mask.astype(jnp.float32)

        def upd(p, g):
            am = a if mask is None else a.reshape(
                a.shape + (1,) * (p.ndim - 1)
            )
            return (
                p.astype(jnp.float32) - am * g.astype(jnp.float32)
            ).astype(p.dtype)

        return _tm(upd, params, grads)

    def mix(self, buf: PyTree) -> PyTree:
        """Exact-wire W application (theta <- W theta) on the engine's
        state representation. Fused engines do not expose a standalone
        mix -- their W lives inside the comm-step kernel."""
        raise NotImplementedError(
            f"{type(self).__name__} mixes inside its fused comm step"
        )

    def wire_bytes(self, cfg: FLConfig) -> Optional[float]:
        """Per-round egress summed over all nodes (None: engine does not
        account -- e.g. the tree engine, whose payload depends on the
        pytree; see training.metrics.comm_bytes_per_gossip)."""
        return None

    # -- round building ----------------------------------------------------

    def check_params(self, cfg: FLConfig, params: PyTree) -> None:
        """Validate the initial state representation (called by
        ``init_fl_state``); base checks node-stacking only."""
        leaves = jax.tree_util.tree_leaves(params)
        if not leaves:
            raise ValueError("empty parameter pytree")
        for leaf in leaves:
            if leaf.shape[:1] != (cfg.n_nodes,):
                raise ValueError(
                    f"param leaf {leaf.shape} is not node-stacked for "
                    f"n={cfg.n_nodes}"
                )

    def make_eval_grads(self, grad_fn):
        """Adapt the vmapped per-node grad fn to the engine's state
        representation (identity for tree state)."""
        return grad_fn

    def params_view(self, params: PyTree) -> PyTree:
        """The pytree view of the engine's parameter state (unpacks flat
        buffers; identity for tree state)."""
        if self.layout is None:
            return params
        return unpack(params, self.layout)

    def init_state(self, cfg: FLConfig, params: PyTree) -> FLState:
        from repro.core.fl import init_fl_state

        return init_fl_state(cfg, params, engine=self)

    def _known_comm_keys(self) -> frozenset:
        """EVERY comm key this engine could ever carry (a cfg-independent
        superset of :meth:`comm_keys` over both algorithms and all
        schedule depths) -- what :meth:`restore_comm` validates restored
        dicts against. Engines with wire buffers extend it."""
        return frozenset(
            ("topo_round", "topo_key", "node_key", "priv_key")
            + tuple(self.topology_program.state_keys())
        )

    def _check_restored_comm_keys(
        self, comm: Dict[str, jnp.ndarray]
    ) -> None:
        """Refuse restored comm dicts carrying keys this engine does not
        know: a silent extra key is a forward-compat hazard (state from a
        newer wire contract would be dropped on the floor, then
        re-initialized to something inconsistent on the next save)."""
        unknown = sorted(set(comm) - self._known_comm_keys())
        if unknown:
            raise ValueError(
                f"restored comm state carries keys {unknown} the "
                f"{self.name!r} engine does not know (known: "
                f"{sorted(self._known_comm_keys())}). The checkpoint was "
                "written under a different wire contract -- rebuild the "
                "engine with the checkpoint manifest's engine/schedule/"
                "topology/node-program/privacy specs (training.checkpoint "
                "restores them verbatim), or migrate the comm dict by "
                "dropping keys the manifest marks as derived."
            )

    def restore_comm(
        self, comm: Dict[str, jnp.ndarray]
    ) -> Dict[str, jnp.ndarray]:
        """Rebuild DERIVED wire-state buffers after a checkpoint restore
        (identity for engines whose comm buffers are all independent).
        Always validates the restored keys first: unknown keys raise
        (see :meth:`_check_restored_comm_keys`)."""
        self._check_restored_comm_keys(comm)
        return comm

    def is_derived_comm_key(self, key: str) -> bool:
        """True for comm buffers that are DERIVED from the independent
        ones (:meth:`restore_comm` rebuilds them from recon): a
        checkpoint's derived keys may safely be dropped when the restore
        template's comm contract no longer carries them -- e.g. a STATIC
        sharded checkpoint's ``mix_recon`` seeding a dynamic-topology run
        whose contract replaced it with per-direction accumulators."""
        return False

    def make_comm_step(self, eval_grads, schedule, cfg: FLConfig):
        """Default EXACT-WIRE comm step: ``self.mix`` applies W, then the
        optimizer update (mix-then-adapt, the paper's Eqs. 2/3). Under a
        dynamic :class:`~repro.core.dynamics.TopologyProgram` the round's
        W is a TRACED operand -- derived from the ``topo_round`` /
        ``topo_key`` counters in ``FLState.comm`` and applied through
        :meth:`mix_dynamic` -- so ONE compiled round function serves
        every round of the program."""
        wire = self.wire_bytes(cfg)
        dynamic = self.dynamic_round

        def comm_step(state: FLState, batch: PyTree):
            step = state.step + 1
            alpha = schedule(step)
            losses, grads = eval_grads(state.params, batch)

            gate_metrics: Dict[str, jnp.ndarray] = {}
            if not dynamic:
                mix, comm = self.mix, state.comm
            else:
                w_off_r, w_diag_r, new_entries, gate_metrics = (
                    self._round_gates(state.comm)
                )
                mix = lambda buf: self.mix_dynamic(buf, w_off_r, w_diag_r)
                comm = dict(state.comm)
                comm.update(new_entries)

            # adapt at fp32, store back at the state dtype (bf16 flat
            # storage narrows only what is STORED, never the arithmetic)
            def adapt(wp, t):
                return (
                    wp.astype(jnp.float32) - alpha * t.astype(jnp.float32)
                ).astype(wp.dtype)

            if cfg.algorithm == "dsgd":
                params = _tm(adapt, mix(state.params), grads)
                new_state = state._replace(step=step, params=params, comm=comm)
            else:
                tracker = _tm(
                    lambda wt, gn, gp: wt + gn.astype(wt.dtype) - gp,
                    mix(state.tracker), grads, state.prev_grad,
                )
                params = _tm(adapt, mix(state.params), tracker)
                new_state = state._replace(
                    step=step,
                    params=params,
                    tracker=tracker,
                    prev_grad=_tm(
                        lambda g, p: g.astype(p.dtype), grads, state.prev_grad
                    ),
                    comm=comm,
                )

            metrics = {
                "loss": jnp.mean(losses),
                "alpha": alpha,
                "grad_norm_sq": _mean_grad_norm_sq(grads),
                "consensus_err": _consensus_error(new_state.params),
                "comm_rounds": jnp.float32(1.0),
            }
            if wire is not None:
                metrics["wire_bytes"] = jnp.float32(wire)
            metrics.update(gate_metrics)
            return new_state, metrics

        return comm_step

    def make_pipelined_round(self, eval_grads, schedule, cfg: FLConfig):
        """The split comm machinery the :class:`PipelinedSchedule` needs:
        ``(ingest, comm_step)`` where ``ingest(state)`` issues the
        collective on the IN-FLIGHT payload (None for engines whose mix
        has no separate collective) and ``comm_step(state, batch, stale)``
        produces this round's payload and mixes with the stale neighbor
        term. Exact-wire engines do not implement it."""
        raise ValueError(
            f"the {self.name!r} engine is sequential-only; the pipelined "
            "schedule needs the fused engines' split produce/collective "
            "comm step (use 'fused' or 'sharded_fused')"
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[GossipEngine]] = {}


def register_engine(cls: Type[GossipEngine]) -> Type[GossipEngine]:
    """Class decorator: make ``cls`` resolvable by ``get_engine(cls.name)``.
    The registry is the ONE list of engine names every CLI / example /
    checkpoint manifest consults -- never hardcode the strings."""
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate engine name {cls.name!r}")
    _REGISTRY[cls.name] = cls
    return cls


def get_engine(name: str) -> Type[GossipEngine]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {engine_names()}"
        ) from None


def engine_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Exact-wire engines
# ---------------------------------------------------------------------------


@register_engine
class TreeEngine(GossipEngine):
    """Node-stacked pytree state; mixing delegated to any tree-level
    gossip backend from ``core.mixing`` (dense-W simulated, mesh
    ppermute, all-gather)."""

    name = "tree"

    def __init__(self, gossip: GossipFn):
        self._gossip = gossip

    def mix(self, tree: PyTree) -> PyTree:
        return self._gossip(tree)

    @classmethod
    def simulated(cls, w: np.ndarray, stacked_params: PyTree, *,
                  wire_dtype=None, topk=None, round_schedule=None,
                  storage_dtype=None, topology_program=None,
                  node_program=None, privacy=None, scope=None, **_ignored):
        """Single-host build: dense-W backend; state stays the input tree."""
        _reject_scope(scope, cls.name)
        _reject_topk(topk, cls.name)
        _require_sequential(round_schedule, cls.name)
        _reject_storage_dtype(storage_dtype, cls.name)
        _reject_privacy(
            privacy, cls.name,
            "engine's pytree wire has no quantize epilogue to pad or "
            "noise",
        )
        _reject_dynamic_program(
            topology_program, cls.name,
            "engine bakes W into its tree-level gossip backend",
        )
        _reject_node_program(
            node_program, cls.name,
            "engine bakes W into its tree-level gossip backend",
        )
        return cls(make_dense_gossip(w, wire_dtype)), stacked_params

    @classmethod
    def from_mesh(cls, mesh: Mesh, node_axes: Sequence[str], stacked_sds,
                  *, specs=None, wire_dtype=None, axes_subset=None,
                  topk=None, round_schedule=None, storage_dtype=None,
                  topology_program=None, node_program=None, privacy=None,
                  scope=None, **_ignored):
        _reject_scope(scope, cls.name)
        _reject_topk(topk, cls.name)
        _require_sequential(round_schedule, cls.name)
        _reject_storage_dtype(storage_dtype, cls.name)
        _reject_privacy(
            privacy, cls.name,
            "engine's pytree wire has no quantize epilogue to pad or "
            "noise",
        )
        _reject_dynamic_program(
            topology_program, cls.name,
            "engine bakes W into its tree-level gossip backend",
        )
        _reject_node_program(
            node_program, cls.name,
            "engine bakes W into its tree-level gossip backend",
        )
        if specs is None:
            raise ValueError("tree engine from_mesh needs the param specs")
        return cls(
            make_mesh_gossip(mesh, node_axes, specs, wire_dtype=wire_dtype,
                             axes_subset=axes_subset)
        )


@register_engine
class FlatEngine(GossipEngine):
    """The state is ONE packed ``(nodes, total)`` buffer end to end;
    mixing is a flat-native backend (one matmul / one ppermute per torus
    direction / one all-gather per round, independent of leaf count).

    ``storage_dtype`` selects the buffer's STORAGE precision
    (``layout.storage_dtype``): the fp32 default is lossless; bf16
    halves the HBM traffic of every buffer-wide op -- the flat mixing
    backends already accumulate their weighted sum in fp32 and cast back
    to the buffer dtype, so only storage narrows, never the mix
    accumulator (equivalence vs fp32 at relaxed tolerance is tested in
    tests/test_schedule.py; the HBM-traffic win is a bench row)."""

    name = "flat"

    def __init__(self, mix_fn: Callable[[jnp.ndarray], jnp.ndarray],
                 layout: FlatLayout, *, topology_program=None,
                 node_program=None, wire_dtype=None, w=None, privacy=None):
        self._mix = mix_fn
        self.layout = layout
        self.topology_program = resolve_program(topology_program)
        self.node_program = resolve_node_program(node_program)
        # The flat engine GAINS the privacy knob but realizes only the
        # vacuous half: its simulated wire is one in-process matmul, so
        # secure_agg is trivially satisfied (no per-edge payload exists
        # to intercept) and is accepted as a no-op; DP is refused at the
        # build sites (no EF epilogue to absorb the noise).
        self.privacy = _reject_dp(
            privacy, self.name, "engine ships an exact un-quantized wire "
            "with no error-feedback residual"
        )
        self._wire_dtype = wire_dtype
        self._w_np = None if w is None else np.asarray(w, dtype=np.float64)
        if self.dynamic_topology and not self.topology_program.bound:
            raise ValueError(
                "a dynamic FlatEngine needs the program bound to the base "
                "W (use FlatEngine.simulated, which binds it)"
            )
        if self.dynamic_nodes:
            if self._w_np is None:
                raise ValueError(
                    "a FlatEngine under a node program needs the dense W "
                    "(use FlatEngine.simulated, which passes it)"
                )
            self.node_program = self.node_program.bind(self._w_np.shape[0])

    def _static_round_w(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        _, w_self, w_off = _split_w_np(self._w_np, self._w_np.shape[0])
        return jnp.asarray(w_off, jnp.float32), jnp.asarray(
            w_self, jnp.float32
        )

    @property
    def storage_dtype(self):
        return jnp.dtype(self.layout.storage_dtype)

    def mix(self, flat: jnp.ndarray) -> jnp.ndarray:
        return self._mix(flat)

    def mix_dynamic(self, flat: jnp.ndarray, w_off_r: jnp.ndarray,
                    w_diag_r: jnp.ndarray) -> jnp.ndarray:
        """Dense flat mixing against the TRACED per-round W: same
        fp32-accumulate / wire-dtype semantics as ``make_dense_flat_mix``
        with the traced ``(w_off_r, w_diag_r)`` in place of the baked
        constants -- one matmul, no recompiles across rounds."""
        from repro.core.mixing import _wire

        xf = flat.astype(jnp.float32)
        sent = _wire(xf, self._wire_dtype)
        return (w_off_r @ sent + w_diag_r[:, None] * xf).astype(flat.dtype)

    def check_params(self, cfg: FLConfig, params: PyTree) -> None:
        _check_flat_params(cfg, params, self.name)

    def make_eval_grads(self, grad_fn):
        return _make_flat_eval_grads(self.layout, grad_fn)

    @classmethod
    def simulated(cls, w: np.ndarray, stacked_params: PyTree, *,
                  scale_chunk: int = 1, wire_dtype=None, topk=None,
                  round_schedule=None, storage_dtype=None,
                  topology_program=None, node_program=None, privacy=None,
                  scope=None, **_ignored):
        _reject_scope(scope, cls.name)
        _reject_topk(topk, cls.name)
        _require_sequential(round_schedule, cls.name)
        prog = resolve_program(topology_program).bind(w)
        flat, layout = pack(stacked_params, pad_to=scale_chunk,
                            buffer_dtype=storage_dtype or jnp.float32)
        return cls(make_dense_flat_mix(w, wire_dtype), layout,
                   topology_program=prog, node_program=node_program,
                   wire_dtype=wire_dtype, w=w, privacy=privacy), flat

    @classmethod
    def from_mesh(cls, mesh: Mesh, node_axes: Sequence[str], stacked_sds,
                  *, wire_dtype=None, axes_subset=None, scale_chunk: int = 512,
                  topk=None, round_schedule=None, storage_dtype=None,
                  topology_program=None, node_program=None, privacy=None,
                  scope=None, **_ignored):
        _reject_scope(scope, cls.name)
        _reject_topk(topk, cls.name)
        _require_sequential(round_schedule, cls.name)
        _reject_privacy(
            privacy, cls.name,
            "engine's mesh build ships raw fp32 payloads through a baked "
            "ppermute backend (no pad/noise epilogue)",
        )
        _reject_dynamic_program(
            topology_program, cls.name,
            "engine's mesh build mixes through a baked ppermute backend",
        )
        _reject_node_program(
            node_program, cls.name,
            "engine's mesh build mixes through a baked ppermute backend",
        )
        layout = pack_layout(stacked_sds, pad_to=scale_chunk,
                             storage_dtype=storage_dtype or jnp.float32)
        return cls(
            make_mesh_flat_mix(mesh, node_axes, wire_dtype=wire_dtype,
                               axes_subset=axes_subset),
            layout,
        )


# ---------------------------------------------------------------------------
# Fused engines
# ---------------------------------------------------------------------------


_WIRE_DTYPE_MSG = (
    "the fused engines' wire is always difference-coded int8; wire_dtype "
    "only applies to the tree/flat exact-wire engines"
)


def _reject_wire_dtype(wire_dtype) -> None:
    if wire_dtype is not None:
        raise ValueError(_WIRE_DTYPE_MSG)


def _reject_topk(topk, name: str) -> None:
    if topk is not None:
        raise ValueError(
            f"topk is a fused-engine knob (sub-int8 sparsified wire); the "
            f"{name!r} engine ships an exact wire -- use 'fused' or "
            "'sharded_fused'"
        )


def _reject_dynamic_program(program, name: str, reason: str) -> TopologyProgram:
    """Resolve a topology-program spec and refuse non-static programs on
    builds that cannot trace per-round weights (returns the resolved
    STATIC program otherwise, so callers can store it uniformly)."""
    prog = resolve_program(program)
    if not prog.is_static:
        raise ValueError(
            f"topology program {prog.spec()!r} needs traced per-round "
            f"mixing weights; the {name!r} {reason} -- use the 'fused' "
            "engine (any W) or 'sharded_fused' on the circulant wire"
        )
    return prog


def _reject_node_program(program, name: str, reason: str) -> NodeProgram:
    """Resolve a node-program spec and refuse non-homogeneous programs
    on builds that cannot trace per-round gates (same discipline as
    :func:`_reject_dynamic_program`)."""
    prog = resolve_node_program(program)
    if not prog.is_static:
        raise ValueError(
            f"node program {prog.spec()!r} needs traced per-round "
            f"compute/payload gates; the {name!r} {reason} -- use the "
            "'flat' (simulated), 'fused', or 'sharded_fused' engine"
        )
    return prog


def _reject_privacy(privacy, name: str, reason: str) -> PrivacySpec:
    """Resolve a privacy spec and refuse ACTIVE specs on engines whose
    wire cannot realize them (returns the resolved inactive spec
    otherwise, same discipline as :func:`_reject_dynamic_program`)."""
    p = resolve_privacy(privacy)
    if p.active:
        raise ValueError(
            f"privacy spec {p.spec()!r}: the {name!r} {reason} -- use "
            "'fused' (dp; secure_agg is vacuously satisfied in-process) "
            "or 'sharded_fused' on the circulant wire (dp + secure_agg)"
        )
    return p


def _reject_scope(scope, name: str) -> FederationScope:
    """Resolve a federation-scope spec and refuse non-full scopes on
    engines whose wire cannot slice the buffer (returns the resolved
    FULL scope otherwise, same discipline as the other axis rejects)."""
    s = resolve_scope(scope)
    if not s.is_full:
        raise ValueError(
            f"federation scope {s.spec()!r}: the {name!r} engine ships "
            "the whole state through a baked exact-wire backend (no "
            "column slicing) -- use the 'fused' engine, or "
            "'sharded_fused' for sub-range scopes on the mesh wire"
        )
    return s


def _reject_dp(privacy, name: str, reason: str) -> PrivacySpec:
    """Resolve a privacy spec, allowing ``secure_agg`` (a no-op where
    no per-edge payload ever exists to read) but refusing DP on engines
    without the EF quantize epilogue that absorbs the noise."""
    p = resolve_privacy(privacy)
    if p.dp:
        raise ValueError(
            f"privacy spec {p.spec()!r}: the {name!r} {reason}, so DP "
            "noise would accumulate unabsorbed -- use the 'fused' or "
            "'sharded_fused' engine (error-feedback wire epilogue)"
        )
    return p


def _reject_storage_dtype(storage_dtype, name: str) -> None:
    if storage_dtype is not None and jnp.dtype(storage_dtype) != jnp.float32:
        raise ValueError(
            f"storage_dtype is a flat-buffer knob (bf16 buffer with fp32 "
            f"mix accumulation); the {name!r} engine has no flat buffer "
            "-- use 'flat', 'fused', or 'sharded_fused'"
        )


#: storage dtypes the FUSED engines accept: the params/tracker buffer may
#: be stored narrow (halving its HBM traffic), but the EF recon/residual
#: wire state stays fp32 regardless -- the residual must not be rounded.
_FUSED_STORAGE_DTYPES = ("float32", "bfloat16")


def _split_w_np(w: np.ndarray, n: int):
    """Shape-checked (w, diag, off-diag) via ``mixing._split_w``."""
    w = np.asarray(w, dtype=np.float64)
    if w.shape != (n, n):
        raise ValueError(f"W shape {w.shape} != ({n}, {n})")
    w_self, w_off = _split_w(w)
    return w, w_self, w_off


def _degrees(w: np.ndarray) -> np.ndarray:
    return (np.abs(w - np.diag(np.diag(w))) > 0).sum(axis=1)


def _dequant(q: jnp.ndarray, scales: jnp.ndarray, scale_chunk: int):
    """(n, t) int8 + (n, t//chunk) fp32 scales -> (n, t) fp32."""
    n, t = q.shape
    q3 = q.astype(jnp.float32).reshape(n, t // scale_chunk, scale_chunk)
    return (q3 * scales[:, :, None]).reshape(n, t)


class _FusedBase(GossipEngine):
    """Shared knobs + validation of the fused (CHOCO int8 wire) engines."""

    def __init__(self, layout: FlatLayout, *, scale_chunk: int = 512,
                 topk: Optional[int] = None, error_feedback: bool = True,
                 difference_coding: bool = True, impl: str = "pallas",
                 round_schedule=None, topology_program=None,
                 node_program=None, privacy=None, scope=None):
        if impl not in ("pallas", "jnp"):
            raise ValueError(f"unknown impl {impl!r}")
        if scale_chunk < 1:
            raise ValueError("scale_chunk must be >= 1")
        if topk is not None and not (1 <= topk):
            raise ValueError("topk must be >= 1 or None")
        if layout.total % scale_chunk:
            raise ValueError(
                f"layout.total {layout.total} not a multiple of scale_chunk "
                f"{scale_chunk}; pack with pad_to={scale_chunk}"
            )
        if jnp.dtype(layout.storage_dtype).name not in _FUSED_STORAGE_DTYPES:
            raise ValueError(
                f"the {self.name!r} engine stores the flat buffer in "
                f"{_FUSED_STORAGE_DTYPES} only (got "
                f"{jnp.dtype(layout.storage_dtype).name!r}); the wire math "
                "and the EF recon/residual state run fp32 either way"
            )
        self.layout = layout
        #: params/tracker storage dtype; wire math always accumulates fp32
        self._store = jnp.dtype(layout.storage_dtype)
        self.scale_chunk = scale_chunk
        self.topk = topk
        self.error_feedback = error_feedback
        self.difference_coding = difference_coding
        self.impl = impl
        self.round_schedule = resolve_schedule(round_schedule)
        self.topology_program = resolve_program(topology_program)
        self.node_program = resolve_node_program(node_program)
        self.privacy = resolve_privacy(privacy)
        if self.privacy.dp and not error_feedback:
            raise ValueError(
                "dp noise rides the EF residual (res-substitution in the "
                "wire-stage epilogue); build the engine with "
                "error_feedback=True or drop the dp token"
            )
        self.scope = resolve_scope(scope)
        # -- scoped geometry: which COLUMNS of the flat buffer the wire
        # sees. A sub-range scope (backbone / ranges) gathers the shared
        # columns into a contiguous chunk-aligned wire buffer, runs the
        # UNMODIFIED wire kernels on it, and scatters the mixed result
        # back around the untouched private columns -- so recon /
        # residual / collectives / wire bytes all shrink to the shared
        # slice. The layerwise scope keeps the full wire (bytes
        # unchanged, recon stays consistent) and gates only the
        # head-column MIX on the traced round counter.
        self._scoped = not self.scope.is_full and not self.scope.needs_round
        self._gate_mask = None
        if self._scoped:
            shared = self.scope.shared_ranges(layout)
            self._wire_layout, self._local_ranges = scoped_layout(
                layout, shared, scale_chunk
            )
            self._local_shared = sum(b - a for a, b in self._local_ranges)
            self._local_padded = self._wire_layout.shard_width
        else:
            self._wire_layout = layout
            self._local_ranges = ((0, layout.shard_width),)
            self._local_shared = self._local_padded = layout.shard_width
            if isinstance(self.scope, LayerwiseScope):
                gate = np.zeros((1, layout.total), np.bool_)
                for a, b in self.scope.gate_ranges(layout):
                    gate[:, a:b] = True
                self._gate_mask = jnp.asarray(gate)

    # -- scope hooks --------------------------------------------------------

    @property
    def wire_layout(self) -> FlatLayout:
        """The layout the WIRE operates at: ``layout`` itself for the
        full / layerwise scopes, the gathered shared-slice layout for
        sub-range scopes. Comm-state widths, wire-byte accounting, and
        DP noise all derive from this, so a scoped wire shrinks every
        one of them proportionally."""
        return self._wire_layout

    def _scope_shards(self, width: int) -> int:
        """How many shard tiles a buffer of trailing ``width`` spans.

        The scoped ranges are PER-SHARD (``scoped_layout`` guarantees
        uniformity); a full-width row (the fused dense path) repeats
        them across every shard, a per-tile row (the shard_map body)
        carries exactly one copy. Width disambiguates: with shards > 1
        the tile width ``shard_width`` differs from ``total``."""
        return 1 if width == self.layout.shard_width else self.layout.shards

    def _gather_cols(self, x: jnp.ndarray) -> jnp.ndarray:
        """Gather the SHARED columns of a buffer row-block (full-width
        or one shard tile) into the contiguous wire buffer, repeating
        the per-shard ranges across shards and zero-padding each
        shard's slice to the chunk multiple (padding behaves exactly
        like the layout's structural tail padding -- zero forever, zero
        wire mass)."""
        if not self._scoped:
            return x
        sw = self.layout.shard_width
        pad = self._local_padded - self._local_shared
        segs = []
        for s in range(self._scope_shards(x.shape[-1])):
            base = s * sw
            segs.extend(
                jax.lax.slice_in_dim(x, base + a, base + b, axis=-1)
                for a, b in self._local_ranges
            )
            if pad:
                segs.append(jnp.zeros(x.shape[:-1] + (pad,), x.dtype))
        return jnp.concatenate(segs, axis=-1)

    def _scatter_cols(self, local_full: jnp.ndarray,
                      mixed_scoped: jnp.ndarray) -> jnp.ndarray:
        """Interleave the mixed SHARED columns back into the locally
        updated full-width row-block: private columns come bit-untouched
        from ``local_full``, shared columns from the wire's mix (the
        wire buffer's zero per-shard tail padding is dropped)."""
        sw = self.layout.shard_width
        segs = []
        for s in range(self._scope_shards(local_full.shape[-1])):
            base = s * sw
            pos_full = base
            pos_s = s * self._local_padded
            for a, b in self._local_ranges:
                if base + a > pos_full:
                    segs.append(jax.lax.slice_in_dim(
                        local_full, pos_full, base + a, axis=-1))
                segs.append(jax.lax.slice_in_dim(
                    mixed_scoped, pos_s, pos_s + (b - a), axis=-1))
                pos_s += b - a
                pos_full = base + b
            if pos_full < base + sw:
                segs.append(jax.lax.slice_in_dim(
                    local_full, pos_full, base + sw, axis=-1))
        return jnp.concatenate(segs, axis=-1)

    def _scope_finish(self, mixed_s: jnp.ndarray, x: jnp.ndarray,
                      g: jnp.ndarray, alpha, fire=None) -> jnp.ndarray:
        """DSGD round epilogue under a scope: rebuild the full-width fp32
        params from the kernel's mixed output. Sub-range scopes scatter
        the (wire-width) mix around the private columns' plain local
        update ``x - alpha g``; the layerwise scope SELECTS the local
        update on the gated head columns when the round does not fire
        (an exact where, so non-firing rounds leave the head bit-equal
        to a never-gossiped trajectory). Full scope is the identity."""
        if not self._scoped and fire is None:
            return mixed_s
        local = self._f32(x) - alpha * self._f32(g)
        if self._scoped:
            return self._scatter_cols(local, mixed_s)
        return jnp.where(self._gate_mask & ~fire, local, mixed_s)

    def _scope_finish_gt(self, mx_s: jnp.ndarray, mt_s: jnp.ndarray,
                         x: jnp.ndarray, t: jnp.ndarray, g: jnp.ndarray,
                         gp: jnp.ndarray, alpha, fire=None):
        """DSGT twin of :meth:`_scope_finish`: the private columns'
        tracker follows the unmixed recursion ``t + g - g_prev`` and the
        params follow ``x - alpha * tracker`` -- identical to what the
        kernel computes on those columns minus the W contraction."""
        if not self._scoped and fire is None:
            return mx_s, mt_s
        th = self._f32(t) + self._f32(g) - self._f32(gp)
        xl = self._f32(x) - alpha * th
        if self._scoped:
            return self._scatter_cols(xl, mx_s), self._scatter_cols(th, mt_s)
        keep = self._gate_mask & ~fire
        return jnp.where(keep, xl, mx_s), jnp.where(keep, th, mt_s)

    def _scope_fire(self, comm: Dict[str, jnp.ndarray]):
        """The layerwise scope's traced gate for THIS round (None when
        the scope never gates) -- derived from the checkpointed round
        counter, so one compiled round serves every phase of the
        frequency."""
        if not self._scope_round:
            return None
        return self.scope.fire(comm["topo_round"])

    # -- privacy hooks ------------------------------------------------------

    @property
    def _dp(self) -> bool:
        return self.privacy.dp

    @property
    def _sa_wire(self) -> bool:
        """True when this build physically masks a transported payload
        (only the sharded circulant wire does; the dense single-host
        engines have no per-edge transport, so their secure_agg is
        vacuously satisfied and numerically a no-op)."""
        return False

    @property
    def _priv_rng(self) -> bool:
        return self._dp or self._sa_wire

    def _noise_scale(self) -> float:
        """Gaussian-mechanism std: ``sigma * clip``."""
        return float(self.privacy.dp_sigma * self.privacy.dp_clip)

    def _dp_kwargs(self):
        """The ``dp_clip`` kwarg forwarded to the wire-stage kernels
        (the noise arrays are per-round traced operands)."""
        return {"dp_clip": float(self.privacy.dp_clip)} if self._dp else {}

    def _dp_noise_full(self, comm: Dict[str, jnp.ndarray], n: int,
                       tracker: bool = False) -> jnp.ndarray:
        """This round's (n, total) Gaussian draw from the checkpointed
        privacy counter -- the fused engine's whole-matrix twin of the
        sharded per-row draw (bitwise-identical rows: the element
        counter is global)."""
        from repro.core.privacy import NOISE_STREAM

        stream = NOISE_STREAM + (TRACKER_STREAM_OFFSET if tracker else 0)
        return dp_noise(
            comm["priv_key"], comm["topo_round"], jnp.arange(n),
            self.wire_layout.total, self._noise_scale(), stream=stream,
        )

    def _privacy_metrics(self, cfg: FLConfig, new_state: FLState):
        """The (epsilon, delta) moments bound over the WIRE RELEASES so
        far: noise is drawn once per comm round (``step / q`` rounds,
        the q local steps between rounds release nothing), and the DSGT
        round releases TWO noised wires (x and tracker), doubling its
        per-round composition count."""
        if not self._dp:
            return {}
        wires = 2 if cfg.algorithm == "dsgt" else 1
        return {
            "dp_epsilon": epsilon_traced(
                self.privacy.dp_sigma,
                (new_state.step // cfg.q) * wires,
                self.privacy.delta,
            )
        }

    def _known_comm_keys(self) -> frozenset:
        return super()._known_comm_keys() | frozenset(
            base + suffix
            for base in ("recon", "residual", "wire_q", "wire_scales")
            for suffix in ("", "_t")
        )

    @property
    def pipelined(self) -> bool:
        """True for every non-blocking schedule (depth >= 1): the round
        splits into produce / collective / stale mix."""
        return self.round_schedule.depth >= 1

    @property
    def staleness_depth(self) -> int:
        return self.round_schedule.depth

    def _static_w_np(self) -> np.ndarray:
        """The engine's compile-time dense W (the fused engine's ``w``,
        the sharded engine's dense equivalent)."""
        raise NotImplementedError

    def _static_round_w(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        w = self._static_w_np()
        _, w_self, w_off = _split_w_np(w, w.shape[0])
        return jnp.asarray(w_off, jnp.float32), jnp.asarray(
            w_self, jnp.float32
        )

    # -- depth-k ring-buffer helpers ---------------------------------------
    #
    # Ring convention (both fused engines): slot 0 is the OLDEST in-flight
    # payload, slot -1 the newest. The consumer reads slot 0; the producer
    # appends at the end, dropping the consumed slot -- one concatenate on
    # the leading-(n) comm buffers, no collective touches more than ONE
    # slot per round (the wire-byte invariant tools/bench_guard.py guards).

    def _ring_slot0(self, comm: Dict[str, jnp.ndarray],
                    keys: Sequence[str]) -> Tuple[jnp.ndarray, ...]:
        """The oldest in-flight payload's buffers: the (n, width) buffers
        themselves at depth 1 (the pipelined double-buffer layout,
        unchanged), the ``[:, 0]`` ring slice at depth >= 2."""
        if self.staleness_depth <= 1:
            return tuple(comm[k] for k in keys)
        return tuple(comm[k][:, 0] for k in keys)

    def _push_wire(self, old_comm: Dict[str, jnp.ndarray],
                   comm: Dict[str, jnp.ndarray], keys: Sequence[str],
                   vals: Sequence[jnp.ndarray]) -> None:
        """Store this round's produced payload: replace at depth 1, ring
        push (drop slot 0, append at the end) at depth >= 2."""
        if self.staleness_depth <= 1:
            comm.update(zip(keys, vals))
            return
        for k, v in zip(keys, vals):
            comm[k] = jnp.concatenate(
                [old_comm[k][:, 1:], v[:, None]], axis=1
            )

    def check_params(self, cfg: FLConfig, params: PyTree) -> None:
        _check_flat_params(cfg, params, self.name)

    def make_eval_grads(self, grad_fn):
        return _make_flat_eval_grads(self.layout, grad_fn)

    def _kernel_kwargs(self):
        return dict(
            scale_chunk=self.scale_chunk,
            error_feedback=self.error_feedback,
            difference_coding=self.difference_coding,
            topk=self.topk,
        )

    def _edge_bytes(self) -> int:
        """Wire bytes one node ships to ONE neighbor per wire per round
        (the SCOPED wire width -- a sub-range scope shrinks it)."""
        return flat_wire_bytes(
            self.wire_layout, 1, self.scale_chunk, self.topk
        )

    # -- narrow-storage helpers --------------------------------------------
    #
    # storage_dtype='bfloat16' stores the params/tracker buffer narrow;
    # every wire-stage input upcasts to fp32 at the kernel boundary
    # (_f32) and every mixed output is stored back narrow (_st), so the
    # int8 wire, the EF recon/residual, and the mix accumulation are
    # bit-for-bit the fp32 computation of the ROUNDED buffer.

    def _f32(self, x: jnp.ndarray) -> jnp.ndarray:
        return x if x.dtype == jnp.float32 else x.astype(jnp.float32)

    def _st(self, x: jnp.ndarray) -> jnp.ndarray:
        return x if x.dtype == self._store else x.astype(self._store)

    def _residual_rms(self, comm: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """RMS of the parameter-wire EF residual -- the adaptive-k signal
        (``topk_schedule``): a large residual means the wire is dropping
        mass faster than EF re-injects it, so the schedule densifies k."""
        res = comm["residual"]
        return jnp.sqrt(jnp.mean(res.astype(jnp.float32) ** 2))


@register_engine
class FusedEngine(_FusedBase):
    """The round megakernel on a dense compile-time W: local update + int8
    quantize (top-k sparsified when ``topk`` is set) + W-row mix + error
    feedback, ONE Pallas call per comm round (``kernels.gossip``;
    ``impl="jnp"`` runs the bit-identical chunked oracle, which is what
    GSPMD partitions in the sharded dry run)."""

    name = "fused"

    def __init__(self, w: np.ndarray, layout: FlatLayout, **kw):
        super().__init__(layout, **kw)
        self.w = np.asarray(w, dtype=np.float64)
        # binding validates per-round Assumption 1 over a sample of the
        # program's emitted rounds (core.dynamics.validate_program)
        self.topology_program.bind(self.w)
        self.node_program = self.node_program.bind(self.w.shape[0])

    def _static_w_np(self) -> np.ndarray:
        return self.w

    def _ring_depth(self) -> int:
        """Ring slots the DENSE engine needs for depth-k staleness: its
        recon buffer already lags the mix by construction (the ``k=1``
        ``stale_mix`` kernel needs ZERO extra buffers), so with
        difference coding the k-round-stale reconstruction is recovered
        by subtracting the last k-1 in-flight payloads from recon
        (``recon^(r-1) - sum dq^(r-1..r-k+1) == recon^(r-k)`` exactly);
        without difference coding recon IS the last payload, so the ring
        holds k and the mix reads the oldest slot."""
        k = self.staleness_depth
        if k <= 1:
            return 0
        return k - 1 if self.difference_coding else k

    def comm_keys(self, cfg: FLConfig) -> Tuple[str, ...]:
        keys = ("recon", "residual")
        if self._ring_depth():
            keys += ("wire_q", "wire_scales")
        if cfg.algorithm == "dsgt":
            keys += ("recon_t", "residual_t")
            if self._ring_depth():
                keys += ("wire_q_t", "wire_scales_t")
        return keys + self._topo_keys()

    def comm_state_sds(
        self, cfg: FLConfig
    ) -> Optional[Dict[str, jax.ShapeDtypeStruct]]:
        # wire state (recon / residual / in-flight rings) lives at the
        # SCOPED wire width: a sub-range scope shrinks every buffer
        n, t = cfg.n_nodes, self.wire_layout.total
        rd = self._ring_depth()
        topo = self._topo_sds()

        def buf(key):
            if key in topo:
                return topo[key]
            if key.startswith("wire_q"):
                return jax.ShapeDtypeStruct((n, rd, t), jnp.int8)
            if key.startswith("wire_scales"):
                return jax.ShapeDtypeStruct(
                    (n, rd, t // self.scale_chunk), jnp.float32
                )
            return jax.ShapeDtypeStruct((n, t), jnp.float32)

        keys = self.comm_keys(cfg)
        return {k: buf(k) for k in keys} or None

    def wire_bytes(self, cfg: FLConfig) -> float:
        wires = 2 if cfg.algorithm == "dsgt" else 1
        return float(wires * _degrees(self.w).sum() * self._edge_bytes())

    def make_comm_step(self, eval_grads, schedule, cfg: FLConfig):
        if self._ring_depth():
            return self._make_bounded_comm_step(eval_grads, schedule, cfg)
        _, w_self, w_off = _split_w_np(self.w, cfg.n_nodes)
        if self.impl == "pallas":
            from repro.kernels.gossip.ops import fused_round, fused_round_gt
        else:
            from repro.kernels.gossip.ref import (
                fused_round_gt_ref as fused_round_gt,
                fused_round_ref as fused_round,
            )
        # Pipelined: the kernel's stale_mix flag contracts W against the
        # INPUT recon -- which IS the neighbor reconstruction as of the
        # end of the previous round -- so the dense engine needs no extra
        # in-flight buffers: it is the exact single-host oracle of the
        # sharded pipelined round. (Bounded staleness at k=1 lands here
        # too -- it IS the pipelined round, bit-identically.)
        kw = dict(self._kernel_kwargs(), stale_mix=self.pipelined)
        egress = self.wire_bytes(cfg)
        dynamic = self.dynamic_round
        dp = self._dp
        n = cfg.n_nodes

        def comm_step(state: FLState, batch: PyTree):
            if state.comm is None:
                raise ValueError(
                    "fused rounds need init_fl_state(..., engine=...)"
                )
            step = state.step + 1
            alpha = schedule(step)
            losses, grads = eval_grads(state.params, batch)
            grads = grads.astype(jnp.float32)

            # Dynamic topology / node gates: the kernels already take
            # (w_off, w_self) as runtime operands, so the per-round
            # realized W is simply the traced program output -- same
            # kernel, same compilation, all rounds.
            gate_metrics: Dict[str, jnp.ndarray] = {}
            if dynamic:
                w_off_r, w_self_r, topo_comm, gate_metrics = (
                    self._round_gates(state.comm)
                )
            else:
                w_off_r, w_self_r = w_off, w_self
                topo_comm = self._priv_comm(state.comm)
            dpkw = dict(self._dp_kwargs())
            if dp:
                dpkw["dp_noise"] = self._dp_noise_full(state.comm, n)
            # Scope: the kernel runs UNCHANGED on the gathered shared
            # columns; private columns never enter it and are rebuilt by
            # _scope_finish[_gt] from the plain local update.
            fire = self._scope_fire(state.comm)

            if cfg.algorithm == "dsgd":
                mixed, recon, res, _ = fused_round(
                    self._gather_cols(self._f32(state.params)),
                    self._gather_cols(grads), state.comm["recon"],
                    state.comm["residual"], w_off_r, w_self_r, alpha,
                    **kw, **dpkw,
                )
                mixed = self._scope_finish(
                    mixed, state.params, grads, alpha, fire
                )
                new_state = state._replace(
                    step=step, params=self._st(mixed),
                    comm={"recon": recon, "residual": res, **topo_comm},
                )
            else:
                if dp:
                    dpkw["dp_noise_t"] = self._dp_noise_full(
                        state.comm, n, tracker=True
                    )
                mx, mt, nrx, nsx, nrt, nst, _, _ = fused_round_gt(
                    self._gather_cols(self._f32(state.params)),
                    self._gather_cols(self._f32(state.tracker)),
                    self._gather_cols(grads),
                    self._gather_cols(self._f32(state.prev_grad)),
                    state.comm["recon"], state.comm["residual"],
                    state.comm["recon_t"], state.comm["residual_t"],
                    w_off_r, w_self_r, alpha, **kw, **dpkw,
                )
                mx, mt = self._scope_finish_gt(
                    mx, mt, state.params, state.tracker, grads,
                    state.prev_grad, alpha, fire,
                )
                new_state = FLState(
                    step=step, params=self._st(mx), tracker=self._st(mt),
                    prev_grad=self._st(grads),
                    comm={"recon": nrx, "residual": nsx,
                          "recon_t": nrt, "residual_t": nst, **topo_comm},
                )

            metrics = {
                "loss": jnp.mean(losses),
                "alpha": alpha,
                "grad_norm_sq": _mean_grad_norm_sq(grads),
                "consensus_err": _consensus_error(new_state.params),
                "comm_rounds": jnp.float32(1.0),
                "wire_bytes": jnp.float32(egress),
                "ef_residual_rms": self._residual_rms(new_state.comm),
            }
            metrics.update(self._privacy_metrics(cfg, new_state))
            metrics.update(gate_metrics)
            return new_state, metrics

        return comm_step

    def _make_bounded_comm_step(self, eval_grads, schedule, cfg: FLConfig):
        """The depth-k (k >= 2) round: the wire stage runs unchanged (ONE
        Pallas call -- same kernel the sharded engine's shards run), the
        mix contracts W against the k-round-STALE reconstruction
        recovered from the in-flight ring (see :meth:`_ring_depth`), and
        this round's payload is pushed onto the ring. Proven equal to the
        hand-written k-delayed sequential oracle in
        tests/test_bounded_staleness.py."""
        _, w_self, w_off = _split_w_np(self.w, cfg.n_nodes)
        if self.impl == "pallas":
            from repro.kernels.gossip.ops import wire_stage, wire_stage_gt
        else:
            from repro.kernels.gossip.ref import (
                wire_stage_gt_ref as wire_stage_gt,
                wire_stage_ref as wire_stage,
            )
        kw = self._kernel_kwargs()
        egress = self.wire_bytes(cfg)
        dynamic = self.dynamic_round
        dp = self._dp
        n = cfg.n_nodes
        dc = self.difference_coding
        chunk = self.scale_chunk
        w_off32 = jnp.asarray(w_off, jnp.float32)
        w_self32 = jnp.asarray(w_self, jnp.float32)

        def stale_recon(recon, wq, wsc):
            """recon^(r-k) from recon^(r-1) and the ring (difference
            coding), or the oldest in-flight payload directly (no
            difference coding: recon IS the payload)."""
            if not dc:
                return _dequant(wq[:, 0], wsc[:, 0], chunk)
            mix = recon
            for j in range(wq.shape[1]):
                mix = mix - _dequant(wq[:, j], wsc[:, j], chunk)
            return mix

        def push(wq, wsc, q, sc):
            return (
                jnp.concatenate([wq[:, 1:], q[:, None]], axis=1),
                jnp.concatenate([wsc[:, 1:], sc[:, None]], axis=1),
            )

        def comm_step(state: FLState, batch: PyTree):
            if state.comm is None:
                raise ValueError(
                    "fused rounds need init_fl_state(..., engine=...)"
                )
            step = state.step + 1
            alpha = schedule(step)
            losses, grads = eval_grads(state.params, batch)
            grads = grads.astype(jnp.float32)
            alpha32 = jnp.asarray(alpha, jnp.float32)

            gate_metrics: Dict[str, jnp.ndarray] = {}
            if dynamic:
                w_off_r, w_self_r, topo_comm, gate_metrics = (
                    self._round_gates(state.comm)
                )
                w_off_r = jnp.asarray(w_off_r, jnp.float32)
                w_self_r = jnp.asarray(w_self_r, jnp.float32)
            else:
                w_off_r, w_self_r = w_off32, w_self32
                topo_comm = self._priv_comm(state.comm)
            dpkw = dict(self._dp_kwargs())
            if dp:
                dpkw["dp_noise"] = self._dp_noise_full(state.comm, n)
            fire = self._scope_fire(state.comm)

            c = state.comm
            if cfg.algorithm == "dsgd":
                h, q, sc, nrecon, nres = wire_stage(
                    self._gather_cols(self._f32(state.params)),
                    self._gather_cols(grads), c["recon"],
                    c["residual"], alpha32, **kw, **dpkw,
                )
                mix = stale_recon(c["recon"], c["wire_q"], c["wire_scales"])
                mixed = self._st(self._scope_finish(
                    w_off_r @ mix + w_self_r[:, None] * h,
                    state.params, grads, alpha32, fire,
                ))
                nwq, nwsc = push(c["wire_q"], c["wire_scales"], q, sc)
                new_state = state._replace(
                    step=step, params=mixed,
                    comm={"recon": nrecon, "residual": nres,
                          "wire_q": nwq, "wire_scales": nwsc, **topo_comm},
                )
            else:
                if dp:
                    dpkw["dp_noise_t"] = self._dp_noise_full(
                        state.comm, n, tracker=True
                    )
                (h, t_half, qx, scx, nrx, nsx, qt, sct, nrt, nst) = (
                    wire_stage_gt(
                        self._gather_cols(self._f32(state.params)),
                        self._gather_cols(self._f32(state.tracker)),
                        self._gather_cols(grads),
                        self._gather_cols(self._f32(state.prev_grad)),
                        c["recon"], c["residual"], c["recon_t"],
                        c["residual_t"], alpha32, **kw, **dpkw,
                    )
                )
                mix_x = stale_recon(c["recon"], c["wire_q"], c["wire_scales"])
                mix_t = stale_recon(
                    c["recon_t"], c["wire_q_t"], c["wire_scales_t"]
                )
                mixed_x, mixed_t = self._scope_finish_gt(
                    w_off_r @ mix_x + w_self_r[:, None] * h,
                    w_off_r @ mix_t + w_self_r[:, None] * t_half,
                    state.params, state.tracker, grads, state.prev_grad,
                    alpha32, fire,
                )
                mixed_x = self._st(mixed_x)
                mixed_t = self._st(mixed_t)
                nwq, nwsc = push(c["wire_q"], c["wire_scales"], qx, scx)
                nwqt, nwsct = push(
                    c["wire_q_t"], c["wire_scales_t"], qt, sct
                )
                new_state = FLState(
                    step=step, params=mixed_x, tracker=mixed_t,
                    prev_grad=self._st(grads),
                    comm={"recon": nrx, "residual": nsx,
                          "recon_t": nrt, "residual_t": nst,
                          "wire_q": nwq, "wire_scales": nwsc,
                          "wire_q_t": nwqt, "wire_scales_t": nwsct,
                          **topo_comm},
                )

            metrics = {
                "loss": jnp.mean(losses),
                "alpha": alpha,
                "grad_norm_sq": _mean_grad_norm_sq(grads),
                "consensus_err": _consensus_error(new_state.params),
                "comm_rounds": jnp.float32(1.0),
                "wire_bytes": jnp.float32(egress),
                "ef_residual_rms": self._residual_rms(new_state.comm),
            }
            metrics.update(self._privacy_metrics(cfg, new_state))
            metrics.update(gate_metrics)
            return new_state, metrics

        return comm_step

    def make_pipelined_round(self, eval_grads, schedule, cfg: FLConfig):
        """The dense engine has no separate collective (its 'wire' is the
        in-kernel W contraction), so ingest is None and the comm step --
        built with ``stale_mix`` -- ignores the stale argument."""
        if not self.pipelined:
            raise ValueError(
                "engine was built with round_schedule='sequential'; build "
                "it with round_schedule='pipelined'"
            )
        comm_step = self.make_comm_step(eval_grads, schedule, cfg)
        return None, lambda state, batch, stale: comm_step(state, batch)

    @classmethod
    def simulated(cls, w: np.ndarray, stacked_params: PyTree, *,
                  scale_chunk: int = 512, topk=None, impl: str = "pallas",
                  error_feedback: bool = True, difference_coding: bool = True,
                  wire_dtype=None, round_schedule=None, storage_dtype=None,
                  topology_program=None, node_program=None, privacy=None,
                  scope=None, **_ignored):
        _reject_wire_dtype(wire_dtype)
        flat, layout = pack(stacked_params, pad_to=scale_chunk,
                            buffer_dtype=storage_dtype or jnp.float32)
        return cls(w, layout, scale_chunk=scale_chunk, topk=topk, impl=impl,
                   error_feedback=error_feedback,
                   difference_coding=difference_coding,
                   round_schedule=round_schedule,
                   topology_program=topology_program,
                   node_program=node_program, privacy=privacy,
                   scope=scope), flat

    @classmethod
    def from_mesh(cls, mesh: Mesh, node_axes: Sequence[str], stacked_sds,
                  *, wire_dtype=None, axes_subset=None, scale_chunk: int = 512,
                  topk=None, impl: str = "jnp", error_feedback: bool = True,
                  difference_coding: bool = True, self_weight=None,
                  round_schedule=None, storage_dtype=None,
                  topology_program=None, node_program=None, privacy=None,
                  scope=None, **_ignored):
        """Mesh build: W is the dense equivalent of the circulant torus the
        ppermute backend realizes over the node axes (directions restricted
        to ``axes_subset`` for hierarchical gossip). ``impl`` defaults to
        the jnp oracle, which GSPMD partitions in lowering-only dry runs."""
        _reject_wire_dtype(wire_dtype)
        w = mesh_gossip_dense_equivalent(
            {a: mesh.shape[a] for a in node_axes}, self_weight=self_weight,
            axes_subset=axes_subset,
        )
        layout = pack_layout(stacked_sds, pad_to=scale_chunk,
                             storage_dtype=storage_dtype or jnp.float32)
        return cls(w, layout, scale_chunk=scale_chunk, topk=topk, impl=impl,
                   error_feedback=error_feedback,
                   difference_coding=difference_coding,
                   round_schedule=round_schedule,
                   topology_program=topology_program,
                   node_program=node_program, privacy=privacy,
                   scope=scope)


@register_engine
class ShardedFusedEngine(_FusedBase):
    """The shard_map-native fused round for real meshes.

    Each device owns its node's row of the flat buffer (sharded
    ``P(node_axes, None)``) and its node's W row. Per round, inside ONE
    shard_map body:

      1. the WIRE STAGE -- local update (DSGD) / tracker arithmetic +
         update (DSGT), difference coding, top-k masking, int8 quantize,
         EF -- runs as ONE Pallas call on this shard's rows
         (``kernels.gossip.wire_stage[_gt]``; ``impl="jnp"`` uses the
         bit-identical oracle);
      2. the payload crosses the wire: one ``ppermute`` per torus
         direction for the circulant W realized by the mesh node axes
         (``w=None``), or one ``all_gather`` over the node axes for an
         arbitrary dense W. With ``topk`` the COMPACT buffers move --
         (k int8 values, k int16 positions, fp32 scales) per chunk, the
         bytes ``flat_wire_bytes`` accounts -- and the receive side
         scatter-accumulates them back to dense
         (``kernels.gossip.ref.scatter_compact_dq``); without ``topk``
         the dense int8 payload + scales move as before;
      3. the mix finishes against the running neighbor-reconstruction
         accumulator: ``mix_recon' = mix_recon + sum_j W_ij dq_j``,
         ``mixed = w_self * h + mix_recon'`` -- O(params/node) state,
         bit-equal (up to summation order) to ``FusedEngine`` on the
         dense equivalent W.

    Under the PIPELINED round schedule the same three stages split in
    time: the comm step stores this round's wire buffers in
    ``FLState.comm`` (``wire_q`` / ``wire_pos`` / ``wire_scales``), the
    NEXT round's ingest runs stage 2 on them before its local-step scan,
    and the mix consumes that one-round-stale term
    (``make_pipelined_round``). Mid-pipeline checkpoints restore
    consistently: ``restore_comm`` rebuilds
    ``mix_recon == W_off @ (recon - dq(in-flight wire))``.
    """

    name = "sharded_fused"
    needs_mesh = True
    supports_wire_k = True

    def __init__(self, mesh: Mesh, node_axes: Sequence[str],
                 layout: FlatLayout, *, w: Optional[np.ndarray] = None,
                 self_weight: Optional[float] = None, axes_subset=None,
                 compact: Optional[bool] = None,
                 model_axis: Optional[str] = None, **kw):
        # Two-axis (gossip_node x model_shard) rounds: with model_axis
        # set, each node's flat buffer row is column-tiled across that
        # mesh axis -- every shard_map body runs per (node, shard) tile,
        # the wire stage is one Pallas call per tile, and the gossip
        # collectives stay on the NODE axes only (the model axis never
        # appears in a ppermute/all_gather), so the per-shard operand
        # bytes are exactly flat_wire_bytes / shards.
        if model_axis is not None:
            if model_axis not in mesh.axis_names:
                raise ValueError(
                    f"model_axis {model_axis!r} not in mesh axes "
                    f"{tuple(mesh.axis_names)}"
                )
            if model_axis in tuple(node_axes):
                raise ValueError(
                    f"model_axis {model_axis!r} is also a gossip node "
                    "axis; the two-axis round shards parameter columns "
                    "over a DIFFERENT axis than the one enumerating nodes"
                )
        self.model_axis = model_axis
        self.model_shards = (
            int(mesh.shape[model_axis]) if model_axis is not None else 1
        )
        if layout.shards != self.model_shards:
            layout = layout.with_shards(self.model_shards)
        super().__init__(layout, **kw)
        if isinstance(self.scope, LayerwiseScope):
            raise ValueError(
                f"federation scope {self.scope.spec()!r}: the layerwise "
                "round-gated mix needs the dense in-kernel W contraction; "
                "the sharded wire accumulates neighbor terms across "
                "collectives -- use --fl-engine fused, or a static "
                "sub-range scope ('backbone' / 'ranges:') here"
            )
        if self.layout.shard_width % self.scale_chunk:
            raise ValueError(
                f"per-shard width {self.layout.shard_width} not a multiple "
                f"of scale_chunk {self.scale_chunk}; pack with "
                f"pad_to={self.scale_chunk} and shards={self.model_shards} "
                "so every shard tile holds whole quantization chunks"
            )
        # The compact wire is only the wire when it is actually SMALLER
        # than dense int8 (k values + k positions + scale <= chunk +
        # scale). `compact=None` auto-enables it exactly in that regime,
        # so the collective operand bytes ALWAYS equal flat_wire_bytes
        # (whose dense cap then never binds for this engine); an
        # explicitly requested uneconomic compact wire is refused rather
        # than shipped while the accounting reports the dense fallback.
        economic = self.topk is not None and self._compact_is_economic()
        if compact is None:
            compact = economic
        if compact:
            if self.topk is None or not (1 <= self.topk < self.scale_chunk):
                raise ValueError(
                    "the compact wire needs a sparsified payload: set "
                    f"1 <= topk < scale_chunk (got topk={self.topk}, "
                    f"scale_chunk={self.scale_chunk}) or pass compact=False"
                )
            if not economic:
                raise ValueError(
                    f"compact encoding of topk={self.topk} costs more than "
                    f"the dense int8 chunk ({self.topk} values + "
                    f"{compact_index_bytes(self.scale_chunk, self.topk)} "
                    f"index bytes > {self.scale_chunk} columns); ship the "
                    "dense wire (compact=False) or lower topk"
                )
        self.compact_wire = bool(compact)
        # The index encoding that actually crosses the collective: the
        # cheaper of explicit positions (k x int16/int32) and the
        # presence bitmap (chunk/8 B, byte-aligned chunks) -- the SAME
        # boundary packing.compact_index_bytes accounts, so flat_wire_bytes
        # IS the operand bytes. Bitmap wins for k > chunk/16.
        self.wire_encoding = "dense"
        if self.compact_wire:
            pos_b = self.topk * jnp.dtype(
                compact_pos_dtype(self.scale_chunk)
            ).itemsize
            bb = bitmap_bytes_per_chunk(self.scale_chunk)
            self.wire_encoding = (
                "bitmap" if (bb is not None and bb < pos_b) else "positions"
            )
        self.mesh = mesh
        self.node_axes = tuple(node_axes)
        self.n_nodes = int(np.prod([mesh.shape[a] for a in self.node_axes]))
        self.axes_subset = tuple(axes_subset) if axes_subset else None
        self.self_weight = self_weight
        if w is None:
            # circulant torus W over the node axes: ppermute wire
            self.w_dense = None
            self.w_self, self.dirs = _mesh_dirs(
                mesh, self.node_axes, self.axes_subset, self_weight
            )
        else:
            w = np.asarray(w, dtype=np.float64)
            if w.shape != (self.n_nodes,) * 2:
                raise ValueError(
                    f"W shape {w.shape} != ({self.n_nodes},) * 2"
                )
            self.w_dense = w
            self.w_self, self.dirs = None, None
        # Dynamic programs gate EITHER wire with zero extra collectives:
        # on the CIRCULANT wire the ppermutes run every round unchanged
        # and a dropped link only zeroes its mixing contribution -- the
        # running neighbor term generalizes from ONE pre-weighted
        # mix_recon to one UNWEIGHTED accumulator per torus direction
        # (each tracks that neighbor's reconstruction exactly), weighted
        # per round by the program's traced gate. On the DENSE all-gather
        # wire every dq already reaches every node, so each node keeps an
        # unweighted replica of ALL reconstructions (``nbr_recon_all``,
        # (n, t) per node -- n x the per-node memory of the circulant
        # accumulators, the price of an arbitrary dense W under churn)
        # and contracts its traced W_r row against it at mix time.
        self.topology_program.bind(self.dense_equivalent())
        self.node_program = self.node_program.bind(self.n_nodes)
        # per-direction sender index: node i receives from _dir_src[d][i],
        # and ships its own payload to _dir_dst[d][i] (the inverse roll)
        # -- row-major node order, identical to dense_equivalent. The dst
        # table keys the SENDER side of the pairwise transport pads.
        self._dir_src: Tuple[np.ndarray, ...] = ()
        self._dir_dst: Tuple[np.ndarray, ...] = ()
        if self.dirs is not None:
            names = list(self.node_axes)
            sizes = [self.mesh.shape[a] for a in names]
            idx = np.arange(self.n_nodes).reshape(sizes)
            self._dir_src = tuple(
                np.roll(idx, shift, axis=names.index(axis_name)).reshape(-1)
                for axis_name, shift, _ in self.dirs
            )
            self._dir_dst = tuple(
                np.roll(idx, -shift, axis=names.index(axis_name)).reshape(-1)
                for axis_name, shift, _ in self.dirs
            )
        if self.privacy.secure_agg and self.dirs is None:
            raise ValueError(
                f"privacy spec {self.privacy.spec()!r}: secure_agg needs "
                "the circulant ppermute wire (per-edge payloads to pad); "
                "the dense all-gather wire broadcasts every payload to "
                "every node, so pairwise pads cannot conceal it -- drop "
                "w= (use the mesh torus W) or drop the secure_agg token"
            )
        if getattr(self.node_program, "heterogeneous_wire_k", False):
            if self.topk is None:
                raise ValueError(
                    f"node program {self.node_program.spec()!r} modulates "
                    "per-node wire k; build the engine with topk= so there "
                    "is a k to modulate"
                )
            if not self.error_feedback:
                raise ValueError(
                    "per-node wire k rides the EF residual (entries a slow "
                    "uplink truncates re-ship later); build with "
                    "error_feedback=True"
                )
            if self._dp:
                raise ValueError(
                    "per-node wire k truncates the noised payload AFTER "
                    "clipping, which breaks the DP calibration; drop the "
                    "dp token or the wire-k program"
                )

    def _compact_is_economic(self) -> bool:
        """True when the compact (values + cheapest index encoding +
        scale) chunk is no larger than the dense int8 chunk -- the regime
        where the compact wire is THE wire and ``flat_wire_bytes``'s
        dense cap never binds. The index encoding is the cheaper of
        explicit positions and the presence bitmap
        (``packing.compact_index_bytes``)."""
        if self.topk is None:
            return False
        idx = compact_index_bytes(self.scale_chunk, self.topk)
        return self.topk + idx <= self.scale_chunk

    @property
    def _sa_wire(self) -> bool:
        """The circulant ppermute wire is the one place a per-edge
        payload physically exists, so it is the one place the pairwise
        pads are real (masked immediately before each ppermute, unmasked
        immediately after -- zero extra collectives, identical operand
        shapes/dtypes, bit-identical arithmetic after the receive)."""
        return self.privacy.secure_agg and self.dirs is not None

    # -- comm-state contract ----------------------------------------------

    def _wire_key_names(self, suffix: str = "") -> Tuple[str, ...]:
        """Names of ONE wire's in-flight payload buffers (pipelined only):
        the int8 values, the index encoding (compact wire: explicit
        positions or the presence bitmap, per ``wire_encoding``), and the
        scales -- exactly what crosses the collective, double-buffered in
        ``FLState.comm`` for one round."""
        if not self.compact_wire:
            names = ("wire_q", "wire_scales")
        elif self.wire_encoding == "bitmap":
            names = ("wire_q", "wire_bits", "wire_scales")
        else:
            names = ("wire_q", "wire_pos", "wire_scales")
        return tuple(n + suffix for n in names)

    def _nbr_key_names(self, suffix: str = "") -> Tuple[str, ...]:
        """Dynamic-round accumulators: one per torus direction on the
        circulant wire, each tracking THAT neighbor's reconstruction (sum
        of every dq that crossed from it), or ONE all-node replica
        (``nbr_recon_all``, (n, n, t) sharded by receiver) on the dense
        all-gather wire. Both replace the single pre-weighted
        ``mix_recon`` -- under a per-round W the weights cannot be folded
        into the running sum, so the weighting moves to mix time (the
        traced gate). Present only with difference coding (without it the
        mix term is rebuilt from the current round's wire alone)."""
        if not (self.dynamic_round and self.difference_coding):
            return ()
        if self.dirs is None:
            return ("nbr_recon_all" + suffix,)
        return tuple(
            f"nbr_recon_{d}{suffix}" for d in range(len(self.dirs))
        )

    def comm_keys(self, cfg: FLConfig) -> Tuple[str, ...]:
        if self.dynamic_round:
            keys = ("recon", "residual") + self._nbr_key_names("")
            if self.pipelined:
                keys += self._wire_key_names("")
            if cfg.algorithm == "dsgt":
                keys += ("recon_t", "residual_t") + self._nbr_key_names("_t")
                if self.pipelined:
                    keys += self._wire_key_names("_t")
            return keys + self._topo_keys()
        keys = ("recon", "residual", "mix_recon")
        if self.pipelined:
            keys += self._wire_key_names("")
        if cfg.algorithm == "dsgt":
            keys += ("recon_t", "residual_t", "mix_recon_t")
            if self.pipelined:
                keys += self._wire_key_names("_t")
        # static rounds under an active privacy transform still need the
        # counter + key (the pads/noise advance with the round index)
        return keys + self._topo_keys()

    def comm_state_sds(
        self, cfg: FLConfig
    ) -> Optional[Dict[str, jax.ShapeDtypeStruct]]:
        # every wire/EF/neighbor buffer lives at the SCOPED wire width
        # (identical to layout.total under the full scope)
        n, t = cfg.n_nodes, self.wire_layout.total
        n_chunks = t // self.scale_chunk
        pos_dtype = compact_pos_dtype(self.scale_chunk)
        topo = self._topo_sds()
        # depth-k rings carry k in-flight payloads per wire buffer: a
        # (n, k, width) middle axis. Depth 1 keeps the flat pipelined
        # (n, width) layout (same contract as before, bit-compatible
        # checkpoints).
        k = self.staleness_depth

        def ring(width, dtype):
            shape = (n, width) if k <= 1 else (n, k, width)
            return jax.ShapeDtypeStruct(shape, dtype)

        def buf(key):
            if key in topo:
                return topo[key]
            if key.startswith("wire_q"):
                width = n_chunks * self.topk if self.compact_wire else t
                return ring(width, jnp.int8)
            if key.startswith("wire_pos"):
                return ring(n_chunks * self.topk, pos_dtype)
            if key.startswith("wire_bits"):
                return ring(n_chunks * (self.scale_chunk // 8), jnp.uint8)
            if key.startswith("wire_scales"):
                return ring(n_chunks, jnp.float32)
            if key.startswith("nbr_recon_all"):
                return jax.ShapeDtypeStruct((n, n, t), jnp.float32)
            return jax.ShapeDtypeStruct((n, t), jnp.float32)

        keys = self.comm_keys(cfg)
        return {k: buf(k) for k in keys} or None

    def is_derived_comm_key(self, key: str) -> bool:
        """The neighbor-mix accumulators -- ``mix_recon[_t]`` (static) and
        ``nbr_recon_{d}[_t]`` (dynamic) -- are all rebuilt from recon by
        :meth:`restore_comm`, so either contract's checkpoint can seed
        the other (modulo the topology-program equality check in
        ``training.checkpoint``)."""
        return key.startswith("mix_recon") or key.startswith("nbr_recon_")

    def _known_comm_keys(self) -> frozenset:
        extra = ["mix_recon", "mix_recon_t", "nbr_recon_all",
                 "nbr_recon_all_t", "wire_pos", "wire_pos_t",
                 "wire_bits", "wire_bits_t"]
        if self.dirs is not None:
            extra += [
                f"nbr_recon_{d}{suffix}"
                for d in range(len(self.dirs))
                for suffix in ("", "_t")
            ]
        return super()._known_comm_keys() | frozenset(extra)

    def dense_equivalent(self) -> np.ndarray:
        """The dense W this engine realizes (the ``FusedEngine`` oracle)."""
        if self.w_dense is not None:
            return self.w_dense
        return mesh_gossip_dense_equivalent(
            {a: self.mesh.shape[a] for a in self.node_axes},
            self_weight=self.self_weight,
            axes_subset=self.axes_subset,
        )

    def _edge_bytes(self) -> int:
        """What ONE neighbor payload physically costs on this wire: the
        compact encoding when the compact-gather epilogue is on (values +
        positions + scales -- the collective's actual operand bytes,
        strictly below dense by the economic check in ``__init__``), the
        DENSE int8 bytes otherwise (a masked-dense top-k payload still
        moves every column; ``compact=False`` is the equivalence baseline
        and the fallback for an uneconomic k)."""
        return flat_wire_bytes(
            self.wire_layout, 1, self.scale_chunk,
            self.topk if self.compact_wire else None,
        )

    def wire_bytes(self, cfg: FLConfig) -> float:
        wires = 2 if cfg.algorithm == "dsgt" else 1
        return float(
            wires * _degrees(self.dense_equivalent()).sum() * self._edge_bytes()
        )

    def _edge_bytes_per_shard(self) -> int:
        """One neighbor payload's cost per (node, shard) tile -- the
        1/shards column slice of :meth:`_edge_bytes`, priced by the same
        boundary (``packing.flat_wire_bytes_per_shard``)."""
        return flat_wire_bytes_per_shard(
            self.wire_layout, 1, self.scale_chunk,
            self.topk if self.compact_wire else None,
        )

    def wire_bytes_per_shard(self, cfg: FLConfig) -> float:
        """Collective operand bytes per round per model shard: on the
        two-axis mesh every ppermute/all_gather moves one (node, shard)
        column tile, so this is exactly ``wire_bytes / model_shards``
        (jaxpr-asserted in tests/test_two_axis.py)."""
        wires = 2 if cfg.algorithm == "dsgt" else 1
        return float(
            wires * _degrees(self.dense_equivalent()).sum()
            * self._edge_bytes_per_shard()
        )

    def _dq_full(self, wire: Tuple[jnp.ndarray, ...]) -> jnp.ndarray:
        """Dense dequant of one wire's payload buffers, at any row AND
        column count: per-(node, shard) tiles inside shard_map, or the
        full (n, .) buffers at restore time -- the dense width is always
        recovered from the scales buffer (chunks per row never straddle
        a shard boundary)."""
        if self.compact_wire:
            t = wire[-1].shape[-1] * self.scale_chunk
            if self.wire_encoding == "bitmap":
                from repro.kernels.gossip.ref import scatter_bitmap_dq

                vals, bits, scales = wire
                return scatter_bitmap_dq(
                    vals, bits, scales, self.scale_chunk, t
                )
            from repro.kernels.gossip.ref import scatter_compact_dq

            q, pos, scales = wire
            return scatter_compact_dq(
                q, pos, scales, self.scale_chunk, t
            )
        q, scales = wire
        return _dequant(q, scales, self.scale_chunk)

    # -- engine-owned partition specs --------------------------------------

    def params_spec(self) -> P:
        """The flat (n, total) buffer's partition spec on this mesh:
        rows over the gossip node axes, columns over the model axis
        (replicated when the engine was built without one)."""
        return P(self.node_axes, self.model_axis)

    def comm_state_specs(self, cfg: FLConfig) -> Dict[str, P]:
        """Partition specs for every comm buffer, matching
        :meth:`comm_state_sds` key for key: node-major buffers shard
        rows over the node axes and their LAST (width) dim over the
        model axis whenever the width tiles evenly (wire and recon
        buffers do; per-node gates and counters replicate their trailing
        dims). Consumers (``launch/dryrun.py``, the train drivers) take
        these instead of re-deriving placement by rank."""
        sds = self.comm_state_sds(cfg) or {}
        out: Dict[str, P] = {}
        s = self.model_shards
        for key, v in sds.items():
            shape = v.shape
            if len(shape) >= 2 and shape[0] == cfg.n_nodes:
                last = (
                    self.model_axis
                    if self.model_axis is not None
                    and shape[-1] % s == 0 and shape[-1] >= s
                    else None
                )
                out[key] = P(
                    self.node_axes, *((None,) * (len(shape) - 2)), last
                )
            else:
                out[key] = P()
        return out

    def restore_comm(
        self, comm: Dict[str, jnp.ndarray]
    ) -> Dict[str, jnp.ndarray]:
        """The mix_recon accumulators are DERIVED state, so a restore
        (possibly from a fused checkpoint that never had them) rebuilds
        them from the restored recon instead of trusting whatever the
        template carried. Sequential invariant: ``mix_recon == W_off @
        recon`` at every round boundary. Pipelined: the sender has already
        advanced recon by the IN-FLIGHT payload its neighbors have not
        mixed yet, so ``mix_recon == W_off @ (recon - dq(wire))`` -- with
        a zero wire (restore from a sequential/fused checkpoint) the
        formulas coincide, which is what makes mid-pipeline restores and
        cross-schedule restores both land in a self-consistent state."""
        self._check_restored_comm_keys(comm)
        w = self.dense_equivalent()
        w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)
        comm = dict(comm)

        def effective_recon(recon_key: str, suffix: str) -> jnp.ndarray:
            """recon minus EVERY in-flight payload: the sender has
            advanced recon by k payloads its neighbors have not mixed
            yet, so the neighbor-visible reconstruction subtracts the
            whole ring (one buffer at depth 1)."""
            recon = jnp.asarray(comm[recon_key], jnp.float32)
            names = self._wire_key_names(suffix)
            if self.pipelined and all(k in comm for k in names):
                bufs = tuple(jnp.asarray(comm[k]) for k in names)
                if self.staleness_depth <= 1:
                    recon = recon - self._dq_full(bufs)
                else:
                    for j in range(self.staleness_depth):
                        recon = recon - self._dq_full(
                            tuple(b[:, j] for b in bufs)
                        )
            return recon

        if self.dynamic_round:
            # per-direction accumulators are DERIVED the same way
            # mix_recon is: nbr_recon_d[i] tracks neighbor src_d(i)'s
            # reconstruction at the same wire lag, i.e. a row permutation
            # of the (restored) full recon matrix; the dense wire's
            # nbr_recon_all[i] is every node's replica of the SAME matrix
            def rebuild(suffix: str) -> None:
                eff = effective_recon(
                    "recon" + suffix, suffix
                )
                names = self._nbr_key_names(suffix)
                if self.dirs is None:
                    for name in names:
                        comm[name] = jnp.broadcast_to(
                            eff[None], (self.n_nodes,) + eff.shape
                        )
                    return
                for d, name in enumerate(names):
                    comm[name] = eff[self._dir_src[d]]

            rebuild("")
            if "recon_t" in comm:
                rebuild("_t")
            return comm

        comm["mix_recon"] = w_off @ effective_recon("recon", "")
        if "recon_t" in comm:
            comm["mix_recon_t"] = w_off @ effective_recon("recon_t", "_t")
        return comm

    # -- the shard_map round ----------------------------------------------

    def _my_index(self) -> jnp.ndarray:
        """This device's row-major node index (trace-time, inside the
        shard_map body) -- the composition of the node-axis indices,
        identical to the ``dense_equivalent`` row order."""
        idx = 0
        for a in self.node_axes:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def _transport(self, wire: Tuple[jnp.ndarray, ...], d: int,
                   priv, stream_base: int) -> Tuple[jnp.ndarray, ...]:
        """ONE direction's masked transport: pad the payload with the
        sender-side edge pad, ppermute every buffer, remove the
        receiver-side pad. Pads are a pure counter hash of (priv_key,
        round, undirected pair index) with the antisymmetric sign fixed
        by ``sender < receiver``, so both endpoints derive the same
        words and mask∘unmask is the exact identity -- the collective's
        operand shapes, dtypes, and count are byte-for-byte those of the
        plaintext wire. With ``priv=None`` this IS the plaintext wire."""
        axis_name, shift, _w = self.dirs[d]
        size = self.mesh.shape[axis_name]
        perm = [(i, (i + shift) % size) for i in range(size)]
        if priv is not None:
            key, r = priv
            n = self.n_nodes
            my = self._my_index()
            dst = jnp.asarray(self._dir_dst[d])[my]
            wire = mask_wire(
                wire, key, r, pair_index(my, dst, n), my < dst,
                stream_base=stream_base,
            )
        recv = tuple(
            jax.lax.ppermute(b, axis_name, perm) for b in wire
        )
        if priv is not None:
            src = jnp.asarray(self._dir_src[d])[my]
            recv = mask_wire(
                recv, key, r, pair_index(src, my, n), src < my,
                stream_base=stream_base, unmask=True,
            )
        return recv

    def _wire_mix(self, wire: Tuple[jnp.ndarray, ...], w_off_rows,
                  priv=None, stream_base: int = PAD_STREAM):
        """Move one wire's payload buffers over the collective and return
        ``sum_j W_ij dq_j`` for this shard's rows. ``wire`` is (q, scales)
        for the dense int8 wire or (q, pos, scales) for the compact
        top-k wire -- EVERY buffer in the tuple is a collective operand,
        so the bytes that move are exactly ``flat_wire_bytes``.
        ``w_off_rows``: replicated (n, n) off-diagonal W (dense-W
        all-gather wire only; ignored for the circulant ppermute wire).
        ``priv``: the traced ``(priv_key, round)`` pair when secure_agg
        masks the transport (see :meth:`_transport`)."""
        rows = wire[0].shape[0]
        # local dense width: total/shards inside a two-axis shard_map
        # body, the full total on a node-only mesh or at restore time
        t = wire[-1].shape[-1] * self.scale_chunk
        if self.dirs is not None:
            acc = jnp.zeros((rows, t), jnp.float32)
            for d, (_axis, _shift, weight) in enumerate(self.dirs):
                recv = self._transport(wire, d, priv, stream_base)
                acc = acc + jnp.float32(weight) * self._dq_full(recv)
            return acc
        # arbitrary dense W: ONE all-gather per wire buffer (secure_agg
        # is rejected at build on this wire -- nothing to pad)
        n = self.n_nodes
        gathered = tuple(
            jax.lax.all_gather(b[0], self.node_axes, tiled=False).reshape(
                n, -1
            )
            for b in wire
        )
        dq = self._dq_full(gathered)
        row = _allgather_row(self.mesh, self.node_axes, w_off_rows)  # (n,)
        return (row @ dq)[None]

    # -- dynamic-topology machinery ----------------------------------------

    def _recv_dqs(self, wire: Tuple[jnp.ndarray, ...], priv=None,
                  stream_base: int = PAD_STREAM):
        """Per-direction receive: the SAME ppermutes as :meth:`_wire_mix`
        (one per wire buffer per direction -- churn adds zero
        collectives), returning each direction's dense dequantized
        payload UNWEIGHTED so the per-round gate can weight it at mix
        time. Masked transport per :meth:`_transport`: unmask happens
        HERE, at the boundary, so the gate weights plaintext arithmetic
        -- a dropped edge drops both directions of its pad with it."""
        out = []
        for d in range(len(self.dirs)):
            recv = self._transport(wire, d, priv, stream_base)
            out.append(self._dq_full(recv))
        return out

    def _dir_gates(self, comm: Dict[str, jnp.ndarray]):
        """The round's traced per-direction mixing weights, derived
        OUTSIDE the shard_map (tiny (n, n) arithmetic) from BOTH dynamic
        axes via :meth:`_round_gates`: ``dgate (n, D)`` where
        ``dgate[i, d] = W_r[i, src_d(i)]`` (zero when the link or either
        endpoint is down), ``ddiag (n, 1)`` the folded self weights, the
        advanced topo/node comm entries, and the realized-fraction
        metrics."""
        w_off_r, w_diag_r, new_comm, gate_metrics = self._round_gates(comm)
        ar = jnp.arange(self.n_nodes)
        dgate = jnp.stack(
            [w_off_r[ar, jnp.asarray(src)] for src in self._dir_src], axis=1
        ).astype(jnp.float32)
        ddiag = w_diag_r.reshape(self.n_nodes, 1).astype(jnp.float32)
        return dgate, ddiag, new_comm, gate_metrics

    def _static_w_np(self) -> np.ndarray:
        return self.dense_equivalent()

    def _make_produce(self):
        """The wire-stage kernels (compact or dense epilogue), normalized
        to return the wire payload as ONE tuple matching
        ``_wire_key_names`` order."""
        if self.impl == "pallas":
            from repro.kernels.gossip.ops import (
                wire_stage,
                wire_stage_compact,
                wire_stage_gt,
                wire_stage_gt_compact,
            )
        else:
            from repro.kernels.gossip.ref import (
                wire_stage_compact_ref as wire_stage_compact,
                wire_stage_gt_compact_ref as wire_stage_gt_compact,
                wire_stage_gt_ref as wire_stage_gt,
                wire_stage_ref as wire_stage,
            )
        kw = self._kernel_kwargs()
        clip_kw = self._dp_kwargs()

        def dpkw(noise, noise_t=None):
            """The per-call DP kwargs: empty without noise (the original
            kernel call, bit-identical), clip + this round's traced
            noise rows otherwise."""
            if noise is None:
                return {}
            out = dict(clip_kw, dp_noise=noise)
            if noise_t is not None:
                out["dp_noise_t"] = noise_t
            return out

        if self.compact_wire:
            # Bitmap wire: on the Pallas path the re-encode (position
            # argsort + bit-pack) is an IN-KERNEL epilogue -- the kernel
            # emits (values, packed bitmap) directly, so nothing touches
            # the explicit positions after the pallas_call. The jnp path
            # (and heterogeneous wire-k, which truncates on explicit
            # positions BEFORE encoding) keeps the post-kernel re-encode.
            # Either way the collective operands are the bitmap buffers
            # and the pallas_call count is unchanged.
            wk = bool(getattr(self.node_program, "heterogeneous_wire_k",
                              False))
            kernel_bitmap = (self.wire_encoding == "bitmap"
                             and self.impl == "pallas" and not wk)
            if kernel_bitmap:
                kw = dict(kw, bitmap=True)

                def encode(q, pos, sc):
                    # kernel already emitted (vals, bits)
                    return q, pos, sc
            elif self.wire_encoding == "bitmap":
                from repro.kernels.gossip.ref import compact_to_bitmap

                def encode(q, pos, sc):
                    vals, bits = compact_to_bitmap(
                        q, pos, self.scale_chunk, self.topk
                    )
                    return vals, bits, sc
            else:
                def encode(q, pos, sc):
                    return q, pos, sc

            def produce(x, g, recon, res, alpha, noise=None, kvec=None):
                h, q, pos, sc, nrecon, nres = wire_stage_compact(
                    x, g, recon, res, alpha, **kw, **dpkw(noise)
                )
                if kvec is not None:
                    q, ddq = self._hetero_truncate(q, sc, kvec, pos=pos)
                    nrecon, nres = nrecon - ddq, nres + ddq
                return h, encode(q, pos, sc), nrecon, nres

            def produce_gt(x, t, g, gp, rx, sx, rt, st, alpha,
                           noise=None, noise_t=None, kvec=None):
                (h, th, qx, px, scx, nrx, nsx,
                 qt, pt, sct, nrt, nst) = wire_stage_gt_compact(
                    x, t, g, gp, rx, sx, rt, st, alpha, **kw,
                    **dpkw(noise, noise_t)
                )
                if kvec is not None:
                    qx, ddx = self._hetero_truncate(qx, scx, kvec, pos=px)
                    nrx, nsx = nrx - ddx, nsx + ddx
                    qt, ddt = self._hetero_truncate(qt, sct, kvec, pos=pt)
                    nrt, nst = nrt - ddt, nst + ddt
                return (h, th, encode(qx, px, scx), nrx, nsx,
                        encode(qt, pt, sct), nrt, nst)
        else:
            def produce(x, g, recon, res, alpha, noise=None, kvec=None):
                h, q, sc, nrecon, nres = wire_stage(
                    x, g, recon, res, alpha, **kw, **dpkw(noise)
                )
                if kvec is not None:
                    q, ddq = self._hetero_truncate(q, sc, kvec)
                    nrecon, nres = nrecon - ddq, nres + ddq
                return h, (q, sc), nrecon, nres

            def produce_gt(x, t, g, gp, rx, sx, rt, st, alpha,
                           noise=None, noise_t=None, kvec=None):
                (h, th, qx, scx, nrx, nsx,
                 qt, sct, nrt, nst) = wire_stage_gt(
                    x, t, g, gp, rx, sx, rt, st, alpha, **kw,
                    **dpkw(noise, noise_t)
                )
                if kvec is not None:
                    qx, ddx = self._hetero_truncate(qx, scx, kvec)
                    nrx, nsx = nrx - ddx, nsx + ddx
                    qt, ddt = self._hetero_truncate(qt, sct, kvec)
                    nrt, nst = nrt - ddt, nst + ddt
                return h, th, (qx, scx), nrx, nsx, (qt, sct), nrt, nst

        if self._scoped:
            # scoped wire: gather the SHARED columns of every per-tile
            # buffer before the (unmodified) wire-stage kernel -- the
            # whole produce path (quantize, top-k, EF, encodings) then
            # runs at the wire width; the round bodies scatter the mixed
            # result back around the untouched private columns.
            produce_full, produce_gt_full = produce, produce_gt

            def produce(x, g, *a, **k):
                return produce_full(
                    self._gather_cols(x), self._gather_cols(g), *a, **k
                )

            def produce_gt(x, t, g, gp, *a, **k):
                return produce_gt_full(
                    self._gather_cols(x), self._gather_cols(t),
                    self._gather_cols(g), self._gather_cols(gp), *a, **k
                )

        return produce, produce_gt

    # -- heterogeneous wire k ----------------------------------------------

    def _hetero_truncate(self, q, scales, kvec, pos=None):
        """Zero all but each node's k_i largest-|q| wire entries per
        chunk (ties broken by position -- deterministic), returning the
        truncated values and the dense dequant of what was DROPPED so
        the caller can move it from the shipped reconstruction back into
        the EF residual. Runs on the kernel's (values, positions) output
        BEFORE any bitmap re-encode, inside the shard_map body: k_i is a
        traced operand, every buffer shape stays static (jit cache 1)."""
        width = self.topk if pos is not None else self.scale_chunk
        rows = q.shape[0]
        qc = q.reshape(rows, -1, width)
        mag = jnp.abs(qc.astype(jnp.int32))
        rank = jnp.argsort(jnp.argsort(-mag, axis=-1), axis=-1)
        keep = rank < kvec.reshape(rows, 1, 1)
        kept = jnp.where(keep, qc, jnp.int8(0)).reshape(q.shape)
        dropped = jnp.where(keep, jnp.int8(0), qc).reshape(q.shape)
        if pos is not None:
            from repro.kernels.gossip.ref import scatter_compact_dq

            ddq = scatter_compact_dq(
                dropped, pos, scales, self.scale_chunk,
                scales.shape[-1] * self.scale_chunk,
            )
        else:
            ddq = _dequant(dropped, scales, self.scale_chunk)
        return kept, ddq

    def _wire_k_vec(self, comm: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """This round's per-node wire k: the node program's fraction
        gate clipped to [1, topk] integers -- a traced (n, 1) operand of
        the one compiled round (nodes speeding up or slowing down never
        recompile)."""
        frac = self.node_program.wire_k_gate(
            comm["topo_round"], comm["node_key"]
        )
        k = jnp.clip(jnp.round(frac * jnp.float32(self.topk)), 1, self.topk)
        return k.astype(jnp.int32).reshape(self.n_nodes, 1)

    def _wire_k_bytes(self, kvec: jnp.ndarray, wires: int) -> jnp.ndarray:
        """Traced per-node wire-byte accounting under heterogeneous k:
        ``flat_wire_bytes``'s per-chunk boundary with the traced k_i in
        place of the static topk -- what each node's egress WOULD cost
        on a k_i-sized wire (the physical buffers stay topk-wide; jit
        shapes are static). Summed over nodes x degree x wires."""
        chunk = self.scale_chunk
        n_chunks = self.wire_layout.total // chunk
        k = kvec.reshape(-1).astype(jnp.float32)
        idx = k * jnp.dtype(compact_pos_dtype(chunk)).itemsize
        bb = bitmap_bytes_per_chunk(chunk)
        if bb is not None:
            idx = jnp.minimum(idx, jnp.float32(bb))
        per_chunk = jnp.minimum(k + idx + 4.0, jnp.float32(chunk + 4))
        deg = jnp.asarray(_degrees(self.dense_equivalent()), jnp.float32)
        return jnp.float32(wires) * jnp.sum(deg * n_chunks * per_chunk)

    def _self_weight(self, w_diag):
        if self.dirs is not None:
            return jnp.float32(self.w_self)
        return jax.lax.dynamic_slice_in_dim(w_diag, self._my_index(), 1)[0]

    def _round_constants(self, cfg: FLConfig):
        if cfg.n_nodes != self.n_nodes:
            raise ValueError(
                f"cfg.n_nodes {cfg.n_nodes} != mesh node axes product "
                f"{self.n_nodes}"
            )
        if self.w_dense is None:
            # rank-matched placeholders; the circulant wire never reads them
            w_diag = jnp.zeros((1,), jnp.float32)
            w_off = jnp.zeros((1, 1), jnp.float32)
        else:
            _, w_diag, w_off = _split_w_np(self.w_dense, self.n_nodes)
        return w_diag, w_off

    def _metrics(self, cfg, losses, grads, alpha, new_state, egress):
        m = {
            "loss": jnp.mean(losses),
            "alpha": alpha,
            "grad_norm_sq": _mean_grad_norm_sq(grads),
            "consensus_err": _consensus_error(new_state.params),
            "comm_rounds": jnp.float32(1.0),
            "wire_bytes": jnp.float32(egress),
            "ef_residual_rms": self._residual_rms(new_state.comm),
        }
        m.update(self._privacy_metrics(cfg, new_state))
        return m

    def _mix_dirs_dynamic(self, dqs, nbrs, dgate):
        """Fold one wire's per-direction dq into the neighbor-recon
        accumulators and weight by the round's gate: ``mix_i = sum_d
        dgate[i, d] * nbr_recon_d'`` == the dense ``W_r_off @ recon'``
        row exactly. Without difference coding the neighbor recon IS this
        round's dq (nothing accumulates)."""
        dc = self.difference_coding
        mix, new_nbrs = None, []
        for d in range(len(self.dirs)):
            nb = (nbrs[d] + dqs[d]) if dc else dqs[d]
            if dc:
                new_nbrs.append(nb)
            term = dgate[:, d:d + 1] * nb
            mix = term if mix is None else mix + term
        return mix, tuple(new_nbrs)

    def _make_dynamic_round(self, eval_grads, schedule, cfg: FLConfig,
                            pipelined: bool):
        """ONE builder for both dynamic-topology round layouts -- the
        sequential and pipelined rounds differ ONLY in where the
        per-direction dqs come from (in-body ppermutes vs the ingested
        in-flight wire) and in whether this round's wire rides out in
        comm, so both are parameterized here instead of maintained as
        near-duplicate bodies (the static schedules share
        ``_assemble_round`` the same way). Wire stage and ppermute count
        are identical to the static engine (churn adds zero collectives,
        zero recompiles); the mix is weighted by the round's traced gate
        against per-direction neighbor-recon accumulators. Returns
        ``(ingest_or_None, comm_step(state, batch, stale))``."""
        self._round_constants(cfg)  # shape validation only
        if self.dirs is None:
            return self._make_dynamic_round_dense(
                eval_grads, schedule, cfg, pipelined
            )
        produce, produce_gt = self._make_produce()
        egress = self.wire_bytes(cfg)
        # buffers whose width is (a fixed fraction of) layout.total tile
        # over the model axis; per-node gates/counters do not
        spec = P(self.node_axes, self.model_axis)
        nspec = P(self.node_axes, None)
        n_dirs = len(self.dirs)
        wk = bool(getattr(self.node_program, "heterogeneous_wire_k", False))
        n_wk = 1 if wk else 0
        nbr_keys = self._nbr_key_names("")
        nbr_keys_t = self._nbr_key_names("_t")
        nnbr = len(nbr_keys)
        # pipelined extras: D ingested-dq operands per wire, and this
        # round's wire buffers appended to the outputs / comm keys
        wire_keys = self._wire_key_names("") if pipelined else ()
        wire_keys_t = self._wire_key_names("_t") if pipelined else ()
        n_adds = n_dirs if pipelined else 0
        n_wire = len(wire_keys)
        dp, sa = self._dp, self._sa_wire
        n_noise = 1 if dp else 0
        # pipelined transport lives in ingest; sequential transport lives
        # in the comm body -- the pad operands ride wherever the
        # ppermutes actually are
        sa_body = sa and not pipelined
        n_priv = 2 if sa_body else 0
        priv_specs = (P(None), P()) if sa_body else ()
        t_stream = PAD_STREAM + TRACKER_STREAM_OFFSET

        def mix_one(wire, nbrs, adds, dgate, priv, stream_base):
            dqs = (adds if pipelined
                   else self._recv_dqs(wire, priv=priv,
                                       stream_base=stream_base))
            return self._mix_dirs_dynamic(dqs, nbrs, dgate)

        def split_priv(tail):
            priv = (tail[0], tail[1]) if sa_body else None
            return tail[n_priv:], priv

        def body(x, g, recon, res, *rest):
            nbrs = rest[:nnbr]
            adds = rest[nnbr:nnbr + n_adds]
            k0 = nnbr + n_adds
            dgate, ddiag = rest[k0:k0 + 2]
            kvec = rest[k0 + 2] if wk else None
            alpha = rest[k0 + 2 + n_wk]
            tail, priv = split_priv(rest[k0 + 3 + n_wk:])
            h, wire, nrecon, nres = produce(x, g, recon, res, alpha, *tail,
                                            kvec=kvec)
            mix, new_nbrs = mix_one(wire, nbrs, adds, dgate, priv,
                                    PAD_STREAM)
            mixed = self._scope_finish(ddiag * h + mix, x, g, alpha)
            out = (mixed, nrecon, nres) + new_nbrs
            return out + (wire if pipelined else ())

        def body_gt(x, t, g, gp, rx, sx, rt, st, *rest):
            nbrs_x = rest[:nnbr]
            nbrs_t = rest[nnbr:2 * nnbr]
            adds_x = rest[2 * nnbr:2 * nnbr + n_adds]
            adds_t = rest[2 * nnbr + n_adds:2 * nnbr + 2 * n_adds]
            k = 2 * nnbr + 2 * n_adds
            dgate, ddiag = rest[k:k + 2]
            kvec = rest[k + 2] if wk else None
            alpha = rest[k + 2 + n_wk]
            tail, priv = split_priv(rest[k + 3 + n_wk:])
            (h, t_half, wire_x, nrx, nsx, wire_t, nrt, nst) = produce_gt(
                x, t, g, gp, rx, sx, rt, st, alpha, *tail, kvec=kvec
            )
            mix_x, new_x = mix_one(wire_x, nbrs_x, adds_x, dgate, priv,
                                   PAD_STREAM)
            mix_t, new_t = mix_one(wire_t, nbrs_t, adds_t, dgate, priv,
                                   t_stream)
            mixed_x, mixed_t = self._scope_finish_gt(
                ddiag * h + mix_x, ddiag * t_half + mix_t,
                x, t, g, gp, alpha,
            )
            out = (mixed_x, mixed_t, nrx, nsx, nrt, nst) + new_x + new_t
            return out + ((wire_x + wire_t) if pipelined else ())

        sm_dsgd = _shard_map(
            body, mesh=self.mesh,
            in_specs=(spec,) * (4 + nnbr + n_adds) + (nspec, nspec)
            + (nspec,) * n_wk + (P(),)
            + priv_specs + (spec,) * n_noise,
            out_specs=(spec,) * (3 + nnbr + n_wire),
        )
        sm_dsgt = _shard_map(
            body_gt, mesh=self.mesh,
            in_specs=(spec,) * (8 + 2 * nnbr + 2 * n_adds)
            + (nspec, nspec) + (nspec,) * n_wk + (P(),)
            + priv_specs + (spec,) * (2 * n_noise),
            out_specs=(spec,) * (6 + 2 * nnbr + 2 * n_wire),
        )

        ingest = None
        if pipelined:
            def make_ingest(stream_base: int):
                def ingest_body(*args):
                    if sa:
                        wire = tuple(args[:n_wire])
                        priv = tuple(args[n_wire:])
                    else:
                        wire, priv = tuple(args), None
                    return tuple(self._recv_dqs(
                        wire, priv=priv, stream_base=stream_base
                    ))

                return _shard_map(
                    ingest_body, mesh=self.mesh,
                    in_specs=(spec,) * n_wire
                    + ((P(None), P()) if sa else ()),
                    out_specs=(spec,) * n_dirs,
                )

            sm_ingest = make_ingest(PAD_STREAM)
            sm_ingest_t = make_ingest(t_stream)

            def ingest(state: FLState):
                if state.comm is None or wire_keys[0] not in state.comm:
                    raise ValueError(
                        "pipelined rounds need init_fl_state(..., "
                        "engine=...) with the pipelined engine (in-flight "
                        "wire buffers)"
                    )
                priv = (
                    (state.comm["priv_key"], state.comm["topo_round"])
                    if sa else ()
                )
                # the collective consumes the OLDEST ring slot only --
                # k in-flight payloads never multiply the operand bytes
                stale = {"dqs": sm_ingest(
                    *self._ring_slot0(state.comm, wire_keys), *priv
                )}
                if cfg.algorithm == "dsgt":
                    stale["dqs_t"] = sm_ingest_t(
                        *self._ring_slot0(state.comm, wire_keys_t), *priv
                    )
                return stale

        def comm_step(state: FLState, batch: PyTree, stale):
            if state.comm is None:
                raise ValueError(
                    "fused rounds need init_fl_state(..., engine=...)"
                )
            step = state.step + 1
            alpha = schedule(step)
            losses, grads = eval_grads(state.params, batch)
            grads = grads.astype(jnp.float32)
            alpha32 = jnp.asarray(alpha, jnp.float32)
            dgate, ddiag, topo_comm, gate_metrics = self._dir_gates(
                state.comm
            )
            kops = (self._wire_k_vec(state.comm),) if wk else ()
            adds = tuple(stale["dqs"]) if pipelined else ()
            priv = (
                (state.comm["priv_key"], state.comm["topo_round"])
                if sa_body else ()
            )
            noises = (
                (self._dp_noise_full(state.comm, cfg.n_nodes),) if dp else ()
            )

            if cfg.algorithm == "dsgd":
                outs = sm_dsgd(
                    self._f32(state.params), grads, state.comm["recon"],
                    state.comm["residual"],
                    *[state.comm[k] for k in nbr_keys],
                    *adds, dgate, ddiag, *kops, alpha32, *priv, *noises,
                )
                mixed, nrecon, nres = outs[:3]
                comm = {"recon": nrecon, "residual": nres, **topo_comm}
                # output order == key order by construction of the bodies
                comm.update(zip(nbr_keys, outs[3:3 + nnbr]))
                self._push_wire(
                    state.comm, comm, wire_keys, outs[3 + nnbr:]
                )
                new_state = state._replace(
                    step=step, params=self._st(mixed), comm=comm
                )
            else:
                adds_t = tuple(stale["dqs_t"]) if pipelined else ()
                if dp:
                    noises += (self._dp_noise_full(state.comm, cfg.n_nodes,
                                                   tracker=True),)
                outs = sm_dsgt(
                    self._f32(state.params), self._f32(state.tracker),
                    grads, self._f32(state.prev_grad),
                    state.comm["recon"], state.comm["residual"],
                    state.comm["recon_t"], state.comm["residual_t"],
                    *[state.comm[k] for k in nbr_keys],
                    *[state.comm[k] for k in nbr_keys_t],
                    *adds, *adds_t, dgate, ddiag, *kops, alpha32,
                    *priv, *noises,
                )
                (mx, mt, nrx, nsx, nrt, nst) = outs[:6]
                comm = {"recon": nrx, "residual": nsx,
                        "recon_t": nrt, "residual_t": nst, **topo_comm}
                comm.update(zip(
                    nbr_keys + nbr_keys_t, outs[6:6 + 2 * nnbr]
                ))
                self._push_wire(
                    state.comm, comm, wire_keys + wire_keys_t,
                    outs[6 + 2 * nnbr:],
                )
                new_state = FLState(
                    step=step, params=self._st(mx), tracker=self._st(mt),
                    prev_grad=self._st(grads), comm=comm,
                )

            metrics = self._metrics(
                cfg, losses, grads, alpha, new_state, egress
            )
            metrics.update(gate_metrics)
            if wk:
                metrics["wire_bytes_effective"] = self._wire_k_bytes(
                    kops[0], wires=2 if cfg.algorithm == "dsgt" else 1
                )
            return new_state, metrics

        return ingest, comm_step

    def _make_dynamic_round_dense(self, eval_grads, schedule, cfg: FLConfig,
                                  pipelined: bool):
        """Dynamic round on the DENSE all-gather wire: the same ONE
        all-gather per wire buffer as the static dense path (a dynamic
        program adds zero collectives), but the pre-weighted ``mix_recon``
        accumulator -- impossible under a per-round W -- is replaced by
        ``nbr_recon_all``: every dq reaches every node anyway, so each
        node keeps an UNWEIGHTED (n, t) replica of all reconstructions
        and contracts its traced W_r row against it at mix time
        (``mix_i = W_r[i] @ nbr_recon_all_i``). Pipelined/bounded rounds
        gather the ring's OLDEST in-flight payload inside the comm body
        (the dense wire has no separate pre-scan collective) and push
        this round's payload onto the ring."""
        produce, produce_gt = self._make_produce()
        egress = self.wire_bytes(cfg)
        spec = P(self.node_axes, self.model_axis)
        nspec = P(self.node_axes, None)
        spec3 = P(self.node_axes, None, self.model_axis)
        wk = bool(getattr(self.node_program, "heterogeneous_wire_k", False))
        n_wk = 1 if wk else 0
        dc = self.difference_coding
        n = self.n_nodes
        nbr_keys = self._nbr_key_names("")
        nbr_keys_t = self._nbr_key_names("_t")
        nnbr = len(nbr_keys)  # 1 with difference coding, else 0
        wire_keys = self._wire_key_names("") if pipelined else ()
        wire_keys_t = self._wire_key_names("_t") if pipelined else ()
        n_wire = len(wire_keys)
        n_stale = n_wire if pipelined else 0
        dp = self._dp
        n_noise = 1 if dp else 0

        def gather_dq(wire):
            """ONE all-gather per wire buffer -> every node's dense dq."""
            gathered = tuple(
                jax.lax.all_gather(
                    b[0], self.node_axes, tiled=False
                ).reshape(n, -1)
                for b in wire
            )
            return self._dq_full(gathered)

        def mix_one(wire, stale_wire, nbr, w_row):
            dq = gather_dq(stale_wire if pipelined else wire)
            new_all = (nbr[0] + dq) if dc else dq  # (n, t)
            mix = (w_row[0] @ new_all)[None]
            return mix, ((new_all[None],) if dc else ())

        def body(x, g, recon, res, *rest):
            nbrs = rest[:nnbr]
            stale_wire = rest[nnbr:nnbr + n_stale]
            k = nnbr + n_stale
            w_row, ddiag = rest[k:k + 2]
            kvec = rest[k + 2] if wk else None
            alpha = rest[k + 2 + n_wk]
            noises = rest[k + 3 + n_wk:]
            h, wire, nrecon, nres = produce(x, g, recon, res, alpha,
                                            *noises, kvec=kvec)
            mix, new_nbr = mix_one(wire, stale_wire, nbrs[0] if dc else None,
                                   w_row)
            mixed = self._scope_finish(ddiag * h + mix, x, g, alpha)
            out = (mixed, nrecon, nres) + new_nbr
            return out + (wire if pipelined else ())

        def body_gt(x, t, g, gp, rx, sx, rt, st, *rest):
            nbrs_x = rest[:nnbr]
            nbrs_t = rest[nnbr:2 * nnbr]
            stale_x = rest[2 * nnbr:2 * nnbr + n_stale]
            stale_t = rest[2 * nnbr + n_stale:2 * nnbr + 2 * n_stale]
            k = 2 * nnbr + 2 * n_stale
            w_row, ddiag = rest[k:k + 2]
            kvec = rest[k + 2] if wk else None
            alpha = rest[k + 2 + n_wk]
            noises = rest[k + 3 + n_wk:]
            (h, t_half, wire_x, nrx, nsx, wire_t, nrt, nst) = produce_gt(
                x, t, g, gp, rx, sx, rt, st, alpha, *noises, kvec=kvec
            )
            mix_x, new_x = mix_one(wire_x, stale_x,
                                   nbrs_x[0] if dc else None, w_row)
            mix_t, new_t = mix_one(wire_t, stale_t,
                                   nbrs_t[0] if dc else None, w_row)
            mixed_x, mixed_t = self._scope_finish_gt(
                ddiag * h + mix_x, ddiag * t_half + mix_t,
                x, t, g, gp, alpha,
            )
            out = (mixed_x, mixed_t, nrx, nsx, nrt, nst) + new_x + new_t
            return out + ((wire_x + wire_t) if pipelined else ())

        sm_dsgd = _shard_map(
            body, mesh=self.mesh,
            in_specs=(spec,) * 4 + (spec3,) * nnbr + (spec,) * n_stale
            + (nspec, nspec) + (nspec,) * n_wk + (P(),)
            + (spec,) * n_noise,
            out_specs=(spec,) * 3 + (spec3,) * nnbr + (spec,) * n_wire,
        )
        sm_dsgt = _shard_map(
            body_gt, mesh=self.mesh,
            in_specs=(spec,) * 8 + (spec3,) * 2 * nnbr
            + (spec,) * 2 * n_stale + (nspec, nspec)
            + (nspec,) * n_wk + (P(),)
            + (spec,) * (2 * n_noise),
            out_specs=(spec,) * 6 + (spec3,) * 2 * nnbr
            + (spec,) * 2 * n_wire,
        )

        def comm_step(state: FLState, batch: PyTree, stale):
            if state.comm is None:
                raise ValueError(
                    "fused rounds need init_fl_state(..., engine=...)"
                )
            step = state.step + 1
            alpha = schedule(step)
            losses, grads = eval_grads(state.params, batch)
            grads = grads.astype(jnp.float32)
            alpha32 = jnp.asarray(alpha, jnp.float32)
            w_off_r, w_diag_r, topo_comm, gate_metrics = self._round_gates(
                state.comm
            )
            w_row = jnp.asarray(w_off_r, jnp.float32)
            ddiag = jnp.asarray(w_diag_r, jnp.float32).reshape(n, 1)
            kops = (self._wire_k_vec(state.comm),) if wk else ()
            adds = (
                self._ring_slot0(state.comm, wire_keys) if pipelined else ()
            )
            noises = (
                (self._dp_noise_full(state.comm, cfg.n_nodes),) if dp else ()
            )

            if cfg.algorithm == "dsgd":
                outs = sm_dsgd(
                    self._f32(state.params), grads, state.comm["recon"],
                    state.comm["residual"],
                    *[state.comm[k] for k in nbr_keys],
                    *adds, w_row, ddiag, *kops, alpha32, *noises,
                )
                mixed, nrecon, nres = outs[:3]
                comm = {"recon": nrecon, "residual": nres, **topo_comm}
                comm.update(zip(nbr_keys, outs[3:3 + nnbr]))
                self._push_wire(
                    state.comm, comm, wire_keys, outs[3 + nnbr:]
                )
                new_state = state._replace(
                    step=step, params=self._st(mixed), comm=comm
                )
            else:
                adds_t = (
                    self._ring_slot0(state.comm, wire_keys_t)
                    if pipelined else ()
                )
                if dp:
                    noises += (self._dp_noise_full(state.comm, cfg.n_nodes,
                                                   tracker=True),)
                outs = sm_dsgt(
                    self._f32(state.params), self._f32(state.tracker),
                    grads, self._f32(state.prev_grad),
                    state.comm["recon"], state.comm["residual"],
                    state.comm["recon_t"], state.comm["residual_t"],
                    *[state.comm[k] for k in nbr_keys],
                    *[state.comm[k] for k in nbr_keys_t],
                    *adds, *adds_t, w_row, ddiag, *kops, alpha32, *noises,
                )
                (mx, mt, nrx, nsx, nrt, nst) = outs[:6]
                comm = {"recon": nrx, "residual": nsx,
                        "recon_t": nrt, "residual_t": nst, **topo_comm}
                comm.update(zip(
                    nbr_keys + nbr_keys_t, outs[6:6 + 2 * nnbr]
                ))
                self._push_wire(
                    state.comm, comm, wire_keys + wire_keys_t,
                    outs[6 + 2 * nnbr:],
                )
                new_state = FLState(
                    step=step, params=self._st(mx), tracker=self._st(mt),
                    prev_grad=self._st(grads), comm=comm,
                )

            metrics = self._metrics(
                cfg, losses, grads, alpha, new_state, egress
            )
            metrics.update(gate_metrics)
            if wk:
                metrics["wire_bytes_effective"] = self._wire_k_bytes(
                    kops[0], wires=2 if cfg.algorithm == "dsgt" else 1
                )
            return new_state, metrics

        return None, comm_step

    def _make_comm_step_dynamic(self, eval_grads, schedule, cfg: FLConfig):
        _, comm_step = self._make_dynamic_round(
            eval_grads, schedule, cfg, pipelined=False
        )
        return lambda state, batch: comm_step(state, batch, None)

    def make_comm_step(self, eval_grads, schedule, cfg: FLConfig):
        if self.dynamic_round:
            return self._make_comm_step_dynamic(eval_grads, schedule, cfg)
        w_diag, w_off = self._round_constants(cfg)
        produce, produce_gt = self._make_produce()
        egress = self.wire_bytes(cfg)
        spec = P(self.node_axes, self.model_axis)

        # With difference coding, recon_j' = recon_j + dq_j, so the
        # neighbor-mix term accumulates: mix_recon' = mix_recon + S W dq.
        # WITHOUT it, recon_j' = dq_j alone, so the term is rebuilt from
        # this round's wire and mix_recon stays zero (replace, don't sum).
        dc = self.difference_coding
        # Privacy operands ride the SAME shard_map call: DP noise rows
        # shard like every (n, t) buffer; the pad key/round replicate.
        dp, sa = self._dp, self._sa_wire
        n_noise = 1 if dp else 0
        priv_specs = (P(None), P()) if sa else ()
        t_stream = PAD_STREAM + TRACKER_STREAM_OFFSET

        def split_extra(extra, wires):
            noises = extra[:n_noise * wires]
            priv = tuple(extra[n_noise * wires:]) or None
            return noises, priv

        def body(x, g, recon, res, mix_recon, alpha, w_diag, w_off, *extra):
            noises, priv = split_extra(extra, 1)
            h, wire, nrecon, nres = produce(x, g, recon, res, alpha, *noises)
            mix_add = self._wire_mix(wire, w_off, priv=priv)
            new_mix = mix_recon + mix_add if dc else mix_add
            mixed = self._scope_finish(
                self._self_weight(w_diag) * h + new_mix, x, g, alpha
            )
            return mixed, nrecon, nres, new_mix

        def body_gt(x, t, g, gp, rx, sx, mrx, rt, st, mrt, alpha, w_diag,
                    w_off, *extra):
            noises, priv = split_extra(extra, 2)
            (h, t_half, wire_x, nrx, nsx, wire_t, nrt, nst) = produce_gt(
                x, t, g, gp, rx, sx, rt, st, alpha, *noises
            )
            w_self = self._self_weight(w_diag)
            mix_x = self._wire_mix(wire_x, w_off, priv=priv)
            mix_t = self._wire_mix(wire_t, w_off, priv=priv,
                                   stream_base=t_stream)
            new_mrx = mrx + mix_x if dc else mix_x
            new_mrt = mrt + mix_t if dc else mix_t
            mixed_x, mixed_t = self._scope_finish_gt(
                w_self * h + new_mrx, w_self * t_half + new_mrt,
                x, t, g, gp, alpha,
            )
            return mixed_x, mixed_t, nrx, nsx, new_mrx, nrt, nst, new_mrt

        rep = P(None, None)
        sm_dsgd = _shard_map(
            body, mesh=self.mesh,
            in_specs=(spec,) * 5 + (P(), P(None), rep)
            + (spec,) * n_noise + priv_specs,
            out_specs=(spec,) * 4,
        )
        sm_dsgt = _shard_map(
            body_gt, mesh=self.mesh,
            in_specs=(spec,) * 10 + (P(), P(None), rep)
            + (spec,) * (2 * n_noise) + priv_specs,
            out_specs=(spec,) * 8,
        )

        def priv_operands(comm, wires):
            ops = ()
            if dp:
                ops += (self._dp_noise_full(comm, cfg.n_nodes),)
                if wires == 2:
                    ops += (self._dp_noise_full(comm, cfg.n_nodes,
                                                tracker=True),)
            if sa:
                ops += (comm["priv_key"], comm["topo_round"])
            return ops

        def comm_step(state: FLState, batch: PyTree):
            if state.comm is None:
                raise ValueError(
                    "fused rounds need init_fl_state(..., engine=...)"
                )
            step = state.step + 1
            alpha = schedule(step)
            losses, grads = eval_grads(state.params, batch)
            grads = grads.astype(jnp.float32)
            alpha32 = jnp.asarray(alpha, jnp.float32)
            priv_comm = self._priv_comm(state.comm)

            if cfg.algorithm == "dsgd":
                mixed, nrecon, nres, new_mix = sm_dsgd(
                    self._f32(state.params), grads, state.comm["recon"],
                    state.comm["residual"], state.comm["mix_recon"],
                    alpha32, w_diag, w_off, *priv_operands(state.comm, 1),
                )
                new_state = state._replace(
                    step=step, params=self._st(mixed),
                    comm={"recon": nrecon, "residual": nres,
                          "mix_recon": new_mix, **priv_comm},
                )
            else:
                (mx, mt, nrx, nsx, nmrx, nrt, nst, nmrt) = sm_dsgt(
                    self._f32(state.params), self._f32(state.tracker),
                    grads, self._f32(state.prev_grad),
                    state.comm["recon"], state.comm["residual"],
                    state.comm["mix_recon"], state.comm["recon_t"],
                    state.comm["residual_t"], state.comm["mix_recon_t"],
                    alpha32, w_diag, w_off, *priv_operands(state.comm, 2),
                )
                new_state = FLState(
                    step=step, params=self._st(mx), tracker=self._st(mt),
                    prev_grad=self._st(grads),
                    comm={"recon": nrx, "residual": nsx, "mix_recon": nmrx,
                          "recon_t": nrt, "residual_t": nst,
                          "mix_recon_t": nmrt, **priv_comm},
                )

            return new_state, self._metrics(
                cfg, losses, grads, alpha, new_state, egress
            )

        return comm_step

    def _make_pipelined_round_dynamic(self, eval_grads, schedule,
                                      cfg: FLConfig):
        """Dynamic-topology pipelined round: ingest ppermutes the
        IN-FLIGHT wire per direction (before the local-step scan, exactly
        like the static path) but returns the per-direction dq
        UNWEIGHTED; the comm step folds each into its neighbor-recon
        accumulator and weights by THIS round's traced gate -- one-round-
        stale neighbor state mixed over the current round's graph,
        matching the fused engine's ``stale_mix`` with per-round W."""
        return self._make_dynamic_round(
            eval_grads, schedule, cfg, pipelined=True
        )

    def make_pipelined_round(self, eval_grads, schedule, cfg: FLConfig):
        """The split round: ``ingest`` runs the collective on the
        IN-FLIGHT payload buffers (``wire_*`` in ``FLState.comm``) --
        nothing it reads depends on this round's compute, so it lands
        BEFORE the local-step scan in the jaxpr; ``comm_step`` produces
        this round's payload (stored for the next round), folds the
        ingested stale neighbor term into ``mix_recon``, and mixes
        ``w_self * h + mix_recon'`` -- one-round-stale neighbor
        information, exactly sequential-with-delay."""
        if not self.pipelined:
            raise ValueError(
                "engine was built with round_schedule='sequential'; build "
                "it with round_schedule='pipelined'"
            )
        if self.dynamic_round:
            return self._make_pipelined_round_dynamic(
                eval_grads, schedule, cfg
            )
        w_diag, w_off = self._round_constants(cfg)
        produce, produce_gt = self._make_produce()
        egress = self.wire_bytes(cfg)
        spec = P(self.node_axes, self.model_axis)
        rep = P(None, None)
        nw = 3 if self.compact_wire else 2
        dc = self.difference_coding
        wire_keys = self._wire_key_names("")
        wire_keys_t = self._wire_key_names("_t")
        dp, sa = self._dp, self._sa_wire
        n_noise = 1 if dp else 0

        # The masked transport lives entirely inside ingest (the comm
        # bodies carry no collective): mask -> ppermute -> unmask with
        # the CURRENT round counter on both ends -- pads never need to
        # match the payload's production round, only the two transport
        # endpoints, which share the replicated (key, r) operands.
        def make_ingest(stream_base: int):
            def ingest_body(*args):
                if sa:
                    wire, w_off = args[:nw], args[nw]
                    priv = tuple(args[nw + 1:])
                else:
                    wire, w_off, priv = args[:-1], args[-1], None
                return self._wire_mix(tuple(wire), w_off, priv=priv,
                                      stream_base=stream_base)

            return _shard_map(
                ingest_body, mesh=self.mesh,
                in_specs=(spec,) * nw + (rep,)
                + ((P(None), P()) if sa else ()),
                out_specs=spec,
            )

        sm_ingest = make_ingest(PAD_STREAM)
        sm_ingest_t = make_ingest(PAD_STREAM + TRACKER_STREAM_OFFSET)

        def ingest(state: FLState):
            if state.comm is None or wire_keys[0] not in state.comm:
                raise ValueError(
                    "pipelined rounds need init_fl_state(..., engine=...) "
                    "with the pipelined engine (in-flight wire buffers)"
                )
            priv = (
                (state.comm["priv_key"], state.comm["topo_round"])
                if sa else ()
            )
            # the collective consumes the OLDEST ring slot only -- depth-k
            # staleness never multiplies the operand bytes per round
            stale = {"mix": sm_ingest(
                *self._ring_slot0(state.comm, wire_keys), w_off, *priv
            )}
            if cfg.algorithm == "dsgt":
                stale["mix_t"] = sm_ingest_t(
                    *self._ring_slot0(state.comm, wire_keys_t), w_off, *priv
                )
            return stale

        # The comm bodies carry NO collective: the wire payload produced
        # here is stored in comm and ingested at the top of the next round.
        def body(x, g, recon, res, mix_recon, mix_add, alpha, w_diag,
                 *noises):
            h, wire, nrecon, nres = produce(x, g, recon, res, alpha,
                                            *noises)
            stale_mix = mix_recon + mix_add if dc else mix_add
            mixed = self._scope_finish(
                self._self_weight(w_diag) * h + stale_mix, x, g, alpha
            )
            return (mixed, nrecon, nres, stale_mix) + wire

        def body_gt(x, t, g, gp, rx, sx, mrx, rt, st, mrt, add_x, add_t,
                    alpha, w_diag, *noises):
            (h, t_half, wire_x, nrx, nsx, wire_t, nrt, nst) = produce_gt(
                x, t, g, gp, rx, sx, rt, st, alpha, *noises
            )
            w_self = self._self_weight(w_diag)
            stale_x = mrx + add_x if dc else add_x
            stale_t = mrt + add_t if dc else add_t
            mixed_x, mixed_t = self._scope_finish_gt(
                w_self * h + stale_x, w_self * t_half + stale_t,
                x, t, g, gp, alpha,
            )
            return ((mixed_x, mixed_t, nrx, nsx, stale_x, nrt, nst, stale_t)
                    + wire_x + wire_t)

        sm_dsgd = _shard_map(
            body, mesh=self.mesh,
            in_specs=(spec,) * 6 + (P(), P(None)) + (spec,) * n_noise,
            out_specs=(spec,) * (4 + nw),
        )
        sm_dsgt = _shard_map(
            body_gt, mesh=self.mesh,
            in_specs=(spec,) * 12 + (P(), P(None)) + (spec,) * (2 * n_noise),
            out_specs=(spec,) * (8 + 2 * nw),
        )

        def comm_step(state: FLState, batch: PyTree, stale):
            step = state.step + 1
            alpha = schedule(step)
            losses, grads = eval_grads(state.params, batch)
            grads = grads.astype(jnp.float32)
            alpha32 = jnp.asarray(alpha, jnp.float32)
            priv_comm = self._priv_comm(state.comm)
            noises = (
                (self._dp_noise_full(state.comm, cfg.n_nodes),) if dp else ()
            )

            if cfg.algorithm == "dsgd":
                outs = sm_dsgd(
                    self._f32(state.params), grads, state.comm["recon"],
                    state.comm["residual"], state.comm["mix_recon"],
                    stale["mix"], alpha32, w_diag, *noises,
                )
                mixed, nrecon, nres, new_mix = outs[:4]
                comm = {"recon": nrecon, "residual": nres,
                        "mix_recon": new_mix, **priv_comm}
                self._push_wire(state.comm, comm, wire_keys, outs[4:])
                new_state = state._replace(
                    step=step, params=self._st(mixed), comm=comm
                )
            else:
                if dp:
                    noises += (self._dp_noise_full(state.comm, cfg.n_nodes,
                                                   tracker=True),)
                outs = sm_dsgt(
                    self._f32(state.params), self._f32(state.tracker),
                    grads, self._f32(state.prev_grad),
                    state.comm["recon"], state.comm["residual"],
                    state.comm["mix_recon"], state.comm["recon_t"],
                    state.comm["residual_t"], state.comm["mix_recon_t"],
                    stale["mix"], stale["mix_t"], alpha32, w_diag, *noises,
                )
                (mx, mt, nrx, nsx, nmrx, nrt, nst, nmrt) = outs[:8]
                comm = {"recon": nrx, "residual": nsx, "mix_recon": nmrx,
                        "recon_t": nrt, "residual_t": nst,
                        "mix_recon_t": nmrt, **priv_comm}
                self._push_wire(state.comm, comm, wire_keys, outs[8:8 + nw])
                self._push_wire(state.comm, comm, wire_keys_t, outs[8 + nw:])
                new_state = FLState(
                    step=step, params=self._st(mx), tracker=self._st(mt),
                    prev_grad=self._st(grads), comm=comm,
                )

            return new_state, self._metrics(
                cfg, losses, grads, alpha, new_state, egress
            )

        return ingest, comm_step

    @classmethod
    def simulated(cls, w, stacked_params, **_ignored):
        raise ValueError(
            "sharded_fused needs a device mesh (use from_mesh); on a single "
            "host use the 'fused' engine -- identical math, dense W"
        )

    @classmethod
    def from_mesh(cls, mesh: Mesh, node_axes: Sequence[str], stacked_sds,
                  *, wire_dtype=None, axes_subset=None, scale_chunk: int = 512,
                  topk=None, impl: str = "pallas", w=None,
                  error_feedback: bool = True, difference_coding: bool = True,
                  self_weight=None, compact=None, round_schedule=None,
                  storage_dtype=None, topology_program=None,
                  node_program=None, privacy=None, model_axis=None,
                  scope=None, **_ignored):
        _reject_wire_dtype(wire_dtype)
        shards = int(mesh.shape[model_axis]) if model_axis is not None else 1
        layout = pack_layout(
            stacked_sds, pad_to=scale_chunk,
            storage_dtype=storage_dtype or jnp.float32, shards=shards,
        )
        return cls(mesh, node_axes, layout, w=w, axes_subset=axes_subset,
                   self_weight=self_weight, model_axis=model_axis,
                   scale_chunk=scale_chunk,
                   topk=topk, impl=impl, error_feedback=error_feedback,
                   difference_coding=difference_coding, compact=compact,
                   round_schedule=round_schedule,
                   topology_program=topology_program,
                   node_program=node_program, privacy=privacy,
                   scope=scope)
