"""GossipEngine protocol: ONE pluggable layer behind ``make_fl_round``.

Historically the round machinery grew three divergent call paths -- the
node-stacked pytree path, the flat ``(nodes, total)`` buffer path
(``layout=``), and the fused round megakernel (``fused=``) -- selected by
a kwarg maze in ``core.fl`` and string-dispatched if-chains in the
launchers. This module replaces all of that with a small protocol:

    init_comm_state(cfg, params)  extra wire state carried in FLState.comm
    local_step(params, grads, a)  the SGD update in the engine's own
                                  state representation
    mix(buf)                      exact-wire W application (tree/flat
                                  engines; fused engines mix inside their
                                  comm step instead)
    wire_bytes(cfg)               per-round egress accounting (all nodes)

plus two build hooks ``make_eval_grads`` (representation adapter around
the vmapped grad fn) and ``make_comm_step`` (the whole communication
step; the base class provides the paper's exact-wire mix-then-adapt
Eqs. 2/3, fused engines override it with adapt-then-combine kernels).

Shipped engines (the registry keys are what ``--fl-engine`` accepts
everywhere -- launch/dryrun.py, launch/train.py, examples -- so names
cannot drift):

    tree           node-stacked pytree state + any tree-level gossip
                   backend (dense-W simulated, mesh ppermute, all-gather)
    flat           the state IS one packed (nodes, total) fp32 buffer;
                   mixing is one matmul / ppermute / all-gather on it
    fused          the round megakernel: local update + int8 quantize +
                   W mix + error feedback in ONE Pallas call
                   (``kernels.gossip``), CHOCO difference-coded wire
    sharded_fused  the shard_map-native fused round: every device owns
                   its node's W row and its rows of the flat buffer, the
                   wire stage (update + top-k + int8 quantize + EF) is
                   ONE Pallas call per round, and the int8 payload moves
                   via ppermute (circulant torus/ring W) or all-gather
                   (arbitrary dense W)

``topk=`` on the fused engines masks the payload to the k largest-|.|
columns per scale chunk inside the kernel; the EF residual absorbs the
truncation, and wire bytes drop below the dense-int8 floor
(``packing.flat_wire_bytes``).

How the sharded engine stays O(params/node) per device: a CHOCO node
needs ``sum_j W_ij recon_j`` over its neighbors' reconstructions, but
``recon_j`` only ever advances by the dequantized wire payload
``dq_j``, so each node carries a running accumulator

    mix_recon_i  <-  mix_recon_i + sum_j W_ij dq_j        (one buffer)
    mixed_i       =  w_ii * h_i + mix_recon_i'

which equals the dense megakernel's ``W_off @ recon' + w_self * h`` row
exactly (up to summation order) without ever materializing neighbor
state. ``mix_recon`` rides in ``FLState.comm`` next to recon/residual.
"""

from __future__ import annotations

import abc
from typing import Any, Callable, ClassVar, Dict, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.fl import (
    FLConfig,
    FLState,
    _consensus_error,
    _mean_grad_norm_sq,
)
from repro.core.mixing import (
    GossipFn,
    _allgather_row,
    _mesh_dirs,
    _shard_map,
    _split_w,
    make_dense_flat_mix,
    make_dense_gossip,
    make_mesh_flat_mix,
    make_mesh_gossip,
    mesh_gossip_dense_equivalent,
)
from repro.core.packing import (
    FlatLayout,
    flat_wire_bytes,
    pack,
    pack_layout,
    pack_like,
    unpack,
)

PyTree = Any

__all__ = [
    "GossipEngine",
    "TreeEngine",
    "FlatEngine",
    "FusedEngine",
    "ShardedFusedEngine",
    "register_engine",
    "get_engine",
    "engine_names",
]


def _tm(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def _check_flat_params(cfg: FLConfig, params: PyTree, name: str) -> None:
    leaves = jax.tree_util.tree_leaves(params)
    if not leaves:
        raise ValueError("empty parameter pytree")
    for leaf in leaves:
        if leaf.shape[:1] != (cfg.n_nodes,):
            raise ValueError(
                f"param leaf {leaf.shape} is not node-stacked for n={cfg.n_nodes}"
            )
    if len(leaves) != 1 or leaves[0].ndim != 2:
        raise ValueError(
            f"{name} engine state must be the packed (nodes, total) flat "
            "buffer (core.packing.pack)"
        )


def _make_flat_eval_grads(layout: FlatLayout, grad_fn):
    def eval_grads(params: jnp.ndarray, batch: PyTree):
        # The tree view exists only inside this call; XLA lowers the
        # unpack/pack pair to slices/concat and fuses them away.
        losses, grads = grad_fn(unpack(params, layout), batch)
        return losses, pack_like(grads, layout)

    return eval_grads


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------


class GossipEngine(abc.ABC):
    """One round engine: state representation + wire + mixing semantics.

    Subclasses set ``name`` (the registry key) and ``layout`` (the
    :class:`FlatLayout` for flat-state engines, None for tree state), and
    either implement :meth:`mix` (exact-wire engines; the base
    :meth:`make_comm_step` then runs the paper's mix-then-adapt Eqs. 2/3)
    or override :meth:`make_comm_step` entirely (fused engines).
    """

    name: ClassVar[str] = "abstract"
    #: True for engines that only run on a device mesh (no ``simulated``)
    needs_mesh: ClassVar[bool] = False
    layout: Optional[FlatLayout] = None

    # -- protocol ----------------------------------------------------------

    def comm_keys(self, cfg: FLConfig) -> Tuple[str, ...]:
        """Names of the engine's extra wire-state buffers (each a
        ``(nodes, layout.total)`` fp32 array in ``FLState.comm``)."""
        return ()

    def init_comm_state(
        self, cfg: FLConfig, params: PyTree
    ) -> Optional[Dict[str, jnp.ndarray]]:
        """Zero-initialized wire state (zeros = the first round
        effectively transmits the full parameters)."""
        keys = self.comm_keys(cfg)
        if not keys:
            return None
        leaves = jax.tree_util.tree_leaves(params)
        z = jnp.zeros(leaves[0].shape, jnp.float32)
        return {k: z for k in keys}

    def local_step(self, params: PyTree, grads: PyTree, alpha) -> PyTree:
        """Eq. 4 in the engine's state representation (works unchanged for
        tree state and for the single-leaf flat buffer)."""
        return _tm(lambda p, g: p - alpha * g.astype(p.dtype), params, grads)

    def mix(self, buf: PyTree) -> PyTree:
        """Exact-wire W application (theta <- W theta) on the engine's
        state representation. Fused engines do not expose a standalone
        mix -- their W lives inside the comm-step kernel."""
        raise NotImplementedError(
            f"{type(self).__name__} mixes inside its fused comm step"
        )

    def wire_bytes(self, cfg: FLConfig) -> Optional[float]:
        """Per-round egress summed over all nodes (None: engine does not
        account -- e.g. the tree engine, whose payload depends on the
        pytree; see training.metrics.comm_bytes_per_gossip)."""
        return None

    # -- round building ----------------------------------------------------

    def check_params(self, cfg: FLConfig, params: PyTree) -> None:
        """Validate the initial state representation (called by
        ``init_fl_state``); base checks node-stacking only."""
        leaves = jax.tree_util.tree_leaves(params)
        if not leaves:
            raise ValueError("empty parameter pytree")
        for leaf in leaves:
            if leaf.shape[:1] != (cfg.n_nodes,):
                raise ValueError(
                    f"param leaf {leaf.shape} is not node-stacked for "
                    f"n={cfg.n_nodes}"
                )

    def make_eval_grads(self, grad_fn):
        """Adapt the vmapped per-node grad fn to the engine's state
        representation (identity for tree state)."""
        return grad_fn

    def params_view(self, params: PyTree) -> PyTree:
        """The pytree view of the engine's parameter state (unpacks flat
        buffers; identity for tree state)."""
        if self.layout is None:
            return params
        return unpack(params, self.layout)

    def init_state(self, cfg: FLConfig, params: PyTree) -> FLState:
        from repro.core.fl import init_fl_state

        return init_fl_state(cfg, params, engine=self)

    def restore_comm(
        self, comm: Dict[str, jnp.ndarray]
    ) -> Dict[str, jnp.ndarray]:
        """Rebuild DERIVED wire-state buffers after a checkpoint restore
        (identity for engines whose comm buffers are all independent)."""
        return comm

    def make_comm_step(self, eval_grads, schedule, cfg: FLConfig):
        """Default EXACT-WIRE comm step: ``self.mix`` applies W, then the
        optimizer update (mix-then-adapt, the paper's Eqs. 2/3)."""
        mix = self.mix
        wire = self.wire_bytes(cfg)

        def comm_step(state: FLState, batch: PyTree):
            step = state.step + 1
            alpha = schedule(step)
            losses, grads = eval_grads(state.params, batch)

            if cfg.algorithm == "dsgd":
                params = _tm(
                    lambda wp, g: wp - alpha * g.astype(wp.dtype),
                    mix(state.params), grads,
                )
                new_state = state._replace(step=step, params=params)
            else:
                tracker = _tm(
                    lambda wt, gn, gp: wt + gn.astype(wt.dtype) - gp,
                    mix(state.tracker), grads, state.prev_grad,
                )
                params = _tm(
                    lambda wp, t: wp - alpha * t, mix(state.params), tracker
                )
                new_state = state._replace(
                    step=step,
                    params=params,
                    tracker=tracker,
                    prev_grad=_tm(
                        lambda g, p: g.astype(p.dtype), grads, state.prev_grad
                    ),
                )

            metrics = {
                "loss": jnp.mean(losses),
                "alpha": alpha,
                "grad_norm_sq": _mean_grad_norm_sq(grads),
                "consensus_err": _consensus_error(new_state.params),
                "comm_rounds": jnp.float32(1.0),
            }
            if wire is not None:
                metrics["wire_bytes"] = jnp.float32(wire)
            return new_state, metrics

        return comm_step


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[GossipEngine]] = {}


def register_engine(cls: Type[GossipEngine]) -> Type[GossipEngine]:
    """Class decorator: make ``cls`` resolvable by ``get_engine(cls.name)``.
    The registry is the ONE list of engine names every CLI / example /
    checkpoint manifest consults -- never hardcode the strings."""
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate engine name {cls.name!r}")
    _REGISTRY[cls.name] = cls
    return cls


def get_engine(name: str) -> Type[GossipEngine]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {engine_names()}"
        ) from None


def engine_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Exact-wire engines
# ---------------------------------------------------------------------------


@register_engine
class TreeEngine(GossipEngine):
    """Node-stacked pytree state; mixing delegated to any tree-level
    gossip backend from ``core.mixing`` (dense-W simulated, mesh
    ppermute, all-gather)."""

    name = "tree"

    def __init__(self, gossip: GossipFn):
        self._gossip = gossip

    def mix(self, tree: PyTree) -> PyTree:
        return self._gossip(tree)

    @classmethod
    def simulated(cls, w: np.ndarray, stacked_params: PyTree, *,
                  wire_dtype=None, topk=None, **_ignored):
        """Single-host build: dense-W backend; state stays the input tree."""
        _reject_topk(topk, cls.name)
        return cls(make_dense_gossip(w, wire_dtype)), stacked_params

    @classmethod
    def from_mesh(cls, mesh: Mesh, node_axes: Sequence[str], stacked_sds,
                  *, specs=None, wire_dtype=None, axes_subset=None,
                  topk=None, **_ignored):
        _reject_topk(topk, cls.name)
        if specs is None:
            raise ValueError("tree engine from_mesh needs the param specs")
        return cls(
            make_mesh_gossip(mesh, node_axes, specs, wire_dtype=wire_dtype,
                             axes_subset=axes_subset)
        )


@register_engine
class FlatEngine(GossipEngine):
    """The state is ONE packed ``(nodes, total)`` fp32 buffer end to end;
    mixing is a flat-native backend (one matmul / one ppermute per torus
    direction / one all-gather per round, independent of leaf count)."""

    name = "flat"

    def __init__(self, mix_fn: Callable[[jnp.ndarray], jnp.ndarray],
                 layout: FlatLayout):
        self._mix = mix_fn
        self.layout = layout

    def mix(self, flat: jnp.ndarray) -> jnp.ndarray:
        return self._mix(flat)

    def check_params(self, cfg: FLConfig, params: PyTree) -> None:
        _check_flat_params(cfg, params, self.name)

    def make_eval_grads(self, grad_fn):
        return _make_flat_eval_grads(self.layout, grad_fn)

    @classmethod
    def simulated(cls, w: np.ndarray, stacked_params: PyTree, *,
                  scale_chunk: int = 1, wire_dtype=None, topk=None,
                  **_ignored):
        _reject_topk(topk, cls.name)
        flat, layout = pack(stacked_params, pad_to=scale_chunk)
        return cls(make_dense_flat_mix(w, wire_dtype), layout), flat

    @classmethod
    def from_mesh(cls, mesh: Mesh, node_axes: Sequence[str], stacked_sds,
                  *, wire_dtype=None, axes_subset=None, scale_chunk: int = 512,
                  topk=None, **_ignored):
        _reject_topk(topk, cls.name)
        layout = pack_layout(stacked_sds, pad_to=scale_chunk)
        return cls(
            make_mesh_flat_mix(mesh, node_axes, wire_dtype=wire_dtype,
                               axes_subset=axes_subset),
            layout,
        )


# ---------------------------------------------------------------------------
# Fused engines
# ---------------------------------------------------------------------------


_WIRE_DTYPE_MSG = (
    "the fused engines' wire is always difference-coded int8; wire_dtype "
    "only applies to the tree/flat exact-wire engines"
)


def _reject_wire_dtype(wire_dtype) -> None:
    if wire_dtype is not None:
        raise ValueError(_WIRE_DTYPE_MSG)


def _reject_topk(topk, name: str) -> None:
    if topk is not None:
        raise ValueError(
            f"topk is a fused-engine knob (sub-int8 sparsified wire); the "
            f"{name!r} engine ships an exact wire -- use 'fused' or "
            "'sharded_fused'"
        )


def _split_w_np(w: np.ndarray, n: int):
    """Shape-checked (w, diag, off-diag) via ``mixing._split_w``."""
    w = np.asarray(w, dtype=np.float64)
    if w.shape != (n, n):
        raise ValueError(f"W shape {w.shape} != ({n}, {n})")
    w_self, w_off = _split_w(w)
    return w, w_self, w_off


def _degrees(w: np.ndarray) -> np.ndarray:
    return (np.abs(w - np.diag(np.diag(w))) > 0).sum(axis=1)


def _dequant(q: jnp.ndarray, scales: jnp.ndarray, scale_chunk: int):
    """(n, t) int8 + (n, t//chunk) fp32 scales -> (n, t) fp32."""
    n, t = q.shape
    q3 = q.astype(jnp.float32).reshape(n, t // scale_chunk, scale_chunk)
    return (q3 * scales[:, :, None]).reshape(n, t)


class _FusedBase(GossipEngine):
    """Shared knobs + validation of the fused (CHOCO int8 wire) engines."""

    def __init__(self, layout: FlatLayout, *, scale_chunk: int = 512,
                 topk: Optional[int] = None, error_feedback: bool = True,
                 difference_coding: bool = True, impl: str = "pallas"):
        if impl not in ("pallas", "jnp"):
            raise ValueError(f"unknown impl {impl!r}")
        if scale_chunk < 1:
            raise ValueError("scale_chunk must be >= 1")
        if topk is not None and not (1 <= topk):
            raise ValueError("topk must be >= 1 or None")
        if layout.total % scale_chunk:
            raise ValueError(
                f"layout.total {layout.total} not a multiple of scale_chunk "
                f"{scale_chunk}; pack with pad_to={scale_chunk}"
            )
        self.layout = layout
        self.scale_chunk = scale_chunk
        self.topk = topk
        self.error_feedback = error_feedback
        self.difference_coding = difference_coding
        self.impl = impl

    def check_params(self, cfg: FLConfig, params: PyTree) -> None:
        _check_flat_params(cfg, params, self.name)

    def make_eval_grads(self, grad_fn):
        return _make_flat_eval_grads(self.layout, grad_fn)

    def _kernel_kwargs(self):
        return dict(
            scale_chunk=self.scale_chunk,
            error_feedback=self.error_feedback,
            difference_coding=self.difference_coding,
            topk=self.topk,
        )

    def _edge_bytes(self) -> int:
        """Wire bytes one node ships to ONE neighbor per wire per round."""
        return flat_wire_bytes(self.layout, 1, self.scale_chunk, self.topk)


@register_engine
class FusedEngine(_FusedBase):
    """The round megakernel on a dense compile-time W: local update + int8
    quantize (top-k sparsified when ``topk`` is set) + W-row mix + error
    feedback, ONE Pallas call per comm round (``kernels.gossip``;
    ``impl="jnp"`` runs the bit-identical chunked oracle, which is what
    GSPMD partitions in the sharded dry run)."""

    name = "fused"

    def __init__(self, w: np.ndarray, layout: FlatLayout, **kw):
        super().__init__(layout, **kw)
        self.w = np.asarray(w, dtype=np.float64)

    def comm_keys(self, cfg: FLConfig) -> Tuple[str, ...]:
        keys = ("recon", "residual")
        if cfg.algorithm == "dsgt":
            keys += ("recon_t", "residual_t")
        return keys

    def wire_bytes(self, cfg: FLConfig) -> float:
        wires = 2 if cfg.algorithm == "dsgt" else 1
        return float(wires * _degrees(self.w).sum() * self._edge_bytes())

    def make_comm_step(self, eval_grads, schedule, cfg: FLConfig):
        _, w_self, w_off = _split_w_np(self.w, cfg.n_nodes)
        if self.impl == "pallas":
            from repro.kernels.gossip.ops import fused_round, fused_round_gt
        else:
            from repro.kernels.gossip.ref import (
                fused_round_gt_ref as fused_round_gt,
                fused_round_ref as fused_round,
            )
        kw = self._kernel_kwargs()
        egress = self.wire_bytes(cfg)

        def comm_step(state: FLState, batch: PyTree):
            if state.comm is None:
                raise ValueError(
                    "fused rounds need init_fl_state(..., engine=...)"
                )
            step = state.step + 1
            alpha = schedule(step)
            losses, grads = eval_grads(state.params, batch)
            grads = grads.astype(jnp.float32)

            if cfg.algorithm == "dsgd":
                mixed, recon, res, _ = fused_round(
                    state.params, grads, state.comm["recon"],
                    state.comm["residual"], w_off, w_self, alpha, **kw,
                )
                new_state = state._replace(
                    step=step, params=mixed,
                    comm={"recon": recon, "residual": res},
                )
            else:
                mx, mt, nrx, nsx, nrt, nst, _, _ = fused_round_gt(
                    state.params, state.tracker, grads, state.prev_grad,
                    state.comm["recon"], state.comm["residual"],
                    state.comm["recon_t"], state.comm["residual_t"],
                    w_off, w_self, alpha, **kw,
                )
                new_state = FLState(
                    step=step, params=mx, tracker=mt, prev_grad=grads,
                    comm={"recon": nrx, "residual": nsx,
                          "recon_t": nrt, "residual_t": nst},
                )

            metrics = {
                "loss": jnp.mean(losses),
                "alpha": alpha,
                "grad_norm_sq": _mean_grad_norm_sq(grads),
                "consensus_err": _consensus_error(new_state.params),
                "comm_rounds": jnp.float32(1.0),
                "wire_bytes": jnp.float32(egress),
            }
            return new_state, metrics

        return comm_step

    @classmethod
    def simulated(cls, w: np.ndarray, stacked_params: PyTree, *,
                  scale_chunk: int = 512, topk=None, impl: str = "pallas",
                  error_feedback: bool = True, difference_coding: bool = True,
                  wire_dtype=None, **_ignored):
        _reject_wire_dtype(wire_dtype)
        flat, layout = pack(stacked_params, pad_to=scale_chunk)
        return cls(w, layout, scale_chunk=scale_chunk, topk=topk, impl=impl,
                   error_feedback=error_feedback,
                   difference_coding=difference_coding), flat

    @classmethod
    def from_mesh(cls, mesh: Mesh, node_axes: Sequence[str], stacked_sds,
                  *, wire_dtype=None, axes_subset=None, scale_chunk: int = 512,
                  topk=None, impl: str = "jnp", error_feedback: bool = True,
                  difference_coding: bool = True, self_weight=None,
                  **_ignored):
        """Mesh build: W is the dense equivalent of the circulant torus the
        ppermute backend realizes over the node axes (directions restricted
        to ``axes_subset`` for hierarchical gossip). ``impl`` defaults to
        the jnp oracle, which GSPMD partitions in lowering-only dry runs."""
        _reject_wire_dtype(wire_dtype)
        w = mesh_gossip_dense_equivalent(
            {a: mesh.shape[a] for a in node_axes}, self_weight=self_weight,
            axes_subset=axes_subset,
        )
        layout = pack_layout(stacked_sds, pad_to=scale_chunk)
        return cls(w, layout, scale_chunk=scale_chunk, topk=topk, impl=impl,
                   error_feedback=error_feedback,
                   difference_coding=difference_coding)


@register_engine
class ShardedFusedEngine(_FusedBase):
    """The shard_map-native fused round for real meshes.

    Each device owns its node's row of the flat buffer (sharded
    ``P(node_axes, None)``) and its node's W row. Per round, inside ONE
    shard_map body:

      1. the WIRE STAGE -- local update (DSGD) / tracker arithmetic +
         update (DSGT), difference coding, top-k masking, int8 quantize,
         EF -- runs as ONE Pallas call on this shard's rows
         (``kernels.gossip.wire_stage[_gt]``; ``impl="jnp"`` uses the
         bit-identical oracle);
      2. the int8 payload + fp32 scales cross the wire: one ``ppermute``
         per torus direction for the circulant W realized by the mesh
         node axes (``w=None``), or one ``all_gather`` over the node axes
         for an arbitrary dense W;
      3. the mix finishes against the running neighbor-reconstruction
         accumulator: ``mix_recon' = mix_recon + sum_j W_ij dq_j``,
         ``mixed = w_self * h + mix_recon'`` -- O(params/node) state,
         bit-equal (up to summation order) to ``FusedEngine`` on the
         dense equivalent W.
    """

    name = "sharded_fused"
    needs_mesh = True

    def __init__(self, mesh: Mesh, node_axes: Sequence[str],
                 layout: FlatLayout, *, w: Optional[np.ndarray] = None,
                 self_weight: Optional[float] = None, axes_subset=None, **kw):
        super().__init__(layout, **kw)
        self.mesh = mesh
        self.node_axes = tuple(node_axes)
        self.n_nodes = int(np.prod([mesh.shape[a] for a in self.node_axes]))
        self.axes_subset = tuple(axes_subset) if axes_subset else None
        self.self_weight = self_weight
        if w is None:
            # circulant torus W over the node axes: ppermute wire
            self.w_dense = None
            self.w_self, self.dirs = _mesh_dirs(
                mesh, self.node_axes, self.axes_subset, self_weight
            )
        else:
            w = np.asarray(w, dtype=np.float64)
            if w.shape != (self.n_nodes,) * 2:
                raise ValueError(
                    f"W shape {w.shape} != ({self.n_nodes},) * 2"
                )
            self.w_dense = w
            self.w_self, self.dirs = None, None

    def comm_keys(self, cfg: FLConfig) -> Tuple[str, ...]:
        keys = ("recon", "residual", "mix_recon")
        if cfg.algorithm == "dsgt":
            keys += ("recon_t", "residual_t", "mix_recon_t")
        return keys

    def dense_equivalent(self) -> np.ndarray:
        """The dense W this engine realizes (the ``FusedEngine`` oracle)."""
        if self.w_dense is not None:
            return self.w_dense
        return mesh_gossip_dense_equivalent(
            {a: self.mesh.shape[a] for a in self.node_axes},
            self_weight=self.self_weight,
            axes_subset=self.axes_subset,
        )

    def wire_bytes(self, cfg: FLConfig) -> float:
        wires = 2 if cfg.algorithm == "dsgt" else 1
        return float(
            wires * _degrees(self.dense_equivalent()).sum() * self._edge_bytes()
        )

    def restore_comm(
        self, comm: Dict[str, jnp.ndarray]
    ) -> Dict[str, jnp.ndarray]:
        """The mix_recon accumulators are DERIVED state -- the invariant is
        ``mix_recon == W_off @ recon`` at every round boundary -- so a
        restore (possibly from a fused checkpoint that never had them)
        rebuilds them from the restored recon instead of trusting whatever
        the template carried."""
        w = self.dense_equivalent()
        w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)
        comm = dict(comm)
        comm["mix_recon"] = w_off @ jnp.asarray(comm["recon"], jnp.float32)
        if "recon_t" in comm:
            comm["mix_recon_t"] = w_off @ jnp.asarray(
                comm["recon_t"], jnp.float32
            )
        return comm

    # -- the shard_map round ----------------------------------------------

    def _wire_mix(self, q, scales, w_off_rows):
        """Move the int8 payload and return ``sum_j W_ij dq_j`` for this
        shard's rows. ``w_off_rows``: replicated (n, n) off-diagonal W
        (dense wire only; None for the circulant ppermute wire)."""
        ck = self.scale_chunk
        if self.dirs is not None:
            acc = jnp.zeros(q.shape, jnp.float32)
            for axis_name, shift, weight in self.dirs:
                size = self.mesh.shape[axis_name]
                perm = [(i, (i + shift) % size) for i in range(size)]
                qr = jax.lax.ppermute(q, axis_name, perm)  # int8 on the wire
                sr = jax.lax.ppermute(scales, axis_name, perm)
                acc = acc + jnp.float32(weight) * _dequant(qr, sr, ck)
            return acc
        # arbitrary dense W: ONE all-gather of the int8 payload + scales
        n = self.n_nodes
        qf = jax.lax.all_gather(q[0], self.node_axes, tiled=False)
        sf = jax.lax.all_gather(scales[0], self.node_axes, tiled=False)
        dq = _dequant(qf.reshape(n, -1), sf.reshape(n, -1), ck)
        row = _allgather_row(self.mesh, self.node_axes, w_off_rows)  # (n,)
        return (row @ dq)[None]

    def _self_weight(self, w_diag):
        if self.dirs is not None:
            return jnp.float32(self.w_self)
        idx = 0
        for a in self.node_axes:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return jax.lax.dynamic_slice_in_dim(w_diag, idx, 1)[0]

    def make_comm_step(self, eval_grads, schedule, cfg: FLConfig):
        if cfg.n_nodes != self.n_nodes:
            raise ValueError(
                f"cfg.n_nodes {cfg.n_nodes} != mesh node axes product "
                f"{self.n_nodes}"
            )
        if self.impl == "pallas":
            from repro.kernels.gossip.ops import wire_stage, wire_stage_gt
        else:
            from repro.kernels.gossip.ref import (
                wire_stage_gt_ref as wire_stage_gt,
                wire_stage_ref as wire_stage,
            )
        kw = self._kernel_kwargs()
        egress = self.wire_bytes(cfg)
        spec = P(self.node_axes, None)
        if self.w_dense is None:
            # rank-matched placeholders; the circulant wire never reads them
            w_diag = jnp.zeros((1,), jnp.float32)
            w_off = jnp.zeros((1, 1), jnp.float32)
        else:
            _, w_diag, w_off = _split_w_np(self.w_dense, self.n_nodes)

        # With difference coding, recon_j' = recon_j + dq_j, so the
        # neighbor-mix term accumulates: mix_recon' = mix_recon + S W dq.
        # WITHOUT it, recon_j' = dq_j alone, so the term is rebuilt from
        # this round's wire and mix_recon stays zero (replace, don't sum).
        dc = self.difference_coding

        def body(x, g, recon, res, mix_recon, alpha, w_diag, w_off):
            h, q, sc, nrecon, nres = wire_stage(x, g, recon, res, alpha, **kw)
            mix_add = self._wire_mix(q, sc, w_off)
            new_mix = mix_recon + mix_add if dc else mix_add
            mixed = self._self_weight(w_diag) * h + new_mix
            return mixed, nrecon, nres, new_mix

        def body_gt(x, t, g, gp, rx, sx, mrx, rt, st, mrt, alpha, w_diag,
                    w_off):
            (h, t_half, qx, scx, nrx, nsx, qt, sct, nrt, nst) = wire_stage_gt(
                x, t, g, gp, rx, sx, rt, st, alpha, **kw
            )
            w_self = self._self_weight(w_diag)
            mix_x = self._wire_mix(qx, scx, w_off)
            mix_t = self._wire_mix(qt, sct, w_off)
            new_mrx = mrx + mix_x if dc else mix_x
            new_mrt = mrt + mix_t if dc else mix_t
            mixed_x = w_self * h + new_mrx
            mixed_t = w_self * t_half + new_mrt
            return mixed_x, mixed_t, nrx, nsx, new_mrx, nrt, nst, new_mrt

        rep = P(None, None)
        sm_dsgd = _shard_map(
            body, mesh=self.mesh,
            in_specs=(spec,) * 5 + (P(), P(None), rep),
            out_specs=(spec,) * 4,
        )
        sm_dsgt = _shard_map(
            body_gt, mesh=self.mesh,
            in_specs=(spec,) * 10 + (P(), P(None), rep),
            out_specs=(spec,) * 8,
        )

        def comm_step(state: FLState, batch: PyTree):
            if state.comm is None:
                raise ValueError(
                    "fused rounds need init_fl_state(..., engine=...)"
                )
            step = state.step + 1
            alpha = schedule(step)
            losses, grads = eval_grads(state.params, batch)
            grads = grads.astype(jnp.float32)
            alpha32 = jnp.asarray(alpha, jnp.float32)

            if cfg.algorithm == "dsgd":
                mixed, nrecon, nres, new_mix = sm_dsgd(
                    state.params, grads, state.comm["recon"],
                    state.comm["residual"], state.comm["mix_recon"],
                    alpha32, w_diag, w_off,
                )
                new_state = state._replace(
                    step=step, params=mixed,
                    comm={"recon": nrecon, "residual": nres,
                          "mix_recon": new_mix},
                )
            else:
                (mx, mt, nrx, nsx, nmrx, nrt, nst, nmrt) = sm_dsgt(
                    state.params, state.tracker, grads, state.prev_grad,
                    state.comm["recon"], state.comm["residual"],
                    state.comm["mix_recon"], state.comm["recon_t"],
                    state.comm["residual_t"], state.comm["mix_recon_t"],
                    alpha32, w_diag, w_off,
                )
                new_state = FLState(
                    step=step, params=mx, tracker=mt, prev_grad=grads,
                    comm={"recon": nrx, "residual": nsx, "mix_recon": nmrx,
                          "recon_t": nrt, "residual_t": nst,
                          "mix_recon_t": nmrt},
                )

            metrics = {
                "loss": jnp.mean(losses),
                "alpha": alpha,
                "grad_norm_sq": _mean_grad_norm_sq(grads),
                "consensus_err": _consensus_error(new_state.params),
                "comm_rounds": jnp.float32(1.0),
                "wire_bytes": jnp.float32(egress),
            }
            return new_state, metrics

        return comm_step

    @classmethod
    def simulated(cls, w, stacked_params, **_ignored):
        raise ValueError(
            "sharded_fused needs a device mesh (use from_mesh); on a single "
            "host use the 'fused' engine -- identical math, dense W"
        )

    @classmethod
    def from_mesh(cls, mesh: Mesh, node_axes: Sequence[str], stacked_sds,
                  *, wire_dtype=None, axes_subset=None, scale_chunk: int = 512,
                  topk=None, impl: str = "pallas", w=None,
                  error_feedback: bool = True, difference_coding: bool = True,
                  self_weight=None, **_ignored):
        _reject_wire_dtype(wire_dtype)
        layout = pack_layout(stacked_sds, pad_to=scale_chunk)
        return cls(mesh, node_axes, layout, w=w, axes_subset=axes_subset,
                   self_weight=self_weight, scale_chunk=scale_chunk,
                   topk=topk, impl=impl, error_feedback=error_feedback,
                   difference_coding=difference_coding)
