"""FederationScope: WHICH parameter columns federate -- the sixth round axis.

The paper's gossip ships the WHOLE parameter vector every round, but
statistically heterogeneous federations (per-hospital label shift) do
better when each node keeps a PRIVATE slice -- its classification head --
and gossips only a shared backbone (Heterogeneous Federated Learning on
a Graph, arXiv:2209.08737; DeceFL, arXiv:2107.07171, likewise scopes
which weights are exchanged). A **FederationScope** maps the model's
pytree paths onto contiguous :class:`~repro.core.packing.FlatLayout`
column sub-ranges and completes the round decomposition:

    engine (WHAT moves) x schedule (WHEN) x topology (WHICH graph) x
    node program (WHO keeps up) x privacy (WHAT the wire reveals) x
    **scope (WHICH columns federate)**

Same registry / spec-string / manifest discipline as the other five
axes (``--fl-scope`` on every CLI, :func:`resolve_scope` at build time,
``scope.spec()`` recorded in checkpoint/snapshot manifests and refused
on mismatch). Registered scopes:

* ``full`` -- the legacy whole-buffer round, bit-identical to a scope-less
  build (the default);
* ``backbone[:private=<substr>]`` -- leaves whose "/"-joined tree path
  contains the pattern (default ``fc2``, the EHR MLP head) stay PRIVATE:
  their columns are never touched by gossip, while every other leaf's
  columns form the shared wire. This is the first axis that changes
  *which bytes exist on the wire*: the fused engines gather the shared
  columns into a contiguous scoped buffer, run the identical wire stage
  (difference coding, top-k, EF, quantization, collectives) on it, and
  scatter the mixed result back -- so ``flat_wire_bytes`` shrinks by the
  shared fraction and private slices stay bit-untouched;
* ``ranges:a-b,c-d,...`` -- explicit global column ranges (half-open,
  in flat-buffer coordinates) for layouts without meaningful tree paths;
* ``layerwise:freq=R[,head=<substr>]`` -- layer-wise gossip frequency:
  every column still ships every round (wire bytes unchanged -- the
  difference-coded recon stream must stay consistent), but the MIX of
  the head-matching columns is applied only every R-th round, through a
  traced round-counter gate (zero recompiles). ``freq=1`` degenerates to
  ``full``.

Scopes are static Python data: the column ranges are resolved against
the layout once at engine build, so the one-compiled-round invariant is
untouched -- a scoped round lowers to the same single pallas_call with a
narrower wire.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Type

import jax

from repro.core.packing import FlatLayout

__all__ = [
    "FederationScope",
    "FullScope",
    "BackboneScope",
    "RangesScope",
    "LayerwiseScope",
    "FULL",
    "register_scope",
    "get_scope",
    "scope_names",
    "parse_scope",
    "resolve_scope",
    "leaf_column_ranges",
    "merge_ranges",
    "complement_ranges",
]

Ranges = Tuple[Tuple[int, int], ...]


# --------------------------------------------------------------- helpers

def leaf_column_ranges(layout: FlatLayout) -> Tuple[Tuple[str, int, int], ...]:
    """``(tree_path, start, stop)`` per leaf, in pack order. Paths are
    "/"-joined key strings -- the SAME encoding snapshot headers use, so
    a pattern that selects a snapshot leaf selects the scope leaf."""
    dummy = jax.tree_util.tree_unflatten(
        layout.treedef, list(range(len(layout.leaves))))
    pairs = jax.tree_util.tree_flatten_with_path(dummy)[0]
    paths = [None] * len(layout.leaves)
    for path, idx in pairs:
        paths[idx] = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path)
    return tuple(
        (p, s.offset, s.offset + s.size)
        for p, s in zip(paths, layout.leaves)
    )


def merge_ranges(ranges) -> Ranges:
    """Sort + coalesce half-open ranges into a canonical disjoint tuple."""
    out = []
    for a, b in sorted((int(a), int(b)) for a, b in ranges):
        if b <= a:
            continue
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return tuple(out)


def complement_ranges(ranges: Ranges, total: int) -> Ranges:
    """The columns of ``[0, total)`` NOT covered by ``ranges`` (which must
    be merged/disjoint)."""
    out = []
    pos = 0
    for a, b in ranges:
        if a > pos:
            out.append((pos, a))
        pos = max(pos, b)
    if pos < total:
        out.append((pos, total))
    return tuple(out)


def _match_leaf_ranges(layout: FlatLayout, pattern: str, where: str):
    """(matching, non_matching) column ranges by path-substring; both
    sides must be non-empty or the scope is vacuous/total."""
    hit, miss = [], []
    for path, a, b in leaf_column_ranges(layout):
        (hit if pattern in path else miss).append((a, b))
    if not hit:
        paths = [p for p, _, _ in leaf_column_ranges(layout)]
        raise ValueError(
            f"{where}: pattern {pattern!r} matches no leaf path; "
            f"leaves are {paths!r}"
        )
    if not miss:
        raise ValueError(
            f"{where}: pattern {pattern!r} matches EVERY leaf -- nothing "
            "left to share; widen the pattern or use scope 'full'"
        )
    return merge_ranges(hit), merge_ranges(miss)


def _parse_knobs(body: str, where: str) -> Dict[str, str]:
    knobs: Dict[str, str] = {}
    if not body:
        return knobs
    for item in body.split(","):
        if "=" not in item:
            raise ValueError(
                f"{where}: knob {item!r} is not k=v (spec grammar is "
                "name:k=v,...)"
            )
        k, v = item.split("=", 1)
        knobs[k.strip()] = v.strip()
    return knobs


# ---------------------------------------------------------------- scopes

@dataclasses.dataclass(frozen=True)
class FederationScope:
    """Base contract of the sixth axis. A scope is frozen, hashable
    Python data; engines resolve it ONCE at build time against their
    :class:`FlatLayout` (``shared_ranges``), so the compiled round never
    re-derives anything per round (except the ``layerwise`` fire gate,
    a traced function of the checkpointed ``topo_round`` counter)."""

    name = "full"

    def spec(self) -> str:
        """Canonical spec string (round-trips through parse_scope);
        recorded in checkpoint/snapshot manifests."""
        return self.name

    @property
    def is_full(self) -> bool:
        """True when every column federates every round with un-gated
        mixing -- the engines' bit-identical legacy path."""
        return False

    @property
    def needs_round(self) -> bool:
        """True when the round counter must be threaded into the compiled
        round (the ``layerwise`` traced gate)."""
        return False

    def shared_ranges(self, layout: FlatLayout) -> Ranges:
        """Merged, disjoint global column ranges gossip operates on."""
        raise NotImplementedError

    def private_ranges(self, layout: FlatLayout) -> Ranges:
        """The complement: columns gossip must leave bit-untouched
        (structural padding included)."""
        return complement_ranges(self.shared_ranges(layout), layout.total)

    @classmethod
    def _parse(cls, body: str) -> "FederationScope":
        if body:
            raise ValueError(f"scope {cls.name!r} takes no knobs, got {body!r}")
        return cls()


_SCOPES: Dict[str, Type[FederationScope]] = {}


def register_scope(cls: Type[FederationScope]) -> Type[FederationScope]:
    """Class decorator: add a scope to the registry (the single source of
    truth behind every ``--fl-scope`` CLI and manifest restore)."""
    _SCOPES[cls.name] = cls
    return cls


def get_scope(name: str) -> Type[FederationScope]:
    try:
        return _SCOPES[name]
    except KeyError:
        raise ValueError(
            f"unknown federation scope {name!r}; registered scopes: "
            f"{', '.join(scope_names())}"
        ) from None


def scope_names():
    return sorted(_SCOPES)


@register_scope
@dataclasses.dataclass(frozen=True)
class FullScope(FederationScope):
    """The legacy whole-buffer round: every column federates."""

    name = "full"

    @property
    def is_full(self) -> bool:
        return True

    def shared_ranges(self, layout: FlatLayout) -> Ranges:
        return ((0, layout.total),)


@register_scope
@dataclasses.dataclass(frozen=True)
class BackboneScope(FederationScope):
    """Per-node private heads + a gossiped shared backbone: leaves whose
    tree path contains ``private`` keep their columns out of the wire."""

    name = "backbone"
    #: path substring selecting the PRIVATE (head) leaves; "fc2" is the
    #: EHR MLP's classification head
    private: str = "fc2"

    def __post_init__(self):
        if not self.private:
            raise ValueError("backbone scope needs a non-empty private= "
                             "pattern (or use scope 'full')")

    def spec(self) -> str:
        if self.private == "fc2":
            return self.name
        return f"{self.name}:private={self.private}"

    def shared_ranges(self, layout: FlatLayout) -> Ranges:
        _, shared = _match_leaf_ranges(layout, self.private,
                                       f"scope {self.spec()!r}")
        return shared

    @classmethod
    def _parse(cls, body: str) -> "BackboneScope":
        knobs = _parse_knobs(body, "scope 'backbone'")
        private = knobs.pop("private", "fc2")
        if knobs:
            raise ValueError(
                f"scope 'backbone': unknown knobs {sorted(knobs)!r} "
                "(takes private=<path substring>)"
            )
        return cls(private=private)


@register_scope
@dataclasses.dataclass(frozen=True)
class RangesScope(FederationScope):
    """Explicit global column ranges (half-open, flat-buffer coordinates)
    -- for layouts whose tree paths carry no layer semantics."""

    name = "ranges"
    ranges: Ranges = ()

    def __post_init__(self):
        if not self.ranges:
            raise ValueError("ranges scope needs at least one a-b range")
        merged = merge_ranges(self.ranges)
        if merged != tuple(self.ranges):
            raise ValueError(
                f"ranges must be sorted, disjoint, non-empty; "
                f"got {self.ranges!r} (canonical: {merged!r})"
            )

    def spec(self) -> str:
        return self.name + ":" + ",".join(f"{a}-{b}" for a, b in self.ranges)

    def shared_ranges(self, layout: FlatLayout) -> Ranges:
        if self.ranges[-1][1] > layout.total:
            raise ValueError(
                f"scope {self.spec()!r} exceeds layout.total="
                f"{layout.total}"
            )
        if self.ranges == ((0, layout.total),):
            raise ValueError(
                f"scope {self.spec()!r} covers the whole buffer; "
                "use scope 'full' (the bit-identical fast path)"
            )
        return self.ranges

    @classmethod
    def _parse(cls, body: str) -> "RangesScope":
        if not body:
            raise ValueError("scope 'ranges' needs a body: ranges:a-b,c-d")
        parsed = []
        for item in body.split(","):
            a, sep, b = item.partition("-")
            if not sep:
                raise ValueError(
                    f"scope 'ranges': {item!r} is not a-b (half-open "
                    "column range)"
                )
            parsed.append((int(a), int(b)))
        return cls(ranges=tuple(parsed))


@register_scope
@dataclasses.dataclass(frozen=True)
class LayerwiseScope(FederationScope):
    """Layer-wise gossip frequency: head-matching columns MIX only every
    ``freq``-th round (rounds freq, 2*freq, ...), gated by a traced
    function of the checkpointed round counter -- zero recompiles.

    Unlike ``backbone``, every column still SHIPS every round: the
    difference-coded wire advances each receiver's reconstruction of the
    sender's state, and that stream must stay consistent whether or not
    the receiver applies the mix this round. So ``layerwise`` keeps the
    full wire (bytes unchanged) and gates only what the mix writes back
    -- a federation-frequency knob, not a wire-byte knob (that is what
    ``backbone`` is for). ``freq=1`` is exactly ``full``.
    """

    name = "layerwise"
    freq: int = 4
    #: path substring selecting the gated (head-adjacent) leaves
    head: str = "fc2"

    def __post_init__(self):
        if self.freq < 1:
            raise ValueError(f"layerwise freq={self.freq} must be >= 1")
        if not self.head:
            raise ValueError("layerwise scope needs a non-empty head= "
                             "pattern")

    def spec(self) -> str:
        s = f"{self.name}:freq={self.freq}"
        if self.head != "fc2":
            s += f",head={self.head}"
        return s

    @property
    def needs_round(self) -> bool:
        return True

    def shared_ranges(self, layout: FlatLayout) -> Ranges:
        # the WIRE is full-width: recon consistency needs every column's
        # difference-coded stream to advance every round
        return ((0, layout.total),)

    def gate_ranges(self, layout: FlatLayout) -> Ranges:
        """Columns whose MIX fires only every freq-th round."""
        gated, _ = _match_leaf_ranges(layout, self.head,
                                      f"scope {self.spec()!r}")
        return gated

    def fire(self, topo_round):
        """Traced boolean gate: True on rounds freq, 2*freq, ...
        (``topo_round`` counts completed rounds, so the round being
        computed is ``topo_round + 1``). The engines SELECT on it
        (exact where), so a non-firing round leaves the gated columns
        bit-equal to a never-gossiped local trajectory."""
        return (topo_round + 1) % self.freq == 0

    @classmethod
    def _parse(cls, body: str) -> "LayerwiseScope":
        knobs = _parse_knobs(body, "scope 'layerwise'")
        if "freq" not in knobs:
            raise ValueError("scope 'layerwise' needs freq=R")
        freq = int(knobs.pop("freq"))
        head = knobs.pop("head", "fc2")
        if knobs:
            raise ValueError(
                f"scope 'layerwise': unknown knobs {sorted(knobs)!r} "
                "(takes freq=R, head=<path substring>)"
            )
        return cls(freq=freq, head=head)


#: the default whole-buffer scope every engine starts from
FULL = FullScope()


def parse_scope(spec: str) -> FederationScope:
    """Parse a ``--fl-scope`` spec string through the registry."""
    name, _, body = spec.partition(":")
    return get_scope(name.strip())._parse(body.strip())


def resolve_scope(spec: Optional[object]) -> FederationScope:
    """None -> FULL; spec string -> parsed scope; scope -> itself."""
    if spec is None:
        return FULL
    if isinstance(spec, FederationScope):
        return spec
    if isinstance(spec, str):
        return parse_scope(spec)
    raise TypeError(
        f"fl_scope must be None, a spec string, or a FederationScope; "
        f"got {type(spec).__name__}"
    )
