"""Node heterogeneity: per-node compute/communication faults as the
round machinery's FOURTH axis.

The paper's round is lockstep: every hospital runs exactly Q local steps
and its payload arrives on time. Production decentralized FL does not
(the straggler/staleness catalog of the FL communication survey, arXiv
2405.20431): nodes run at different speeds, payloads are late or lost.
This module supplies a :class:`NodeProgram` -- a pluggable, registered
object exactly like ``TopologyProgram`` (``core.dynamics``) -- mapping
(round counter, RNG key) to per-node TRACED operands of the ONE compiled
round function:

  * a **compute rate**: which of the round's ``q - 1`` local-step scan
    iterations each node actually executes (:meth:`step_gate`, a masked
    scan -- a slow node's skipped iteration costs zero gradient motion,
    not a recompile);
  * a **payload gate**: whether each node's wire payload lands this
    round (:meth:`wire_gate` -- late and dropped payloads are the same
    event at round granularity: the receiver cannot use what has not
    arrived).

Graceful degradation is W-row renormalization, shared with topology
churn: a missing payload masks BOTH directions of every edge at the node
(the symmetric outer-product gate ``up_i * up_j``), and the lost weight
folds into the two self-loops -- every realized W_r stays symmetric
doubly stochastic (property-tested with hypothesis over arbitrary drop
masks), so consensus is unchanged in expectation and the convergence
theory keeps holding with a spectral gap shrunk by ~uptime**2
(``schedules.robust_alpha_scale`` shrinks alpha accordingly).

The wire itself still crosses EVERY round -- the gate only zeroes the
mixing contribution. That is deliberate: the difference-coded recon
contract requires every receiver to fold every dq it is sent (skipping
one would desynchronize recon), and it keeps the fault axis free of
extra collectives and recompiles (jaxpr-asserted, like topology churn).

Registered programs (the ``--fl-node-program`` spec strings):

    homogeneous                the lockstep default (static; engines keep
                               their historical fast path)
    stragglers:frac=,rate=,drop=,seed=
                               per round, each node is slow i.i.d. with
                               probability ``frac``; a slow node runs
                               only ``ceil(rate * (q-1))`` of its local
                               steps and -- when ``drop=1`` (default) --
                               its payload misses the round
    slow_nodes:frac=,rate=,seed=
                               a FIXED random subset of ``ceil(frac*n)``
                               nodes is permanently slow (runs
                               ``ceil(rate * (q-1))`` local steps);
                               payloads always arrive -- pure compute
                               heterogeneity
    payload_drop:p=,seed=      every node's payload independently lost
                               with probability ``p`` per round; full
                               compute -- pure communication faults

Randomness uses the same counter-based splitmix32 hash as the topology
programs (partition-invariant; the checkpointed ``node_key`` in
``FLState.comm`` seeds it), on streams 11-13 (disjoint from topology's
1-4).
"""

from __future__ import annotations

import abc
import math
from typing import Any, ClassVar, Dict, Tuple, Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dynamics import _parse_value, _u01

__all__ = [
    "NodeProgram",
    "HomogeneousProgram",
    "StragglerProgram",
    "SlowNodesProgram",
    "PayloadDropProgram",
    "HOMOGENEOUS",
    "compose_node_gate",
    "register_node_program",
    "get_node_program",
    "node_program_names",
    "parse_node_program",
    "resolve_node_program",
]


def compose_node_gate(
    w_off_r: jnp.ndarray, w_diag_r: jnp.ndarray, up: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fold a per-node payload gate ``up (n,) {0,1}`` into a round's
    mixing matrix: an edge needs BOTH endpoints' payloads (the symmetric
    outer product), and the dropped weight refolds into the self-loops --
    so if ``w_off_r + diag(w_diag_r)`` is symmetric doubly stochastic,
    the composed matrix is too (hypothesis property test over arbitrary
    drop masks in tests/test_heterogeneity.py). Composes with the
    topology gate multiplicatively, in either order."""
    w_off = w_off_r * (up[:, None] * up[None, :])
    w_diag = 1.0 - jnp.sum(w_off, axis=1)
    return w_off, w_diag


class NodeProgram(abc.ABC):
    """Per-round per-node compute/communication fault program.

    Life cycle mirrors :class:`~repro.core.dynamics.TopologyProgram`:
    construct with knobs (or :func:`parse_node_program` a CLI spec), an
    engine ``bind(n_nodes)``s it at build time, then :meth:`step_gate`
    and :meth:`wire_gate` are traced per-round functions of the round
    counter and the checkpointed ``node_key``."""

    #: registry key; first token of the CLI spec string
    name: ClassVar[str] = "abstract"
    #: True only for :class:`HomogeneousProgram` -- engines keep their
    #: historical lockstep path (no node_key, no masked scan)
    is_static: ClassVar[bool] = False
    #: False when every node always runs all q-1 local steps -- lets the
    #: round builder skip the masked scan entirely (payload-only faults)
    heterogeneous_compute: ClassVar[bool] = True
    #: True when :meth:`wire_k_gate` actually modulates per-node top-k
    #: (slow uplink -> sparser wire); engines without a per-node k knob
    #: refuse such programs at build time (sharded_fused supports it)
    heterogeneous_wire_k: ClassVar[bool] = False

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._n: int = 0

    @property
    def bound(self) -> bool:
        return self._n > 0

    def bind(self, n_nodes: int) -> "NodeProgram":
        n_nodes = int(n_nodes)
        if n_nodes < 1:
            raise ValueError(f"n_nodes={n_nodes} must be >= 1")
        if self._n and self._n != n_nodes:
            raise ValueError(
                f"node program {self.spec()!r} is already bound to "
                f"{self._n} nodes; build a fresh instance"
            )
        self._n = n_nodes
        self._bind_aux()
        return self

    def _bind_aux(self) -> None:
        """Subclass hook: precompute static auxiliaries from n_nodes."""

    def _require_bound(self) -> None:
        if not self._n:
            raise ValueError(
                f"node program {self.spec()!r} is unbound; engines bind "
                "it at build time (program.bind(n_nodes))"
            )

    @property
    def n_nodes(self) -> int:
        self._require_bound()
        return self._n

    # -- the per-round contract ---------------------------------------------

    def step_gate(
        self, r: jnp.ndarray, base_key: jnp.ndarray, q: int
    ) -> jnp.ndarray:
        """Traced ``(max(q - 1, 1), n)`` fp32 {0,1} mask over the round's
        local-step scan iterations (row i gates iteration i for every
        node). All-ones by default. The comm-round update itself is
        never masked -- a fully stalled node still mixes (it just moved
        nothing)."""
        self._require_bound()
        return jnp.ones((max(int(q) - 1, 1), self._n), jnp.float32)

    def wire_gate(
        self, r: jnp.ndarray, base_key: jnp.ndarray
    ) -> jnp.ndarray:
        """Traced ``(n,)`` fp32 {0,1}: 1 where the node's payload lands
        this round. All-ones by default."""
        self._require_bound()
        return jnp.ones((self._n,), jnp.float32)

    def wire_k_gate(
        self, r: jnp.ndarray, base_key: jnp.ndarray
    ) -> jnp.ndarray:
        """Traced ``(n,)`` fp32 fraction of the engine's base top-k each
        node ships this round (engines clip ``round(frac * topk)`` to
        ``[1, topk]``). All-ones by default; only read when
        ``heterogeneous_wire_k`` is True."""
        self._require_bound()
        return jnp.ones((self._n,), jnp.float32)

    def expected_uptime(self) -> float:
        """Stationary payload-arrival probability in [0, 1] -- feeds the
        staleness/churn-aware step-size controller."""
        return 1.0

    def init_key(self) -> np.ndarray:
        """The program's base RNG key -- carried in ``FLState.comm`` as
        ``node_key`` (checkpointed: restores replay the identical fault
        sequence)."""
        # Pure numpy (threefry PRNGKey layout) so it is safe under jit.
        s = int(self.seed) ^ 0x5EED
        return np.array([(s >> 32) & 0xFFFFFFFF, s & 0xFFFFFFFF], np.uint32)

    # -- spec round trip ----------------------------------------------------

    def params(self) -> Dict[str, Any]:
        return {"seed": self.seed}

    def spec(self) -> str:
        """Canonical ``name:k=v,...`` string (checkpoint manifest record
        and ``--fl-node-program`` syntax); floats at repr precision so
        ``parse_node_program(spec()).spec() == spec()`` exactly."""
        p = self.params()
        if not p:
            return self.name
        return self.name + ":" + ",".join(
            f"{k}={v!r}" if isinstance(v, float) else f"{k}={v}"
            for k, v in sorted(p.items())
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging sugar
        return f"<NodeProgram {self.spec()}>"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_NODE_PROGRAMS: Dict[str, Type[NodeProgram]] = {}


def register_node_program(cls: Type[NodeProgram]) -> Type[NodeProgram]:
    if cls.name in _NODE_PROGRAMS:
        raise ValueError(f"duplicate node program name {cls.name!r}")
    _NODE_PROGRAMS[cls.name] = cls
    return cls


def get_node_program(name: str) -> Type[NodeProgram]:
    try:
        return _NODE_PROGRAMS[name]
    except KeyError:
        raise ValueError(
            f"unknown node program {name!r}; registered: "
            f"{node_program_names()}"
        ) from None


def node_program_names() -> Tuple[str, ...]:
    return tuple(sorted(_NODE_PROGRAMS))


def parse_node_program(spec: str) -> NodeProgram:
    """Build a node program from a ``name[:k=v,...]`` spec string."""
    name, _, rest = spec.partition(":")
    cls = get_node_program(name.strip())
    kwargs = {}
    if rest.strip():
        for item in rest.split(","):
            k, eq, v = item.partition("=")
            if not eq:
                raise ValueError(
                    f"bad node program knob {item!r} in {spec!r}; use k=v"
                )
            kwargs[k.strip()] = _parse_value(v.strip())
    try:
        return cls(**kwargs)
    except TypeError as e:
        raise ValueError(f"bad knobs for node program {name!r}: {e}") from None


def resolve_node_program(
    program: Union[None, str, NodeProgram]
) -> NodeProgram:
    """Spec string, instance, or None (the homogeneous default -- a
    fresh instance, since instances bind to one node count)."""
    if program is None:
        return HomogeneousProgram()
    if isinstance(program, NodeProgram):
        return program
    return parse_node_program(program)


# ---------------------------------------------------------------------------
# Programs
# ---------------------------------------------------------------------------


@register_node_program
class HomogeneousProgram(NodeProgram):
    """The lockstep default: every node runs every local step and every
    payload arrives. Engines detect ``is_static`` and keep the
    historical path (no node_key counter, no masked scan)."""

    name = "homogeneous"
    is_static = True
    heterogeneous_compute = False

    def __init__(self):
        super().__init__(seed=0)

    def bind(self, n_nodes: int) -> "NodeProgram":
        # no per-binding state: the shared HOMOGENEOUS sentinel may
        # default any number of engines over different node counts
        self._n = 0
        return super().bind(n_nodes)

    def params(self) -> Dict[str, Any]:
        return {}


#: shared unbound sentinel for "no heterogeneity" default arguments
HOMOGENEOUS = HomogeneousProgram()


def _slow_steps(rate: float, q: int) -> int:
    """Local steps a slow node completes out of ``q - 1``."""
    return min(max(int(math.ceil(rate * (q - 1))), 0), max(q - 1, 0))


@register_node_program
class StragglerProgram(NodeProgram):
    """Transient stragglers: per round, each node is slow i.i.d. with
    probability ``frac``. A slow node completes only
    ``ceil(rate * (q-1))`` of the round's local steps and, when
    ``drop=1`` (the default), its payload misses the round -- the
    late-arrival regime: compute AND communication degrade together."""

    name = "stragglers"

    def __init__(self, frac: float = 0.25, rate: float = 0.5,
                 drop: int = 1, seed: int = 0):
        super().__init__(seed=seed)
        self.frac = float(frac)
        self.rate = float(rate)
        self.drop = int(bool(drop))
        if not (0.0 <= self.frac <= 1.0):
            raise ValueError(f"straggler fraction frac={frac} not in [0, 1]")
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"straggler compute rate={rate} not in [0, 1]")

    def _slow(self, r, base_key):
        u = _u01(base_key, r, jnp.arange(self._n, dtype=jnp.uint32),
                 stream=11)
        return (u < self.frac).astype(jnp.float32)  # 1 = slow

    def step_gate(self, r, base_key, q):
        self._require_bound()
        steps = max(int(q) - 1, 1)
        slow = self._slow(r, base_key)  # (n,)
        done = _slow_steps(self.rate, int(q))
        # a slow node runs the FIRST `done` iterations, then idles
        runs = jnp.where(slow > 0.5, jnp.float32(done), jnp.float32(steps))
        i = jnp.arange(steps, dtype=jnp.float32)[:, None]
        return (i < runs[None, :]).astype(jnp.float32)

    def wire_gate(self, r, base_key):
        self._require_bound()
        if not self.drop:
            return jnp.ones((self._n,), jnp.float32)
        return 1.0 - self._slow(r, base_key)

    def expected_uptime(self) -> float:
        return 1.0 - self.frac if self.drop else 1.0

    def params(self) -> Dict[str, Any]:
        return {"drop": self.drop, "frac": self.frac, "rate": self.rate,
                "seed": self.seed}


@register_node_program
class SlowNodesProgram(NodeProgram):
    """Persistent compute heterogeneity: a FIXED random subset of
    ``ceil(frac * n)`` nodes (drawn once from the seed at bind) is slow
    every round, completing ``ceil(rate * (q-1))`` local steps; payloads
    always arrive on time. Isolates the objective-inconsistency effect
    of unequal local work from communication faults."""

    name = "slow_nodes"

    def __init__(self, frac: float = 0.25, rate: float = 0.5, seed: int = 0):
        super().__init__(seed=seed)
        self.frac = float(frac)
        self.rate = float(rate)
        if not (0.0 <= self.frac <= 1.0):
            raise ValueError(f"slow fraction frac={frac} not in [0, 1]")
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"slow compute rate={rate} not in [0, 1]")
        self._slow_mask: np.ndarray | None = None

    def _bind_aux(self) -> None:
        rng = np.random.default_rng(self.seed)
        k = int(math.ceil(self.frac * self._n))
        mask = np.zeros((self._n,), np.float32)
        mask[rng.permutation(self._n)[:k]] = 1.0
        self._slow_mask = mask

    def step_gate(self, r, base_key, q):
        self._require_bound()
        steps = max(int(q) - 1, 1)
        done = _slow_steps(self.rate, int(q))
        slow = jnp.asarray(self._slow_mask)
        runs = jnp.where(slow > 0.5, jnp.float32(done), jnp.float32(steps))
        i = jnp.arange(steps, dtype=jnp.float32)[:, None]
        return (i < runs[None, :]).astype(jnp.float32)

    def params(self) -> Dict[str, Any]:
        return {"frac": self.frac, "rate": self.rate, "seed": self.seed}


@register_node_program
class SlowUplinkProgram(NodeProgram):
    """Persistent COMMUNICATION heterogeneity: a fixed random subset of
    ``ceil(frac * n)`` nodes (drawn once from the seed at bind, like
    :class:`SlowNodesProgram`) sits behind a slow uplink and ships only
    ``round(k_scale * topk)`` wire entries per chunk every round --
    compute and payload arrival are unaffected, only the wire SPARSITY
    drops. Engines with a per-node k knob (``sharded_fused``) truncate
    the kernel's top-k payload to the program's traced k_i and roll the
    dropped entries back into the EF residual, so a slow node's updates
    arrive late-but-intact rather than lost; the per-node wire-byte
    accounting rides the round metrics (``wire_bytes_effective``)."""

    name = "slow_uplink"
    heterogeneous_compute = False
    heterogeneous_wire_k = True

    def __init__(self, frac: float = 0.25, k_scale: float = 0.25,
                 seed: int = 0):
        super().__init__(seed=seed)
        self.frac = float(frac)
        self.k_scale = float(k_scale)
        if not (0.0 <= self.frac <= 1.0):
            raise ValueError(f"slow fraction frac={frac} not in [0, 1]")
        if not (0.0 < self.k_scale <= 1.0):
            raise ValueError(
                f"uplink k scale k_scale={k_scale} not in (0, 1]"
            )
        self._slow_mask: np.ndarray | None = None

    def _bind_aux(self) -> None:
        rng = np.random.default_rng(self.seed)
        k = int(math.ceil(self.frac * self._n))
        mask = np.zeros((self._n,), np.float32)
        mask[rng.permutation(self._n)[:k]] = 1.0
        self._slow_mask = mask

    def wire_k_gate(self, r, base_key):
        self._require_bound()
        slow = jnp.asarray(self._slow_mask)
        return jnp.where(
            slow > 0.5, jnp.float32(self.k_scale), jnp.float32(1.0)
        )

    def params(self) -> Dict[str, Any]:
        return {"frac": self.frac, "k_scale": self.k_scale,
                "seed": self.seed}


@register_node_program
class PayloadDropProgram(NodeProgram):
    """Pure communication faults: every node's payload is independently
    LOST with probability ``p`` per round (both directions of all its
    edges renormalize away); compute is unaffected."""

    name = "payload_drop"
    heterogeneous_compute = False

    def __init__(self, p: float = 0.1, seed: int = 0):
        super().__init__(seed=seed)
        self.p = float(p)
        if not (0.0 <= self.p < 1.0):
            raise ValueError(f"payload drop probability p={p} not in [0, 1)")

    def wire_gate(self, r, base_key):
        self._require_bound()
        u = _u01(base_key, r, jnp.arange(self._n, dtype=jnp.uint32),
                 stream=13)
        return (u >= self.p).astype(jnp.float32)

    def expected_uptime(self) -> float:
        return 1.0 - self.p

    def params(self) -> Dict[str, Any]:
        return {"p": self.p, "seed": self.seed}
