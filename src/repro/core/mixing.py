"""Gossip (mixing) backends: the communication primitive of decentralized FL.

The paper's algorithms interleave local SGD/GT steps with a *mixing* step

    theta_i <- sum_{j in N_i} W_ij theta_j

over the node graph. This module provides three interchangeable backends
operating on **node-stacked pytrees** (every leaf has a leading ``nodes``
axis):

1. ``make_dense_gossip(w)`` -- simulated: ``theta' = W @ Theta`` as ONE
   matmul over the flat-packed state. Works on a single device (CPU-scale
   runs, the EHR reproduction, and the oracle for equivalence tests).
   Supports ANY mixing matrix.

2. ``make_mesh_gossip(mesh, node_axes, specs)`` -- TPU-native: a
   ``shard_map`` over the node mesh axes implementing the ring/torus
   circulant W with ``jax.lax.ppermute`` -- nearest-neighbor ICI transfers,
   the cheapest collective on a TPU torus. The local shards are packed
   into ONE contiguous payload, so a round issues exactly one ppermute per
   graph direction **total** (independent of leaf count); the ``model``-axis
   shards of each leaf pass through untouched because mixing is elementwise
   across nodes.

3. ``make_allgather_gossip(mesh, node_axes, specs, w)`` -- TPU fallback for
   ARBITRARY graphs: ONE all-gather of the packed node payload over the
   node axes, contracted with the W row. O(N x) more collective bytes than
   ppermute gossip -- kept for generality and as the roofline
   counter-example.

**Flat-buffer engine.** All backends route through ``core.packing``: the
node-stacked pytree is collapsed into a single ``(nodes, total_params)``
buffer (pack/unpack are reshape+concat/slice, fused away by XLA), turning
a round from O(n_leaves) collectives/matmuls into O(1). The historical
leaf-by-leaf implementations are kept as ``*_per_leaf`` references -- the
equivalence oracles and the benchmark baseline (``benchmarks/
gossip_bench.py`` measures the speedup; ``tests/test_gossip_flat.py``
property-tests flat == per-leaf).

Wire-byte accounting: a full-precision flat round moves ``total_params *
itemsize(wire_dtype)`` bytes per direction per node; see
``core.compression`` / ``core.packing.flat_wire_bytes`` for the int8 path.

All backends support a ``wire_dtype`` (e.g. ``jnp.bfloat16``): payloads are
rounded to the wire dtype before communication and the weighted sum is
accumulated in fp32. This is the beyond-paper "bf16 gossip" optimization
(halves the collective term); ``wire_dtype=None`` is the paper-faithful
full-precision wire.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.packing import pack, unpack

try:  # jax >= 0.6
    _shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x: replication inference cannot see through
    # the pack (concat/slice) ops, so disable the static check there
    from functools import partial as _partial

    from jax.experimental.shard_map import shard_map as _sm_impl

    _shard_map = _partial(_sm_impl, check_rep=False)

PyTree = Any
GossipFn = Callable[[PyTree], PyTree]
FlatMixFn = Callable[[jnp.ndarray], jnp.ndarray]

__all__ = [
    "make_dense_gossip",
    "make_dense_flat_mix",
    "make_dense_gossip_per_leaf",
    "make_mesh_gossip",
    "make_mesh_flat_mix",
    "make_mesh_gossip_per_leaf",
    "make_allgather_gossip",
    "make_allgather_gossip_per_leaf",
    "make_mean_consensus",
    "mesh_gossip_directions",
    "mesh_gossip_dense_equivalent",
]


def _wire(x: jnp.ndarray, wire_dtype) -> jnp.ndarray:
    """Round a payload to the wire dtype (simulating the comm precision)."""
    if wire_dtype is None:
        return x
    return x.astype(wire_dtype).astype(x.dtype)


def _split_w(w: np.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(diag, off-diagonal) of W as fp32 device constants."""
    w = np.asarray(w, dtype=np.float64)
    w_self = jnp.asarray(np.diag(w), dtype=jnp.float32)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), dtype=jnp.float32)
    return w_self, w_off


# ---------------------------------------------------------------------------
# 1. Dense-W simulated backend (any graph, any device count)
# ---------------------------------------------------------------------------


def make_dense_flat_mix(w: np.ndarray, wire_dtype=None) -> FlatMixFn:
    """Flat-native dense mixing: ONE ``W @ Theta`` matmul on the packed
    ``(nodes, total)`` buffer.

    The diagonal (self) term is kept at full precision; only off-diagonal
    contributions pass through the wire dtype, mirroring what a real
    transport would quantize.
    """
    w_self, w_off = _split_w(w)
    n = w_self.shape[0]

    def mix(flat: jnp.ndarray) -> jnp.ndarray:
        if flat.ndim != 2 or flat.shape[0] != n:
            raise ValueError(f"flat buffer {flat.shape} != ({n}, total)")
        xf = flat.astype(jnp.float32)
        sent = _wire(xf, wire_dtype)
        return (w_off @ sent + w_self[:, None] * xf).astype(flat.dtype)

    return mix


def make_dense_gossip(w: np.ndarray, wire_dtype=None) -> GossipFn:
    """theta' = W @ Theta over the leading node axis of every leaf.

    Packs the pytree into one ``(nodes, total)`` buffer and issues a single
    matmul regardless of leaf count (the per-leaf path is
    :func:`make_dense_gossip_per_leaf`)."""
    mix = make_dense_flat_mix(w, wire_dtype)

    def gossip(tree: PyTree) -> PyTree:
        flat, layout = pack(tree)
        return unpack(mix(flat), layout)

    return gossip


def make_dense_gossip_per_leaf(w: np.ndarray, wire_dtype=None) -> GossipFn:
    """Leaf-by-leaf reference implementation: one einsum per leaf per round.

    Kept as the equivalence oracle for the flat engine and the benchmark
    baseline; O(n_leaves) dispatches -- do not use on the hot path."""
    w_self, w_off = _split_w(w)
    n = w_self.shape[0]

    def mix_leaf(x: jnp.ndarray) -> jnp.ndarray:
        if x.shape[0] != n:
            raise ValueError(f"leaf leading axis {x.shape[0]} != n_nodes {n}")
        flat = x.reshape(n, -1)
        sent = _wire(flat, wire_dtype).astype(jnp.float32)
        mixed = w_off @ sent + w_self[:, None] * flat.astype(jnp.float32)
        return mixed.astype(x.dtype).reshape(x.shape)

    return lambda tree: jax.tree_util.tree_map(mix_leaf, tree)


def make_mean_consensus(n: int) -> GossipFn:
    """W = (1/N) 1 1^T: exact averaging. This is the fictitious fusion
    center / FedAvg-server mixing (and the limit of infinitely many gossip
    rounds)."""
    return make_dense_gossip(np.full((n, n), 1.0 / n))


# ---------------------------------------------------------------------------
# 2. Mesh (ring/torus) ppermute backend -- the TPU-native path
# ---------------------------------------------------------------------------


def mesh_gossip_directions(
    axis_sizes: Dict[str, int], self_weight: Optional[float] = None
) -> Tuple[float, Tuple[Tuple[str, int, float], ...]]:
    """Directions of the circulant torus W over the given node axes.

    Returns (w_self, ((axis_name, shift, weight), ...)). An axis of size 2
    contributes ONE direction (its +1 and -1 neighbors coincide); size 1
    axes contribute none; larger axes contribute +/-1.
    """
    dirs = []
    for name, size in axis_sizes.items():
        if size == 2:
            dirs.append((name, 1))
        elif size > 2:
            dirs.append((name, 1))
            dirs.append((name, -1))
    if not dirs:
        return 1.0, ()
    w_self = 1.0 / (len(dirs) + 1) if self_weight is None else float(self_weight)
    if not (0.0 < w_self <= 1.0):
        raise ValueError("self_weight must be in (0, 1]")
    share = (1.0 - w_self) / len(dirs)
    return w_self, tuple((name, shift, share) for name, shift in dirs)


def mesh_gossip_dense_equivalent(
    axis_sizes: Dict[str, int],
    self_weight: Optional[float] = None,
    axes_subset: Optional[Sequence[str]] = None,
) -> np.ndarray:
    """The dense W the ppermute backend realizes (row-major node order).

    Used as the oracle in sharded-vs-simulated equivalence tests, as the
    compile-time W of the fused engine's mesh build, and to check
    Assumption 1 for the production topology. ``axes_subset`` restricts
    the mixing directions to those axes (hierarchical gossip: the other
    axes contribute no edges, so e.g. ("data",) on a (pod, data) mesh
    yields the intra-pod block-diagonal W).
    """
    names = list(axis_sizes)
    sizes = [axis_sizes[k] for k in names]
    n = int(np.prod(sizes))
    active = dict(axis_sizes)
    if axes_subset is not None:
        for a in axes_subset:
            if a not in axis_sizes:
                raise ValueError(f"axes_subset {axes_subset} not in {names}")
        active = {a: axis_sizes[a] for a in axes_subset}
    w_self, dirs = mesh_gossip_directions(active, self_weight)
    w = np.eye(n) * w_self if dirs else np.eye(n)
    idx = np.arange(n).reshape(sizes)
    for name, shift, weight in dirs:
        ax = names.index(name)
        # receiving from the node `shift` positions back along axis `ax`
        src = np.roll(idx, shift, axis=ax).reshape(-1)
        for dst_node, src_node in enumerate(src.tolist()):
            w[dst_node, src_node] += weight
    return w


def _mesh_dirs(mesh, node_axes, axes_subset, self_weight):
    node_axes = tuple(node_axes)
    active = tuple(axes_subset) if axes_subset is not None else node_axes
    for a in active:
        if a not in node_axes:
            raise ValueError(f"axes_subset {active} not within node_axes {node_axes}")
    axis_sizes = {a: mesh.shape[a] for a in active}
    return mesh_gossip_directions(axis_sizes, self_weight)


def make_mesh_gossip(
    mesh: Mesh,
    node_axes: Sequence[str],
    specs: PyTree,
    self_weight: Optional[float] = None,
    wire_dtype=None,
    axes_subset: Optional[Sequence[str]] = None,
) -> GossipFn:
    """Ring/torus gossip via ppermute inside a shard_map.

    The local shards of every leaf are packed into ONE contiguous fp32
    buffer inside the shard_map body, so the compiled round contains
    exactly one ``collective-permute`` per torus direction no matter how
    many leaves the state has (asserted against the compiled HLO in
    tests/test_gossip_flat.py). With a narrow ``wire_dtype`` the ENTIRE
    neighbor path stays in that dtype -- payload, permute, weighting -- so
    no convert exists for XLA's simplifier to hoist across the permute
    (which would silently re-widen the wire); the self term and the final
    accumulation stay in fp32.

    Args:
      mesh: the device mesh (must contain every axis in ``specs``).
      node_axes: mesh axes enumerating FL nodes, e.g. ("data",) or
        ("pod", "data"). Every leaf's spec must shard its leading axis over
        exactly these (``P((*node_axes,), ...)``).
      specs: pytree of PartitionSpec matching the state pytree.
      self_weight: W_ii; default 1/(ndirs+1) (1/3 ring, 1/5 torus).
      wire_dtype: payload dtype on the wire (None = fp32).
      axes_subset: if given, gossip ONLY along these node axes (the others
        contribute no direction). This powers *hierarchical gossip*: mix
        over the cheap intra-pod "data" links every round and over the
        expensive inter-pod links less often.
    """
    w_self, dirs = _mesh_dirs(mesh, node_axes, axes_subset, self_weight)

    def body(tree: PyTree) -> PyTree:
        flat, layout = pack(tree)  # local shards -> one (local_nodes, T) buffer
        wire = wire_dtype or flat.dtype
        payload = flat.astype(wire)
        acc = flat.astype(jnp.float32) * w_self
        for axis_name, shift, weight in dirs:
            n = mesh.shape[axis_name]
            perm = [(i, (i + shift) % n) for i in range(n)]
            recv = jax.lax.ppermute(payload, axis_name, perm)
            acc = acc + (recv * jnp.asarray(weight, wire)).astype(jnp.float32)
        return unpack(acc, layout)

    sm = _shard_map(body, mesh=mesh, in_specs=(specs,), out_specs=specs)
    return lambda tree: sm(tree)


def make_mesh_flat_mix(
    mesh: Mesh,
    node_axes: Sequence[str],
    self_weight: Optional[float] = None,
    wire_dtype=None,
    axes_subset: Optional[Sequence[str]] = None,
) -> FlatMixFn:
    """Flat-native ring/torus gossip: ppermute directly on the packed
    ``(nodes, total)`` buffer, sharded ``P(node_axes, None)``.

    The mesh counterpart of :func:`make_dense_flat_mix` for the flat
    engine (``make_fl_round(engine=FlatEngine(...))``): the state
    ALREADY lives flat, so the
    shard_map body skips the per-call pack/unpack of :func:`make_mesh_gossip`
    and is exactly one ppermute per torus direction. Same wire-dtype
    semantics as the tree backend (the whole neighbor path stays in
    ``wire_dtype``; self term and accumulation in fp32).
    """
    w_self, dirs = _mesh_dirs(mesh, node_axes, axes_subset, self_weight)
    spec = P(tuple(node_axes), None)

    def body(flat: jnp.ndarray) -> jnp.ndarray:
        wire = wire_dtype or flat.dtype
        payload = flat.astype(wire)
        acc = flat.astype(jnp.float32) * w_self
        for axis_name, shift, weight in dirs:
            n = mesh.shape[axis_name]
            perm = [(i, (i + shift) % n) for i in range(n)]
            recv = jax.lax.ppermute(payload, axis_name, perm)
            acc = acc + (recv * jnp.asarray(weight, wire)).astype(jnp.float32)
        return acc.astype(flat.dtype)

    sm = _shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec)
    return lambda flat: sm(flat)


def make_mesh_gossip_per_leaf(
    mesh: Mesh,
    node_axes: Sequence[str],
    specs: PyTree,
    self_weight: Optional[float] = None,
    wire_dtype=None,
    axes_subset: Optional[Sequence[str]] = None,
) -> GossipFn:
    """Leaf-by-leaf mesh gossip reference: one ppermute per direction PER
    LEAF. Equivalence oracle + the collective-count counter-example for
    the HLO dry-run test."""
    w_self, dirs = _mesh_dirs(mesh, node_axes, axes_subset, self_weight)

    def mix_leaf(x: jnp.ndarray) -> jnp.ndarray:
        wire = wire_dtype or x.dtype
        payload = x.astype(wire)
        acc = x.astype(jnp.float32) * w_self
        for axis_name, shift, weight in dirs:
            n = mesh.shape[axis_name]
            perm = [(i, (i + shift) % n) for i in range(n)]
            recv = jax.lax.ppermute(payload, axis_name, perm)
            acc = acc + (recv * jnp.asarray(weight, wire)).astype(jnp.float32)
        return acc.astype(x.dtype)

    def body(tree: PyTree) -> PyTree:
        return jax.tree_util.tree_map(mix_leaf, tree)

    sm = _shard_map(body, mesh=mesh, in_specs=(specs,), out_specs=specs)
    return lambda tree: sm(tree)


# ---------------------------------------------------------------------------
# 3. All-gather backend for arbitrary graphs at scale
# ---------------------------------------------------------------------------


def _allgather_row(mesh, node_axes, wmat):
    """This shard's W row, via the flat node index (row-major node order)."""
    idx = 0
    for a in node_axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return jax.lax.dynamic_slice_in_dim(wmat, idx, 1, axis=0)[0]  # (n,)


def make_allgather_gossip(
    mesh: Mesh,
    node_axes: Sequence[str],
    specs: PyTree,
    w: np.ndarray,
    wire_dtype=None,
) -> GossipFn:
    """Arbitrary-W gossip: ONE all-gather of the packed node payload over
    the node axes, then contract with this node's W row. Collective bytes
    ~ N x the ppermute backend -- the price of a non-torus graph on a torus
    interconnect -- but still a single collective regardless of leaf count.
    """
    node_axes = tuple(node_axes)
    n = int(np.prod([mesh.shape[a] for a in node_axes]))
    if w.shape != (n, n):
        raise ValueError(f"W shape {w.shape} != ({n},{n})")
    w_rows = jnp.asarray(w, dtype=jnp.float32)  # (n, n), replicated

    def body(tree: PyTree, wmat: jnp.ndarray) -> PyTree:
        row = _allgather_row(mesh, node_axes, wmat)
        flat, layout = pack(tree)  # (1, T_local) node slice
        payload = flat[0] if wire_dtype is None else flat[0].astype(wire_dtype)
        full = jax.lax.all_gather(payload, node_axes, tiled=False).reshape(n, -1)
        mixed = row @ full.astype(jnp.float32)
        return unpack(mixed[None].astype(flat.dtype), layout)

    sm = _shard_map(
        body, mesh=mesh, in_specs=(specs, P(None, None)), out_specs=specs
    )
    return lambda tree: sm(tree, w_rows)


def make_allgather_gossip_per_leaf(
    mesh: Mesh,
    node_axes: Sequence[str],
    specs: PyTree,
    w: np.ndarray,
    wire_dtype=None,
) -> GossipFn:
    """Leaf-by-leaf all-gather gossip reference: one all-gather PER LEAF."""
    node_axes = tuple(node_axes)
    n = int(np.prod([mesh.shape[a] for a in node_axes]))
    if w.shape != (n, n):
        raise ValueError(f"W shape {w.shape} != ({n},{n})")
    w_rows = jnp.asarray(w, dtype=jnp.float32)

    def body(tree: PyTree, wmat: jnp.ndarray) -> PyTree:
        row = _allgather_row(mesh, node_axes, wmat)

        def mix_leaf(x: jnp.ndarray) -> jnp.ndarray:
            payload = x[0] if wire_dtype is None else x[0].astype(wire_dtype)
            full = jax.lax.all_gather(payload, node_axes, tiled=False).reshape(n, -1)
            mixed = row @ full.astype(jnp.float32)
            return mixed.astype(x.dtype).reshape(x.shape[1:])[None]

        return jax.tree_util.tree_map(mix_leaf, tree)

    sm = _shard_map(
        body, mesh=mesh, in_specs=(specs, P(None, None)), out_specs=specs
    )
    return lambda tree: sm(tree, w_rows)
