"""Gossip (mixing) backends: the communication primitive of decentralized FL.

The paper's algorithms interleave local SGD/GT steps with a *mixing* step

    theta_i <- sum_{j in N_i} W_ij theta_j

over the node graph. This module provides three interchangeable backends
operating on **node-stacked pytrees** (every leaf has a leading ``nodes``
axis):

1. ``make_dense_gossip(w)`` -- simulated: ``theta' = W @ Theta`` as an
   einsum over the leading axis. Works on a single device (CPU-scale runs,
   the EHR reproduction, and the oracle for equivalence tests). Supports
   ANY mixing matrix.

2. ``make_mesh_gossip(mesh, node_axes, specs)`` -- TPU-native: a
   ``shard_map`` over the node mesh axes implementing the ring/torus
   circulant W with ``jax.lax.ppermute`` -- nearest-neighbor ICI transfers,
   the cheapest collective on a TPU torus. One ppermute per graph
   direction; the ``model``-axis shards of each leaf pass through untouched
   because mixing is elementwise across nodes.

3. ``make_allgather_gossip(mesh, node_axes, specs, w)`` -- TPU fallback for
   ARBITRARY graphs: all-gather the node-stacked leaf over the node axes
   and contract with the W row. O(N x) more collective bytes than ppermute
   gossip -- kept for generality and as the roofline counter-example.

All backends support a ``wire_dtype`` (e.g. ``jnp.bfloat16``): payloads are
rounded to the wire dtype before communication and the weighted sum is
accumulated in the leaf's own dtype. This is the beyond-paper
"bf16 gossip" optimization (halves the collective term); ``wire_dtype=None``
is the paper-faithful full-precision wire.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any
GossipFn = Callable[[PyTree], PyTree]

__all__ = [
    "make_dense_gossip",
    "make_mesh_gossip",
    "make_allgather_gossip",
    "make_mean_consensus",
    "mesh_gossip_directions",
    "mesh_gossip_dense_equivalent",
]


def _wire(x: jnp.ndarray, wire_dtype) -> jnp.ndarray:
    """Round a payload to the wire dtype (simulating the comm precision)."""
    if wire_dtype is None:
        return x
    return x.astype(wire_dtype).astype(x.dtype)


# ---------------------------------------------------------------------------
# 1. Dense-W simulated backend (any graph, any device count)
# ---------------------------------------------------------------------------


def make_dense_gossip(w: np.ndarray, wire_dtype=None) -> GossipFn:
    """theta' = W @ Theta over the leading node axis of every leaf.

    The diagonal (self) term is kept at full precision; only off-diagonal
    contributions pass through the wire dtype, mirroring what a real
    transport would quantize.
    """
    w = np.asarray(w, dtype=np.float64)
    n = w.shape[0]
    w_self = jnp.asarray(np.diag(w), dtype=jnp.float32)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), dtype=jnp.float32)

    def mix_leaf(x: jnp.ndarray) -> jnp.ndarray:
        if x.shape[0] != n:
            raise ValueError(f"leaf leading axis {x.shape[0]} != n_nodes {n}")
        flat = x.reshape(n, -1)
        sent = _wire(flat, wire_dtype).astype(jnp.float32)
        mixed = w_off @ sent + w_self[:, None] * flat.astype(jnp.float32)
        return mixed.astype(x.dtype).reshape(x.shape)

    return lambda tree: jax.tree_util.tree_map(mix_leaf, tree)


def make_mean_consensus(n: int) -> GossipFn:
    """W = (1/N) 1 1^T: exact averaging. This is the fictitious fusion
    center / FedAvg-server mixing (and the limit of infinitely many gossip
    rounds)."""
    return make_dense_gossip(np.full((n, n), 1.0 / n))


# ---------------------------------------------------------------------------
# 2. Mesh (ring/torus) ppermute backend -- the TPU-native path
# ---------------------------------------------------------------------------


def mesh_gossip_directions(
    axis_sizes: Dict[str, int], self_weight: Optional[float] = None
) -> Tuple[float, Tuple[Tuple[str, int, float], ...]]:
    """Directions of the circulant torus W over the given node axes.

    Returns (w_self, ((axis_name, shift, weight), ...)). An axis of size 2
    contributes ONE direction (its +1 and -1 neighbors coincide); size 1
    axes contribute none; larger axes contribute +/-1.
    """
    dirs = []
    for name, size in axis_sizes.items():
        if size == 2:
            dirs.append((name, 1))
        elif size > 2:
            dirs.append((name, 1))
            dirs.append((name, -1))
    if not dirs:
        return 1.0, ()
    w_self = 1.0 / (len(dirs) + 1) if self_weight is None else float(self_weight)
    if not (0.0 < w_self <= 1.0):
        raise ValueError("self_weight must be in (0, 1]")
    share = (1.0 - w_self) / len(dirs)
    return w_self, tuple((name, shift, share) for name, shift in dirs)


def mesh_gossip_dense_equivalent(
    axis_sizes: Dict[str, int], self_weight: Optional[float] = None
) -> np.ndarray:
    """The dense W the ppermute backend realizes (row-major node order).

    Used as the oracle in sharded-vs-simulated equivalence tests and to
    check Assumption 1 for the production topology.
    """
    names = list(axis_sizes)
    sizes = [axis_sizes[k] for k in names]
    n = int(np.prod(sizes))
    w_self, dirs = mesh_gossip_directions(axis_sizes, self_weight)
    w = np.eye(n) * w_self if dirs else np.eye(n)
    idx = np.arange(n).reshape(sizes)
    for name, shift, weight in dirs:
        ax = names.index(name)
        # receiving from the node `shift` positions back along axis `ax`
        src = np.roll(idx, shift, axis=ax).reshape(-1)
        for dst_node, src_node in enumerate(src.tolist()):
            w[dst_node, src_node] += weight
    return w


def make_mesh_gossip(
    mesh: Mesh,
    node_axes: Sequence[str],
    specs: PyTree,
    self_weight: Optional[float] = None,
    wire_dtype=None,
    axes_subset: Optional[Sequence[str]] = None,
) -> GossipFn:
    """Ring/torus gossip via ppermute inside a shard_map.

    Args:
      mesh: the device mesh (must contain every axis in ``specs``).
      node_axes: mesh axes enumerating FL nodes, e.g. ("data",) or
        ("pod", "data"). Every leaf's spec must shard its leading axis over
        exactly these (``P((*node_axes,), ...)``).
      specs: pytree of PartitionSpec matching the state pytree.
      self_weight: W_ii; default 1/(ndirs+1) (1/3 ring, 1/5 torus).
      wire_dtype: payload dtype on the wire (None = leaf dtype).
      axes_subset: if given, gossip ONLY along these node axes (the others
        contribute no direction). This powers *hierarchical gossip*: mix
        over the cheap intra-pod "data" links every round and over the
        expensive inter-pod links less often.
    """
    node_axes = tuple(node_axes)
    active = tuple(axes_subset) if axes_subset is not None else node_axes
    for a in active:
        if a not in node_axes:
            raise ValueError(f"axes_subset {active} not within node_axes {node_axes}")
    axis_sizes = {a: mesh.shape[a] for a in active}
    w_self, dirs = mesh_gossip_directions(axis_sizes, self_weight)

    def mix_leaf(x: jnp.ndarray) -> jnp.ndarray:
        # With a narrow wire dtype the ENTIRE neighbor path stays in that
        # dtype -- payload, permute, weighting -- so no convert exists for
        # XLA's simplifier to hoist across the permute (which would silently
        # re-widen the wire; observed with a down/up-cast pair on XLA CPU).
        # The self term and the final accumulation stay in fp32.
        wire = wire_dtype or x.dtype
        payload = x.astype(wire)
        acc = x.astype(jnp.float32) * w_self
        for axis_name, shift, weight in dirs:
            n = mesh.shape[axis_name]
            perm = [(i, (i + shift) % n) for i in range(n)]
            recv = jax.lax.ppermute(payload, axis_name, perm)
            acc = acc + (recv * jnp.asarray(weight, wire)).astype(jnp.float32)
        return acc.astype(x.dtype)

    def body(tree: PyTree) -> PyTree:
        return jax.tree_util.tree_map(mix_leaf, tree)

    sm = jax.shard_map(body, mesh=mesh, in_specs=(specs,), out_specs=specs)
    return lambda tree: sm(tree)


# ---------------------------------------------------------------------------
# 3. All-gather backend for arbitrary graphs at scale
# ---------------------------------------------------------------------------


def make_allgather_gossip(
    mesh: Mesh,
    node_axes: Sequence[str],
    specs: PyTree,
    w: np.ndarray,
    wire_dtype=None,
) -> GossipFn:
    """Arbitrary-W gossip: all-gather each leaf over the node axes, then
    contract with this node's W row. Collective bytes ~ N x the ppermute
    backend -- the price of a non-torus graph on a torus interconnect.
    """
    node_axes = tuple(node_axes)
    n = int(np.prod([mesh.shape[a] for a in node_axes]))
    if w.shape != (n, n):
        raise ValueError(f"W shape {w.shape} != ({n},{n})")
    w_rows = jnp.asarray(w, dtype=jnp.float32)  # (n, n), replicated

    def body(tree: PyTree, wmat: jnp.ndarray) -> PyTree:
        # flat node index of this shard (row-major over node_axes)
        idx = 0
        for a in node_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        row = jax.lax.dynamic_slice_in_dim(wmat, idx, 1, axis=0)[0]  # (n,)

        def mix_leaf(x: jnp.ndarray) -> jnp.ndarray:
            # x: (1, ...) local node slice; gather -> (n, ...). The gather
            # payload carries the wire dtype (cast before, upcast after).
            payload = x[0] if wire_dtype is None else x[0].astype(wire_dtype)
            full = jax.lax.all_gather(payload, node_axes, tiled=False).reshape(n, -1)
            mixed = row @ full.astype(jnp.float32)
            return mixed.astype(x.dtype).reshape(x.shape[1:])[None]

        return jax.tree_util.tree_map(mix_leaf, tree)

    sm = jax.shard_map(
        body, mesh=mesh, in_specs=(specs, P(None, None)), out_specs=specs
    )
    return lambda tree: sm(tree, w_rows)
