"""Compressed gossip: int8 quantization with error feedback (beyond-paper).

The paper saves communication ROUNDS (Q local steps); this module saves
BYTES PER ROUND: neighbor payloads are quantized to int8 (4x smaller than
fp32) with symmetric scaling, and the quantization residual is fed back
into the next round's payload (error feedback / EF-SGD style), which keeps
the long-run mixing unbiased -- plain quantized gossip accumulates an
O(quant-err / spectral-gap) consensus floor, while EF drives it to the
same floor as exact gossip (property-tested).

**Flat-buffer engine.** The hot path operates on the packed
``(nodes, total_params)`` buffer from ``core.packing``: ONE
quantize-mix-EF pass per round instead of one per leaf, with scales
computed per ``(node, scale_chunk)`` column block (finer than the
historical per-leaf scales for big leaves, coarser for confetti-sized
ones; the chunk is the tile of the fused Pallas kernel in
``repro.kernels.gossip``, which eliminates the materialized payload/dq/
recon intermediates entirely). ``make_compressed_dense_gossip`` wraps the
flat engine in pack/unpack for the tree API;
``make_compressed_dense_gossip_per_leaf`` keeps the historical per-leaf
implementation as the equivalence oracle.

State per node: the shared reconstruction theta_hat (what neighbors can
rebuild from wire traffic alone) + the error-feedback residual. The
compressed gossip has signature

    (tree, state) -> (mixed_tree, new_state)

threaded at the driver level (tests/test_compression.py shows the FL
loop; comm accounting in benchmarks/comm_bytes.py).

Quantizer: symmetric int8: q = round(x / s), s = max|x| / 127, dequant =
q * s. Wire payload per round = 1 byte/param + 4 bytes per scale block
(per-node-per-leaf for the per-leaf path -- ``compressed_wire_bytes`` --
per ``(node, scale_chunk)`` for the flat engine --
``packing.flat_wire_bytes``).
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import pack, unpack

PyTree = Any
FlatGossipFn = Callable[
    [jnp.ndarray, "dict[str, jnp.ndarray]"],
    Tuple[jnp.ndarray, "dict[str, jnp.ndarray]"],
]

# Default scale granularity of the flat engine == the default VMEM tile of
# the fused kernel (one fp32 scale per 512 int8 params: 0.8% wire overhead).
DEFAULT_SCALE_CHUNK = 512

__all__ = [
    "DEFAULT_SCALE_CHUNK",
    "quantize_int8",
    "dequantize_int8",
    "make_compressed_dense_gossip",
    "make_compressed_dense_gossip_per_leaf",
    "make_compressed_flat_gossip",
    "init_compression_state",
    "init_flat_compression_state",
    "zeros_like_residual",
    "compressed_wire_bytes",
]


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-node symmetric int8. x: (nodes, ...) -> (q int8, scale (nodes,))."""
    flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
    scale = jnp.max(jnp.abs(flat), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(flat / safe[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    flat = q.reshape(q.shape[0], -1).astype(jnp.float32)
    return (flat * scale[:, None]).reshape(q.shape)


def zeros_like_residual(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), tree)


def init_compression_state(tree: PyTree) -> PyTree:
    """{recon, residual} per leaf. ``recon`` is the shared reconstruction
    every neighbor can maintain from the wire traffic alone (starts at 0:
    the first round effectively transmits the full parameters)."""
    z = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), tree)
    return {"recon": z, "residual": jax.tree_util.tree_map(jnp.copy, z)}


def init_flat_compression_state(flat: jnp.ndarray) -> dict:
    """Flat-engine compression state: {recon, residual} as (nodes, total)
    fp32 buffers (zeros: the first round transmits the full parameters)."""
    z = jnp.zeros(flat.shape, jnp.float32)
    return {"recon": z, "residual": z}


def make_compressed_flat_gossip(
    w: np.ndarray,
    error_feedback: bool = True,
    difference_coding: bool = True,
    scale_chunk: int = DEFAULT_SCALE_CHUNK,
    impl: str = "jnp",
    topk: int | None = None,
) -> FlatGossipFn:
    """Flat-native CHOCO-style gossip on the packed ``(nodes, total)``
    buffer (``total`` must be a multiple of ``scale_chunk``; pack with
    ``pad_to=scale_chunk``).

    Difference coding: both sides share a reconstruction theta_hat built
    purely from wire traffic, and only the change is quantized:

        payload = theta - theta_hat + residual
        q, s    = int8(payload)               <- the only wire bytes
        theta_hat' = theta_hat + dq(q, s)
        residual'  = payload - dq(q, s)       (EF)
        theta' = W_ii theta + sum_{j!=i} W_ij theta_hat_j'

    As consensus approaches, payload scales -> 0, so quantization error
    -> 0 and the mixing becomes EXACT in the limit. Plain quantized gossip
    -- and even EF over full-parameter payloads -- stalls at an
    O(max|theta| / 127 / gap) consensus floor because the quantization
    STEP never shrinks (measured; see tests).

    ``impl="jnp"`` runs the chunked jnp reference; ``impl="pallas"`` the
    fused VMEM-tiled kernel (``repro.kernels.gossip``) that computes
    quantize -> W-row mix -> dequant + EF in one pass with no materialized
    full-size payload/dq/recon intermediates. ``topk=k`` ships only the k
    largest-|payload| columns per scale chunk (sub-int8 wire bytes; the EF
    residual absorbs the truncation, so consensus contraction survives --
    property-tested in tests/test_topk_property.py).
    """
    if impl == "jnp":
        from repro.kernels.gossip.ref import gossip_mix_ref as mix_impl
    elif impl == "pallas":
        from repro.kernels.gossip.ops import gossip_mix as mix_impl
    else:
        raise ValueError(f"unknown impl {impl!r}")
    w = np.asarray(w, dtype=np.float64)
    w_self = jnp.asarray(np.diag(w), dtype=jnp.float32)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), dtype=jnp.float32)

    def gossip(flat: jnp.ndarray, state: dict) -> Tuple[jnp.ndarray, dict]:
        mixed, recon, res, _ = mix_impl(
            flat.astype(jnp.float32),
            state["recon"],
            state["residual"],
            w_off,
            w_self,
            scale_chunk=scale_chunk,
            error_feedback=error_feedback,
            difference_coding=difference_coding,
            topk=topk,
        )
        return mixed.astype(flat.dtype), {"recon": recon, "residual": res}

    return gossip


def make_compressed_dense_gossip(
    w: np.ndarray,
    error_feedback: bool = True,
    difference_coding: bool = True,
    scale_chunk: int = DEFAULT_SCALE_CHUNK,
    impl: str = "jnp",
) -> Callable[[PyTree, PyTree], Tuple[PyTree, PyTree]]:
    """Tree-API wrapper of :func:`make_compressed_flat_gossip`: packs the
    parameters and the {recon, residual} state into flat buffers, runs ONE
    quantize-mix-EF pass, and unpacks. Signature and state layout are
    unchanged from the historical per-leaf version
    (:func:`make_compressed_dense_gossip_per_leaf`)."""
    flat_gossip = make_compressed_flat_gossip(
        w, error_feedback, difference_coding, scale_chunk, impl
    )

    def gossip(tree: PyTree, state: PyTree) -> Tuple[PyTree, PyTree]:
        flat, layout = pack(tree, pad_to=scale_chunk)
        recon, f32_layout = pack(state["recon"], pad_to=scale_chunk)
        res, _ = pack(state["residual"], pad_to=scale_chunk)
        mixed, new_state = flat_gossip(flat, {"recon": recon, "residual": res})
        return unpack(mixed, layout), {
            "recon": unpack(new_state["recon"], f32_layout),
            "residual": unpack(new_state["residual"], f32_layout),
        }

    return gossip


def make_compressed_dense_gossip_per_leaf(
    w: np.ndarray, error_feedback: bool = True, difference_coding: bool = True
) -> Callable[[PyTree, PyTree], Tuple[PyTree, PyTree]]:
    """Historical leaf-by-leaf CHOCO gossip (per-node-per-LEAF scales, one
    quantize+matmul pass and three materialized full-size intermediates
    per leaf per round). Kept as the flat engine's equivalence oracle and
    the benchmark baseline."""
    w = np.asarray(w, dtype=np.float64)
    n = w.shape[0]
    w_self = jnp.asarray(np.diag(w), dtype=jnp.float32)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), dtype=jnp.float32)

    def mix_leaf(x, recon, res):
        xf = x.astype(jnp.float32)
        base = recon if difference_coding else jnp.zeros_like(recon)
        payload = xf - base + (res if error_feedback else 0.0)
        q, s = quantize_int8(payload)
        dq = dequantize_int8(q, s)
        new_recon = base + dq
        new_res = payload - dq if error_feedback else res
        mixed = w_off @ new_recon.reshape(n, -1) + w_self[:, None] * xf.reshape(n, -1)
        return mixed.reshape(x.shape).astype(x.dtype), new_recon, new_res

    def gossip(tree: PyTree, state: PyTree) -> Tuple[PyTree, PyTree]:
        triples = jax.tree_util.tree_map(mix_leaf, tree, state["recon"], state["residual"])
        is_triple = lambda v: isinstance(v, tuple)
        mixed = jax.tree_util.tree_map(lambda p: p[0], triples, is_leaf=is_triple)
        recon = jax.tree_util.tree_map(lambda p: p[1], triples, is_leaf=is_triple)
        res = jax.tree_util.tree_map(lambda p: p[2], triples, is_leaf=is_triple)
        return mixed, {"recon": recon, "residual": res}

    return gossip


def compressed_wire_bytes(tree: PyTree, degree: int) -> int:
    """Per-node egress bytes per round for the PER-LEAF path: 1 B/param +
    4 B scale per leaf, times the out-degree. The flat engine's accounting
    (4 B per ``scale_chunk`` columns instead) is
    ``packing.flat_wire_bytes``."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        per_node = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
        total += per_node + 4
    return degree * total
