"""Compressed gossip: int8 quantization with error feedback (beyond-paper).

The paper saves communication ROUNDS (Q local steps); this module saves
BYTES PER ROUND: neighbor payloads are quantized to int8 (4x smaller than
fp32) with per-leaf symmetric scaling, and the quantization residual is
fed back into the next round's payload (error feedback / EF-SGD style),
which keeps the long-run mixing unbiased -- plain quantized gossip
accumulates an O(quant-err / spectral-gap) consensus floor, while EF drives
it to the same floor as exact gossip (property-tested).

State per node: the shared reconstruction theta_hat (what neighbors can
rebuild from wire traffic alone) + the error-feedback residual. The
compressed gossip has signature

    (tree, state) -> (mixed_tree, new_state)

threaded at the driver level (tests/test_compression.py shows the FL
loop; comm accounting in benchmarks/comm_bytes.py).

Quantizer: per-leaf-per-node symmetric int8: q = round(x / s), s =
max|x| / 127, dequant = q * s. Wire payload per round = 1 byte/param
+ 4 bytes/node/leaf for the scale.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "make_compressed_dense_gossip",
    "init_compression_state",
    "zeros_like_residual",
    "compressed_wire_bytes",
]


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-node symmetric int8. x: (nodes, ...) -> (q int8, scale (nodes,))."""
    flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
    scale = jnp.max(jnp.abs(flat), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(flat / safe[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    flat = q.reshape(q.shape[0], -1).astype(jnp.float32)
    return (flat * scale[:, None]).reshape(q.shape)


def zeros_like_residual(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), tree)


def init_compression_state(tree: PyTree) -> PyTree:
    """{recon, residual} per leaf. ``recon`` is the shared reconstruction
    every neighbor can maintain from the wire traffic alone (starts at 0:
    the first round effectively transmits the full parameters)."""
    z = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), tree)
    return {"recon": z, "residual": jax.tree_util.tree_map(jnp.copy, z)}


def make_compressed_dense_gossip(
    w: np.ndarray, error_feedback: bool = True, difference_coding: bool = True
) -> Callable[[PyTree, PyTree], Tuple[PyTree, PyTree]]:
    """Dense-W gossip over int8 DIFFERENCE-CODED payloads (CHOCO-gossip
    style) with error feedback.

    Plain quantized gossip -- and even EF over full-parameter payloads --
    stalls at an O(max|theta| / 127 / gap) consensus floor because the
    quantization STEP never shrinks (measured; see tests). Difference
    coding fixes this: both sides share a reconstruction theta_hat built
    purely from wire traffic, and only the change is quantized:

        payload_i = theta_i - theta_hat_i + residual_i
        q_i, s_i  = int8(payload_i)              <- the only wire bytes
        theta_hat_i' = theta_hat_i + dq(q_i, s_i)
        residual_i'  = payload_i - dq(q_i, s_i)  (EF)
        theta_i' = W_ii theta_i + sum_{j!=i} W_ij theta_hat_j'

    As consensus approaches, payload scales -> 0, so quantization error
    -> 0 and the mixing becomes EXACT in the limit.
    """
    w = np.asarray(w, dtype=np.float64)
    n = w.shape[0]
    w_self = jnp.asarray(np.diag(w), dtype=jnp.float32)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), dtype=jnp.float32)

    def mix_leaf(x, recon, res):
        xf = x.astype(jnp.float32)
        base = recon if difference_coding else jnp.zeros_like(recon)
        payload = xf - base + (res if error_feedback else 0.0)
        q, s = quantize_int8(payload)
        dq = dequantize_int8(q, s)
        new_recon = base + dq
        new_res = payload - dq if error_feedback else res
        mixed = w_off @ new_recon.reshape(n, -1) + w_self[:, None] * xf.reshape(n, -1)
        return mixed.reshape(x.shape).astype(x.dtype), new_recon, new_res

    def gossip(tree: PyTree, state: PyTree) -> Tuple[PyTree, PyTree]:
        triples = jax.tree_util.tree_map(mix_leaf, tree, state["recon"], state["residual"])
        is_triple = lambda v: isinstance(v, tuple)
        mixed = jax.tree_util.tree_map(lambda p: p[0], triples, is_leaf=is_triple)
        recon = jax.tree_util.tree_map(lambda p: p[1], triples, is_leaf=is_triple)
        res = jax.tree_util.tree_map(lambda p: p[2], triples, is_leaf=is_triple)
        return mixed, {"recon": recon, "residual": res}

    return gossip


def compressed_wire_bytes(tree: PyTree, degree: int) -> int:
    """Per-node egress bytes per round: 1 B/param + 4 B scale per leaf,
    times the out-degree."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        per_node = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
        total += per_node + 4
    return degree * total
