"""Core library: the paper's contribution — fully decentralized federated
learning (DSGD / DSGT with Q local steps) over an explicit node graph.

Public API:
    topology  — graphs + mixing matrices (Assumption 1 machinery)
    packing   — flat-buffer engine: pytree <-> one (nodes, total) buffer
    mixing    — gossip backends (dense-W simulated, ppermute mesh, all-gather)
    engine    — the GossipEngine protocol + registry (tree / flat / fused /
                sharded_fused) behind make_fl_round(engine=...)
    dynamics  — TopologyProgram registry: per-round time-varying graphs
                (node churn, link failure) as the third pluggable round
                axis (engine = WHAT moves, schedule = WHEN, program =
                over WHICH graph)
    heterogeneity — NodeProgram registry: per-node compute rates, payload
                delays and drops as the fourth pluggable round axis
                (WHICH nodes keep up), with drop-renormalized mixing
    privacy   — PrivacySpec: pairwise-masked secure aggregation + DP
                noise in the wire-stage epilogue as the fifth round axis
                (WHAT a neighbor can read), with (epsilon, delta) moments
                accounting
    scope     — FederationScope registry: partial-parameter federation as
                the sixth round axis (WHICH columns gossip touches) —
                shared-backbone gossip with per-node private heads
                ('backbone' / 'ranges:' / 'layerwise:freq=')
    fl        — FLState + DSGD/DSGT/FD round builders + baselines
    schedules — alpha^r schedules (paper's 0.02/sqrt(r), Theorem 1 rate, ...)
"""

from repro.core.compression import (
    init_compression_state,
    init_flat_compression_state,
    make_compressed_dense_gossip,
    make_compressed_flat_gossip,
    quantize_int8,
)
from repro.core.dynamics import (
    EdgeFailureProgram,
    NodeChurnProgram,
    RGGRewireProgram,
    RoundRobinSubgraphsProgram,
    StaticProgram,
    TopologyProgram,
    get_program,
    parse_program,
    program_names,
    register_program,
    resolve_program,
    validate_program,
)
from repro.core.engine import (
    BoundedStalenessSchedule,
    FlatEngine,
    FusedEngine,
    GossipEngine,
    PipelinedSchedule,
    RoundSchedule,
    SequentialSchedule,
    ShardedFusedEngine,
    TreeEngine,
    engine_names,
    get_engine,
    get_schedule,
    register_engine,
    register_schedule,
    resolve_schedule,
    schedule_names,
)
from repro.core.heterogeneity import (
    HomogeneousProgram,
    NodeProgram,
    PayloadDropProgram,
    SlowNodesProgram,
    SlowUplinkProgram,
    StragglerProgram,
    compose_node_gate,
    get_node_program,
    node_program_names,
    parse_node_program,
    register_node_program,
    resolve_node_program,
)
from repro.core.fl import (
    FLConfig,
    FLState,
    consensus_params,
    init_fl_state,
    make_fl_round,
)
from repro.core.scope import (
    BackboneScope,
    FederationScope,
    FullScope,
    LayerwiseScope,
    RangesScope,
    get_scope,
    parse_scope,
    register_scope,
    resolve_scope,
    scope_names,
)
from repro.core.privacy import (
    PrivacySpec,
    analytic_epsilon,
    parse_privacy,
    rdp_epsilon,
    resolve_privacy,
)
from repro.core.mixing import (
    make_allgather_gossip,
    make_dense_flat_mix,
    make_dense_gossip,
    make_mean_consensus,
    make_mesh_flat_mix,
    make_mesh_gossip,
    mesh_gossip_dense_equivalent,
)
from repro.core.packing import (
    FlatLayout,
    compact_pos_dtype,
    flat_wire_bytes,
    flat_wire_bytes_per_shard,
    pack,
    pack_like,
    scoped_layout,
    unpack,
)
from repro.core.topology import (
    Graph,
    check_assumption1,
    complete_graph,
    erdos_renyi_graph,
    hospital20_graph,
    metropolis_weights,
    mixing_matrix,
    ring_graph,
    spectral_gap,
    star_graph,
    torus_graph,
    uniform_neighbor_weights,
)
from repro.core import schedules

__all__ = [
    "init_compression_state",
    "init_flat_compression_state",
    "make_compressed_dense_gossip",
    "make_compressed_flat_gossip",
    "quantize_int8",
    "FlatLayout",
    "flat_wire_bytes",
    "flat_wire_bytes_per_shard",
    "pack",
    "pack_like",
    "scoped_layout",
    "unpack",
    "make_dense_flat_mix",
    "FLConfig",
    "FLState",
    "GossipEngine",
    "TreeEngine",
    "FlatEngine",
    "FusedEngine",
    "ShardedFusedEngine",
    "register_engine",
    "get_engine",
    "engine_names",
    "RoundSchedule",
    "SequentialSchedule",
    "PipelinedSchedule",
    "BoundedStalenessSchedule",
    "register_schedule",
    "get_schedule",
    "schedule_names",
    "resolve_schedule",
    "TopologyProgram",
    "StaticProgram",
    "EdgeFailureProgram",
    "NodeChurnProgram",
    "RoundRobinSubgraphsProgram",
    "RGGRewireProgram",
    "register_program",
    "get_program",
    "program_names",
    "parse_program",
    "resolve_program",
    "validate_program",
    "NodeProgram",
    "HomogeneousProgram",
    "StragglerProgram",
    "SlowNodesProgram",
    "SlowUplinkProgram",
    "PayloadDropProgram",
    "compose_node_gate",
    "register_node_program",
    "get_node_program",
    "node_program_names",
    "parse_node_program",
    "resolve_node_program",
    "PrivacySpec",
    "parse_privacy",
    "resolve_privacy",
    "FederationScope",
    "FullScope",
    "BackboneScope",
    "RangesScope",
    "LayerwiseScope",
    "register_scope",
    "get_scope",
    "scope_names",
    "parse_scope",
    "resolve_scope",
    "rdp_epsilon",
    "analytic_epsilon",
    "compact_pos_dtype",
    "consensus_params",
    "init_fl_state",
    "make_fl_round",
    "make_allgather_gossip",
    "make_dense_gossip",
    "make_mean_consensus",
    "make_mesh_flat_mix",
    "make_mesh_gossip",
    "mesh_gossip_dense_equivalent",
    "Graph",
    "check_assumption1",
    "complete_graph",
    "erdos_renyi_graph",
    "hospital20_graph",
    "metropolis_weights",
    "mixing_matrix",
    "ring_graph",
    "spectral_gap",
    "star_graph",
    "torus_graph",
    "uniform_neighbor_weights",
    "schedules",
]
