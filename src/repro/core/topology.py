"""Communication graphs and mixing matrices for decentralized FL.

Implements the graph substrate of the paper: a multi-agent system
``G = (V, E)`` of N nodes where only neighbors exchange parameters, mixed
through a symmetric doubly-stochastic matrix ``W`` (Assumption 1):

    W = W^T,   W 1 = 1,   |lambda_2(W)| < 1.

Provides the standard graph families (ring, 2-D torus, complete, star,
Erdos--Renyi) plus a 20-node "hospital" graph mimicking the paper's Fig. 1
(left), and two W constructions:

* Metropolis--Hastings weights -- valid for ANY connected graph, the
  default for arbitrary topologies.
* uniform-neighbor (circulant) weights for ring/torus -- these are what the
  TPU-native ``ppermute`` gossip backend realizes with nearest-neighbor ICI
  transfers.

All matrices are plain ``numpy`` (they are compile-time constants baked
into the training step); spectral checks are numpy too.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = [
    "Graph",
    "ring_graph",
    "torus_graph",
    "complete_graph",
    "star_graph",
    "erdos_renyi_graph",
    "hospital20_graph",
    "metropolis_weights",
    "uniform_neighbor_weights",
    "mixing_matrix",
    "check_assumption1",
    "spectral_gap",
    "ring_mixing_coeffs",
    "torus_mixing_coeffs",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected communication graph over ``n`` FL nodes.

    ``edges`` are canonical (i < j) pairs. ``name`` identifies the family
    (used to pick the TPU gossip backend: ring/torus have ppermute
    realizations; anything else falls back to the dense-W backend).
    """

    n: int
    edges: Tuple[Tuple[int, int], ...]
    name: str = "custom"

    def __post_init__(self) -> None:
        for i, j in self.edges:
            if not (0 <= i < j < self.n):
                raise ValueError(f"bad edge ({i},{j}) for n={self.n}")

    @property
    def adjacency(self) -> np.ndarray:
        a = np.zeros((self.n, self.n), dtype=bool)
        for i, j in self.edges:
            a[i, j] = a[j, i] = True
        return a

    def neighbors(self, i: int) -> List[int]:
        return sorted(
            ({j for a, j in self.edges if a == i} | {a for a, j in self.edges if j == i})
        )

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1).astype(np.int64)

    def is_connected(self) -> bool:
        if self.n == 1:
            return True
        adj = self.adjacency
        seen = np.zeros(self.n, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            u = stack.pop()
            for v in np.nonzero(adj[u])[0]:
                if not seen[v]:
                    seen[v] = True
                    stack.append(int(v))
        return bool(seen.all())


def ring_graph(n: int) -> Graph:
    """Cycle C_n: node i <-> (i+1) mod n. The single-pod TPU topology."""
    if n < 2:
        raise ValueError("ring needs n >= 2")
    edges = {tuple(sorted((i, (i + 1) % n))) for i in range(n)}
    return Graph(n=n, edges=tuple(sorted(edges)), name="ring")


def torus_graph(rows: int, cols: int) -> Graph:
    """2-D torus (rows x cols): the multi-pod topology (pod x data axes).

    Node id = r * cols + c. Each node has 4 neighbors (2 if a dim == 2,
    where +1 and -1 coincide).
    """
    n = rows * cols
    edges = set()
    for r in range(rows):
        for c in range(cols):
            u = r * cols + c
            for v in ((r * cols + (c + 1) % cols), (((r + 1) % rows) * cols + c)):
                if u != v:
                    edges.add(tuple(sorted((u, v))))
    return Graph(n=n, edges=tuple(sorted(edges)), name="torus")


def complete_graph(n: int) -> Graph:
    edges = tuple((i, j) for i in range(n) for j in range(i + 1, n))
    return Graph(n=n, edges=edges, name="complete")


def star_graph(n: int) -> Graph:
    """Hub-and-spoke: node 0 is the parameter server. The FedAvg baseline
    topology (the paper argues AGAINST requiring this trusted center)."""
    edges = tuple((0, j) for j in range(1, n))
    return Graph(n=n, edges=edges, name="star")


def erdos_renyi_graph(n: int, p: float, seed: int = 0) -> Graph:
    """G(n, p), resampled until connected (adds a ring if hopeless)."""
    rng = np.random.default_rng(seed)
    for _ in range(64):
        mask = rng.random((n, n)) < p
        edges = tuple(
            (i, j) for i in range(n) for j in range(i + 1, n) if mask[i, j]
        )
        g = Graph(n=n, edges=edges, name="erdos_renyi")
        if g.is_connected():
            return g
    ring = {tuple(sorted((i, (i + 1) % n))) for i in range(n)}
    return Graph(n=n, edges=tuple(sorted(set(edges) | ring)), name="erdos_renyi")


def hospital20_graph() -> Graph:
    """A fixed 20-node sparse connected graph standing in for the paper's
    Fig. 1 (left) hospital network (the exact edge list is not published).

    Construction: a ring backbone (every hospital talks to two regional
    peers) plus a handful of long-range referral links, giving mean degree
    ~3 -- visually consistent with Fig. 1 and a realistic sparse inter-
    hospital agreement network.
    """
    n = 20
    edges = {tuple(sorted((i, (i + 1) % n))) for i in range(n)}
    extra = [(0, 7), (2, 13), (4, 16), (5, 11), (9, 18), (3, 8), (12, 19)]
    edges |= {tuple(sorted(e)) for e in extra}
    return Graph(n=n, edges=tuple(sorted(edges)), name="hospital20")


# ---------------------------------------------------------------------------
# Mixing matrices
# ---------------------------------------------------------------------------


def metropolis_weights(graph: Graph) -> np.ndarray:
    """Metropolis--Hastings weights: W_ij = 1/(1+max(d_i,d_j)) for edges,
    W_ii = 1 - sum_j W_ij. Symmetric, doubly stochastic, and satisfies
    Assumption 1 for any connected non-bipartite-problematic graph.
    """
    n = graph.n
    deg = graph.degrees
    w = np.zeros((n, n), dtype=np.float64)
    for i, j in graph.edges:
        w[i, j] = w[j, i] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def uniform_neighbor_weights(graph: Graph, self_weight: float | None = None) -> np.ndarray:
    """W_ij = (1 - w_self)/d for neighbors on a REGULAR graph.

    For the ring this is the circulant [w_self, (1-w_self)/2, (1-w_self)/2]
    that the ppermute gossip backend implements; default w_self = 1/(d+1)
    gives the classic 1/3-1/3-1/3 ring mixing.
    """
    deg = graph.degrees
    d = int(deg[0])
    if not np.all(deg == d):
        raise ValueError("uniform_neighbor_weights requires a regular graph")
    w_self = 1.0 / (d + 1) if self_weight is None else float(self_weight)
    if not (0.0 < w_self < 1.0):
        raise ValueError("self_weight must be in (0, 1)")
    n = graph.n
    w = np.zeros((n, n), dtype=np.float64)
    share = (1.0 - w_self) / d
    for i, j in graph.edges:
        w[i, j] = w[j, i] = share
    np.fill_diagonal(w, w_self)
    return w


_GRAPHS = {
    "ring": lambda n, **kw: ring_graph(n),
    "complete": lambda n, **kw: complete_graph(n),
    "star": lambda n, **kw: star_graph(n),
    "hospital20": lambda n, **kw: hospital20_graph(),
    "erdos_renyi": lambda n, **kw: erdos_renyi_graph(n, kw.get("p", 0.3), kw.get("seed", 0)),
}


def mixing_matrix(topology: str, n: int, **kwargs) -> np.ndarray:
    """Build W for a named topology. torus takes topology='torus:RxC'."""
    if topology.startswith("torus"):
        if ":" in topology:
            r, c = (int(v) for v in topology.split(":")[1].split("x"))
        else:
            r = int(np.floor(np.sqrt(n)))
            while n % r:
                r -= 1
            c = n // r
        if r * c != n:
            raise ValueError(f"torus {r}x{c} != n={n}")
        g = torus_graph(r, c)
        return uniform_neighbor_weights(g) if r > 2 or c > 2 else metropolis_weights(g)
    if topology not in _GRAPHS:
        raise ValueError(f"unknown topology {topology!r}; have {sorted(_GRAPHS)} + torus")
    g = _GRAPHS[topology](n, **kwargs)
    if g.n != n:
        raise ValueError(f"topology {topology} has fixed n={g.n}, requested {n}")
    try:
        return uniform_neighbor_weights(g)
    except ValueError:
        return metropolis_weights(g)


# ---------------------------------------------------------------------------
# Assumption 1 checks
# ---------------------------------------------------------------------------


def spectral_gap(w: np.ndarray) -> float:
    """1 - |lambda_2|, where lambda_2 is the second-largest-magnitude
    eigenvalue. Governs the consensus contraction rate."""
    eig = np.linalg.eigvalsh(0.5 * (w + w.T))
    mags = np.sort(np.abs(eig))[::-1]
    # the largest must be the trivial eigenvalue 1 (eigenvector 1)
    return float(1.0 - mags[1]) if len(mags) > 1 else 1.0


def check_assumption1(
    w: np.ndarray, atol: float = 1e-10, require_connected: bool = True
) -> Dict[str, float]:
    """Verify the paper's Assumption 1; raises on violation.

    ``require_connected=False`` relaxes ONLY the spectral-gap positivity
    (|lambda_2| < 1): a single round emitted by a dynamic
    :class:`~repro.core.dynamics.TopologyProgram` may legitimately
    disconnect (gap == 0 -- isolated nodes self-loop and mix nothing that
    round), while symmetry, double stochasticity, and |lambda|_max <= 1
    must still hold every round. The time-varying convergence analyses
    need joint connectivity over a window, not per-round connectivity.

    Returns diagnostics {sym_err, row_sum_err, lambda2, spectral_gap}.
    """
    n = w.shape[0]
    if w.shape != (n, n):
        raise ValueError("W must be square")
    sym_err = float(np.abs(w - w.T).max())
    row_err = float(np.abs(w.sum(axis=1) - 1.0).max())
    if sym_err > atol:
        raise AssertionError(f"W not symmetric: err={sym_err}")
    if row_err > atol:
        raise AssertionError(f"W 1 != 1: err={row_err}")
    gap = spectral_gap(w)
    if require_connected and gap <= 0.0:
        raise AssertionError("|lambda_2(W)| >= 1: graph mixes too slowly/not at all")
    if gap < -atol:
        raise AssertionError(f"|lambda_2(W)| > 1: spectral radius exceeded ({gap})")
    return {
        "sym_err": sym_err,
        "row_sum_err": row_err,
        "lambda2": 1.0 - gap,
        "spectral_gap": gap,
    }


# ---------------------------------------------------------------------------
# Coefficients for the ppermute gossip backends
# ---------------------------------------------------------------------------


def ring_mixing_coeffs(n: int, self_weight: float | None = None) -> Tuple[float, float, float]:
    """(w_self, w_prev, w_next) of the circulant ring W realized by two
    ppermutes over a mesh axis of size n. n == 2 degenerates (prev == next);
    we fold the two shares together so W stays doubly stochastic."""
    if n < 2:
        return (1.0, 0.0, 0.0)
    w_self = 1.0 / 3.0 if self_weight is None else float(self_weight)
    share = (1.0 - w_self) / 2.0
    return (w_self, share, share)


def torus_mixing_coeffs(
    rows: int, cols: int, self_weight: float | None = None
) -> Dict[str, float]:
    """Coefficients of the 2-D-torus W realized by 4 ppermutes over the
    (pod, data) axes. Degenerate dims (size 2) fold their two directions."""
    dirs: Dict[str, float] = {}
    n_dirs = (1 if rows == 2 else 2 if rows > 2 else 0) + (1 if cols == 2 else 2 if cols > 2 else 0)
    if n_dirs == 0:
        return {"self": 1.0}
    w_self = 1.0 / (n_dirs + 1) if self_weight is None else float(self_weight)
    share = (1.0 - w_self) / n_dirs
    dirs["self"] = w_self
    if rows == 2:
        dirs["row+"] = share
    elif rows > 2:
        dirs["row+"] = dirs["row-"] = share
    if cols == 2:
        dirs["col+"] = share
    elif cols > 2:
        dirs["col+"] = dirs["col-"] = share
    return dirs
