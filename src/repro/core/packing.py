"""Flat-buffer packing: the node-stacked pytree as ONE contiguous matrix.

Every gossip backend mixes along the leading ``nodes`` axis and treats the
rest of each leaf as an opaque payload. Traversing the pytree leaf-by-leaf
therefore pays per-leaf overhead (one einsum / one ppermute-per-direction /
one quantize pass *per leaf per round*) for no semantic gain. This module
collapses the state into a single ``(nodes, total_params)`` buffer plus a
static :class:`FlatLayout` record (per-leaf offset/shape/dtype), so a gossip
round becomes ONE matmul (dense W), ONE ppermute per torus direction (mesh
backend), or ONE all-gather (arbitrary W) -- independent of leaf count.

Layouts are static Python data (hashable, usable as a jit static argument);
``pack``/``unpack`` lower to pure reshapes + concatenate / slices, which XLA
fuses away, and the round trip is lossless: each leaf is stored in its own
dtype's bit-width inside a common buffer dtype wide enough to hold it
exactly (fp32 holds bf16/fp16/fp32 losslessly).

Wire-byte accounting: a flat int8 payload costs ``total`` bytes +
4 bytes per (node, scale-chunk) for the scales -- see
:func:`flat_wire_bytes` and ``compression.compressed_wire_bytes`` for the
per-leaf equivalent.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = [
    "FlatLayout",
    "pack",
    "pack_layout",
    "pack_like",
    "unpack",
    "flat_wire_bytes",
    "flat_wire_bytes_per_shard",
    "scoped_layout",
    "compact_pos_dtype",
    "compact_index_bytes",
    "bitmap_bytes_per_chunk",
]


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    offset: int  # column offset into the flat buffer
    shape: Tuple[int, ...]  # per-node shape (leading nodes axis stripped)
    dtype: str  # original leaf dtype name, restored by unpack

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static description of a packed node-stacked pytree -- the CONTRACT
    between the tree world and the flat engine.

    A layout promises, for a buffer ``flat`` of shape
    ``(n_nodes, total)``:

    * **Column map.** Leaf ``k`` (in ``tree_flatten`` order) occupies
      columns ``[leaves[k].offset, leaves[k].offset + leaves[k].size)``;
      leaves are contiguous, in order, and non-overlapping
      (``offset[k+1] == offset[k] + size[k]``).
    * **Padding.** Columns ``[used, total)`` are structural zero padding
      (``pack(..., pad_to=k)`` rounds ``total`` up so the buffer tiles
      evenly into kernel ``scale_chunk`` blocks). Engine ops must keep
      them zero-preserving: every shipped backend is columnwise, so zeros
      mix/update/quantize to zeros and ``unpack`` never reads them.
    * **Dtype round trip.** ``unpack(pack(tree)) == tree`` exactly when
      ``storage_dtype`` holds every leaf dtype losslessly (the fp32
      default covers fp32/bf16/fp16): each leaf is stored widened to the
      buffer dtype and ``unpack`` restores ``leaves[k].dtype``. A NARROW
      ``storage_dtype`` (bf16 flat storage -- halves the HBM traffic of
      every buffer-wide op) rounds wider leaves on pack; engines that
      opt in keep fp32 only in their mix accumulators.
    * **Static + hashable.** Layouts are plain Python data (treedef +
      tuple of :class:`LeafSpec`), computable from ShapeDtypeStructs alone
      (:func:`pack_layout`) -- usable as a jit static argument and at
      trace time in lowering-only dry runs.

    Mutating state between pack and unpack is fine as long as shapes stay
    ``(n_nodes, total)``: the flat/fused GossipEngines
    (``make_fl_round(engine=...)``) run whole training rounds on the
    buffer and unpack only at the read-out boundary.
    """

    treedef: Any
    leaves: Tuple[LeafSpec, ...]
    n_nodes: int
    total: int
    #: dtype the flat buffer is STORED in ("float32" default; "bfloat16"
    #: halves HBM traffic of every buffer-wide op -- engines keep fp32
    #: only in the mix accumulator). Not necessarily lossless for wider
    #: leaf dtypes.
    storage_dtype: str = "float32"
    #: how many equal column tiles the buffer splits into on a two-axis
    #: ``(gossip_node, model_shard)`` mesh: shard s owns columns
    #: ``[s * shard_width, (s + 1) * shard_width)``. ``total`` is padded
    #: so every shard is a whole number of kernel chunks (pack with
    #: ``pad_to=scale_chunk, shards=S``); the default 1 is the
    #: single-axis layout every pre-two-axis engine uses.
    shards: int = 1

    def __post_init__(self):
        if self.shards < 1:
            raise ValueError(f"shards={self.shards} must be >= 1")
        if self.total % self.shards:
            raise ValueError(
                f"layout.total {self.total} not divisible by "
                f"shards={self.shards}; pack with pad_to and shards "
                "together so each shard is a whole tile"
            )

    @property
    def used(self) -> int:
        return sum(l.size for l in self.leaves)

    @property
    def n_leaves(self) -> int:
        return len(self.leaves)

    @property
    def shard_width(self) -> int:
        """Columns each model shard owns (``total / shards``)."""
        return self.total // self.shards

    def with_shards(self, shards: int) -> "FlatLayout":
        """The same layout re-tiled over ``shards`` model shards (the
        padded ``total`` must already divide evenly -- pack with
        ``shards=`` to get the right padding up front)."""
        return dataclasses.replace(self, shards=int(shards))


def _layout(treedef, leaf_list, n_nodes: int, pad_to: int,
            storage_dtype, shards: int = 1) -> FlatLayout:
    specs = []
    off = 0
    for leaf in leaf_list:
        shape = tuple(leaf.shape[1:])
        specs.append(LeafSpec(off, shape, jnp.dtype(leaf.dtype).name))
        off += specs[-1].size
    # each model shard must itself tile into whole pad_to (scale_chunk)
    # blocks, so the effective rounding unit is pad_to * shards
    unit = max(pad_to, 1) * max(int(shards), 1)
    total = off if unit <= 1 else ((off + unit - 1) // unit) * unit
    return FlatLayout(treedef, tuple(specs), n_nodes, total,
                      jnp.dtype(storage_dtype).name, max(int(shards), 1))


def pack_layout(tree: PyTree, pad_to: int = 1,
                storage_dtype=jnp.float32, shards: int = 1) -> FlatLayout:
    """Compute the layout without materializing the buffer (works on
    ShapeDtypeStructs too -- used by lowering-only dry runs).
    ``shards > 1`` pads ``total`` to a multiple of ``pad_to * shards``
    so every model shard is a whole number of kernel chunks."""
    leaf_list, treedef = jax.tree_util.tree_flatten(tree)
    if not leaf_list:
        raise ValueError("cannot pack an empty pytree")
    n_nodes = leaf_list[0].shape[0]
    for leaf in leaf_list:
        if leaf.ndim < 1 or leaf.shape[0] != n_nodes:
            raise ValueError(
                f"leaf shape {leaf.shape} is not node-stacked for n={n_nodes}"
            )
    return _layout(treedef, leaf_list, n_nodes, pad_to, storage_dtype, shards)


def pack(
    tree: PyTree, pad_to: int = 1, buffer_dtype=jnp.float32, shards: int = 1
) -> Tuple[jnp.ndarray, FlatLayout]:
    """Pack a node-stacked pytree into one ``(nodes, total)`` buffer.

    Args:
      tree: pytree whose every leaf is ``(nodes, ...)``.
      pad_to: round ``total`` up to a multiple (zero-filled tail) so the
        buffer tiles evenly into kernel chunks.
      buffer_dtype: storage dtype of the flat buffer (recorded as
        ``layout.storage_dtype``). fp32 holds fp32/bf16/fp16 losslessly;
        bf16 storage rounds fp32 leaves (the flat engine's bf16 mode).

    Returns:
      (flat, layout) with ``flat.shape == (nodes, layout.total)``.
    """
    layout = pack_layout(tree, pad_to, storage_dtype=buffer_dtype,
                         shards=shards)
    leaf_list = jax.tree_util.tree_leaves(tree)
    n = layout.n_nodes
    cols = [l.reshape(n, -1).astype(buffer_dtype) for l in leaf_list]
    if layout.total > layout.used:
        cols.append(jnp.zeros((n, layout.total - layout.used), buffer_dtype))
    return jnp.concatenate(cols, axis=1), layout


def pack_like(tree: PyTree, layout: FlatLayout, buffer_dtype=None) -> jnp.ndarray:
    """Pack a pytree into an EXISTING layout (same structure and per-leaf
    shapes; zero-padded to ``layout.total``; stored in the layout's
    ``storage_dtype`` unless overridden). Used to flatten gradients into
    the same columns as the packed parameters they update."""
    leaf_list, treedef = jax.tree_util.tree_flatten(tree)
    if treedef != layout.treedef:
        raise ValueError(f"tree structure {treedef} != layout {layout.treedef}")
    if buffer_dtype is None:
        buffer_dtype = layout.storage_dtype
    n = layout.n_nodes
    cols = []
    for leaf, spec in zip(leaf_list, layout.leaves):
        if leaf.shape != (n,) + spec.shape:
            raise ValueError(f"leaf shape {leaf.shape} != layout {(n,) + spec.shape}")
        cols.append(leaf.reshape(n, -1).astype(buffer_dtype))
    if layout.total > layout.used:
        cols.append(jnp.zeros((n, layout.total - layout.used), buffer_dtype))
    return jnp.concatenate(cols, axis=1)


def unpack(flat: jnp.ndarray, layout: FlatLayout) -> PyTree:
    """Invert :func:`pack`: slice, reshape, and restore each leaf's dtype."""
    if flat.shape != (layout.n_nodes, layout.total):
        raise ValueError(
            f"flat buffer {flat.shape} does not match layout "
            f"({layout.n_nodes}, {layout.total})"
        )
    n = layout.n_nodes
    leaves = [
        jax.lax.slice_in_dim(flat, s.offset, s.offset + s.size, axis=1)
        .reshape((n,) + s.shape)
        .astype(s.dtype)
        for s in layout.leaves
    ]
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def compact_pos_dtype(scale_chunk: int):
    """Dtype of the compact wire's in-chunk position buffer: int16 when a
    chunk index fits (the common case -- chunk <= 32768), int32 otherwise.
    The SAME boundary drives :func:`flat_wire_bytes`, so the accounting
    is the bytes the collective actually moves."""
    return jnp.int16 if scale_chunk <= 2 ** 15 else jnp.int32


def bitmap_bytes_per_chunk(scale_chunk: int) -> int | None:
    """Bytes of one chunk's presence bitmap, or None when the bitmap
    encoding is unavailable (chunk not byte-aligned). The SAME predicate
    gates the engine's encoding choice and the accounting."""
    return scale_chunk // 8 if scale_chunk % 8 == 0 else None


def compact_index_bytes(scale_chunk: int, topk: int) -> int:
    """Index bytes ONE chunk's compact top-k payload ships: the cheaper
    of explicit positions (k x int16/int32, :func:`compact_pos_dtype`)
    and the presence bitmap (chunk/8 B, byte-aligned chunks only). The
    bitmap wins for k > chunk/16 (int16 positions) -- the boundary the
    sharded engine's ``wire_encoding`` mirrors exactly, so the accounted
    bytes ARE the collective operand bytes."""
    explicit = topk * jnp.dtype(compact_pos_dtype(scale_chunk)).itemsize
    bitmap = bitmap_bytes_per_chunk(scale_chunk)
    return explicit if bitmap is None else min(explicit, bitmap)


def flat_wire_bytes(
    layout: FlatLayout, degree: int, scale_chunk: int = 0,
    topk: int | None = None,
) -> int:
    """Per-node egress bytes per round for an int8 flat payload, times the
    out-degree.

    Dense int8 (``topk=None``): 1 B/param + 4 B per scale chunk
    (``scale_chunk=0``: one scale per node).

    Top-k sparsified (``topk=k``): the COMPACT encoding the wire-stage
    kernels actually emit (``kernels.gossip.wire_stage_compact`` + the
    engine's encoding epilogue) -- per scale chunk, exactly k int8 values
    + the CHEAPER index encoding (:func:`compact_index_bytes`: explicit
    int16/int32 positions vs the chunk/8-byte presence bitmap, picked
    per (k, chunk)) + the 4 B scale, capped at the dense chunk bytes (a
    sender whose compact encoding would exceed dense just ships dense).
    This is not a model: the collective's operand shapes ARE these
    buffers (asserted against the jaxpr in tests/test_schedule.py and
    tests/test_dynamics.py).
    """
    n_scales = 1 if scale_chunk <= 0 else -(-layout.total // scale_chunk)
    if topk is None or scale_chunk <= 0 or topk >= scale_chunk:
        return degree * (layout.total + 4 * n_scales)
    index_bytes = compact_index_bytes(scale_chunk, topk)
    per_chunk = min(topk + index_bytes + 4, scale_chunk + 4)
    return degree * (n_scales * per_chunk)


def scoped_layout(
    layout: FlatLayout, ranges, scale_chunk: int
) -> Tuple[FlatLayout, Tuple[Tuple[int, int], ...]]:
    """Accounting layout + shard-local column ranges for a SCOPED wire.

    A :class:`~repro.core.scope.FederationScope` restricts gossip to the
    merged, disjoint global column ``ranges`` of ``layout``. The fused
    engines gather those columns into one contiguous scoped buffer (per
    shard tile on a two-axis mesh), run the unchanged wire stage on it,
    and scatter the mixed result back -- so the wire state (recon, EF
    residual, in-flight rings), the quantization scales, the collective
    operands, and the byte accounting all live at the SCOPED width.

    Returns ``(wire_layout, local_ranges)``:

    * ``local_ranges`` -- the ranges intersected with one shard tile, in
      SHARD-LOCAL coordinates. They must come out IDENTICAL for every
      shard (each shard's wire slice must be the same width and chunk
      geometry -- the same reason ``with_shards`` pads per shard);
      a scope whose ranges straddle shard tiles unevenly is refused with
      the mismatching shards named.
    * ``wire_layout`` -- a synthetic single-leaf :class:`FlatLayout`
      whose ``total`` is the chunk-padded scoped width (x shards, shards
      preserved) and whose ``used`` is the un-padded shared column count,
      so :func:`flat_wire_bytes` / :func:`flat_wire_bytes_per_shard` on
      it ARE the scoped wire accounting, byte-compatible with the
      collective operands the scoped round lowers to.
    """
    ranges = tuple((int(a), int(b)) for a, b in ranges)
    pos = 0
    for a, b in ranges:
        if not (pos <= a < b <= layout.total):
            raise ValueError(
                f"scoped ranges {ranges!r} must be sorted, disjoint, "
                f"non-empty, within [0, {layout.total})"
            )
        pos = b
    s = layout.shards
    w = layout.shard_width
    per_shard = []
    for i in range(s):
        lo, hi = i * w, (i + 1) * w
        local = tuple(
            (max(a, lo) - lo, min(b, hi) - lo)
            for a, b in ranges if a < hi and b > lo
        )
        per_shard.append(local)
    if any(local != per_shard[0] for local in per_shard):
        widths = [sum(b - a for a, b in local) for local in per_shard]
        raise ValueError(
            f"scoped ranges are not uniform across the {s} model shards "
            f"(per-shard shared widths {widths}); every shard tile must "
            "carry the same scoped slice -- align the scope's ranges "
            "with the shard tiles (shard_width="
            f"{w}) or run single-axis"
        )
    local_ranges = per_shard[0]
    shared_local = sum(b - a for a, b in local_ranges)
    if shared_local == 0:
        raise ValueError(
            f"scoped ranges {ranges!r} share no columns; a scope must "
            "leave something on the wire"
        )
    unit = max(int(scale_chunk), 1)
    padded_local = ((shared_local + unit - 1) // unit) * unit
    wire_layout = FlatLayout(
        treedef=jax.tree_util.tree_structure(0),
        leaves=(LeafSpec(0, (shared_local * s,), "float32"),),
        n_nodes=layout.n_nodes,
        total=padded_local * s,
        storage_dtype=layout.storage_dtype,
        shards=s,
    )
    return wire_layout, local_ranges


def flat_wire_bytes_per_shard(
    layout: FlatLayout, degree: int, scale_chunk: int = 0,
    topk: int | None = None,
) -> int:
    """Per-(node, shard) egress bytes per round on a two-axis mesh: each
    model shard ships its own chunk-aligned slice of the wire, so the
    per-shard bytes are exactly ``flat_wire_bytes / shards`` -- the
    identity the sharded engine's per-tile collective operands realize
    (and the jaxpr assertions in tests/test_two_axis.py check). Requires
    the shard-aligned padding :func:`pack_layout` with ``shards=``
    guarantees (``total % (scale_chunk * shards) == 0``)."""
    s = layout.shards
    if s <= 1:
        return flat_wire_bytes(layout, degree, scale_chunk, topk)
    if scale_chunk > 0 and layout.shard_width % scale_chunk:
        raise ValueError(
            f"shard width {layout.shard_width} not a multiple of "
            f"scale_chunk {scale_chunk}; pack with pad_to={scale_chunk}, "
            f"shards={s}"
        )
    n_scales = 1 if scale_chunk <= 0 else layout.shard_width // scale_chunk
    if topk is None or scale_chunk <= 0 or topk >= scale_chunk:
        return degree * (layout.shard_width + 4 * n_scales)
    index_bytes = compact_index_bytes(scale_chunk, topk)
    per_chunk = min(topk + index_bytes + 4, scale_chunk + 4)
    return degree * (n_scales * per_chunk)
