"""The while-aware HLO analyzer vs ground truth (unrolled lowerings)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _cost(compiled):
    """compiled.cost_analysis() returns a dict (jax >= 0.5) or a 1-list of
    dicts (jax 0.4.x)."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_scan_flops_match_unrolled():
    def f_scan(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=9)
        return h.sum()

    def f_unroll(x, w):
        h = x
        for _ in range(9):
            h = jnp.tanh(h @ w)
        return h.sum()

    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a_scan = analyze_hlo(_compile(f_scan, xs, ws).as_text())
    c_unroll = _compile(f_unroll, xs, ws)
    truth = _cost(c_unroll)["flops"]
    dot_flops = 9 * 2 * 64 * 128 * 128
    assert abs(a_scan.flops - truth) / truth < 0.02
    assert a_scan.flops >= dot_flops


def test_nested_scan_multiplication():
    def f(x, w):
        def outer(h, _):
            def inner(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, None, length=4)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h.sum()

    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    a = analyze_hlo(_compile(f, xs, ws).as_text())
    expect = 3 * 4 * 2 * 32 * 64 * 64
    assert abs(a.flops - expect) / expect < 0.05


def test_grad_of_scan_counts_forward_and_backward():
    def loss(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=6)
        return jnp.sum(h * h)

    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    a_fwd = analyze_hlo(_compile(loss, xs, ws).as_text())
    a_grad = analyze_hlo(_compile(jax.grad(loss, argnums=(0, 1)), xs, ws).as_text())
    # backward ~ 2x forward matmul cost (dx and dw) on top of the forward
    assert a_grad.flops > 2.4 * a_fwd.flops


def test_collectives_exact_count_and_bytes():
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.make_mesh((8,), ("model",))
        def g(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, None, length=5)
            return h.sum()
        xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        with mesh:
            c = jax.jit(g, in_shardings=(
                NamedSharding(mesh, P(None, "model")),
                NamedSharding(mesh, P("model", None)))).lower(xs, ws).compile()
        a = analyze_hlo(c.as_text())
        ar = a.collectives["all-reduce"]
        # 5 in-loop activation all-reduces (128x256 fp32) + 1 scalar
        assert ar["count"] == 6, ar
        assert abs(ar["bytes"] - (5 * 128 * 256 * 4 + 4)) < 8, ar
        print("COLL-OK")
        """
    )
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "COLL-OK" in proc.stdout


def test_q4_round_hand_count_and_trip_inference():
    """A Q=4 DSGD round vs an exact hand count of its matmul flops --
    and the same HLO with every ``known_trip_count`` annotation stripped
    must analyze IDENTICALLY (trip count recovered from the loop
    condition's ``counter < N`` bound). Before that fallback existed,
    an un-annotated scanned body silently counted once."""
    import re

    from repro.core.fl import FLConfig, init_fl_state, make_fl_round
    from repro.core.mixing import make_dense_gossip
    from repro.core.topology import metropolis_weights, ring_graph

    n, din, dh, q, batch = 4, 32, 64, 4, 8
    key = jax.random.key(0)
    params = {
        "w1": jax.random.normal(key, (n, din, dh), jnp.float32),
        "w2": jax.random.normal(key, (n, dh, 2), jnp.float32),
    }

    def loss_fn(p, b):
        x, y = b
        h = jnp.tanh(x @ p["w1"])
        return jnp.mean((h @ p["w2"] - y) ** 2)

    gossip = make_dense_gossip(metropolis_weights(ring_graph(n)))
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    state = init_fl_state(cfg, params)
    round_fn = make_fl_round(
        loss_fn, gossip, schedule=lambda s: jnp.float32(0.01), cfg=cfg)
    batches = (jnp.zeros((q, n, batch, din)), jnp.zeros((q, n, batch, 2)))
    text = _compile(round_fn, state, batches).as_text()

    # hand count, per local step, all n nodes:
    #   forward   x@w1 (2*n*B*dh*din) + h@w2 (2*n*B*2*dh)
    #   backward  dlogits@w2^T (2*n*B*dh*2)   [dx of layer 2]
    #             x^T@dh (2*n*din*dh*B)       [dw1]
    #             h^T@dlogits (2*n*dh*2*B)    [dw2]
    #   (no dx for layer 1: x is data, grads are wrt params only)
    per_step = (2 * n * batch * dh * din + 2 * n * batch * 2 * dh
                + 2 * n * batch * dh * 2 + 2 * n * din * dh * batch
                + 2 * n * dh * 2 * batch)
    # gossip mix: W (n,n) @ params (n, total); XLA concatenates the two
    # leaves into one (n, din*dh + dh*2) operand
    total = din * dh + dh * 2
    hand_dots = q * per_step + 2 * n * n * total

    a = analyze_hlo(text)
    # analyzer = exact dot flops + a 1-flop/elem fusion estimate on top
    assert a.flops >= hand_dots
    assert a.flops <= hand_dots * 1.25

    stripped = re.sub(r'"?known_trip_count"?\s*:\s*\{[^}]*\},?', "", text)
    assert "known_trip_count" not in stripped
    a_inferred = analyze_hlo(stripped)
    assert a_inferred.flops == a.flops
    assert a_inferred.traffic_bytes == a.traffic_bytes


def test_traffic_includes_loop_body():
    def f_scan(x):
        def body(h, _):
            return jnp.sin(h) * 2.0, None
        h, _ = jax.lax.scan(body, x, None, length=50)
        return h

    xs = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    a = analyze_hlo(_compile(f_scan, xs).as_text())
    one_buffer = 1024 * 1024 * 4
    # >= 50 reads + 50 writes of the carried buffer
    assert a.traffic_bytes >= 90 * one_buffer
