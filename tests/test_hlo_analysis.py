"""The while-aware HLO analyzer vs ground truth (unrolled lowerings)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _cost(compiled):
    """compiled.cost_analysis() returns a dict (jax >= 0.5) or a 1-list of
    dicts (jax 0.4.x)."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_scan_flops_match_unrolled():
    def f_scan(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=9)
        return h.sum()

    def f_unroll(x, w):
        h = x
        for _ in range(9):
            h = jnp.tanh(h @ w)
        return h.sum()

    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a_scan = analyze_hlo(_compile(f_scan, xs, ws).as_text())
    c_unroll = _compile(f_unroll, xs, ws)
    truth = _cost(c_unroll)["flops"]
    dot_flops = 9 * 2 * 64 * 128 * 128
    assert abs(a_scan.flops - truth) / truth < 0.02
    assert a_scan.flops >= dot_flops


def test_nested_scan_multiplication():
    def f(x, w):
        def outer(h, _):
            def inner(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, None, length=4)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h.sum()

    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    a = analyze_hlo(_compile(f, xs, ws).as_text())
    expect = 3 * 4 * 2 * 32 * 64 * 64
    assert abs(a.flops - expect) / expect < 0.05


def test_grad_of_scan_counts_forward_and_backward():
    def loss(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=6)
        return jnp.sum(h * h)

    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    a_fwd = analyze_hlo(_compile(loss, xs, ws).as_text())
    a_grad = analyze_hlo(_compile(jax.grad(loss, argnums=(0, 1)), xs, ws).as_text())
    # backward ~ 2x forward matmul cost (dx and dw) on top of the forward
    assert a_grad.flops > 2.4 * a_fwd.flops


def test_collectives_exact_count_and_bytes():
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.make_mesh((8,), ("model",))
        def g(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, None, length=5)
            return h.sum()
        xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        with mesh:
            c = jax.jit(g, in_shardings=(
                NamedSharding(mesh, P(None, "model")),
                NamedSharding(mesh, P("model", None)))).lower(xs, ws).compile()
        a = analyze_hlo(c.as_text())
        ar = a.collectives["all-reduce"]
        # 5 in-loop activation all-reduces (128x256 fp32) + 1 scalar
        assert ar["count"] == 6, ar
        assert abs(ar["bytes"] - (5 * 128 * 256 * 4 + 4)) < 8, ar
        print("COLL-OK")
        """
    )
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "COLL-OK" in proc.stdout


def test_traffic_includes_loop_body():
    def f_scan(x):
        def body(h, _):
            return jnp.sin(h) * 2.0, None
        h, _ = jax.lax.scan(body, x, None, length=50)
        return h

    xs = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    a = analyze_hlo(_compile(f_scan, xs).as_text())
    one_buffer = 1024 * 1024 * 4
    # >= 50 reads + 50 writes of the carried buffer
    assert a.traffic_bytes >= 90 * one_buffer
