"""End-to-end FL training: the paper's EHR task + LM smoke training +
checkpoint roundtrip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLRunConfig, get_config
from repro.configs.ehr_mlp import class_weights
from repro.core.fl import FLConfig, init_fl_state
from repro.data.ehr import generate_ehr_cohort, make_node_batcher
from repro.data.tokens import make_fl_token_batches
from repro.models import build_model
from repro.models.mlp import (
    make_mlp_loss,
    mlp_accuracy,
    mlp_init,
    mlp_logits,
    mlp_loss,
)
from repro.training.checkpoint import load_fl_state, save_fl_state
from repro.training.trainer import train_decentralized

# real multi-round training runs (~30 s): excluded from the fast tier-1 subset
pytestmark = pytest.mark.slow


def test_ehr_fl_training_learns(tmp_path):
    """DSGT on the synthetic 20-hospital cohort: loss drops, consensus model
    beats chance comfortably (the paper's Section 3 setting, scaled down),
    and class weighting lifts balanced accuracy off the ~0.6 saturation the
    unweighted loss hits on the 79%-MCI cohort."""
    data = generate_ehr_cohort(seed=0)
    params = mlp_init(jax.random.key(0))

    xall = np.concatenate(data.features)
    yall = np.concatenate(data.labels)

    def eval_fn(consensus):
        pred = np.asarray(jnp.argmax(mlp_logits(consensus, jnp.asarray(xall)), -1))
        bal = np.mean([(pred[yall == k] == k).mean() for k in np.unique(yall)])
        return {
            "acc": float(mlp_accuracy(consensus, jnp.asarray(xall), jnp.asarray(yall))),
            "bal_acc": float(bal),
        }

    results = {}
    for name, loss in (("unweighted", mlp_loss),
                       ("weighted", make_mlp_loss(class_weights("balanced")))):
        run = FLRunConfig(
            algorithm="dsgt", q=5, topology="hospital20", n_nodes=20,
            batch_per_node=20, alpha0=0.05, schedule="constant",
        )
        results[name] = train_decentralized(
            loss, params, run, make_node_batcher(data, m=20, seed=1),
            rounds=60, eval_fn=eval_fn, eval_every=60,
        )

    result = results["unweighted"]
    hist = result.history
    losses = hist.column("loss")
    assert losses[-1] < losses[0] * 0.8
    # The cohort is 79% MCI, so plain accuracy near 0.80 is close to the
    # majority rate; require it not to degenerate AND require balanced
    # accuracy (chance = 0.5) to show learning on BOTH classes.
    assert hist.last()["eval_acc"] > 0.78
    assert hist.last()["eval_bal_acc"] > 0.55

    # Class weighting (configs.ehr_mlp.class_weights) must move balanced
    # accuracy off the unweighted saturation point by a real margin.
    bal_un = hist.last()["eval_bal_acc"]
    bal_w = results["weighted"].history.last()["eval_bal_acc"]
    assert bal_w > 0.64, bal_w
    assert bal_w > bal_un + 0.04, (bal_un, bal_w)

    # checkpoint roundtrip on the real state
    path = os.path.join(tmp_path, "ckpt")
    save_fl_state(path, result.state, extra={"run": "test"})
    cfg = FLConfig(algorithm="dsgt", q=5, n_nodes=20)
    template = init_fl_state(cfg, jax.tree.map(lambda p: jnp.zeros_like(p), result.state.params))
    restored = load_fl_state(path, template)
    assert int(restored.step) == int(result.state.step)
    for a, b in zip(jax.tree.leaves(restored.params), jax.tree.leaves(result.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_fd_q_saves_communication_at_matched_quality():
    """The paper's headline: at a matched ITERATION budget, Q=10 uses 10x
    fewer communication rounds and reaches comparable loss."""
    data = generate_ehr_cohort(seed=0)
    results = {}
    t_iterations = 200
    for q in (1, 10):
        run = FLRunConfig(
            algorithm="dsgt", q=q, topology="hospital20", n_nodes=20,
            batch_per_node=20, alpha0=0.05, schedule="constant", seed=0,
        )
        res = train_decentralized(
            mlp_loss, mlp_init(jax.random.key(0)), run,
            make_node_batcher(data, m=20, seed=2), rounds=t_iterations // q,
        )
        results[q] = res.history.last()
    assert results[10]["comm_rounds"] == results[1]["comm_rounds"] / 10
    assert results[10]["iteration"] == results[1]["iteration"]
    # comparable final loss (within 15%)
    assert results[10]["loss"] < results[1]["loss"] * 1.15


def test_lm_smoke_training_loss_decreases():
    """A reduced llama-family model actually learns the synthetic token
    structure under FD-DSGT."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg)
    run = FLRunConfig(
        algorithm="dsgt", q=2, topology="ring", n_nodes=4,
        batch_per_node=2, alpha0=0.5, schedule="constant",
    )
    rounds_iter = make_fl_token_batches(cfg.vocab_size, 4, 2, 64, q=1, seed=0)

    def step_batches():
        while True:
            yield {k: v[0] for k, v in next(rounds_iter).items()}

    res = train_decentralized(
        bundle.loss_fn, bundle.init_fn(jax.random.key(0)), run,
        step_batches(), rounds=25,
    )
    losses = res.history.column("loss")
    assert losses[-1] < losses[0] - 0.3, losses
    assert np.isfinite(losses).all()
