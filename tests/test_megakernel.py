"""Round megakernel: fused update+quantize+mix+EF == the driver-level
(local-step then gossip-reference) composition for DSGD and DSGT, the
Pallas kernels == the jnp oracles, and the fused comm round emits exactly
ONE kernel call.

The composition oracle is built from the PRE-EXISTING primitives only --
``make_fl_round`` with an identity mix (whose comm step is then exactly
the plain local update / tracker arithmetic) followed by
``make_compressed_flat_gossip`` on each wire -- so these tests pin the
megakernel to the semantics the engine already had, not to a parallel
reimplementation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (
    init_flat_compression_state,
    make_compressed_flat_gossip,
)
from repro.core.engine import FlatEngine, FusedEngine
from repro.core.fl import FLConfig, init_fl_state, make_fl_round
from repro.core.packing import pack, unpack
from repro.core.schedules import constant, inv_sqrt
from repro.core.topology import mixing_matrix

ATOL = 1e-5


def _problem(n, q, seed=0):
    rng = np.random.default_rng(seed)

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {
        "w": jnp.asarray(rng.normal(size=(n, 4, 3)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    }
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 3)), jnp.float32)}
    return loss, params, batches


def _run_fused(loss, flat, layout, batches, cfg, w, chunk, impl, rounds, sched):
    engine = FusedEngine(w, layout, scale_chunk=chunk, impl=impl)
    rf = jax.jit(make_fl_round(loss, None, sched, cfg, engine=engine))
    st = init_fl_state(cfg, flat, engine=engine)
    m = None
    for _ in range(rounds):
        st, m = rf(st, batches)
    return st, m


def _run_composition(loss, flat, layout, batches, cfg, w, chunk, rounds, sched):
    """Local-step-then-gossip-reference: make_fl_round with the identity
    mix runs Q local steps plus the bare update/tracker arithmetic (an
    identity-W comm step IS the local update), then each wire goes through
    one compressed flat gossip round -- the unfused engine of PR 1."""
    rf_local = jax.jit(
        make_fl_round(loss, None, sched, cfg, engine=FlatEngine(lambda f: f, layout))
    )
    gossip = make_compressed_flat_gossip(w, scale_chunk=chunk)
    gossip = jax.jit(gossip)
    st = init_fl_state(cfg, flat)
    comp_x = init_flat_compression_state(flat)
    comp_t = init_flat_compression_state(flat)
    m = None
    for _ in range(rounds):
        st, m = rf_local(st, batches)
        px, comp_x = gossip(st.params, comp_x)
        if cfg.algorithm == "dsgt":
            pt, comp_t = gossip(st.tracker, comp_t)
            st = st._replace(params=px, tracker=pt)
        else:
            st = st._replace(params=px)
    return st, m


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
@pytest.mark.parametrize("n,topo,chunk", [
    (8, "ring", 8),
    (8, "ring", 32),
    (16, "torus:4x4", 8),
    (16, "torus:4x4", 64),
])
def test_fused_round_matches_update_then_mix(impl, algorithm, n, topo, chunk):
    """The megakernel round == (Q local steps, update, then compressed
    gossip of each wire) across >= 2 chunk sizes and node counts, for both
    impls, over several rounds (so the EF/recon state threading is
    exercised, not just one application)."""
    q, rounds = 3, 4
    w = mixing_matrix(topo, n)
    loss, params, batches = _problem(n, q, seed=n + chunk)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    flat, layout = pack(params, pad_to=chunk)
    sched = inv_sqrt(0.05)

    st_f, m_f = _run_fused(loss, flat, layout, batches, cfg, w, chunk, impl, rounds, sched)
    st_c, m_c = _run_composition(loss, flat, layout, batches, cfg, w, chunk, rounds, sched)

    np.testing.assert_allclose(
        np.asarray(st_f.params), np.asarray(st_c.params), atol=ATOL
    )
    if algorithm == "dsgt":
        np.testing.assert_allclose(
            np.asarray(st_f.tracker), np.asarray(st_c.tracker), atol=ATOL
        )
        np.testing.assert_allclose(
            np.asarray(st_f.prev_grad), np.asarray(st_c.prev_grad), atol=ATOL
        )
    # unpacked view agrees leaf-by-leaf too
    back_f, back_c = unpack(st_f.params, layout), unpack(st_c.params, layout)
    for k in back_f:
        np.testing.assert_allclose(np.asarray(back_f[k]), np.asarray(back_c[k]), atol=ATOL)
    for k in ("loss", "grad_norm_sq", "local_loss"):
        np.testing.assert_allclose(float(m_f[k]), float(m_c[k]), rtol=1e-4, atol=1e-6)
    # the composition's consensus_err metric is measured before its gossip
    # stage (identity mix), so compare the fused metric against a direct
    # recomputation on the final mixed parameters instead
    pf = np.asarray(st_f.params)
    dev = pf - pf.mean(axis=0, keepdims=True)
    np.testing.assert_allclose(
        float(m_f["consensus_err"]), float((dev * dev).sum() / n), rtol=1e-4, atol=1e-6
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("cfg", [
    # (n, t, chunk, ef, dc)
    (16, 256, 64, True, True),
    (8, 512, 128, True, False),
    (64, 1024, 256, True, True),
    (8, 96, 32, False, True),
])
def test_fused_dsgd_kernel_matches_ref(seed, cfg):
    """fused_round (Pallas, interpret on CPU) == fused_round_ref on every
    output, atol 1e-5."""
    from repro.kernels.gossip import fused_round, fused_round_ref

    n, t, ck, ef, dc = cfg
    rng = np.random.default_rng(seed)
    w = mixing_matrix("ring", n)
    w_self = jnp.asarray(np.diag(w), jnp.float32)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)
    scale = 10.0 ** rng.integers(-2, 2)
    mk = lambda s: jnp.asarray(s * rng.normal(size=(n, t)), jnp.float32)
    x, g, recon, res = mk(scale), mk(scale), mk(scale), mk(0.1 * scale)
    alpha = jnp.float32(0.05)
    outs_k = fused_round(x, g, recon, res, w_off, w_self, alpha, scale_chunk=ck,
                         error_feedback=ef, difference_coding=dc)
    outs_r = fused_round_ref(x, g, recon, res, w_off, w_self, alpha, scale_chunk=ck,
                             error_feedback=ef, difference_coding=dc)
    for name, a, b in zip(("mixed", "recon", "res", "scales"), outs_k, outs_r):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=ATOL * max(scale, 1.0), err_msg=name
        )


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n,t,ck", [(16, 256, 64), (8, 128, 32), (64, 512, 256)])
def test_fused_dsgt_kernel_matches_ref(seed, n, t, ck):
    """fused_round_gt (Pallas, interpret on CPU) == fused_round_gt_ref on
    all eight outputs, atol 1e-5."""
    from repro.kernels.gossip import fused_round_gt, fused_round_gt_ref

    rng = np.random.default_rng(seed)
    w = mixing_matrix("ring", n)
    w_self = jnp.asarray(np.diag(w), jnp.float32)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)
    mk = lambda s: jnp.asarray(s * rng.normal(size=(n, t)), jnp.float32)
    args = (mk(1.0), mk(0.3), mk(0.5), mk(0.5), mk(1.0), mk(0.1), mk(1.0), mk(0.1))
    alpha = jnp.float32(0.02)
    outs_k = fused_round_gt(*args, w_off, w_self, alpha, scale_chunk=ck)
    outs_r = fused_round_gt_ref(*args, w_off, w_self, alpha, scale_chunk=ck)
    names = ("mixed_x", "mixed_t", "recon_x", "res_x", "recon_t", "res_t",
             "scales_x", "scales_t")
    for name, a, b in zip(names, outs_k, outs_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL, err_msg=name)


# ---------------------------------------------------------------------------
# single-kernel-call lowering assert
# ---------------------------------------------------------------------------


def _count_primitive(jaxpr, name: str) -> int:
    """Count `name` eqns in a jaxpr, descending into sub-jaxprs (scan
    bodies, cond branches, pjit calls)."""
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            count += 1
        for v in eqn.params.values():
            subs = v if isinstance(v, (list, tuple)) else [v]
            for sub in subs:
                if hasattr(sub, "jaxpr"):  # ClosedJaxpr
                    count += _count_primitive(sub.jaxpr, name)
                elif hasattr(sub, "eqns"):  # Jaxpr
                    count += _count_primitive(sub, name)
    return count


@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
def test_fused_round_is_single_kernel_call(algorithm):
    """The whole comm round -- local update + quantize + mix + EF, both
    wires for DSGT -- lowers to exactly ONE pallas_call, with the Q-1
    local-step scan contributing none. (Non-interpret HLO can only be
    emitted on a TPU backend, where the same program must contain exactly
    one tpu_custom_call; on CPU the jaxpr is the lowering contract.)"""
    n, q, chunk = 8, 3, 32
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    flat, layout = pack(params, pad_to=chunk)
    engine = FusedEngine(w, layout, scale_chunk=chunk, impl="pallas")
    rf = make_fl_round(loss, None, constant(0.05), cfg, engine=engine)
    st = init_fl_state(cfg, flat, engine=engine)

    jaxpr = jax.make_jaxpr(rf)(st, batches)
    assert _count_primitive(jaxpr.jaxpr, "pallas_call") == 1

    if jax.default_backend() == "tpu":
        txt = jax.jit(rf).lower(st, batches).as_text()
        assert txt.count("tpu_custom_call") == 1


def test_fused_requires_flat_layout_and_comm_state():
    n = 8
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, 1)
    cfg = FLConfig(algorithm="dsgd", q=1, n_nodes=n)
    flat, layout = pack(params, pad_to=32)
    with pytest.raises(ValueError, match="scale_chunk"):
        FusedEngine(w, layout, scale_chunk=7)
    with pytest.raises(ValueError, match="flat buffer"):
        init_fl_state(cfg, params, engine=FusedEngine(w, layout, scale_chunk=32))
    # the historical kwargs raise with a migration hint
    with pytest.raises(TypeError, match="GossipEngine"):
        make_fl_round(loss, None, constant(0.05), cfg, layout=layout)
    with pytest.raises(TypeError, match="GossipEngine"):
        make_fl_round(loss, None, constant(0.05), cfg,
                      fused=object())
    with pytest.raises(TypeError, match="GossipEngine"):
        init_fl_state(cfg, flat, fused=True)


def test_fused_checkpoint_roundtrip(tmp_path):
    """FLState.comm (the int8 wire state) survives save/load; pre-comm
    checkpoints restore onto fused templates with zeroed wire buffers."""
    from repro.training.checkpoint import load_fl_state, save_fl_state

    cfg = FLConfig(algorithm="dsgt", q=2, n_nodes=4)
    w = mixing_matrix("ring", 4)
    flat = jnp.arange(4 * 16, dtype=jnp.float32).reshape(4, 16)
    from repro.core.packing import pack_layout
    engine = FusedEngine(w, pack_layout(flat), scale_chunk=16)
    st = init_fl_state(cfg, flat, engine=engine)
    st = st._replace(comm={k: v + 1.5 for k, v in st.comm.items()})
    save_fl_state(str(tmp_path), st, engine=engine)
    back = load_fl_state(str(tmp_path), init_fl_state(cfg, flat, engine=engine),
                         engine=engine)
    for k in st.comm:
        np.testing.assert_array_equal(np.asarray(back.comm[k]), np.asarray(st.comm[k]))
    np.testing.assert_array_equal(np.asarray(back.params), np.asarray(st.params))


def test_fused_dsgt_tracking_invariant():
    """mean_i tracker == mean_i prev_grad at every comm round up to the
    EF-corrected quantization drift (the megakernel preserves the GT
    invariant that makes DSGT converge)."""
    n, q, chunk, rounds = 16, 2, 32, 8
    w = mixing_matrix("torus:4x4", n)
    loss, params, batches = _problem(n, q, seed=7)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    flat, layout = pack(params, pad_to=chunk)
    engine = FusedEngine(w, layout, scale_chunk=chunk, impl="jnp")
    rf = jax.jit(make_fl_round(loss, None, constant(0.02), cfg, engine=engine))
    st = init_fl_state(cfg, flat, engine=engine)
    for _ in range(rounds):
        st, _ = rf(st, batches)
        t_bar = np.asarray(st.tracker).mean(axis=0)
        g_bar = np.asarray(st.prev_grad).mean(axis=0)
        drift = np.abs(t_bar - g_bar).max()
        q_step = max(np.abs(np.asarray(st.tracker)).max(), 1e-6) / 127.0
        assert drift < 10 * q_step + 1e-5, drift
