"""Assumption 1 machinery: graphs, mixing matrices, spectral gaps."""

import numpy as np
import pytest

try:  # only the property test needs hypothesis; the rest must run bare
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

from repro.core.topology import (
    check_assumption1,
    complete_graph,
    erdos_renyi_graph,
    hospital20_graph,
    metropolis_weights,
    mixing_matrix,
    ring_graph,
    spectral_gap,
    star_graph,
    torus_graph,
    uniform_neighbor_weights,
)
from repro.core.mixing import mesh_gossip_dense_equivalent


@pytest.mark.parametrize(
    "topo,n",
    [("ring", 4), ("ring", 16), ("complete", 8), ("star", 8), ("hospital20", 20), ("torus:4x4", 16), ("torus:2x16", 32)],
)
def test_named_topologies_satisfy_assumption1(topo, n):
    w = mixing_matrix(topo, n)
    diag = check_assumption1(w)
    assert diag["spectral_gap"] > 0.0
    assert np.all(w >= -1e-12), "nonnegative weights"


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        n=st.integers(4, 24),
        p=st.floats(0.15, 0.9),
        seed=st.integers(0, 10_000),
    )
    def test_metropolis_weights_any_connected_graph(n, p, seed):
        g = erdos_renyi_graph(n, p, seed)
        assert g.is_connected()
        w = metropolis_weights(g)
        diag = check_assumption1(w)
        assert 0.0 < diag["spectral_gap"] <= 1.0
        # doubly stochastic both ways (symmetry + row sums)
        np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-10)
else:  # pragma: no cover - CI installs hypothesis

    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_metropolis_weights_any_connected_graph():
        pass


def test_ring_spectral_gap_shrinks_with_n():
    gaps = [spectral_gap(mixing_matrix("ring", n)) for n in (4, 8, 16, 32)]
    assert all(g1 > g2 for g1, g2 in zip(gaps, gaps[1:]))


def test_torus_beats_ring_at_same_size():
    ring = spectral_gap(mixing_matrix("ring", 16))
    torus = spectral_gap(mixing_matrix("torus:4x4", 16))
    assert torus > ring


def test_hospital20_structure():
    g = hospital20_graph()
    assert g.n == 20
    assert g.is_connected()
    deg = g.degrees
    assert deg.mean() >= 2.0 and deg.max() <= 6


def test_mesh_gossip_equivalent_matches_assumption1():
    for sizes in ({"data": 16}, {"pod": 2, "data": 16}, {"pod": 4, "data": 4}):
        w = mesh_gossip_dense_equivalent(sizes)
        diag = check_assumption1(w)
        assert diag["spectral_gap"] > 0.0


def test_uniform_neighbor_requires_regular():
    with pytest.raises(ValueError):
        uniform_neighbor_weights(star_graph(5))
    w = uniform_neighbor_weights(ring_graph(6))
    np.testing.assert_allclose(np.diag(w), 1.0 / 3.0)


def test_graph_validation():
    with pytest.raises(ValueError):
        ring_graph(1)
    g = torus_graph(2, 4)
    assert g.n == 8 and g.is_connected()


def test_erdos_renyi_ring_fallback_connectivity():
    """p so small that 64 resamples cannot connect the graph: the
    constructor falls back to unioning a ring -- the result must still be
    connected, keep the family name, and yield a valid Metropolis W."""
    g = erdos_renyi_graph(12, 0.0, seed=0)
    assert g.is_connected()
    assert g.name == "erdos_renyi"
    ring_edges = {tuple(sorted((i, (i + 1) % 12))) for i in range(12)}
    assert ring_edges <= set(g.edges)
    check_assumption1(metropolis_weights(g))
    # near-zero p: the fallback union keeps any sampled extras too
    g2 = erdos_renyi_graph(12, 1e-9, seed=3)
    assert g2.is_connected() and ring_edges <= set(g2.edges)


def test_torus_mixing_coeffs_degenerate_dims():
    from repro.core.topology import ring_mixing_coeffs, torus_mixing_coeffs

    # size-2 dims fold their +1/-1 directions into ONE share
    d22 = torus_mixing_coeffs(2, 2)
    assert set(d22) == {"self", "row+", "col+"}
    assert sum(d22.values()) == pytest.approx(1.0)
    assert d22["self"] == pytest.approx(1.0 / 3.0)
    # mixed: one folded dim, one full dim
    d24 = torus_mixing_coeffs(2, 4)
    assert set(d24) == {"self", "row+", "col+", "col-"}
    assert sum(d24.values()) == pytest.approx(1.0)
    assert d24["col+"] == d24["col-"] == d24["row+"]
    # size-1 dims contribute no direction at all
    d14 = torus_mixing_coeffs(1, 4)
    assert set(d14) == {"self", "col+", "col-"}
    assert sum(d14.values()) == pytest.approx(1.0)
    d11 = torus_mixing_coeffs(1, 1)
    assert d11 == {"self": 1.0}
    # the coefficient dict must agree with the ppermute backend's dense
    # equivalent (which drives the fused/sharded engines)
    for rows, cols in ((2, 2), (2, 4), (1, 4)):
        dirs = torus_mixing_coeffs(rows, cols)
        w = mesh_gossip_dense_equivalent({"pod": rows, "data": cols})
        np.testing.assert_allclose(np.diag(w), dirs["self"], atol=1e-12)
        check_assumption1(w)
    # ring: n=2 degenerates (prev == next) -- explicitly n < 2 is a
    # self-loop-only program
    assert ring_mixing_coeffs(1) == (1.0, 0.0, 0.0)
    w_self, prev_, next_ = ring_mixing_coeffs(2)
    assert w_self + prev_ + next_ == pytest.approx(1.0)


def test_check_assumption1_per_round_relaxation():
    """The dynamic-topology relaxation: a disconnected-but-stochastic
    per-round W passes only with require_connected=False; asymmetry and
    broken row sums are never accepted."""
    w = np.eye(4)  # fully churned round: everyone self-loops
    with pytest.raises(AssertionError, match="lambda_2"):
        check_assumption1(w)
    diag = check_assumption1(w, require_connected=False)
    assert diag["spectral_gap"] == pytest.approx(0.0)
    bad = np.full((4, 4), 0.25)
    bad[0, 1] += 0.1  # asymmetric
    with pytest.raises(AssertionError, match="not symmetric"):
        check_assumption1(bad, require_connected=False)
    bad2 = np.eye(4) * 0.9  # rows do not sum to 1
    with pytest.raises(AssertionError, match="W 1 != 1"):
        check_assumption1(bad2, require_connected=False)
