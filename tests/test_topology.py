"""Assumption 1 machinery: graphs, mixing matrices, spectral gaps."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.topology import (
    check_assumption1,
    complete_graph,
    erdos_renyi_graph,
    hospital20_graph,
    metropolis_weights,
    mixing_matrix,
    ring_graph,
    spectral_gap,
    star_graph,
    torus_graph,
    uniform_neighbor_weights,
)
from repro.core.mixing import mesh_gossip_dense_equivalent


@pytest.mark.parametrize(
    "topo,n",
    [("ring", 4), ("ring", 16), ("complete", 8), ("star", 8), ("hospital20", 20), ("torus:4x4", 16), ("torus:2x16", 32)],
)
def test_named_topologies_satisfy_assumption1(topo, n):
    w = mixing_matrix(topo, n)
    diag = check_assumption1(w)
    assert diag["spectral_gap"] > 0.0
    assert np.all(w >= -1e-12), "nonnegative weights"


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(4, 24),
    p=st.floats(0.15, 0.9),
    seed=st.integers(0, 10_000),
)
def test_metropolis_weights_any_connected_graph(n, p, seed):
    g = erdos_renyi_graph(n, p, seed)
    assert g.is_connected()
    w = metropolis_weights(g)
    diag = check_assumption1(w)
    assert 0.0 < diag["spectral_gap"] <= 1.0
    # doubly stochastic both ways (symmetry + row sums)
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-10)


def test_ring_spectral_gap_shrinks_with_n():
    gaps = [spectral_gap(mixing_matrix("ring", n)) for n in (4, 8, 16, 32)]
    assert all(g1 > g2 for g1, g2 in zip(gaps, gaps[1:]))


def test_torus_beats_ring_at_same_size():
    ring = spectral_gap(mixing_matrix("ring", 16))
    torus = spectral_gap(mixing_matrix("torus:4x4", 16))
    assert torus > ring


def test_hospital20_structure():
    g = hospital20_graph()
    assert g.n == 20
    assert g.is_connected()
    deg = g.degrees
    assert deg.mean() >= 2.0 and deg.max() <= 6


def test_mesh_gossip_equivalent_matches_assumption1():
    for sizes in ({"data": 16}, {"pod": 2, "data": 16}, {"pod": 4, "data": 4}):
        w = mesh_gossip_dense_equivalent(sizes)
        diag = check_assumption1(w)
        assert diag["spectral_gap"] > 0.0


def test_uniform_neighbor_requires_regular():
    with pytest.raises(ValueError):
        uniform_neighbor_weights(star_graph(5))
    w = uniform_neighbor_weights(ring_graph(6))
    np.testing.assert_allclose(np.diag(w), 1.0 / 3.0)


def test_graph_validation():
    with pytest.raises(ValueError):
        ring_graph(1)
    g = torus_graph(2, 4)
    assert g.n == 8 and g.is_connected()
