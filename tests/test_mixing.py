"""Gossip backend properties (simulated dense-W; sharded backends are
covered by tests/test_sharded.py in a multi-device subprocess)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.mixing import make_dense_gossip, make_mean_consensus, mesh_gossip_dense_equivalent
from repro.core.topology import mixing_matrix, spectral_gap


def _tree(n, seed):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32),
        "b": {"c": jnp.asarray(rng.normal(size=(n, 3, 4)), jnp.float32)},
    }


@settings(max_examples=25, deadline=None)
@given(
    topo=st.sampled_from(["ring", "complete", "hospital20", "torus:4x4"]),
    seed=st.integers(0, 1000),
)
def test_gossip_preserves_mean(topo, seed):
    """1^T W = 1^T  =>  mixing never moves the node-average (the quantity
    the consensus model serves)."""
    n = 20 if topo == "hospital20" else 16
    w = mixing_matrix(topo, n)
    g = make_dense_gossip(w)
    tree = _tree(n, seed)
    mixed = g(tree)
    for k_in, k_out in zip(jax.tree.leaves(tree), jax.tree.leaves(mixed)):
        np.testing.assert_allclose(
            np.asarray(k_in.mean(0)), np.asarray(k_out.mean(0)), atol=1e-5
        )


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), reps=st.integers(1, 4))
def test_gossip_contracts_disagreement(seed, reps):
    """||Theta - mean|| shrinks by at least (1 - spectral_gap) per round."""
    n = 16
    w = mixing_matrix("ring", n)
    lam2 = 1.0 - spectral_gap(w)
    g = make_dense_gossip(w)
    tree = _tree(n, seed)

    def dev(t):
        x = np.asarray(t["a"])
        return float(np.linalg.norm(x - x.mean(0)))

    cur = tree
    before = dev(cur)
    for _ in range(reps):
        cur = g(cur)
    after = dev(cur)
    assert after <= lam2**reps * before + 1e-4


def test_mean_consensus_is_exact_average():
    tree = _tree(8, 0)
    out = make_mean_consensus(8)(tree)
    for leaf_in, leaf_out in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        expect = np.broadcast_to(np.asarray(leaf_in).mean(0), leaf_in.shape)
        np.testing.assert_allclose(np.asarray(leaf_out), expect, atol=1e-6)


def test_bf16_wire_error_is_bounded():
    n = 16
    w = mixing_matrix("ring", n)
    tree = _tree(n, 1)
    exact = make_dense_gossip(w)(tree)
    wired = make_dense_gossip(w, wire_dtype=jnp.bfloat16)(tree)
    for a, b in zip(jax.tree.leaves(exact), jax.tree.leaves(wired)):
        rel = np.abs(np.asarray(a) - np.asarray(b)).max() / (np.abs(np.asarray(a)).max() + 1e-9)
        assert rel < 0.02  # bf16 has ~3 decimal digits


def test_dense_equivalent_is_circulant_for_ring():
    w = mesh_gossip_dense_equivalent({"data": 8})
    # circulant: every row is a rotation of the first
    for i in range(8):
        np.testing.assert_allclose(w[i], np.roll(w[0], i), atol=1e-12)
    np.testing.assert_allclose(np.diag(w), 1.0 / 3.0)
