"""Data substrate: EHR cohort statistics, non-IID partitions, token streams."""

import numpy as np

from repro.data.ehr import N_AD, N_MCI, generate_ehr_cohort, make_node_batcher
from repro.data.partition import dirichlet_partition, label_shift_stats
from repro.data.tokens import TokenStream, make_fl_token_batches


def test_cohort_matches_paper_statistics():
    data = generate_ehr_cohort(seed=0)
    totals = data.totals()
    assert totals["ad"] == N_AD == 2103
    assert totals["mci"] == N_MCI == 7919
    assert data.n_nodes == 20
    sizes = data.node_sizes()
    # "about 500 recordings per each"
    assert 250 < min(sizes) and max(sizes) < 850
    assert data.features[0].shape[1] == 42


def test_cohort_is_heterogeneous_but_learnable():
    data = generate_ehr_cohort(seed=0, heterogeneity=1.5)
    # per-node means genuinely differ (Fig. 1 right: separated clusters)
    means = np.stack([x.mean(0) for x in data.features])
    spread = np.linalg.norm(means - means.mean(0), axis=1)
    assert spread.mean() > 0.5
    # globally a linear probe (with intercept -- the classes are 21/79
    # imbalanced) must beat chance; the per-hospital shift keeps the
    # no-intercept global probe weak, which is exactly the non-IID regime
    x = np.concatenate(data.features)
    y = np.concatenate(data.labels)
    xb = np.concatenate([x, np.ones((len(x), 1))], axis=1)
    w = np.linalg.lstsq(xb, 2.0 * y - 1.0, rcond=None)[0]
    acc = ((xb @ w > 0) == (y == 1)).mean()
    assert acc > 0.75


def test_cohort_deterministic():
    a = generate_ehr_cohort(seed=3)
    b = generate_ehr_cohort(seed=3)
    np.testing.assert_array_equal(a.features[5], b.features[5])
    c = generate_ehr_cohort(seed=4)
    assert not np.array_equal(a.features[5], c.features[5])


def test_node_batcher_shapes():
    data = generate_ehr_cohort(seed=0)
    it = make_node_batcher(data, m=20, seed=1)
    batch = next(it)
    assert batch["x"].shape == (20, 20, 42)
    assert batch["y"].shape == (20, 20)
    assert set(np.unique(batch["y"])) <= {0, 1}


def test_dirichlet_partition_heterogeneity_ordering():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, size=5000)
    skewed = dirichlet_partition(labels, 8, alpha=0.05, seed=1)
    iid = dirichlet_partition(labels, 8, alpha=100.0, seed=1)
    s_skew = label_shift_stats(labels, skewed)
    s_iid = label_shift_stats(labels, iid)
    assert s_skew["tv_mean"] > 3 * s_iid["tv_mean"]
    assert sum(len(p) for p in skewed) == 5000


def test_token_stream_determinism_and_node_variation():
    s0 = TokenStream(vocab_size=128, node=0, seed=7)
    s1 = TokenStream(vocab_size=128, node=1, seed=7)
    a = s0.sample(2, 32, step=5)
    b = s0.sample(2, 32, step=5)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, s1.sample(2, 32, step=5))
    assert a.max() < 128 and a.min() >= 0


def test_fl_token_batches_layout():
    it = make_fl_token_batches(
        vocab_size=64, n_nodes=4, per_node_batch=2, seq_len=16, q=3,
        extras={"prefix_embeds": (8, 32)},
    )
    batch = next(it)
    assert batch["tokens"].shape == (3, 4, 2, 17)
    assert batch["prefix_embeds"].shape == (3, 4, 2, 8, 32)
    batch2 = next(it)
    assert not np.array_equal(batch["tokens"], batch2["tokens"])
