"""Property tests (hypothesis): top-k + EF compressed gossip still
contracts to consensus on ring / torus / hospital20 graphs -- the EF
residual defers the truncated payload mass instead of losing it -- and
``topk == scale_chunk`` degenerates to the exact dense-int8 round."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.compression import (
    init_flat_compression_state,
    make_compressed_flat_gossip,
)
from repro.core.topology import mixing_matrix


@settings(max_examples=12, deadline=None)
@given(
    topo=st.sampled_from(["ring", "torus:4x4", "hospital20"]),
    seed=st.integers(0, 100),
    topk=st.sampled_from([1, 2, 4]),
    scale=st.floats(0.1, 10.0),
)
def test_topk_ef_gossip_contracts_to_consensus(topo, seed, topk, scale):
    n = 20 if topo == "hospital20" else 16
    w = mixing_matrix(topo, n)
    chunk = 16
    rng = np.random.default_rng(seed)
    flat = jnp.asarray(scale * rng.normal(size=(n, 64)), jnp.float32)
    gossip = jax.jit(make_compressed_flat_gossip(w, scale_chunk=chunk, topk=topk))
    state = init_flat_compression_state(flat)

    def disagreement(x):
        a = np.asarray(x)
        return float(np.linalg.norm(a - a.mean(0)))

    d0 = disagreement(flat)
    x = flat
    for _ in range(60):
        x, state = gossip(x, state)
    # mean is preserved by the doubly-stochastic mix through recon +
    # exact self term, up to EF-deferred mass still in flight
    assert disagreement(x) < 0.05 * d0 + 1e-5
    np.testing.assert_allclose(
        np.asarray(x).mean(0), np.asarray(flat).mean(0), atol=2e-2 * scale
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100))
def test_topk_matches_dense_when_k_is_chunk(seed):
    """topk == scale_chunk must be the EXACT dense-int8 round."""
    n, t, chunk = 8, 64, 16
    w = mixing_matrix("ring", n)
    rng = np.random.default_rng(seed)
    flat = jnp.asarray(rng.normal(size=(n, t)), jnp.float32)
    g_dense = make_compressed_flat_gossip(w, scale_chunk=chunk)
    g_k = make_compressed_flat_gossip(w, scale_chunk=chunk, topk=chunk)
    out_d, st_d = g_dense(flat, init_flat_compression_state(flat))
    out_k, st_k = g_k(flat, init_flat_compression_state(flat))
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_k))
    for k in st_d:
        np.testing.assert_array_equal(np.asarray(st_d[k]), np.asarray(st_k[k]))


