"""Environment guard: the suite must see ONE device (the dry-run's
512-device XLA override must never leak into tests or benches)."""

import jax


def test_single_device_environment():
    assert jax.device_count() == 1
