"""FederationScope (sixth round axis) suite.

What is proven:

* **grammar + registry** -- spec strings round-trip through
  ``parse_scope`` (``full`` / ``backbone[:private=PAT]`` /
  ``ranges:a-b,...`` / ``layerwise:freq=R``), unknown names and
  malformed knobs raise, ``resolve_scope(None)`` is the FULL singleton;
* **layout mapping** -- ``shared_ranges`` on a packed MLP layout merges
  the non-private leaves' contiguous column ranges;
  ``scoped_layout`` pads the shared slice to a scale-chunk multiple and
  REFUSES ranges whose per-shard restriction differs across shards;
* **the private-column property** (the axis's core invariant) -- under
  a partial scope, gossip leaves the private columns BIT-identical to a
  never-gossiped local trajectory: with a zero-gradient loss the
  private columns of every node equal their distinct per-node inits
  after rounds of mixing, across fused + sharded engines x sequential +
  bounded-staleness schedules x secure_agg, dsgd and dsgt, while the
  SHARED columns provably mix;
* **layerwise gating** -- ``layerwise:freq=R`` ships the FULL wire but
  keeps head columns bit-equal to local between firings; ``freq=1`` is
  bitwise the full scope; the sharded engine rejects it at build time;
* **wire accounting** -- ``wire_bytes`` obeys the exact linearity
  identity ``wire_scoped * total_full == wire_full * total_scoped``,
  and on the sharded jaxpr one gossip direction's ppermute operand
  bytes == ``flat_wire_bytes_per_shard`` of the SCOPED wire layout, to
  the byte;
* **manifests** -- checkpoints record the scope and refuse a mismatched
  restore; snapshots carry per-node private heads and
  ``load_snapshot(..., node=i)`` overlays hospital i's head bit-exactly
  (refusing unscoped snapshots and out-of-range nodes);
* **engine contract** -- tree/flat engines reject partial scopes at
  build time.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core import (  # noqa: E402
    FLConfig,
    FusedEngine,
    init_fl_state,
    make_fl_round,
    pack,
    parse_scope,
    resolve_scope,
    scope_names,
    scoped_layout,
)
from repro.core.scope import FULL, LayerwiseScope  # noqa: E402
from repro.core.schedules import constant  # noqa: E402
from repro.core.topology import mixing_matrix  # noqa: E402

N = 4
CHUNK = 16


def _params(seed=0):
    """Distinct per-node params: head (N,3) at cols [0,3), trunk (N,6,5)
    at cols [3,33); pad_to=CHUNK pads the layout to 48."""
    rng = np.random.default_rng(seed)
    return {
        "head": jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
        "trunk": jnp.asarray(rng.normal(size=(N, 6, 5)), jnp.float32),
    }


def _zero_loss(p, batch):
    return 0.0 * (jnp.sum(p["head"]) + jnp.sum(p["trunk"]))


def _sq_loss(p, batch):
    return jnp.sum((p["trunk"] - batch["t"]) ** 2) + jnp.sum(p["head"] ** 2)


def _run_rounds(scope, algorithm="dsgd", schedule=None, privacy=None,
                loss=_zero_loss, rounds=3, topk=None):
    params = _params()
    w = mixing_matrix("ring", N)
    engine, flat0 = FusedEngine.simulated(
        w, params, scale_chunk=CHUNK, impl="jnp", topk=topk,
        round_schedule=schedule, privacy=privacy, scope=scope)
    cfg = FLConfig(algorithm=algorithm, q=2, n_nodes=N)
    rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg,
                               engine=engine))
    state = init_fl_state(cfg, flat0, engine=engine)
    batches = {"t": jnp.zeros((2, N, 6, 5), jnp.float32)}
    m = {}
    for _ in range(rounds):
        state, m = rf(state, batches)
    return engine, np.asarray(flat0), state, m


# ---------------------------------------------------------------- grammar


def test_parse_spec_roundtrip_and_registry():
    assert set(scope_names()) >= {"full", "backbone", "ranges", "layerwise"}
    for spec in ("full", "backbone", "backbone:private=head",
                 "ranges:0-3,16-32", "layerwise:freq=4",
                 "layerwise:freq=2,head=fc1"):
        s = parse_scope(spec)
        assert parse_scope(s.spec()).spec() == s.spec(), spec
    assert resolve_scope(None) is FULL
    assert resolve_scope("full").is_full
    assert not parse_scope("backbone").is_full
    # the instance passthrough contract every axis shares
    bb = parse_scope("backbone")
    assert resolve_scope(bb) is bb
    for bad in ("nope", "ranges:", "ranges:5-3", "ranges:1-2-3",
                "layerwise:freq=0", "layerwise:freq=x",
                "backbone:unknown=1"):
        with pytest.raises(ValueError):
            parse_scope(bad)


def test_shared_private_ranges_on_layout():
    _, layout = pack(_params(), pad_to=CHUNK)
    assert layout.total == 48 and layout.used == 33
    bb = parse_scope("backbone:private=head")
    assert bb.shared_ranges(layout) == ((3, 33),)
    # the complement picks up the private leaf AND the structural pad
    assert bb.private_ranges(layout) == ((0, 3), (33, 48))
    rs = parse_scope("ranges:0-16,32-48")
    assert rs.shared_ranges(layout) == ((0, 16), (32, 48))
    # a private pattern matching NO leaf or EVERY leaf is a spec error
    with pytest.raises(ValueError):
        parse_scope("backbone:private=nothing").shared_ranges(layout)
    # a pattern matching EVERY leaf leaves nothing to share
    _, lay1 = pack({"only": jnp.zeros((N, 5))}, pad_to=CHUNK)
    with pytest.raises(ValueError, match="EVERY leaf"):
        parse_scope("backbone:private=only").shared_ranges(lay1)
    with pytest.raises(ValueError):
        parse_scope("ranges:0-64").shared_ranges(layout)  # out of bounds


def test_scoped_layout_math():
    _, layout = pack(_params(), pad_to=CHUNK)
    wire, local = scoped_layout(layout, ((3, 33),), CHUNK)
    # 30 shared columns pad to two 16-chunks
    assert wire.total == 32 and local == ((3, 33),)
    assert wire.n_nodes == layout.n_nodes
    for bad in ((), ((5, 3),), ((0, 8), (4, 12)), ((0, 64),)):
        with pytest.raises(ValueError):
            scoped_layout(layout, bad, CHUNK)
    # two shards: a range living in one shard only is refused -- the
    # per-shard wire must be uniform for the single compiled kernel
    _, lay2 = pack(_params(), pad_to=CHUNK, shards=2)
    assert lay2.total == 64 and lay2.shard_width == 32
    with pytest.raises(ValueError, match="shard"):
        scoped_layout(lay2, ((0, 8),), 8)
    wire2, local2 = scoped_layout(lay2, ((0, 8), (32, 40)), 8)
    assert wire2.total == 16 and wire2.shards == 2
    assert local2 == ((0, 8),)


# ------------------------------------------- the private-column property


@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
@pytest.mark.parametrize("schedule", [None, "bounded_staleness:k=2"])
@pytest.mark.parametrize("privacy", [None, "secure_agg"])
def test_private_columns_bit_identical(algorithm, schedule, privacy):
    engine, flat0, state, _ = _run_rounds(
        "backbone:private=head", algorithm=algorithm, schedule=schedule,
        privacy=privacy)
    got = np.asarray(state.params)
    shared = engine.scope.shared_ranges(engine.layout)
    private = engine.scope.private_ranges(engine.layout)
    assert private and shared
    for a, b in private:
        assert np.array_equal(got[:, a:b], flat0[:, a:b]), (
            algorithm, schedule, privacy, a, b)
    # the shared columns DID mix (distinct inits contract toward mean)
    changed = any(not np.array_equal(got[:, a:b], flat0[:, a:b])
                  for a, b in shared)
    assert changed, "shared columns never mixed -- scope gossiped nothing"
    if algorithm == "dsgt":
        # the tracker's private columns carry the pure local recursion
        # t <- t + g - g_prev, which is identically zero under zero
        # gradients -- any wire contamination would perturb it
        tr = np.asarray(state.tracker)
        for a, b in private:
            assert np.array_equal(tr[:, a:b], np.zeros_like(tr[:, a:b]))


def test_full_scope_bitwise_matches_default():
    _, _, st_none, m_none = _run_rounds(None, loss=_sq_loss)
    _, _, st_full, m_full = _run_rounds("full", loss=_sq_loss)
    assert np.array_equal(np.asarray(st_none.params),
                          np.asarray(st_full.params))
    assert float(m_none["wire_bytes"]) == float(m_full["wire_bytes"])


def test_scoped_wire_bytes_linearity():
    cfg = FLConfig(algorithm="dsgd", q=2, n_nodes=N)
    eng_f, _, _, m_f = _run_rounds(None, rounds=1)
    eng_b, _, _, m_b = _run_rounds("backbone:private=head", rounds=1)
    assert eng_b.wire_layout.total == 32 < eng_f.layout.total == 48
    # flat_wire_bytes is LINEAR in the layout total, so the scoped wire
    # obeys the shared-fraction x full-wire identity EXACTLY
    assert (eng_b.wire_bytes(cfg) * eng_f.layout.total
            == eng_f.wire_bytes(cfg) * eng_b.wire_layout.total)
    assert float(m_b["wire_bytes"]) < float(m_f["wire_bytes"])
    assert float(m_b["wire_bytes"]) == eng_b.wire_bytes(cfg)


# ------------------------------------------------------ layerwise gating


def test_layerwise_gate_between_firings():
    # freq far beyond the horizon: the head NEVER fires, so its columns
    # are bit-equal to the never-gossiped local trajectory (zero-grad:
    # the inits)
    engine, flat0, state, m = _run_rounds("layerwise:freq=1000,head=head")
    got = np.asarray(state.params)
    for a, b in engine.scope.gate_ranges(engine.layout):
        assert np.array_equal(got[:, a:b], flat0[:, a:b])
    # but the wire is the FULL wire -- the gate changes what the mix
    # keeps, never what the collective moves
    _, _, _, m_full = _run_rounds(None)
    assert float(m["wire_bytes"]) == float(m_full["wire_bytes"])


def test_layerwise_freq1_is_full():
    _, _, st_f1, _ = _run_rounds("layerwise:freq=1,head=head",
                                 loss=_sq_loss)
    _, _, st_full, _ = _run_rounds(None, loss=_sq_loss)
    assert np.array_equal(np.asarray(st_f1.params),
                          np.asarray(st_full.params))


def test_layerwise_fire_counts_completed_rounds():
    s = LayerwiseScope(freq=3)
    fires = [bool(s.fire(r)) for r in range(6)]
    # topo_round counts COMPLETED rounds: the round being computed is
    # topo_round+1, so firings land on rounds 3 and 6
    assert fires == [False, False, True, False, False, True]


# ------------------------------------------------------ engine contract


def test_tree_flat_engines_reject_scope():
    from repro.core import FlatEngine, TreeEngine

    params = _params()
    w = mixing_matrix("ring", N)
    for cls in (TreeEngine, FlatEngine):
        with pytest.raises(ValueError, match="scope"):
            cls.simulated(w, params, scope="backbone:private=head")
        # full passes through: the axis default is every engine's no-op
        cls.simulated(w, params, scope="full")


# ------------------------------------------------- manifests + snapshots


def test_checkpoint_scope_mismatch_refused(tmp_path):
    from repro.training.checkpoint import (
        engine_manifest,
        load_fl_state,
        save_fl_state,
    )

    eng_b, _, state, _ = _run_rounds("backbone:private=head", rounds=1)
    eng_f, _, _, _ = _run_rounds(None, rounds=1)
    assert engine_manifest(eng_b)["scope"] == "backbone:private=head"
    assert engine_manifest(eng_f)["scope"] == "full"
    path = str(tmp_path / "ck")
    save_fl_state(path, state, engine=eng_b)
    back = load_fl_state(path, state, engine=eng_b)
    assert np.array_equal(np.asarray(back.params), np.asarray(state.params))
    with pytest.raises(ValueError, match="federation scope"):
        load_fl_state(path, state, engine=eng_f)


def test_snapshot_private_heads(tmp_path):
    from repro.training.snapshot import load_snapshot, write_snapshot

    eng, flat0, state, _ = _run_rounds("backbone:private=head", rounds=2)
    flat = np.asarray(state.params)
    d = str(tmp_path / "snaps")
    write_snapshot(d, state.params, eng.layout, round_frontier=2, engine=eng)
    snap = load_snapshot(d)
    assert "scope" in snap.header
    assert snap.header["scope"]["spec"] == "backbone:private=head"
    cons = np.asarray(snap.flat)
    assert np.allclose(cons, flat.mean(axis=0))
    private = eng.scope.private_ranges(eng.layout)
    for i in range(N):
        pers = np.asarray(load_snapshot(d, node=i).flat)
        for a, b in private:
            # hospital i's private head, BIT-exact (zero-grad run: still
            # the distinct per-node init)
            assert np.array_equal(pers[a:b], flat[i, a:b])
            assert np.array_equal(pers[a:b], flat0[i, a:b])
        sa, sb = eng.scope.shared_ranges(eng.layout)[0]
        assert np.array_equal(pers[sa:sb], cons[sa:sb])
    with pytest.raises(ValueError, match="out of range"):
        load_snapshot(d, node=N)
    # an UNscoped snapshot has no private block to overlay
    eng_f, _, state_f, _ = _run_rounds(None, rounds=1)
    d2 = str(tmp_path / "snaps_full")
    write_snapshot(d2, state_f.params, eng_f.layout, round_frontier=1,
                   engine=eng_f)
    with pytest.raises(ValueError, match="no per-node private"):
        load_snapshot(d2, node=0)


# ------------------------------------------- sharded engine (subprocess)


def _run(script: str, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


_PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (FLConfig, FusedEngine, ShardedFusedEngine,
                            flat_wire_bytes_per_shard, init_fl_state,
                            make_fl_round, pack)
    from repro.core.schedules import constant
    from repro.launch.mesh import make_test_mesh, node_axes, n_fl_nodes

    rng = np.random.default_rng(0)
    q, chunk = 2, 8
    # w spans cols [3, 23); with shards=2 the total pads to 32 and the
    # shard-uniform scope 'ranges:0-8,16-24' shares the first half of
    # each shard, leaving [8,16) + [24,32) private
    SCOPE = "ranges:0-8,16-24"

    def mkparams(n):
        return {"b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
                "w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32)}

    def zero_loss(p, batch):
        return 0.0 * (jnp.sum(p["w"]) + jnp.sum(p["b"]))

    def sq_loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)
    """
)


@pytest.mark.slow
def test_sharded_scope_private_columns_and_oracle():
    out = _run(_PRELUDE + textwrap.dedent(
        """
        def run(algorithm, schedule, privacy, loss, rounds=3):
            mesh = make_test_mesh((4, 2))
            na = node_axes(mesh); n = n_fl_nodes(mesh)
            params = mkparams(n)
            batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)),
                                        jnp.float32)}
            sh = ShardedFusedEngine.from_mesh(
                mesh, na, params, scale_chunk=chunk, topk=None, impl="jnp",
                model_axis="model", round_schedule=schedule,
                privacy=privacy, scope=SCOPE)
            flat, layout = pack(params, pad_to=chunk, shards=2)
            fe = FusedEngine(sh.dense_equivalent(), layout,
                             scale_chunk=chunk, round_schedule=schedule,
                             privacy=privacy, scope=SCOPE, impl="jnp")
            cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
            rf_f = jax.jit(make_fl_round(loss, None, constant(0.05), cfg,
                                         engine=fe))
            st_f = init_fl_state(cfg, flat, engine=fe)
            with mesh:
                rf_s = jax.jit(make_fl_round(loss, None, constant(0.05),
                                             cfg, engine=sh))
                st_s = init_fl_state(cfg, jax.device_put(
                    flat, NamedSharding(mesh, sh.params_spec())),
                    engine=sh)
                for _ in range(rounds):
                    st_f, m_f = rf_f(st_f, batches)
                    st_s, m_s = rf_s(st_s, batches)
            return sh, np.asarray(flat), st_f, st_s, m_f, m_s

        # the private-column property on the SHARDED wire, across
        # schedules x secure_agg x algorithms; fused twin == oracle
        for algorithm in ("dsgd", "dsgt"):
            for schedule in (None, "bounded_staleness:k=2"):
                for privacy in (None, "secure_agg"):
                    sh, flat0, st_f, st_s, m_f, m_s = run(
                        algorithm, schedule, privacy, zero_loss)
                    private = sh.scope.private_ranges(sh.layout)
                    assert private == ((8, 16), (24, 32)), private
                    for st in (st_f, st_s):
                        got = np.asarray(st.params)
                        for a, b in private:
                            assert np.array_equal(got[:, a:b],
                                                  flat0[:, a:b]), (
                                algorithm, schedule, privacy, a, b)
                        for a, b in sh.scope.shared_ranges(sh.layout):
                            assert not np.array_equal(got[:, a:b],
                                                      flat0[:, a:b])

        # real-gradient oracle: sharded == fused dense twin at 1e-5
        for algorithm in ("dsgd", "dsgt"):
            sh, _, st_f, st_s, m_f, m_s = run(algorithm, None, None,
                                              sq_loss)
            err = float(jnp.abs(st_f.params - st_s.params).max())
            assert err < 1e-5, (algorithm, err)
            assert float(m_f["wire_bytes"]) == float(m_s["wire_bytes"])

        # the round-gated layerwise scope needs the dense in-kernel W
        # contraction -- the sharded engine refuses it at build time
        mesh = make_test_mesh((4, 2))
        na = node_axes(mesh); n = n_fl_nodes(mesh)
        try:
            ShardedFusedEngine.from_mesh(
                mesh, na, mkparams(n), scale_chunk=chunk, impl="jnp",
                model_axis="model", scope="layerwise:freq=4,head=b")
            raise SystemExit("layerwise on sharded was not refused")
        except ValueError as e:
            assert "layerwise" in str(e), e
        print("SHARDED-SCOPE-OK")
        """
    ))
    assert "SHARDED-SCOPE-OK" in out


@pytest.mark.slow
def test_sharded_scope_jaxpr_operand_bytes():
    out = _run(_PRELUDE + textwrap.dedent(
        """
        def walk(jaxpr, name, found):
            for eqn in jaxpr.eqns:
                if eqn.primitive.name == name:
                    found.append(eqn)
                for v in eqn.params.values():
                    subs = v if isinstance(v, (list, tuple)) else [v]
                    for sub in subs:
                        if hasattr(sub, "jaxpr"):
                            walk(sub.jaxpr, name, found)
                        elif hasattr(sub, "eqns"):
                            walk(sub, name, found)
            return found

        mesh = make_test_mesh((4, 2))
        na = node_axes(mesh); n = n_fl_nodes(mesh)
        params = mkparams(n)
        batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)),
                                    jnp.float32)}
        cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)

        for topk, n_buffers in ((4, 3), (None, 2)):
            for scope in (None, SCOPE):
                eng = ShardedFusedEngine.from_mesh(
                    mesh, na, params, scale_chunk=chunk, topk=topk,
                    impl="pallas", model_axis="model", scope=scope)
                flat, _ = pack(params, pad_to=chunk, shards=2)
                with mesh:
                    rf = make_fl_round(sq_loss, None, constant(0.05), cfg,
                                       engine=eng)
                    st = init_fl_state(cfg, jax.device_put(
                        flat, NamedSharding(mesh, eng.params_spec())),
                        engine=eng)
                    jx = jax.make_jaxpr(rf)(st, batches)
                pp = walk(jx.jaxpr, "ppermute", [])
                moved = sum(
                    int(np.prod(e.invars[0].aval.shape))
                    * e.invars[0].aval.dtype.itemsize
                    for e in pp[:n_buffers])
                # the collective moves the SCOPED wire layout -- the
                # shared slice's bytes EXACTLY, never the private cols
                expect = flat_wire_bytes_per_shard(
                    eng.wire_layout, 1, eng.scale_chunk,
                    eng.topk if eng.compact_wire else None)
                assert moved == expect, (topk, scope, moved, expect)
                if scope is not None:
                    full = flat_wire_bytes_per_shard(
                        eng.layout, 1, eng.scale_chunk,
                        eng.topk if eng.compact_wire else None)
                    assert expect < full, (expect, full)
        print("JAXPR-SCOPE-OK")
        """
    ))
    assert "JAXPR-SCOPE-OK" in out
