"""Decode-attention kernel sweep vs oracle (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.decode_attention import decode_attention_bhd
from repro.kernels.decode_attention.ref import decode_attention_ref

CASES = [
    # (b, h, kv, cache_len, hd, block_c)
    (2, 4, 2, 512, 64, 256),
    (1, 8, 1, 300, 128, 128),  # ragged last block (300 % 128 != 0)
    (3, 2, 2, 64, 64, 64),
    (1, 16, 4, 1024, 64, 256),
    (2, 3, 1, 128, 256, 64),  # odd head count, big head dim
]


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    b, h, kv, c, hd, bc = case
    rng = np.random.default_rng(abs(hash(case)) % 2**31)
    q = jnp.asarray(rng.normal(size=(b * h, 1, hd)), jnp.float32).astype(dtype)
    k = jnp.asarray(rng.normal(size=(b * kv, c, hd)), jnp.float32).astype(dtype)
    v = jnp.asarray(rng.normal(size=(b * kv, c, hd)), jnp.float32).astype(dtype)
    nv = jnp.asarray(rng.integers(1, c + 1, size=(b,)), jnp.int32)
    out = decode_attention_bhd(
        q, k, v, nv, n_q_heads=h, n_kv_heads=kv, block_c=bc, interpret=True
    )
    ref = decode_attention_ref(q, k, v, nv, n_q_heads=h, n_kv_heads=kv)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_decode_attention_empty_cache_rows():
    """n_valid = 1 (only the just-written token) must not NaN."""
    b, h, kv, c, hd = 2, 2, 1, 128, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b * h, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b * kv, c, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b * kv, c, hd)), jnp.float32)
    nv = jnp.ones((b,), jnp.int32)
    out = decode_attention_bhd(q, k, v, nv, n_q_heads=h, n_kv_heads=kv, interpret=True)
    ref = decode_attention_ref(q, k, v, nv, n_q_heads=h, n_kv_heads=kv)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.slow
def test_decode_kernel_integrated_matches_ref_path():
    """attn_decode(impl='decode_kernel') == the ref cached-decode path,
    including GQA and padded-head layouts."""
    import jax

    from repro.models.attention import attn_decode, attn_init, init_kv_cache

    rng = np.random.default_rng(0)
    for (h, kv, hd, pad) in [(4, 2, 64, 0), (3, 1, 64, 4)]:
        d = 128
        hl = h if pad == 0 else pad
        p = attn_init(jax.random.key(0), d, h, kv, hd, jnp.float32, n_heads_layout=hl)
        x = jnp.asarray(rng.normal(size=(2, 1, d)), jnp.float32)
        kwargs = dict(n_heads=h, n_kv_heads=kv, head_dim=hd, rope_theta=1e4,
                      compute_dtype=jnp.float32, n_heads_layout=hl)
        c1 = init_kv_cache(2, 32, kv, hd, jnp.float32)
        c2 = init_kv_cache(2, 32, kv, hd, jnp.float32)
        for _ in range(5):
            o_ref, c1 = attn_decode(p, x, c1, **kwargs)
            o_k, c2 = attn_decode(p, x, c2, impl="decode_kernel", **kwargs)
            np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_k), atol=1e-5)
