"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs ref.py oracles.

All kernels execute in interpret mode (kernel body in Python on CPU), per
the container's validation contract; the BlockSpec tiling is the TPU
target."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref_bhsd
from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_ref
from repro.kernels.rwkv6_scan.rwkv6_scan import wkv6_chunked_pallas
from repro.kernels.rwkv6_scan.ref import wkv6_ref
from repro.models.rglru import rglru_scan_assoc
from repro.models.rwkv6 import wkv6_chunked


def _rand(rng, shape, dtype, scale=1.0):
    return jnp.asarray(scale * rng.normal(size=shape), jnp.float32).astype(dtype)


FLASH_CASES = [
    # (b, n_q, n_kv, seq, hd, causal, window, bq, bk)
    (2, 2, 1, 256, 64, True, 0, 128, 128),
    (1, 4, 4, 128, 128, True, 64, 64, 64),
    (2, 2, 2, 200, 64, True, 0, 128, 128),  # ragged tail blocks
    (1, 2, 1, 256, 64, False, 0, 128, 128),
    (1, 8, 2, 384, 256, True, 128, 128, 128),  # recurrentgemma-like hd
    (1, 3, 1, 192, 64, True, 0, 64, 64),  # odd head count (smollm-like)
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(c) for c in FLASH_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, nq, nkv, seq, hd, causal, window, bq, bk = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = _rand(rng, (b * nq, seq, hd), dtype)
    k = _rand(rng, (b * nkv, seq, hd), dtype)
    v = _rand(rng, (b * nkv, seq, hd), dtype)
    out = flash_attention_bhsd(
        q, k, v, causal=causal, window=window, n_q_heads=nq, n_kv_heads=nkv,
        block_q=bq, block_k=bk, interpret=True,
    )
    ref = attention_ref_bhsd(q, k, v, causal=causal, window=window, n_q_heads=nq, n_kv_heads=nkv)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


WKV_CASES = [
    # (bh, seq, chunk)
    (4, 128, 64),
    (2, 256, 32),
    (3, 64, 64),
    (1, 512, 128),
]


@pytest.mark.parametrize("case", WKV_CASES, ids=[str(c) for c in WKV_CASES])
def test_wkv6_kernel_matches_naive_scan(case):
    bh, seq, chunk = case
    hd = 64
    rng = np.random.default_rng(seq + bh)
    r = _rand(rng, (bh, seq, hd), jnp.float32)
    k = _rand(rng, (bh, seq, hd), jnp.float32, 0.5)
    v = _rand(rng, (bh, seq, hd), jnp.float32)
    log_w = -jnp.exp(_rand(rng, (bh, seq, hd), jnp.float32) - 1.0)
    u = _rand(rng, (bh, hd), jnp.float32, 0.3)
    s0 = _rand(rng, (bh, hd, hd), jnp.float32, 0.1)
    y_k, s_k = wkv6_chunked_pallas(r, k, v, log_w, u, s0, chunk=chunk, interpret=True)
    y_r, s_r = wkv6_ref(r, k, v, log_w, u, s0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=5e-4, rtol=1e-3)


def test_wkv6_model_chunked_matches_naive_scan():
    """The pure-jnp chunked form used in training == the sequential oracle."""
    bh, seq, hd = 3, 128, 64
    rng = np.random.default_rng(0)
    r = _rand(rng, (bh, seq, hd), jnp.float32)
    k = _rand(rng, (bh, seq, hd), jnp.float32, 0.5)
    v = _rand(rng, (bh, seq, hd), jnp.float32)
    log_w = -jnp.exp(_rand(rng, (bh, seq, hd), jnp.float32) - 1.0)
    u0 = _rand(rng, (hd,), jnp.float32, 0.3)
    s0 = _rand(rng, (bh, hd, hd), jnp.float32, 0.1)
    y_c, s_c = wkv6_chunked(
        r[:, :, None], k[:, :, None], v[:, :, None], log_w[:, :, None],
        u0[None], s0[:, None], chunk=32,
    )
    u = jnp.broadcast_to(u0, (bh, hd))
    y_r, s_r = wkv6_ref(r, k, v, log_w, u, s0)
    np.testing.assert_allclose(np.asarray(y_c[:, :, 0]), np.asarray(y_r), atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_c[:, 0]), np.asarray(s_r), atol=5e-4, rtol=1e-3)


RGLRU_CASES = [
    # (b, seq, width, block_d, chunk)
    (2, 128, 256, 128, 64),
    (3, 64, 128, 128, 64),
    (2, 256, 384, 128, 32),
    (1, 512, 128, 64, 128),
]


@pytest.mark.parametrize("case", RGLRU_CASES, ids=[str(c) for c in RGLRU_CASES])
def test_rglru_kernel_matches_naive_scan(case):
    b, seq, w, bd, ck = case
    rng = np.random.default_rng(b * seq)
    log_a = -jnp.exp(_rand(rng, (b, seq, w), jnp.float32))
    bb = _rand(rng, (b, seq, w), jnp.float32)
    h0 = _rand(rng, (b, w), jnp.float32)
    h_k, hl_k = rglru_scan_pallas(log_a, bb, h0, block_d=bd, chunk=ck, interpret=True)
    h_r, hl_r = rglru_ref(log_a, bb, h0)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hl_k), np.asarray(hl_r), atol=1e-4, rtol=1e-4)


def test_rglru_assoc_scan_matches_naive():
    rng = np.random.default_rng(9)
    log_a = -jnp.exp(_rand(rng, (2, 96, 64), jnp.float32))
    bb = _rand(rng, (2, 96, 64), jnp.float32)
    h0 = _rand(rng, (2, 64), jnp.float32)
    h_a, _ = rglru_scan_assoc(log_a, bb, h0)
    h_r, _ = rglru_ref(log_a, bb, h0)
    np.testing.assert_allclose(np.asarray(h_a), np.asarray(h_r), atol=1e-4, rtol=1e-4)


def test_rglru_strong_decay_stability():
    """Extreme decay (log_a ~ -60) must not produce NaN/Inf (the kernel's
    closed form keeps every exponent <= 0)."""
    b, s, w = 1, 64, 128
    log_a = jnp.full((b, s, w), -60.0)
    bb = jnp.ones((b, s, w))
    h0 = jnp.full((b, w), 1e6)
    h_k, _ = rglru_scan_pallas(log_a, bb, h0, block_d=128, chunk=64, interpret=True)
    assert np.isfinite(np.asarray(h_k)).all()
    np.testing.assert_allclose(np.asarray(h_k[:, 1:]), 1.0, atol=1e-5)
