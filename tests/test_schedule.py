"""RoundSchedule layer: pipelined == sequential-with-one-round-delay
(against a hand-written delayed oracle and across engines/wires), the
compact top-k wire's lossless gather -> wire -> scatter round trip, bf16
flat storage, the adaptive-k hook, and mid-pipeline checkpoint restores.

The multi-device sharded assertions (both wires, jaxpr collective-before-
scan ordering, compact collective operand bytes) run in a subprocess with
8 forced host devices, like tests/test_sharded_engine.py.
"""

import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FLConfig,
    FusedEngine,
    get_engine,
    get_schedule,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
    pack,
    resolve_schedule,
    schedule_names,
)
from repro.core.engine import PipelinedSchedule, SequentialSchedule
from repro.core.schedules import constant, inv_sqrt
from repro.kernels.gossip.ref import wire_stage_gt_ref, wire_stage_ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _problem(n, q, seed=0):
    rng = np.random.default_rng(seed)

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {
        "w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    }
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)), jnp.float32)}
    return loss, params, batches


# ---------------------------------------------------------------------------
# registry + engine gating
# ---------------------------------------------------------------------------


def test_schedule_registry():
    assert schedule_names() == ("bounded_staleness", "pipelined", "sequential")
    assert isinstance(get_schedule("sequential"), SequentialSchedule)
    assert isinstance(get_schedule("pipelined"), PipelinedSchedule)
    assert resolve_schedule(None).name == "sequential"
    assert resolve_schedule("pipelined").name == "pipelined"
    sched = get_schedule("pipelined")
    assert resolve_schedule(sched) is sched
    with pytest.raises(ValueError, match="sequential"):
        get_schedule("does-not-exist")


def test_schedule_spec_round_trip():
    sched = resolve_schedule("bounded_staleness:k=3")
    assert sched.depth == 3 and sched.spec() == "bounded_staleness:k=3"
    assert resolve_schedule(sched.spec()).depth == 3
    assert resolve_schedule("sequential").spec() == "sequential"
    assert resolve_schedule("pipelined").spec() == "pipelined"
    with pytest.raises(ValueError, match="k"):
        resolve_schedule("bounded_staleness:k=0")
    with pytest.raises(ValueError):
        resolve_schedule("bounded_staleness:k=two")
    with pytest.raises(ValueError):
        resolve_schedule("sequential:k=2")


@pytest.mark.parametrize("name", ["tree", "flat"])
def test_exact_wire_engines_are_sequential_only(name):
    w = mixing_matrix("ring", 4)
    _, params, _ = _problem(4, 1)
    with pytest.raises(ValueError, match="sequential-only"):
        get_engine(name).simulated(w, params, round_schedule="pipelined")


def test_engine_records_its_schedule():
    w = mixing_matrix("ring", 4)
    _, params, _ = _problem(4, 1)
    eng_s, _ = FusedEngine.simulated(w, params, scale_chunk=8)
    eng_p, _ = FusedEngine.simulated(w, params, scale_chunk=8,
                                     round_schedule="pipelined")
    assert eng_s.round_schedule.name == "sequential" and not eng_s.pipelined
    assert eng_p.round_schedule.name == "pipelined" and eng_p.pipelined


# ---------------------------------------------------------------------------
# pipelined == sequential-with-one-round-delay (hand-written oracle)
# ---------------------------------------------------------------------------


def _delayed_oracle(loss, params, batches, w, cfg, sched, rounds, chunk):
    """Sequential-with-one-round-delay, written from first principles:
    local steps by hand, the wire stage via the jnp oracle, and the mix
    contracting W_off against the PREVIOUS round's reconstruction."""
    flat, layout = pack(params, pad_to=chunk)
    w_self = jnp.asarray(np.diag(w), jnp.float32)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)
    grad_fn = jax.vmap(jax.value_and_grad(loss))

    from repro.core.packing import pack_like, unpack

    def eval_grads(fb, batch):
        losses, grads = grad_fn(unpack(fb, layout), batch)
        return losses, pack_like(grads, layout)

    q = cfg.q
    x = flat + 0.0
    zeros = jnp.zeros_like(x)
    recon, res = zeros, zeros
    if cfg.algorithm == "dsgt":
        tr, gp = zeros, zeros
        recon_t, res_t = zeros, zeros
    step = 0
    for _ in range(rounds):
        for i in range(q - 1):
            # Algorithm 1: local rounds are Eq. 4 (plain gradient) for
            # DSGD and DSGT alike
            step += 1
            alpha = jnp.float32(sched(jnp.int32(step)))
            _, g = eval_grads(x, {k: v[i] for k, v in batches.items()})
            x = x - alpha * g
        step += 1
        alpha = jnp.float32(sched(jnp.int32(step)))
        _, g = eval_grads(x, {k: v[q - 1] for k, v in batches.items()})
        if cfg.algorithm == "dsgd":
            h, _, _, nrecon, nres = wire_stage_ref(
                x, g, recon, res, alpha, scale_chunk=chunk
            )
            x = w_off @ recon + w_self[:, None] * h  # DELAYED neighbor term
            recon, res = nrecon, nres
        else:
            (h, t_half, _, _, nrx, nsx, _, _, nrt, nst) = wire_stage_gt_ref(
                x, tr, g, gp, recon, res, recon_t, res_t, alpha,
                scale_chunk=chunk,
            )
            x = w_off @ recon + w_self[:, None] * h
            tr = w_off @ recon_t + w_self[:, None] * t_half
            recon, res, recon_t, res_t, gp = nrx, nsx, nrt, nst, g
    return x


@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
def test_fused_pipelined_equals_delayed_sequential(algorithm):
    n, q, chunk, rounds = 8, 3, 16, 4
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=3)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    sched = inv_sqrt(0.05)

    eng, flat = FusedEngine.simulated(w, params, scale_chunk=chunk,
                                      round_schedule="pipelined")
    rf = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng))
    st = init_fl_state(cfg, flat, engine=eng)
    for _ in range(rounds):
        st, m = rf(st, batches)

    oracle = _delayed_oracle(loss, params, batches, w, cfg, sched, rounds,
                             chunk)
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(oracle),
                               atol=1e-5)
    # staleness is REAL: the sequential engine lands somewhere else
    eng_s, flat_s = FusedEngine.simulated(w, params, scale_chunk=chunk)
    rf_s = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng_s))
    st_s = init_fl_state(cfg, flat_s, engine=eng_s)
    for _ in range(rounds):
        st_s, _ = rf_s(st_s, batches)
    assert float(jnp.abs(st.params - st_s.params).max()) > 1e-6


# ---------------------------------------------------------------------------
# compact gather -> wire -> scatter: lossless round trip (hypothesis)
# ---------------------------------------------------------------------------


def test_compact_round_trip_basic():
    from repro.kernels.gossip.ref import (
        scatter_compact_dq,
        wire_stage_compact_ref,
    )

    rng = np.random.default_rng(0)
    n, t, chunk, k = 6, 64, 16, 4
    x = jnp.asarray(rng.normal(size=(n, t)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(n, t)), jnp.float32)
    recon = jnp.asarray(0.1 * rng.normal(size=(n, t)), jnp.float32)
    res = jnp.asarray(0.1 * rng.normal(size=(n, t)), jnp.float32)
    h, q, pos, sc, nrecon, nres = wire_stage_compact_ref(
        x, g, recon, res, jnp.float32(0.05), scale_chunk=chunk, topk=k
    )
    assert q.dtype == jnp.int8 and pos.dtype == jnp.int16
    assert q.shape == (n, (t // chunk) * k)
    dq = scatter_compact_dq(q, pos, sc, chunk, t)
    # the receiver rebuilds EXACTLY what the sender's recon advanced by
    np.testing.assert_allclose(np.asarray(dq), np.asarray(nrecon - recon),
                               atol=1e-6)
    # EF absorbs the truncation: res' = payload - dq for the FULL payload
    np.testing.assert_allclose(np.asarray(nres),
                               np.asarray((h - recon + res) - dq), atol=1e-6)


def test_uneconomic_compact_wire_refused():
    """The collective operand bytes must ALWAYS equal flat_wire_bytes:
    when k values + k int16 positions exceed the dense chunk, the compact
    epilogue is not auto-enabled (the dense wire ships, and the dense cap
    in the accounting is what actually moves), and explicitly requesting
    it is refused rather than shipped while the accounting caps."""
    import subprocess as sp

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax.numpy as jnp
        from repro.core import ShardedFusedEngine
        from repro.launch.mesh import make_test_mesh, node_axes, n_fl_nodes
        mesh = make_test_mesh((2, 2, 2))
        naxes = node_axes(mesh); n = n_fl_nodes(mesh)
        params = {"w": jnp.zeros((n, 30), jnp.float32)}
        # chunk=16: k=4 is economic via the BITMAP index (4 values + 2
        # bitmap bytes <= 16); k=8 is economic the same way (8 + 2 <= 16
        # -- explicit positions alone would cost 8 + 16 > 16); k=15 is
        # not (15 + 2 > 16)
        eng = ShardedFusedEngine.from_mesh(mesh, naxes, params,
                                           scale_chunk=16, topk=4)
        assert eng.compact_wire and eng.wire_encoding == "bitmap"
        eng = ShardedFusedEngine.from_mesh(mesh, naxes, params,
                                           scale_chunk=16, topk=8)
        assert eng.compact_wire and eng.wire_encoding == "bitmap"
        eng = ShardedFusedEngine.from_mesh(mesh, naxes, params,
                                           scale_chunk=16, topk=15)
        assert not eng.compact_wire  # auto-falls back to the dense wire
        assert eng.wire_encoding == "dense"
        try:
            ShardedFusedEngine.from_mesh(mesh, naxes, params,
                                         scale_chunk=16, topk=15,
                                         compact=True)
        except ValueError as e:
            assert "costs more" in str(e)
        else:
            raise AssertionError("uneconomic compact=True not refused")
        print("ECONOMIC-OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = sp.run([sys.executable, "-c", script], env=env,
                  capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ECONOMIC-OK" in proc.stdout


def test_compact_round_trip_property():
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.kernels.gossip.ref import (
        _quantize_ef_compact_chunks,
        scatter_compact_dq,
    )

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        k=st.sampled_from([1, 3, 8, 15]),
        structure=st.sampled_from(["normal", "ties", "sparse", "zeros"]),
    )
    def check(seed, k, structure):
        n, chunk, c = 4, 16, 3
        t = c * chunk
        rng = np.random.default_rng(seed)
        if structure == "normal":
            payload = rng.normal(size=(n, t))
        elif structure == "ties":  # heavy exact ties at the threshold
            payload = rng.integers(-3, 4, size=(n, t)).astype(np.float64)
        elif structure == "sparse":
            payload = rng.normal(size=(n, t)) * (rng.random((n, t)) < 0.1)
        else:
            payload = np.zeros((n, t))
        payload = jnp.asarray(payload, jnp.float32)
        q, pos, scales, dq = _quantize_ef_compact_chunks(payload, chunk, k)
        rebuilt = scatter_compact_dq(
            q.astype(jnp.int8), pos.astype(jnp.int16), scales, chunk, t
        )
        # gather -> wire encode -> scatter reproduces the sender-side
        # masked-dense dq EXACTLY (ties broken identically by top_k)
        np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(dq))
        # exactly k survivors per chunk, positions in-range and unique
        p = np.asarray(pos).reshape(n, c, k)
        assert p.min() >= 0 and p.max() < chunk
        for row in p.reshape(-1, k):
            assert len(set(row.tolist())) == k

    check()


# ---------------------------------------------------------------------------
# bf16 flat storage
# ---------------------------------------------------------------------------


def test_flat_engine_bf16_storage_matches_fp32():
    n, q = 8, 2
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=7)
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    sched = constant(0.05)

    eng32, p32 = get_engine("flat").simulated(w, params, scale_chunk=8)
    eng16, p16 = get_engine("flat").simulated(
        w, params, scale_chunk=8, storage_dtype=jnp.bfloat16
    )
    assert p16.dtype == jnp.bfloat16
    assert eng16.layout.storage_dtype == "bfloat16"
    assert eng16.storage_dtype == jnp.dtype(jnp.bfloat16)
    rf32 = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng32))
    rf16 = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng16))
    st32 = init_fl_state(cfg, p32, engine=eng32)
    st16 = init_fl_state(cfg, p16, engine=eng16)
    for _ in range(3):
        st32, _ = rf32(st32, batches)
        st16, _ = rf16(st16, batches)
    assert st16.params.dtype == jnp.bfloat16  # storage never widens
    a32 = np.asarray(st32.params, np.float32)
    a16 = np.asarray(st16.params.astype(jnp.float32))
    # bf16 has ~3 decimal digits; a few rounds of drift stay ~1e-2
    np.testing.assert_allclose(a16, a32, atol=5e-2, rtol=5e-2)


def test_tree_engine_rejects_bf16_storage():
    w = mixing_matrix("ring", 4)
    _, params, _ = _problem(4, 1)
    with pytest.raises(ValueError, match="storage_dtype"):
        get_engine("tree").simulated(w, params, storage_dtype=jnp.bfloat16)


def test_fused_engine_bf16_storage_matches_fp32():
    """bf16 params/tracker storage on the FUSED engine: the wire stage
    upcasts at the kernel boundary and the mixed output downcasts back,
    so the EF recon/residual state and the int8 wire stay fp32 while
    every (n, total) param buffer halves its HBM bytes. Drift vs the
    fp32 build stays at bf16 rounding scale over a few rounds."""
    n, q = 8, 2
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=7)
    sched = constant(0.05)
    for algorithm in ("dsgd", "dsgt"):
        cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
        eng32, p32 = get_engine("fused").simulated(
            w, params, scale_chunk=8, impl="jnp")
        eng16, p16 = get_engine("fused").simulated(
            w, params, scale_chunk=8, impl="jnp",
            storage_dtype=jnp.bfloat16)
        assert p16.dtype == jnp.bfloat16
        assert eng16.layout.storage_dtype == "bfloat16"
        rf32 = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng32))
        rf16 = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng16))
        st32 = init_fl_state(cfg, p32, engine=eng32)
        st16 = init_fl_state(cfg, p16, engine=eng16)
        for _ in range(3):
            st32, _ = rf32(st32, batches)
            st16, _ = rf16(st16, batches)
        assert st16.params.dtype == jnp.bfloat16  # storage never widens
        # EF state stays fp32 regardless of the storage dtype
        assert st16.comm["recon"].dtype == jnp.float32
        a32 = np.asarray(st32.params, np.float32)
        a16 = np.asarray(st16.params.astype(jnp.float32))
        np.testing.assert_allclose(a16, a32, atol=5e-2, rtol=5e-2)


# ---------------------------------------------------------------------------
# adaptive k (topk_schedule)
# ---------------------------------------------------------------------------


def test_topk_schedule_config_knob():
    from repro.configs.ehr_mlp import TOPK_SCHEDULE, topk_schedule

    assert topk_schedule(None) is None
    assert topk_schedule() == TOPK_SCHEDULE
    assert topk_schedule(("8", "32", "0.5")) == (8, 32, 0.5)
    with pytest.raises(ValueError, match="k_sparse"):
        topk_schedule((32, 8, 0.5))
    with pytest.raises(ValueError, match="k_sparse"):
        topk_schedule((8, 32, -1.0))


def test_adaptive_topk_hysteresis_no_duty_cycle():
    """The two-threshold band: a residual trace that HOVERS around the
    densify threshold (the EHR cohort's shape after the cold start) must
    not flap k every round. The old single-threshold rule flips on every
    crossing; the hysteresis controller switches exactly twice -- up at
    the cold start, down once genuinely drained."""
    from repro.training.trainer import AdaptiveTopK

    high, low = 3e-3, 1.5e-3
    # cold start far above, then a drain that hovers around `high`
    trace = [9e-3, 3.2e-3, 2.9e-3, 3.1e-3, 2.8e-3, 3.05e-3, 2.6e-3,
             2.2e-3, 1.8e-3, 1.4e-3, 9e-4, 8e-4, 7e-4]
    # the trace really does hover: a single threshold would duty-cycle
    single_threshold_flips = sum(
        int((a > high) != (b > high)) for a, b in zip(trace, trace[1:])
    )
    assert single_threshold_flips >= 4

    ctl = AdaptiveTopK((64, 512, high, low), scale_chunk=512)
    ks = []
    for rms in trace:
        ks.append(ctl.current_k)
        ctl.update(rms)
    assert ctl.switches == 2, (ctl.switches, ks)
    # dense from round 2 until the drain below `low` (trace[9]=1.4e-3)
    assert ks == [64] + [512] * 9 + [64] * 3
    assert ctl.dense_rounds == 9

    # the 3-tuple spec defaults the low threshold to high / 2
    ctl3 = AdaptiveTopK((64, 512, high), scale_chunk=512)
    assert ctl3.low == pytest.approx(high / 2)
    # and the band must be ordered
    with pytest.raises(ValueError, match="low <= high"):
        AdaptiveTopK((64, 512, 1e-3, 2e-3), scale_chunk=512)

    from repro.configs.ehr_mlp import topk_schedule
    assert topk_schedule((8, 32, 0.5, 0.2)) == (8, 32, 0.5, 0.2)
    with pytest.raises(ValueError, match="resparsify_low"):
        topk_schedule((8, 32, 0.5, 0.8))


def test_adaptive_topk_densifies_on_residual():
    """The trainer's topk_schedule hook: start sparse, densify while the
    EF-residual RMS is above threshold. With a threshold between the
    cold-start residual and the steady-state one, BOTH wire widths must
    be exercised, on the same state, without recompiles."""
    from repro.configs.base import FLRunConfig
    from repro.training.trainer import train_decentralized

    n = 8
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)

    def loss(p, batch):
        return jnp.mean((p["w"] - batch["t"]) ** 2)

    params = {"w": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)}

    def batches():
        while True:
            yield {"t": np.broadcast_to(np.asarray(target), (n, 4, 5))}

    run = FLRunConfig(algorithm="dsgd", q=2, topology="ring", n_nodes=n,
                      batch_per_node=1, alpha0=0.05, schedule="constant")
    result = train_decentralized(
        loss, params, run, batches(), rounds=12, engine="fused",
        scale_chunk=8, topk_schedule=(2, 8, 1e-3),
    )
    ks = result.history.column("topk")
    assert 2.0 in ks, ks          # sparse rounds ran
    assert 8.0 in ks, ks          # densified rounds ran
    resid = result.history.column("ef_residual_rms")
    assert resid[0] > 1e-3        # cold start above threshold
    # wire bytes differ between the two widths and are accumulated
    assert result.history.column("comm_bytes")[-1] > 0


# ---------------------------------------------------------------------------
# sharded: both wires, jaxpr ordering, compact collective bytes,
# mid-pipeline checkpoint restore (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

def _run(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


_PIPELINE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (FLConfig, FusedEngine, ShardedFusedEngine,
                            flat_wire_bytes, init_fl_state, make_fl_round,
                            mixing_matrix, pack)
    from repro.core.schedules import inv_sqrt
    from repro.launch.mesh import make_test_mesh, node_axes, n_fl_nodes

    mesh = make_test_mesh((2, 2, 2))
    naxes = node_axes(mesh); n = n_fl_nodes(mesh)
    rng = np.random.default_rng(0)
    q, chunk = 2, 16

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {"w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)), jnp.float32)}
    flat, layout = pack(params, pad_to=chunk)
    sched = inv_sqrt(0.05)
    w_er = mixing_matrix("erdos_renyi", n, p=0.7, seed=1)

    # 1. pipelined sharded == pipelined fused (which equals the delayed
    #    oracle -- tests/test_schedule.py proves that single-host) over
    #    dsgd/dsgt x {dense int8, compact top-k} x {circulant, dense W}
    def compare(algorithm, topk, w):
        cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
        sh = ShardedFusedEngine.from_mesh(
            mesh, naxes, params, scale_chunk=chunk, topk=topk,
            impl="pallas", w=w, round_schedule="pipelined")
        fe = FusedEngine(sh.dense_equivalent(), layout, scale_chunk=chunk,
                         topk=topk, impl="pallas",
                         round_schedule="pipelined")
        rf_f = jax.jit(make_fl_round(loss, None, sched, cfg, engine=fe))
        st_f = init_fl_state(cfg, flat, engine=fe)
        with mesh:
            rf_s = jax.jit(make_fl_round(loss, None, sched, cfg, engine=sh))
            st_s = init_fl_state(
                cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
                engine=sh)
            for _ in range(4):
                st_f, m_f = rf_f(st_f, batches)
                st_s, m_s = rf_s(st_s, batches)
        err = float(jnp.abs(st_f.params - st_s.params).max())
        assert err < 1e-5, (algorithm, topk, err)
        if algorithm == "dsgt":
            terr = float(jnp.abs(st_f.tracker - st_s.tracker).max())
            assert terr < 1e-5, (algorithm, topk, terr)
        assert float(m_f["wire_bytes"]) == float(m_s["wire_bytes"])

    for algorithm in ("dsgd", "dsgt"):
        for topk in (None, 4):
            compare(algorithm, topk, None)
            compare(algorithm, topk, w_er)

    # 2. jaxpr: the collective for the IN-FLIGHT payload precedes the
    #    local-step scan (that is the overlap window), the whole round is
    #    still ONE wire-stage kernel, and the compact wire's ppermute
    #    operands are exactly the flat_wire_bytes encoding.
    def walk(jaxpr, name, found):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == name:
                found.append(eqn)
            for v in eqn.params.values():
                subs = v if isinstance(v, (list, tuple)) else [v]
                for sub in subs:
                    if hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr, name, found)
                    elif hasattr(sub, "eqns"):
                        walk(sub, name, found)
        return found

    q3 = 3
    batches3 = {"t": jnp.asarray(rng.normal(size=(q3, n, 4, 5)), jnp.float32)}
    for algorithm in ("dsgd", "dsgt"):
        cfg = FLConfig(algorithm=algorithm, q=q3, n_nodes=n)
        eng = ShardedFusedEngine.from_mesh(
            mesh, naxes, params, scale_chunk=chunk, topk=4, impl="pallas",
            round_schedule="pipelined")
        with mesh:
            rf = make_fl_round(loss, None, inv_sqrt(0.05), cfg, engine=eng)
            st = init_fl_state(
                cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
                engine=eng)
            jaxpr = jax.make_jaxpr(rf)(st, batches3)
        top = jaxpr.jaxpr.eqns
        scan_idx = [e.primitive.name for e in top].index("scan")
        pre, post = top[:scan_idx], top[scan_idx + 1:]

        def count_in(eqns, name):
            found = []
            for e in eqns:
                for v in e.params.values():
                    subs = v if isinstance(v, (list, tuple)) else [v]
                    for sub in subs:
                        if hasattr(sub, "jaxpr"):
                            walk(sub.jaxpr, name, found)
                        elif hasattr(sub, "eqns"):
                            walk(sub, name, found)
                if e.primitive.name == name:
                    found.append(e)
            return found

        wires = 2 if algorithm == "dsgt" else 1
        pp_pre = count_in(pre, "ppermute")
        # compact wire: values + positions + scales per direction per wire,
        # ALL issued before the scan; none after it
        assert len(pp_pre) == 3 * 2 * wires, (algorithm, len(pp_pre))
        assert len(count_in(post, "ppermute")) == 0, algorithm
        # the wire stage stays ONE kernel, after the scan
        assert len(count_in(pre, "pallas_call")) == 0, algorithm
        assert len(count_in(post, "pallas_call")) == 1, algorithm
        # one direction's operands == the accounted compact bytes
        one_dir = pp_pre[:3]
        moved = sum(int(np.prod(e.invars[0].aval.shape))
                    * e.invars[0].aval.dtype.itemsize for e in one_dir)
        assert moved == flat_wire_bytes(layout, 1, chunk, 4), moved

    # 3. mid-pipeline checkpoint restore: save after round 2 (payload in
    #    flight), restore with the engine hook, continue -- bit-compatible
    #    with the uninterrupted run
    import tempfile
    from repro.training.checkpoint import load_fl_state, save_fl_state
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    eng = ShardedFusedEngine.from_mesh(
        mesh, naxes, params, scale_chunk=chunk, topk=4, impl="pallas",
        round_schedule="pipelined")
    with mesh:
        rf = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng))
        st = init_fl_state(
            cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
            engine=eng)
        for _ in range(2):
            st, _ = rf(st, batches)
        with tempfile.TemporaryDirectory() as d:
            save_fl_state(d, st, engine=eng)
            import json as _json
            manifest = _json.load(open(os.path.join(d, "manifest.json")))
            assert manifest["round_schedule"] == "pipelined"
            assert any(k.startswith("wire_q") for k in manifest["comm_keys"])
            template = init_fl_state(
                cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
                engine=eng)
            back = load_fl_state(d, template, engine=eng)
        for _ in range(2):
            st, _ = rf(st, batches)
            back, _ = rf(back, batches)
    err = float(jnp.abs(st.params - back.params).max())
    assert err < 1e-6, err
    print("SCHEDULE-SHARDED-OK")
    """
)


@pytest.mark.slow
def test_sharded_pipelined_and_compact_wire():
    out = _run(_PIPELINE_SCRIPT)
    assert "SCHEDULE-SHARDED-OK" in out


# ---------------------------------------------------------------------------
# staleness convergence note (EHR cohort)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_staleness_balanced_accuracy_within_002():
    """One-round staleness must not cost more than 0.02 balanced accuracy
    on the 20-hospital cohort at Q in {1, 4, 16} (equal iteration budget;
    the full-budget experiment is benchmarks/staleness_ehr.py ->
    experiments/staleness_ehr.json)."""
    sys.path.insert(0, REPO)
    from benchmarks.staleness_ehr import run_cell

    budget = 160  # iterations per cell (the committed experiment uses 320)
    for q in (1, 4, 16):
        rounds = max(1, budget // q)
        seq = run_cell(q, "sequential", rounds)
        pipe = run_cell(q, "pipelined", rounds)
        delta = seq["bal_acc"] - pipe["bal_acc"]
        assert delta <= 0.02, (q, seq["bal_acc"], pipe["bal_acc"])
