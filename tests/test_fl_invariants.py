"""Property tests of the FL optimizer core (paper Eq. 2-4, Algorithm 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis WIDENS the property search; the rest must run bare
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import (
    FLConfig,
    consensus_params,
    init_fl_state,
    make_dense_gossip,
    make_fl_round,
    make_mean_consensus,
    mixing_matrix,
)
from repro.core.schedules import constant, inv_sqrt


def quad_loss(params, batch):
    """f_i(x) = 0.5 ||x - b_i||^2 with per-node targets -> non-IID."""
    return 0.5 * jnp.sum((params["x"] - batch["b"]) ** 2)


def _setup(algo, q, n, d=6, alpha=0.05, topo="ring", seed=0):
    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = mixing_matrix(topo, n)
    cfg = FLConfig(algorithm=algo, q=q, n_nodes=n)
    state = init_fl_state(cfg, {"x": jnp.zeros((n, d))})
    rf = jax.jit(make_fl_round(quad_loss, make_dense_gossip(w), constant(alpha), cfg))
    batches = {"b": jnp.broadcast_to(b, (q, n, d))}
    return state, rf, batches, b


def _check_gradient_tracking_invariant(n, q, seed):
    """mean_i tracker_i == mean_i g_i at every comm round, for any
    doubly-stochastic W (the defining property of gradient tracking)."""
    state, rf, batches, _ = _setup("dsgt", q, n, seed=seed)
    for _ in range(5):
        state, _ = rf(state, batches)
        mt = jnp.mean(state.tracker["x"], axis=0)
        mg = jnp.mean(state.prev_grad["x"], axis=0)
        np.testing.assert_allclose(np.asarray(mt), np.asarray(mg), atol=1e-5)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.sampled_from([4, 8, 16]),
        q=st.sampled_from([1, 3, 5]),
        seed=st.integers(0, 100),
    )
    def test_gradient_tracking_invariant(n, q, seed):
        _check_gradient_tracking_invariant(n, q, seed)
else:  # pragma: no cover - CI installs hypothesis

    @pytest.mark.parametrize("n,q,seed", [(4, 1, 0), (8, 3, 7), (16, 5, 23)])
    def test_gradient_tracking_invariant(n, q, seed):
        _check_gradient_tracking_invariant(n, q, seed)


@pytest.mark.parametrize("algo", ["dsgd", "dsgt"])
@pytest.mark.parametrize("q", [1, 4])
def test_converges_to_global_optimum(algo, q):
    """Every node reaches the consensus optimum mean(b) 'as if it owned all
    the data as a fictitious fusion center' (paper Section 1.1)."""
    state, rf, batches, b = _setup(algo, q, n=8)
    for _ in range(600):
        state, m = rf(state, batches)
    xbar = consensus_params(state)["x"]
    np.testing.assert_allclose(np.asarray(xbar), np.asarray(b.mean(0)), atol=2e-3)
    assert float(m["grad_norm_sq"]) < 1e-5


def test_dsgt_kills_consensus_error_dsgd_does_not():
    """With constant alpha on non-IID data, DSGD has an O(alpha) residual
    consensus error while gradient tracking drives it to ~0 -- the paper's
    core argument for DSGT on heterogeneous EHR data."""
    errs = {}
    for algo in ("dsgd", "dsgt"):
        state, rf, batches, _ = _setup(algo, q=1, n=8, alpha=0.1)
        for _ in range(800):
            state, m = rf(state, batches)
        errs[algo] = float(m["consensus_err"])
    assert errs["dsgt"] < errs["dsgd"] / 50.0


def test_fedavg_is_fd_with_mean_consensus():
    """FedAvg = Algorithm 1 with W = (1/N) 1 1^T: after each comm round all
    nodes hold identical parameters."""
    n, q = 6, 5
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    state = init_fl_state(cfg, {"x": jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)})
    rf = jax.jit(make_fl_round(quad_loss, make_mean_consensus(n), constant(0.05), cfg))
    batches = {"b": jnp.broadcast_to(b, (q, n, 4))}
    state, m = rf(state, batches)
    # DSGD comm step: mix THEN local gradient step => per-node params differ
    # only by alpha * (g_i - g_j); consensus error is O(alpha^2)
    assert float(m["consensus_err"]) < 0.05
    # one more mean-consensus mixing restores exact agreement
    mixed = make_mean_consensus(n)(state.params)["x"]
    assert np.asarray(mixed).std(axis=0).max() < 1e-6


def test_q_reduces_comm_rounds_for_same_iterations():
    """Algorithm 1's accounting: Q local steps per round => for a fixed
    iteration budget T, communication rounds = T/Q."""
    t_budget = 60
    for q in (1, 5, 15):
        state, rf, batches, _ = _setup("dsgt", q, n=4)
        rounds = t_budget // q
        for _ in range(rounds):
            state, _ = rf(state, batches)
        assert int(state.step) == t_budget
        # comm rounds == rounds executed
        assert rounds == t_budget // q


def test_schedule_matches_paper():
    sched = inv_sqrt(0.02)
    assert np.isclose(float(sched(jnp.int32(1))), 0.02)
    assert np.isclose(float(sched(jnp.int32(100))), 0.002)


def test_init_fl_state_validates_stacking():
    cfg = FLConfig(algorithm="dsgd", q=1, n_nodes=4)
    with pytest.raises(ValueError):
        init_fl_state(cfg, {"x": jnp.zeros((3, 2))})  # wrong node count


def _check_realized_round_w(topo, tprog, nprog, seed):
    """The REALIZED per-round W -- after the topology program's edge/node
    gates AND the node program's payload gate compose -- stays symmetric
    and doubly stochastic every round, not just the static base. This is
    the exact invariant the privacy wire leans on: pairwise masks cancel
    because a dropped edge drops BOTH directions (W_r symmetric) and the
    dropped weight folds into the self-loops (rows sum to 1)."""
    from repro.core import FusedEngine

    n = 20 if topo == "hospital20" else 16
    w = mixing_matrix(topo, n)
    d = 8
    params = {"x": jnp.zeros((n, d), jnp.float32)}
    eng, flat = FusedEngine.simulated(
        w, params, scale_chunk=8,
        topology_program=tprog.format(s=seed),
        node_program=nprog.format(s=seed),
    )
    cfg = FLConfig(algorithm="dsgd", q=1, n_nodes=n)
    state = init_fl_state(cfg, flat, engine=eng)
    comm = dict(state.comm)
    for _ in range(4):
        w_off_r, w_diag_r, new_comm, _ = eng._round_gates(comm)
        w_r = np.asarray(w_off_r) + np.diag(np.asarray(w_diag_r))
        np.testing.assert_allclose(w_r, w_r.T, atol=1e-6)
        np.testing.assert_allclose(w_r.sum(axis=1), 1.0, atol=1e-5)
        assert w_r.min() >= -1e-7
        # realized off-diagonal support never exceeds the base graph
        base_off = w - np.diag(np.diag(w))
        assert np.all((np.asarray(w_off_r) > 1e-9) <= (base_off > 1e-9))
        comm.update(new_comm)


_TPROGS = ["static", "edge_failure:p=0.3,seed={s}",
           "node_churn:p_down=0.25,mean_downtime=3,seed={s}"]
_NPROGS = ["homogeneous", "payload_drop:p=0.3,seed={s}",
           "stragglers:frac=0.25,rate=0.5,seed={s}"]


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        topo=st.sampled_from(["ring", "torus", "hospital20"]),
        tprog=st.sampled_from(_TPROGS),
        nprog=st.sampled_from(_NPROGS),
        seed=st.integers(0, 50),
    )
    def test_realized_round_w_symmetric_doubly_stochastic(topo, tprog,
                                                          nprog, seed):
        _check_realized_round_w(topo, tprog, nprog, seed)
else:  # pragma: no cover - CI installs hypothesis

    @pytest.mark.parametrize("topo", ["ring", "torus", "hospital20"])
    @pytest.mark.parametrize("tprog", _TPROGS[1:])
    @pytest.mark.parametrize("nprog", _NPROGS[1:])
    def test_realized_round_w_symmetric_doubly_stochastic(topo, tprog,
                                                          nprog):
        _check_realized_round_w(topo, tprog, nprog, seed=11)
