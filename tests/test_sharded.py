"""Sharded-vs-simulated equivalence, run in a subprocess with 8 placeholder
devices (jax locks the device count at init, and the rest of the suite must
see a single device).

The key system test: one FL round executed (a) sharded over a (2,2,2)
(pod, data, model) mesh with ppermute gossip and (b) simulated on the node
axis with the dense-W oracle, must produce identical parameters.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (FLConfig, init_fl_state, make_fl_round,
                            make_dense_gossip, make_mesh_gossip,
                            mesh_gossip_dense_equivalent)
    from repro.core.schedules import constant
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.sharding import model_param_specs, node_stack_specs
    from repro.launch.mesh import make_test_mesh, node_axes, n_fl_nodes

    mesh = make_test_mesh((2, 2, 2))
    naxes = node_axes(mesh)
    nodes = n_fl_nodes(mesh)

    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg)
    key = jax.random.key(0)
    params1 = bundle.init_fn(key)
    stacked = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (nodes,) + p.shape) * 1.0, params1)
    # per-node perturbation so gossip actually moves parameters
    leaves, tdef = jax.tree_util.tree_flatten(stacked)
    ks = jax.random.split(jax.random.key(1), len(leaves))
    leaves = [l + 0.01 * jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype)
              for l, k in zip(leaves, ks)]
    stacked = jax.tree_util.tree_unflatten(tdef, leaves)

    rng = np.random.default_rng(0)
    q = 2
    batches = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(q, nodes, 2, 33)), jnp.int32)}

    fl_cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=nodes)
    sched = constant(0.1)

    # (a) simulated: dense-W oracle of the mesh torus
    w = mesh_gossip_dense_equivalent({a: mesh.shape[a] for a in naxes})
    rf_sim = jax.jit(make_fl_round(bundle.loss_fn, make_dense_gossip(w), sched, fl_cfg))
    st_sim = init_fl_state(fl_cfg, stacked)
    st_sim, m_sim = rf_sim(st_sim, batches)
    st_sim, m_sim = rf_sim(st_sim, batches)

    # (b) sharded: ppermute gossip over (pod, data), TP over model
    pspecs = node_stack_specs(model_param_specs(params1), naxes)
    gossip = make_mesh_gossip(mesh, naxes, pspecs)
    rf_sh = make_fl_round(bundle.loss_fn, gossip, sched, fl_cfg)
    def shardings(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))
    st_sh = init_fl_state(fl_cfg, jax.device_put(stacked, shardings(pspecs)))
    with mesh:
        rf_sh_j = jax.jit(rf_sh)
        st_sh, m_sh = rf_sh_j(st_sh, batches)
        st_sh, m_sh = rf_sh_j(st_sh, batches)

    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(st_sim.params)[0][0:999],
        jax.tree_util.tree_flatten_with_path(st_sh.params)[0][0:999],
    ):
        err = float(jnp.abs(a - b).max())
        rel = err / (float(jnp.abs(a).max()) + 1e-9)
        # bf16 matmul reduction orders differ between the sharded (vocab-
        # partitioned logits) and single-device lowerings; 1% is the
        # expected bf16 agreement after two optimizer rounds.
        assert rel < 2e-2, (pa, err, rel)
    print("loss sim/sh:", float(m_sim["loss"]), float(m_sh["loss"]))
    assert abs(float(m_sim["loss"]) - float(m_sh["loss"])) < 1e-2
    tr_err = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(st_sim.tracker), jax.tree.leaves(st_sh.tracker)))
    print("tracker max err:", tr_err)
    print("SHARDED-EQUIV-OK")
    """
)


@pytest.mark.slow
def test_sharded_fl_round_matches_simulated():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARDED-EQUIV-OK" in proc.stdout


_GOSSIP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (make_dense_gossip, make_mesh_gossip,
                            make_allgather_gossip, mesh_gossip_dense_equivalent,
                            mixing_matrix)
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 2, 2))
    tree = {"w": jnp.arange(4 * 6 * 4, dtype=jnp.float32).reshape(4, 6, 4),
            "b": jnp.linspace(0, 1, 20, dtype=jnp.float32).reshape(4, 5)}
    specs = {"w": P(("pod", "data"), None, "model"), "b": P(("pod", "data"), None)}

    with mesh:
        out_mesh = jax.jit(make_mesh_gossip(mesh, ("pod", "data"), specs))(tree)
        w_er = mixing_matrix("erdos_renyi", 4, p=0.7, seed=1)
        out_ag = jax.jit(make_allgather_gossip(mesh, ("pod", "data"), specs, w_er))(tree)

    ref_mesh = make_dense_gossip(mesh_gossip_dense_equivalent({"pod": 2, "data": 2}))(tree)
    ref_ag = make_dense_gossip(w_er)(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out_mesh[k]), np.asarray(ref_mesh[k]), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out_ag[k]), np.asarray(ref_ag[k]), rtol=1e-5)
    print("GOSSIP-BACKENDS-OK")
    """
)


@pytest.mark.slow
def test_sharded_gossip_backends_match_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _GOSSIP_SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "GOSSIP-BACKENDS-OK" in proc.stdout
