"""Consensus snapshot path: mmap round trip, hot-swap, staleness.

The contracts under test (ISSUE 9 tentpole):
* save -> mmap-load -> BITWISE-equal consensus params, through zero-copy
  views (no materialized pytree copy for storage-dtype leaves);
* hot-swap while a decode batch is in flight: outputs match a no-swap
  oracle up to the swap boundary, the post-swap continuation matches an
  oracle stepping the NEW weights from the boundary caches, and nothing
  is dropped;
* staleness metric == training frontier minus snapshot round, exactly.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import get_engine
from repro.core.packing import pack
from repro.core.topology import metropolis_weights, ring_graph
from repro.models import build_model
from repro.serving.engine import ServeEngine
from repro.training.checkpoint import engine_manifest
from repro.training.snapshot import (
    latest_round,
    load_snapshot,
    snapshot_paths,
    write_snapshot,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_fn(jax.random.key(0))
    return cfg, bundle, params


def _stack(params, n, scale):
    """Node-stack a single-model pytree with per-node perturbations."""
    return jax.tree_util.tree_map(
        lambda x: jnp.stack([x * (1.0 + scale * i) for i in range(n)]),
        params)


def test_snapshot_mmap_bitwise_roundtrip(tmp_path, tiny):
    cfg, bundle, params = tiny
    n = 4
    stacked = _stack(params, n, 0.01)
    flat, layout = pack(stacked, pad_to=512)
    write_snapshot(str(tmp_path), flat, layout, round_frontier=5)

    snap = load_snapshot(str(tmp_path), verify=True)
    assert snap.round_frontier == 5
    expect = jax.tree_util.tree_map(lambda x: np.asarray(x.mean(axis=0)),
                                    stacked)
    # the consensus reduction ran over the FLAT buffer; per-leaf mean of
    # fp32 leaves is the same contiguous columns, bitwise
    expect_flat = np.asarray(flat.mean(axis=0))
    got, exp = jax.tree_util.tree_flatten(snap.params)[0], \
        jax.tree_util.tree_flatten(expect)[0]
    assert len(got) == len(exp)
    for g, e in zip(got, exp):
        assert g.dtype == e.dtype
        np.testing.assert_array_equal(np.asarray(g), e)
    np.testing.assert_array_equal(np.asarray(snap.flat), expect_flat)

    # template-driven load restores the exact container structure
    tmpl = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    snap_t = load_snapshot(str(tmp_path), template=tmpl)
    assert (jax.tree_util.tree_structure(snap_t.params)
            == jax.tree_util.tree_structure(params))


def test_snapshot_views_are_zero_copy(tmp_path, tiny):
    """fp32 leaves must be views into the mmap'd blob -- no staging
    copy. (astype is reserved for dtype-mismatched leaves.)"""
    cfg, bundle, params = tiny
    stacked = _stack(params, 2, 0.1)
    flat, layout = pack(stacked, pad_to=512)
    write_snapshot(str(tmp_path), flat, layout, round_frontier=1)
    snap = load_snapshot(str(tmp_path))
    for leaf in jax.tree_util.tree_leaves(snap.params):
        bases = []
        b = leaf
        while getattr(b, "base", None) is not None:
            bases.append(b)
            b = b.base
        assert any(isinstance(x, np.memmap) for x in bases), (
            f"leaf is a copy, not an mmap view: {type(leaf)}")


def test_snapshot_header_round_spec_matches_checkpoint_manifest(tmp_path):
    """The five-axis round spec in a snapshot header is the SAME record
    a checkpoint manifest carries (one codepath: engine_manifest)."""
    n = 4
    key = jax.random.key(1)
    params = {"w": jax.random.normal(key, (n, 96), jnp.float32)}
    flat, layout = pack(params, pad_to=512)
    w = metropolis_weights(ring_graph(n))
    eng = get_engine("fused")(w, layout, impl="jnp")
    write_snapshot(str(tmp_path), flat, layout, round_frontier=3, engine=eng)
    snap = load_snapshot(str(tmp_path))
    assert snap.header["round_spec"] == engine_manifest(eng)
    assert snap.header["round_spec"]["engine"] == "fused"


def test_snapshot_publish_is_versioned_and_atomic(tmp_path):
    key = jax.random.key(2)
    params = {"w": jax.random.normal(key, (2, 64), jnp.float32)}
    flat, layout = pack(params)
    write_snapshot(str(tmp_path), flat, layout, round_frontier=1)
    write_snapshot(str(tmp_path), 2.0 * flat, layout, round_frontier=2)
    assert latest_round(str(tmp_path)) == 2
    # older rounds stay immutable and loadable after a newer publish
    old = load_snapshot(str(tmp_path), round_frontier=1)
    new = load_snapshot(str(tmp_path))
    np.testing.assert_array_equal(2.0 * np.asarray(old.flat),
                                  np.asarray(new.flat))
    # no torn temp files left behind
    import os

    leftovers = [f for f in os.listdir(tmp_path) if ".tmp." in f]
    assert leftovers == []


def test_hot_swap_in_flight_matches_boundary_oracles(tiny):
    """Publish new weights while a decode batch is in flight: the decode
    output must equal the OLD-weights oracle up to the swap boundary and
    the NEW-weights-from-boundary-caches oracle after it, with no steps
    dropped and the caches carried across the swap untouched."""
    cfg, bundle, params_a = tiny
    params_b = jax.tree_util.tree_map(lambda x: x * 1.05, params_a)
    b, p, n_steps, k_swap = 2, 4, 10, 6
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, p)), jnp.int32)

    def greedy(logits):
        masked = np.asarray(logits, np.float32)[:, :cfg.vocab_size]
        return jnp.asarray(np.argmax(masked, -1), jnp.int32)

    # ---- oracle A: no swap, params_a throughout
    eng_a = ServeEngine(bundle, params_a, max_seq=64, batch=b)
    caches = eng_a.new_caches()
    logits = None
    for t in range(p):
        logits, caches, _ = eng_a.decode_step(prompt[:, t], caches)
    oracle_a, cur = [], greedy(logits)
    caches_at_boundary = None
    for i in range(n_steps):
        if i == k_swap:
            caches_at_boundary = jax.tree_util.tree_map(
                lambda x: x, caches)  # snapshot the boundary caches
            cur_at_boundary = cur
        oracle_a.append(np.asarray(cur))
        logits, caches, _ = eng_a.decode_step(cur, caches)
        cur = greedy(logits)

    # ---- oracle B: params_b from the boundary caches onward
    eng_b = ServeEngine(bundle, params_b, max_seq=64, batch=b)
    oracle_b, caches, cur = [], caches_at_boundary, cur_at_boundary
    for i in range(k_swap, n_steps):
        oracle_b.append(np.asarray(cur))
        logits, caches, _ = eng_b.decode_step(cur, caches)
        cur = greedy(logits)

    # ---- live run: swap lands at the k_swap boundary mid-batch
    eng = ServeEngine(bundle, params_a, max_seq=64, batch=b,
                      snapshot_round=1)
    caches = eng.new_caches()
    for t in range(p):
        logits, caches, swapped = eng.decode_step(prompt[:, t], caches)
        assert not swapped
    live, cur = [], greedy(logits)
    for i in range(n_steps):
        if i == k_swap:
            # published from "outside" between steps -- the engine must
            # promote it at this boundary without touching the caches
            eng.publish(params_b, snapshot_round=2)
        live.append(np.asarray(cur))
        logits, caches, swapped = eng.decode_step(cur, caches)
        assert swapped == (i == k_swap)
        cur = greedy(logits)

    assert eng.swap_count == 1
    assert eng.snapshot_round == 2
    assert len(eng.swap_pauses) == 1
    assert len(live) == n_steps, "steps were dropped across the swap"
    # pre-boundary: identical to the no-swap oracle
    for i in range(k_swap):
        np.testing.assert_array_equal(live[i], oracle_a[i])
    # the swap changed the trajectory (params_b differs enough)
    # post-boundary: identical to new-weights-from-boundary oracle
    for j, i in enumerate(range(k_swap, n_steps)):
        np.testing.assert_array_equal(live[i], oracle_b[j])


def test_generate_promotes_pending_at_step_boundary(tiny):
    """generate() picks up a mid-flight publish at the next step
    boundary and records it in swap_steps; the result keeps every
    requested token."""
    cfg, bundle, params_a = tiny
    params_b = jax.tree_util.tree_map(lambda x: x * 0.95, params_a)
    eng = ServeEngine(bundle, params_a, max_seq=64, batch=1)
    prompts = np.ones((1, 3), np.int32)

    orig = eng.decode_step
    calls = {"n": 0}

    def hooked(tokens, caches):
        out = orig(tokens, caches)
        calls["n"] += 1
        if calls["n"] == 5:  # publish AFTER step index 4 completes
            eng.publish(params_b, snapshot_round=9)
        return out

    eng.decode_step = hooked
    out = eng.generate(prompts, max_new_tokens=8, temperature=0.0)
    assert out.tokens.shape == (1, 3 + 8)
    assert out.steps == 3 + 8
    assert eng.swap_count == 1
    assert out.swap_steps == (5,)
    assert eng.snapshot_round == 9


def test_staleness_is_exactly_frontier_minus_round(tmp_path, tiny):
    cfg, bundle, params = tiny
    stacked = _stack(params, 2, 0.01)
    flat, layout = pack(stacked, pad_to=512)
    write_snapshot(str(tmp_path), flat, layout, round_frontier=7)
    snap = load_snapshot(str(tmp_path))

    eng = ServeEngine.from_snapshot(bundle, snap, max_seq=32, batch=1)
    assert eng.snapshot_round == 7
    assert eng.staleness(7) == 0
    assert eng.staleness(12) == 5

    write_snapshot(str(tmp_path), flat, layout, round_frontier=9)
    eng.publish_snapshot(load_snapshot(str(tmp_path)))
    assert eng.staleness(12) == 5, "pending snapshot must not change " \
        "staleness before the swap boundary"
    eng._maybe_swap()
    assert eng.staleness(12) == 3
    assert eng.staleness(9) == 0

    # raw-params engines have no round: staleness undefined, not 0
    eng2 = ServeEngine(bundle, params, max_seq=32, batch=1)
    assert eng2.staleness(5) is None


def test_from_snapshot_serves_greedy(tmp_path, tiny):
    """End-to-end: stacked params -> snapshot -> mmap -> ServeEngine
    generates, and matches an engine built from the in-memory consensus."""
    cfg, bundle, params = tiny
    stacked = _stack(params, 4, 0.02)
    flat, layout = pack(stacked, pad_to=512)
    write_snapshot(str(tmp_path), flat, layout, round_frontier=11)
    tmpl = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    snap = load_snapshot(str(tmp_path), template=tmpl)

    eng = ServeEngine.from_snapshot(bundle, snap, max_seq=64, batch=2)
    rng = np.random.default_rng(4)
    prompts = rng.integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=5, temperature=0.0)

    consensus = jax.tree_util.tree_map(lambda x: x.mean(axis=0), stacked)
    ref = ServeEngine(bundle, consensus, max_seq=64, batch=2)
    out_ref = ref.generate(prompts, max_new_tokens=5, temperature=0.0)
    np.testing.assert_array_equal(out.tokens, out_ref.tokens)
