"""Property tests of the privacy wire (the fifth round axis).

Three layers, mirroring the design:

* **pad algebra** (``core.privacy``, eager): mask -> unmask is the exact
  bit-level identity for every wire dtype; the pads are antisymmetric
  (``m_ij = -m_ji mod 2^w``) so they cancel in any symmetric sum -- on
  EVERY realized edge of random per-round topologies (edge failure,
  node churn), which is the cancellation the masked mix leans on; an
  intercepted single-edge payload is statistically unreadable (full
  byte-range support, ~uniform, ~zero correlation with the plaintext).
* **engines** (fused, eager): DP noise rides the EF residual -- consensus
  still contracts on the hospital graph, the ``ef_residual_rms`` signal
  stays bounded and steady enough that ``AdaptiveTopK`` does not flap;
  the ``dp_epsilon`` metric equals the analytic moments bound; restore
  refuses mismatched privacy specs and unknown comm keys.
* **sharded wire** (subprocess, slow): masked rounds are BIT-IDENTICAL
  to unmasked rounds across algorithm x schedule depth x wire encoding
  x topology program, with the identical collective count and operand
  shapes in the jaxpr (zero wire overhead), and the dense all-gather W
  build refuses secure_agg loudly.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis WIDENS the property search; the rest must run bare
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import (
    FLConfig,
    FusedEngine,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
)
from repro.core.privacy import (
    NONE,
    PrivacySpec,
    analytic_epsilon,
    dp_noise,
    epsilon_traced,
    mask_wire,
    pad_bits,
    pair_index,
    parse_privacy,
    rdp_epsilon,
    resolve_privacy,
)
from repro.core.schedules import constant
from repro.training.checkpoint import load_fl_state, save_fl_state
from repro.training.trainer import AdaptiveTopK

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# spec grammar


@pytest.mark.parametrize("spec", [
    "none",
    "secure_agg",
    "secure_agg:seed=7",
    "dp:sigma=0.5,clip=1.0",
    "dp:sigma=0.5,clip=1.0,delta=1e-6",
    "dp:sigma=0.5,clip=1.0,seed=3",
    "secure_agg+dp:sigma=0.25,clip=2.0",
])
def test_spec_roundtrip(spec):
    p = parse_privacy(spec)
    assert parse_privacy(p.spec()) == p


@pytest.mark.parametrize("spec", [
    "bogus",
    "secure_agg:p=2",
    "dp:clip=1.0",                      # sigma missing
    "dp:sigma=0.5",                     # clip missing (sensitivity!)
    "dp:sigma=-1,clip=1.0",
    "dp:sigma=0.5,clip=1.0,delta=2",
    "dp:sigma=0.5,clip=1.0,rho=3",
])
def test_spec_validation_errors(spec):
    with pytest.raises(ValueError):
        parse_privacy(spec)


def test_resolve_privacy():
    assert resolve_privacy(None) is NONE
    p = PrivacySpec(secure_agg=True)
    assert resolve_privacy(p) is p
    assert resolve_privacy("secure_agg") == p
    with pytest.raises(TypeError):
        resolve_privacy(3)
    assert not NONE.active and not NONE.needs_rng
    assert parse_privacy("dp:sigma=0.5,clip=1.0").dp


# ---------------------------------------------------------------------------
# pad algebra (satellite: masks cancel for random payloads / topologies)


_WIRE_DTYPES = (jnp.int8, jnp.int16, jnp.int32, jnp.float32, jnp.uint8)


def _random_wire(rng, rows, width):
    """One buffer per maskable wire dtype (q / pos / scales / bitmap)."""
    return tuple(
        jnp.asarray(
            rng.integers(-100, 100, size=(rows, width))
            if jnp.dtype(dt).kind != "f"
            else rng.normal(size=(rows, width)),
            dt,
        )
        for dt in _WIRE_DTYPES
    )


def _as_uint(arr):
    a = np.asarray(arr)
    if a.dtype.kind == "f":
        a = a.view(np.uint32)
    elif a.dtype.kind == "i":
        a = a.view(a.dtype.str.replace("i", "u"))
    return a


def _check_mask_roundtrip(seed, r, rows, width):
    rng = np.random.default_rng(seed)
    key = PrivacySpec(secure_agg=True, seed=seed).init_key()
    wire = _random_wire(rng, rows, width)
    pair = jnp.asarray(rng.integers(0, 400, size=rows), jnp.int32)
    lt = jnp.asarray(rng.integers(0, 2, size=rows).astype(bool))
    masked = mask_wire(wire, key, r, pair, lt)
    # the payload actually changed (the pad is not degenerate)
    for m, x in zip(masked, wire):
        assert not np.array_equal(np.asarray(m), np.asarray(x))
    # mask -> unmask is the exact bit-level identity
    back = mask_wire(masked, key, r, pair, lt, unmask=True)
    for b, x in zip(back, wire):
        assert np.array_equal(np.asarray(b), np.asarray(x)), x.dtype
    # antisymmetry: the reverse-direction pad is the exact inverse, so
    # masking once per direction composes to the identity (m_ij = -m_ji)
    both = mask_wire(masked, key, r, pair, ~lt)
    for b, x in zip(both, wire):
        assert np.array_equal(np.asarray(b), np.asarray(x)), x.dtype


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        r=st.integers(0, 10_000),
        rows=st.integers(1, 9),
        width=st.sampled_from([1, 7, 32]),
    )
    def test_mask_unmask_identity_and_antisymmetry(seed, r, rows, width):
        _check_mask_roundtrip(seed, r, rows, width)
else:  # pragma: no cover - CI installs hypothesis

    @pytest.mark.parametrize("seed,r,rows,width",
                             [(0, 0, 1, 1), (7, 3, 5, 32), (23, 997, 9, 7)])
    def test_mask_unmask_identity_and_antisymmetry(seed, r, rows, width):
        _check_mask_roundtrip(seed, r, rows, width)


def test_pads_cancel_in_symmetric_sums():
    """``m_ij + m_ji == x_ij + x_ji (mod 2^w)``: the two directions of an
    edge carry exactly opposite pads, so ANY symmetric aggregate of the
    masked payloads equals the plaintext aggregate -- the invariant the
    symmetric-W mix inherits."""
    rng = np.random.default_rng(1)
    key = PrivacySpec(secure_agg=True, seed=1).init_key()
    rows, width = 6, 24
    pair = jnp.asarray(rng.integers(0, 400, size=rows), jnp.int32)
    for dt in (jnp.int8, jnp.int16, jnp.float32):
        x_ij = _random_wire(rng, rows, width)[0].astype(dt)
        x_ji = _random_wire(rng, rows, width)[1].astype(dt)
        m_ij = mask_wire((x_ij,), key, 5, pair, True)[0]
        m_ji = mask_wire((x_ji,), key, 5, pair, False)[0]
        lhs = _as_uint(m_ij) + _as_uint(m_ji)
        rhs = _as_uint(x_ij) + _as_uint(x_ji)
        np.testing.assert_array_equal(lhs, rhs)


def test_pads_vary_by_round_pair_and_stream():
    key = PrivacySpec(secure_agg=True, seed=0).init_key()
    idx = jnp.arange(64, dtype=jnp.uint32)
    base = np.asarray(pad_bits(key, 3, jnp.int32(17), idx, 21))
    assert not np.array_equal(base, np.asarray(pad_bits(key, 4, jnp.int32(17), idx, 21)))
    assert not np.array_equal(base, np.asarray(pad_bits(key, 3, jnp.int32(18), idx, 21)))
    assert not np.array_equal(base, np.asarray(pad_bits(key, 3, jnp.int32(17), idx, 22)))
    other = PrivacySpec(secure_agg=True, seed=1).init_key()
    assert not np.array_equal(base, np.asarray(pad_bits(other, 3, jnp.int32(17), idx, 21)))


def _check_masks_cancel_on_realized_graph(topo, tprog, seed):
    """On EVERY realized directed edge of the per-round gated graph, the
    pads derived from ``pair_index`` + ``sender < receiver`` cancel; a
    dropped edge drops BOTH directions (W_r stays symmetric, asserted in
    tests/test_fl_invariants.py), so no orphaned half-pad can survive."""
    n = 20 if topo == "hospital20" else 16
    w = mixing_matrix(topo, n)
    eng, flat = FusedEngine.simulated(
        w, {"x": jnp.zeros((n, 8), jnp.float32)}, scale_chunk=8,
        topology_program=tprog.format(s=seed),
    )
    cfg = FLConfig(algorithm="dsgd", q=1, n_nodes=n)
    comm = dict(init_fl_state(cfg, flat, engine=eng).comm)
    key = PrivacySpec(secure_agg=True, seed=seed).init_key()
    rng = np.random.default_rng(seed)
    for r in range(3):
        w_off_r, _, new_comm, _ = eng._round_gates(comm)
        i_idx, j_idx = np.nonzero(np.asarray(w_off_r) > 1e-9)
        upper = i_idx < j_idx
        i_idx, j_idx = i_idx[upper], j_idx[upper]
        assert len(i_idx) > 0  # the gated graph never fully disconnects
        pair = pair_index(jnp.asarray(i_idx), jnp.asarray(j_idx), n)
        x_ij = jnp.asarray(
            rng.integers(-100, 100, size=(len(i_idx), 16)), jnp.int8)
        x_ji = jnp.asarray(
            rng.integers(-100, 100, size=(len(i_idx), 16)), jnp.int8)
        m_ij = mask_wire((x_ij,), key, r, pair, True)[0]
        m_ji = mask_wire((x_ji,), key, r, pair, False)[0]
        np.testing.assert_array_equal(
            _as_uint(m_ij) + _as_uint(m_ji), _as_uint(x_ij) + _as_uint(x_ji))
        np.testing.assert_array_equal(
            np.asarray(mask_wire((m_ij,), key, r, pair, True, unmask=True)[0]),
            np.asarray(x_ij))
        comm.update(new_comm)


_PRIV_TPROGS = ["static", "edge_failure:p=0.3,seed={s}",
                "node_churn:p_down=0.25,mean_downtime=3,seed={s}"]


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        topo=st.sampled_from(["ring", "torus", "hospital20"]),
        tprog=st.sampled_from(_PRIV_TPROGS),
        seed=st.integers(0, 50),
    )
    def test_masks_cancel_on_realized_topologies(topo, tprog, seed):
        _check_masks_cancel_on_realized_graph(topo, tprog, seed)
else:  # pragma: no cover - CI installs hypothesis

    @pytest.mark.parametrize("topo", ["ring", "torus", "hospital20"])
    @pytest.mark.parametrize("tprog", _PRIV_TPROGS[1:])
    def test_masks_cancel_on_realized_topologies(topo, tprog):
        _check_masks_cancel_on_realized_graph(topo, tprog, seed=5)


def test_intercepted_payload_is_unreadable():
    """A single intercepted edge payload carries ~no information about
    the plaintext: a narrow int8 distribution (the EF residual regime)
    is spread over the full byte range, ~uniformly, with ~zero
    correlation -- the distribution shifts by the full mask range."""
    rng = np.random.default_rng(2)
    rows, width = 16, 4096
    plain = jnp.asarray(rng.integers(-2, 3, size=(rows, width)), jnp.int8)
    key = PrivacySpec(secure_agg=True, seed=2).init_key()
    pair = jnp.asarray(rng.integers(0, 400, size=rows), jnp.int32)
    lt = jnp.asarray(rng.integers(0, 2, size=rows).astype(bool))
    masked = np.asarray(mask_wire((plain,), key, 9, pair, lt)[0])
    assert len(np.unique(np.asarray(plain))) <= 5
    bytes_ = masked.view(np.uint8).ravel()
    # full support: every one of the 256 byte values occurs
    counts = np.bincount(bytes_, minlength=256)
    assert (counts > 0).sum() == 256
    # ~uniform: each bin within +-50% of the expected count (65536/256
    # = 256/bin; binomial 3-sigma is ~6%, so 50% is an 8-sigma bound)
    assert counts.min() > 128 and counts.max() < 384
    # ~zero linear correlation with the plaintext
    corr = np.corrcoef(np.asarray(plain).ravel().astype(np.float64),
                       masked.ravel().astype(np.float64))[0, 1]
    assert abs(corr) < 0.05


def test_dp_noise_partition_invariant():
    """The sharded per-row draw equals the fused whole-matrix draw
    bitwise (global element counter), and the draw is calibrated."""
    key = PrivacySpec(dp_sigma=0.5, dp_clip=1.0, seed=3).init_key()
    full = np.asarray(dp_noise(key, 7, jnp.arange(8), 512, 2.0))
    part = np.asarray(dp_noise(key, 7, jnp.arange(4, 8), 512, 2.0))
    np.testing.assert_array_equal(full[4:], part)
    big = np.asarray(dp_noise(key, 7, jnp.arange(16), 4096, 2.0))
    assert abs(big.mean()) < 0.05
    assert abs(big.std() / 2.0 - 1.0) < 0.05
    # a fresh round is a fresh draw
    assert not np.array_equal(full, np.asarray(
        dp_noise(key, 8, jnp.arange(8), 512, 2.0)))


# ---------------------------------------------------------------------------
# (epsilon, delta) accounting


def _check_accountant(sigma, steps, delta):
    grid = rdp_epsilon(sigma, steps, delta)
    oracle = analytic_epsilon(sigma, steps, delta)
    # the grid minimum upper-bounds the continuous optimum, tightly
    assert oracle <= grid <= oracle * 1.02
    traced = float(epsilon_traced(sigma, jnp.int32(steps), delta))
    assert traced == pytest.approx(oracle, rel=1e-3)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        sigma=st.floats(0.05, 20.0),
        steps=st.integers(1, 10_000),
        delta=st.sampled_from([1e-7, 1e-5, 1e-3]),
    )
    def test_accountant_matches_analytic_oracle(sigma, steps, delta):
        _check_accountant(sigma, steps, delta)
else:  # pragma: no cover - CI installs hypothesis

    @pytest.mark.parametrize("sigma,steps,delta", [
        (0.25, 4, 1e-5), (0.5, 100, 1e-5), (2.0, 1, 1e-7),
        (8.0, 10_000, 1e-3),
    ])
    def test_accountant_matches_analytic_oracle(sigma, steps, delta):
        _check_accountant(sigma, steps, delta)


def test_accountant_edge_cases_and_monotonicity():
    assert rdp_epsilon(0.0, 5, 1e-5) == float("inf")
    assert rdp_epsilon(0.5, 0, 1e-5) == 0.0
    assert analytic_epsilon(0.5, 0, 1e-5) == 0.0
    assert rdp_epsilon(0.5, 8, 1e-5) > rdp_epsilon(0.5, 4, 1e-5)
    assert rdp_epsilon(1.0, 4, 1e-5) < rdp_epsilon(0.5, 4, 1e-5)
    assert rdp_epsilon(0.5, 4, 1e-7) > rdp_epsilon(0.5, 4, 1e-5)


# ---------------------------------------------------------------------------
# engines (eager fused paths)


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params["x"] - batch["b"]) ** 2)


def _dp_run(privacy, algorithm="dsgd", rounds=40, topk=None, n=20, d=16,
            seed=0, alpha=0.05, init_scale=4.0):
    rng = np.random.default_rng(seed)
    w = mixing_matrix("hospital20", n)
    params = {"x": jnp.asarray(
        init_scale * rng.normal(size=(n, d)), jnp.float32)}
    b = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    eng, flat = FusedEngine.simulated(
        w, params, scale_chunk=8, topk=topk, privacy=privacy)
    cfg = FLConfig(algorithm=algorithm, q=1, n_nodes=n)
    rf = jax.jit(make_fl_round(quad_loss, None, constant(alpha), cfg,
                               engine=eng))
    state = init_fl_state(cfg, flat, engine=eng)
    batches = {"b": b[None]}
    hist = []
    for _ in range(rounds):
        state, m = rf(state, batches)
        hist.append({k: float(v) for k, v in m.items()})
    return eng, state, hist


def test_dp_noise_absorbed_by_error_feedback():
    """Satellite: with dp_sigma > 0 the EF residual absorbs clip + noise
    like it absorbs quantization error -- consensus error still
    CONTRACTS on hospital20 and the residual stays bounded."""
    eng, state, hist = _dp_run("dp:sigma=0.25,clip=1.0")
    errs = [h["consensus_err"] for h in hist]
    rms = [h["ef_residual_rms"] for h in hist]
    assert all(np.isfinite(errs)) and all(np.isfinite(rms))
    # consensus contracts from the scattered init to a small noise floor
    assert errs[-1] < 0.3 * errs[0]
    # the EF residual neither blows up nor drifts: bounded, steady tail
    assert max(rms) < 50.0
    assert np.mean(rms[-10:]) < 3.0 * np.mean(rms[5:15]) + 1e-6
    # dp_epsilon is surfaced every round and grows with composition
    eps = [h["dp_epsilon"] for h in hist]
    assert all(np.diff(eps) > 0)


def test_dp_epsilon_metric_matches_accountant():
    _, _, hist = _dp_run("dp:sigma=0.5,clip=1.0", rounds=4)
    assert hist[-1]["dp_epsilon"] == pytest.approx(
        analytic_epsilon(0.5, 4, 1e-5), rel=1e-5)
    # the DSGT round releases TWO noised wires per step
    _, _, hist_t = _dp_run("dp:sigma=0.5,clip=1.0", algorithm="dsgt",
                           rounds=2)
    assert hist_t[-1]["dp_epsilon"] == pytest.approx(
        analytic_epsilon(0.5, 4, 1e-5), rel=1e-5)


def test_adaptive_topk_does_not_flap_under_dp():
    """Regression (satellite): ``ef_residual_rms`` remains the adaptive-k
    signal under DP -- the noise floor it settles to is steady enough
    that the hysteresis band holds one regime instead of duty-cycling."""
    _, _, hist = _dp_run("dp:sigma=0.25,clip=1.0", topk=2, rounds=40)
    rms = [h["ef_residual_rms"] for h in hist]
    warm = np.mean(rms[:10])
    assert warm > 0  # top-k + dp defers real mass
    ctl = AdaptiveTopK((2, 8, warm * 1.5, warm * 0.5), scale_chunk=8)
    for v in rms[10:]:
        ctl.pick(lambda: None, lambda: None)
        ctl.update(v)
    assert ctl.switches <= 2, (ctl.switches, rms)
    # the dp noise floor is steady, not wild (what makes the band hold)
    tail = np.asarray(rms[10:])
    assert tail.std() < 0.75 * tail.mean()


def test_fused_secure_agg_is_vacuous_noop():
    """The single-host fused engine has no per-edge transport: it accepts
    secure_agg but runs BIT-IDENTICAL to the plain build (and carries no
    privacy counters in comm -- nothing consumes them)."""
    _, st_plain, hist_plain = _dp_run(None, rounds=3)
    eng, st_mask, hist_mask = _dp_run("secure_agg", rounds=3)
    assert eng.privacy.secure_agg
    assert np.array_equal(np.asarray(st_plain.params),
                          np.asarray(st_mask.params))
    assert "priv_key" not in (st_mask.comm or {})
    assert hist_plain[-1] == hist_mask[-1]


def test_engine_gating():
    """Tree rejects any active privacy; flat takes secure_agg as a no-op
    but refuses dp; fused refuses dp without the EF epilogue."""
    n, d = 8, 4
    w = mixing_matrix("ring", n)
    tree_params = {"x": jnp.zeros((n, d), jnp.float32)}
    with pytest.raises(ValueError, match="privacy spec"):
        get_engine("tree").simulated(w, tree_params, privacy="secure_agg")
    with pytest.raises(ValueError, match="privacy spec"):
        get_engine("tree").simulated(w, tree_params,
                                     privacy="dp:sigma=0.5,clip=1.0")
    flat_eng, _ = get_engine("flat").simulated(
        w, tree_params, privacy="secure_agg")
    assert flat_eng.privacy.secure_agg
    with pytest.raises(ValueError, match="error-feedback"):
        get_engine("flat").simulated(w, tree_params,
                                     privacy="dp:sigma=0.5,clip=1.0")
    with pytest.raises(ValueError, match="error_feedback"):
        FusedEngine.simulated(w, tree_params, scale_chunk=4,
                              error_feedback=False,
                              privacy="dp:sigma=0.5,clip=1.0")


# ---------------------------------------------------------------------------
# checkpoint contract


def test_restore_comm_rejects_unknown_keys():
    """Satellite fix: a restored comm dict carrying keys the engine does
    not know is an explicit error with a migration hint, never a silent
    drop."""
    n = 8
    w = mixing_matrix("ring", n)
    eng, flat = FusedEngine.simulated(
        w, {"x": jnp.zeros((n, 8), jnp.float32)}, scale_chunk=8,
        privacy="dp:sigma=0.5,clip=1.0")
    cfg = FLConfig(algorithm="dsgd", q=1, n_nodes=n)
    comm = dict(init_fl_state(cfg, flat, engine=eng).comm)
    assert eng.restore_comm(dict(comm)) == comm  # known keys pass through
    bad = dict(comm, wire_fancy_new=np.zeros(3, np.float32))
    with pytest.raises(ValueError) as ei:
        eng.restore_comm(bad)
    msg = str(ei.value)
    assert "wire_fancy_new" in msg
    assert "rebuild the engine" in msg  # the migration hint


def test_checkpoint_records_and_refuses_privacy_spec(tmp_path):
    n, d = 8, 8
    rng = np.random.default_rng(0)
    w = mixing_matrix("ring", n)
    params = {"x": jnp.asarray(rng.normal(size=(n, d)), jnp.float32)}
    b = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    spec = "dp:sigma=0.5,clip=1.0"
    eng, flat = FusedEngine.simulated(w, params, scale_chunk=8, privacy=spec)
    cfg = FLConfig(algorithm="dsgd", q=1, n_nodes=n)
    rf = jax.jit(make_fl_round(quad_loss, None, constant(0.05), cfg,
                               engine=eng))
    state = init_fl_state(cfg, flat, engine=eng)
    state, _ = rf(state, {"b": b[None]})
    path = str(tmp_path / "ckpt")
    save_fl_state(path, state, engine=eng)
    import json
    with open(os.path.join(path, "manifest.json")) as f:
        assert json.load(f)["privacy"] == spec
    # same-spec restore round-trips exactly (priv counters included)
    restored = load_fl_state(
        path, init_fl_state(cfg, flat, engine=eng), engine=eng)
    assert int(restored.step) == int(state.step)
    np.testing.assert_array_equal(np.asarray(restored.comm["priv_key"]),
                                  np.asarray(state.comm["priv_key"]))
    # a mismatched spec is refused: the streams and the accounting are
    # only truthful under the sigma/clip/delta that actually trained
    eng2, _ = FusedEngine.simulated(w, params, scale_chunk=8,
                                    privacy="dp:sigma=1.0,clip=1.0")
    with pytest.raises(ValueError, match="privacy spec"):
        load_fl_state(path, init_fl_state(cfg, flat, engine=eng2),
                      engine=eng2)


# ---------------------------------------------------------------------------
# sharded wire (subprocess: 8 forced host devices)


def _run(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


_SHARDED_PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (FLConfig, FusedEngine, ShardedFusedEngine,
                            init_fl_state, make_fl_round, mixing_matrix,
                            pack)
    from repro.core.schedules import inv_sqrt
    from repro.launch.mesh import make_test_mesh, node_axes, n_fl_nodes

    mesh = make_test_mesh((2, 2, 2))
    naxes = node_axes(mesh); n = n_fl_nodes(mesh)
    rng = np.random.default_rng(0)
    q = 2

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {"w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)), jnp.float32)}
    sched = inv_sqrt(0.05)

    def run(privacy, algorithm, schedule, topk, tprog, chunk=16, rounds=4,
            jaxpr=False):
        cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
        flat, _ = pack(params, pad_to=chunk)
        sh = ShardedFusedEngine.from_mesh(
            mesh, naxes, params, scale_chunk=chunk, topk=topk,
            impl="pallas", round_schedule=schedule,
            topology_program=tprog, privacy=privacy)
        if privacy is not None:  # the knob must not be silently dropped
            assert sh.privacy.spec() != "none", privacy
        with mesh:
            rf = jax.jit(make_fl_round(loss, None, sched, cfg, engine=sh))
            st = init_fl_state(
                cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
                engine=sh)
            jx = jax.make_jaxpr(rf)(st, batches) if jaxpr else None
            m = {}
            for _ in range(rounds):
                st, m = rf(st, batches)
        return st, m, jx

    def ppermutes(jx):
        found = []
        def walk(jaxpr):
            for eqn in jaxpr.eqns:
                if eqn.primitive.name == "ppermute":
                    found.append(tuple(str(v.aval) for v in eqn.invars))
                for p in eqn.params.values():
                    cands = p if isinstance(p, (list, tuple)) else (p,)
                    for cand in cands:
                        inner = getattr(cand, "jaxpr", cand)
                        if hasattr(inner, "eqns"):
                            walk(inner)
        walk(jx.jaxpr)
        return found
    """
)


_BIT_IDENTITY_SCRIPT = _SHARDED_PRELUDE + textwrap.dedent(
    """
    # axis-covering matrix: algorithm x staleness depth x wire encoding
    # (dense int8 / bitmap top-k / compact top-k) x topology program
    CHURN = "edge_failure:p=0.3,seed=3"
    combos = [
        ("dsgd", "sequential",            16, None, None),
        ("dsgt", "sequential",            16, 4,    CHURN),
        ("dsgd", "bounded_staleness:k=2", 16, 4,    None),
        ("dsgt", "bounded_staleness:k=1", 16, None, CHURN),
        ("dsgd", "bounded_staleness:k=4", 16, None, CHURN),
        ("dsgt", "bounded_staleness:k=4", 16, 4,    None),
        ("dsgd", "sequential",            64, 2,    CHURN),
    ]
    for algorithm, schedule, chunk, topk, tprog in combos:
        st_p, m_p, _ = run(None, algorithm, schedule, topk, tprog,
                           chunk=chunk)
        st_m, m_m, _ = run("secure_agg", algorithm, schedule, topk, tprog,
                           chunk=chunk)
        tag = (algorithm, schedule, chunk, topk, tprog)
        assert "priv_key" in st_m.comm, tag
        assert np.array_equal(np.asarray(st_p.params),
                              np.asarray(st_m.params)), tag
        if st_p.tracker is not None:
            assert np.array_equal(np.asarray(st_p.tracker),
                                  np.asarray(st_m.tracker)), tag
        assert float(m_p["wire_bytes"]) == float(m_m["wire_bytes"]), tag
        print("bit-identical:", tag)
    print("SHARDED-MASKED-BIT-IDENTICAL-OK")
    """
)


_OVERHEAD_AND_DP_SCRIPT = _SHARDED_PRELUDE + textwrap.dedent(
    """
    # 1. zero wire overhead: masked and unmasked rounds lower to the SAME
    #    collective count with the SAME operand shapes (pads are folded
    #    into the existing int8/scale payloads, never shipped)
    for combo in (("dsgd", "sequential", None, None),
                  ("dsgt", "bounded_staleness:k=2", 4,
                   "edge_failure:p=0.3,seed=3")):
        algorithm, schedule, topk, tprog = combo
        _, _, jx_p = run(None, algorithm, schedule, topk, tprog,
                         rounds=1, jaxpr=True)
        _, _, jx_m = run("secure_agg", algorithm, schedule, topk, tprog,
                         rounds=1, jaxpr=True)
        p_plain, p_mask = ppermutes(jx_p), ppermutes(jx_m)
        assert len(p_plain) > 0, combo  # the walker actually found them
        assert len(p_plain) == len(p_mask), (combo, len(p_plain), len(p_mask))
        assert sorted(p_plain) == sorted(p_mask), combo
        print("jaxpr parity:", combo, len(p_plain), "ppermutes")

    # 2. the dense all-gather W build has no pairwise transport to pad:
    #    secure_agg is refused loudly at build time
    w_er = mixing_matrix("erdos_renyi", n, p=0.7, seed=1)
    try:
        ShardedFusedEngine.from_mesh(
            mesh, naxes, params, scale_chunk=16, impl="pallas", w=w_er,
            privacy="secure_agg")
        raise SystemExit("dense-W secure_agg was not rejected")
    except ValueError as e:
        assert "secure_agg" in str(e)
        print("dense-W rejection ok")

    # 3. sharded DP: runs, accounts, and matches the fused oracle (the
    #    noise draw is partition-invariant, so the rows agree bitwise
    #    and the trajectories to 1e-5 like the plain wire)
    from repro.core.privacy import analytic_epsilon
    spec = "dp:sigma=0.5,clip=1.0"
    st_s, m_s, _ = run(spec, "dsgd", "sequential", None, None, rounds=3)
    assert np.isfinite(np.asarray(st_s.params)).all()
    assert float(m_s["dp_epsilon"]) == float(
        jnp.float32(analytic_epsilon(0.5, 3, 1e-5))), m_s["dp_epsilon"]

    chunk = 16
    flat, layout = pack(params, pad_to=chunk)
    sh = ShardedFusedEngine.from_mesh(
        mesh, naxes, params, scale_chunk=chunk, impl="pallas", privacy=spec)
    fe = FusedEngine(sh.dense_equivalent(), layout, scale_chunk=chunk,
                     privacy=spec)
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    rf_f = jax.jit(make_fl_round(loss, None, sched, cfg, engine=fe))
    st_f = init_fl_state(cfg, flat, engine=fe)
    with mesh:
        rf_s = jax.jit(make_fl_round(loss, None, sched, cfg, engine=sh))
        st_sh = init_fl_state(
            cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
            engine=sh)
        for _ in range(3):
            st_f, m_f = rf_f(st_f, batches)
            st_sh, m_sh = rf_s(st_sh, batches)
    err = float(jnp.abs(st_f.params - st_sh.params).max())
    assert err < 1e-5, err
    assert float(m_f["dp_epsilon"]) == float(m_sh["dp_epsilon"])
    print("sharded dp matches fused oracle, err", err)

    # 4. secure_agg composes with dp at zero cost: pads are an exact
    #    no-op on top of the noised wire (same seed -> same noise)
    st_d, _, _ = run("dp:sigma=0.5,clip=1.0", "dsgt",
                     "bounded_staleness:k=2", 4, None, rounds=3)
    st_b, _, _ = run("secure_agg+dp:sigma=0.5,clip=1.0", "dsgt",
                     "bounded_staleness:k=2", 4, None, rounds=3)
    assert np.array_equal(np.asarray(st_d.params), np.asarray(st_b.params))
    assert np.array_equal(np.asarray(st_d.tracker), np.asarray(st_b.tracker))
    print("SHARDED-PRIVACY-OVERHEAD-DP-OK")
    """
)


@pytest.mark.slow
def test_sharded_masked_rounds_bit_identical():
    out = _run(_BIT_IDENTITY_SCRIPT)
    assert "SHARDED-MASKED-BIT-IDENTICAL-OK" in out


@pytest.mark.slow
def test_sharded_privacy_overhead_rejection_and_dp():
    out = _run(_OVERHEAD_AND_DP_SCRIPT)
    assert "SHARDED-PRIVACY-OVERHEAD-DP-OK" in out
