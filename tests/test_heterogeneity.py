"""NodeProgram layer: per-node compute/communication faults as the
FOURTH round axis.

Covers the registry/spec round trips, the hypothesis property that
``compose_node_gate`` keeps every realized W_r symmetric doubly
stochastic under ARBITRARY drop masks, the engine-vs-eager-oracle
equalities (fused + flat, masked local-step scan + gated payload mixing,
composed with topology churn and with depth-k staleness), the
zero-recompile discipline across faulty rounds, mid-fault checkpoint
replay, the staleness/churn-aware alpha controller, and the trainer
plumbing (``staleness_depth`` sugar, ``robust_alpha``, fault metrics in
the history).
"""

import collections
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FLConfig,
    FusedEngine,
    compose_node_gate,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
    node_program_names,
    pack,
    parse_node_program,
    resolve_node_program,
)
from repro.core.schedules import constant, inv_sqrt, robust_alpha_scale, scaled
from repro.core.topology import check_assumption1
from repro.kernels.gossip.ref import (
    fused_round_gt_ref,
    fused_round_ref,
    wire_stage_ref,
)
from repro.core.packing import pack_like, unpack
from repro.training.checkpoint import load_fl_state, save_fl_state

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: one spec per registered fault program, sized for small test graphs
NODE_SPECS = (
    "stragglers:drop=1,frac=0.4,rate=0.5,seed=3",
    "slow_nodes:frac=0.25,rate=0.5,seed=1",
    "payload_drop:p=0.3,seed=2",
)


def _problem(n, q, seed=0):
    rng = np.random.default_rng(seed)

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {
        "w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    }
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)), jnp.float32)}
    return loss, params, batches


# ---------------------------------------------------------------------------
# registry + spec round trips + bind contract
# ---------------------------------------------------------------------------


def test_node_program_registry_and_specs():
    assert node_program_names() == (
        "homogeneous", "payload_drop", "slow_nodes", "slow_uplink",
        "stragglers",
    )
    assert resolve_node_program(None).is_static
    assert resolve_node_program("homogeneous").is_static
    prog = parse_node_program("stragglers:frac=0.3,rate=0.25,seed=7")
    assert prog.frac == 0.3 and prog.rate == 0.25 and prog.seed == 7
    assert resolve_node_program(prog) is prog
    for spec in ("homogeneous",) + NODE_SPECS:
        p = parse_node_program(spec)
        assert parse_node_program(p.spec()).spec() == p.spec()
    with pytest.raises(ValueError, match="unknown node program"):
        parse_node_program("does_not_exist:p=1")
    with pytest.raises(ValueError, match="bad node program knob"):
        parse_node_program("payload_drop:p")
    with pytest.raises(ValueError, match="bad knobs"):
        parse_node_program("payload_drop:nope=3")
    with pytest.raises(ValueError, match="p=1.5"):
        parse_node_program("payload_drop:p=1.5")
    with pytest.raises(ValueError, match="frac=2.0"):
        parse_node_program("stragglers:frac=2.0")
    # full float precision survives the manifest round trip
    hp = parse_node_program("payload_drop:p=0.1234567891,seed=0")
    assert parse_node_program(hp.spec()).p == hp.p == 0.1234567891


def test_node_program_bind_contract():
    prog = parse_node_program("payload_drop:p=0.2,seed=0")
    with pytest.raises(ValueError, match="unbound"):
        prog.wire_gate(jnp.int32(0), jnp.zeros((2,), jnp.uint32))
    prog.bind(8)
    prog.bind(8)  # idempotent
    with pytest.raises(ValueError, match="already bound"):
        prog.bind(4)
    # the shared HOMOGENEOUS sentinel rebinds freely across node counts
    from repro.core.heterogeneity import HOMOGENEOUS

    HOMOGENEOUS.bind(4)
    HOMOGENEOUS.bind(20)


def test_expected_uptime():
    assert parse_node_program("homogeneous").expected_uptime() == 1.0
    assert parse_node_program("payload_drop:p=0.3").expected_uptime() == 0.7
    assert parse_node_program(
        "stragglers:frac=0.25,drop=1").expected_uptime() == 0.75
    assert parse_node_program(
        "stragglers:frac=0.25,drop=0").expected_uptime() == 1.0
    assert parse_node_program("slow_nodes:frac=0.5").expected_uptime() == 1.0


# ---------------------------------------------------------------------------
# graceful degradation: drop-renormalization keeps Assumption 1
# (hypothesis property over arbitrary masks)
# ---------------------------------------------------------------------------


def test_compose_node_gate_keeps_w_doubly_stochastic_property():
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(
        wseed=st.integers(0, 50),
        p=st.sampled_from([0.3, 0.6, 0.9]),
        mask_bits=st.lists(st.booleans(), min_size=12, max_size=12),
    )
    def check(wseed, p, mask_bits):
        n = 12
        w = mixing_matrix("erdos_renyi", n, p=p, seed=wseed)
        w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)
        w_diag = jnp.asarray(np.diag(w), jnp.float32)
        up = jnp.asarray(np.array(mask_bits, np.float32))
        g_off, g_diag = compose_node_gate(w_off, w_diag, up)
        w_r = np.asarray(g_off) + np.diag(np.asarray(g_diag))
        diag = check_assumption1(w_r, atol=1e-5, require_connected=False)
        assert diag["sym_err"] <= 1e-5
        # support shrinks, never grows
        base_off = np.abs(np.asarray(w_off)) > 0
        assert not (np.abs(np.asarray(g_off)) > 0)[~base_off].any()
        # a dropped node is fully isolated: self-loop weight exactly 1
        down = np.asarray(up) < 0.5
        assert not np.asarray(g_off)[down].any()
        assert not np.asarray(g_off)[:, down].any()
        np.testing.assert_allclose(np.asarray(g_diag)[down], 1.0, atol=1e-6)
        # gates compose multiplicatively in either order
        up2 = jnp.asarray((np.arange(n) % 2).astype(np.float32))
        a_off, a_diag = compose_node_gate(g_off, g_diag, up2)
        b_off, b_diag = compose_node_gate(w_off, w_diag, up * up2)
        np.testing.assert_allclose(np.asarray(a_off), np.asarray(b_off),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(a_diag), np.asarray(b_diag),
                                   atol=1e-6)

    check()


def test_compose_node_gate_deterministic_sweep():
    """The same Assumption-1 property on a fixed mask grid (always runs;
    the hypothesis test widens the search when the dep is present):
    includes the all-up identity and the all-down fully-isolated graph."""
    n = 12
    rng = np.random.default_rng(0)
    masks = [np.ones(n), np.zeros(n)] + [
        (rng.random(n) < p).astype(np.float64)
        for p in (0.2, 0.5, 0.8) for _ in range(10)
    ]
    for wseed in (0, 1):
        w = mixing_matrix("erdos_renyi", n, p=0.6, seed=wseed)
        w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)
        w_diag = jnp.asarray(np.diag(w), jnp.float32)
        for mask in masks:
            up = jnp.asarray(mask, jnp.float32)
            g_off, g_diag = compose_node_gate(w_off, w_diag, up)
            w_r = np.asarray(g_off) + np.diag(np.asarray(g_diag))
            diag = check_assumption1(w_r, atol=1e-5, require_connected=False)
            assert diag["sym_err"] <= 1e-5
            down = mask < 0.5
            assert not np.asarray(g_off)[down].any()
            np.testing.assert_allclose(np.asarray(g_diag)[down], 1.0,
                                       atol=1e-6)
        # all-up is the identity gate
        i_off, i_diag = compose_node_gate(w_off, w_diag,
                                          jnp.ones((n,), jnp.float32))
        np.testing.assert_allclose(np.asarray(i_off), np.asarray(w_off),
                                   atol=1e-7)
        np.testing.assert_allclose(np.asarray(i_diag), np.asarray(w_diag),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# engine gating
# ---------------------------------------------------------------------------


def test_tree_engine_rejects_node_program():
    w = mixing_matrix("ring", 4)
    _, params, _ = _problem(4, 1)
    with pytest.raises(ValueError, match="node program"):
        get_engine("tree").simulated(
            w, params, node_program="payload_drop:p=0.2"
        )


def test_homogeneous_program_keeps_static_path():
    n, q = 8, 2
    w = mixing_matrix("ring", n)
    _, params, _ = _problem(n, q)
    eng, _ = FusedEngine.simulated(w, params, scale_chunk=8,
                                   node_program=None)
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    assert not eng.dynamic_nodes
    assert "node_key" not in eng.comm_keys(cfg)
    assert eng.make_step_mask(cfg) is None


def test_node_program_comm_contract():
    n = 8
    w = mixing_matrix("ring", n)
    _, params, _ = _problem(n, 1)
    eng, flat0 = FusedEngine.simulated(
        w, params, scale_chunk=8, node_program="payload_drop:p=0.2,seed=1",
    )
    cfg = FLConfig(algorithm="dsgd", q=1, n_nodes=n)
    keys = eng.comm_keys(cfg)
    assert "topo_round" in keys and "node_key" in keys
    assert "topo_key" not in keys  # static topology contributes nothing
    comm = eng.init_comm_state(cfg, flat0)
    np.testing.assert_array_equal(
        np.asarray(comm["node_key"]),
        np.asarray(eng.node_program.init_key()),
    )
    # payload-only faults never trigger the masked scan
    assert eng.make_step_mask(cfg) is None
    assert eng.make_step_mask(FLConfig(
        algorithm="dsgd", q=4, n_nodes=n)) is None


# ---------------------------------------------------------------------------
# the eager fault oracle: masked local steps + gated per-round W
# ---------------------------------------------------------------------------


def _eager_gates(prog, r, q):
    """The traced gates evaluated eagerly at round ``r`` (same key the
    engine carries in ``FLState.comm['node_key']``)."""
    key = jnp.asarray(prog.init_key())
    up = np.asarray(prog.wire_gate(jnp.int32(r), key))
    mask = np.asarray(prog.step_gate(jnp.int32(r), key, q))
    return up, mask


def _fault_oracle(loss, params, batches, w, cfg, alpha, rounds, chunk,
                  node_prog, engine_kind="fused", topo_prog=None):
    """Hand-written faulty round loop: masked local steps (a gated node's
    scan iteration moves nothing), then the comm round against the
    composed per-round W (topology gate first, then the payload gate's
    symmetric drop-renormalization) via the fused jnp references or the
    exact flat mix."""
    flat, layout = pack(params, pad_to=chunk)
    grad_fn = jax.vmap(jax.value_and_grad(loss))

    def eval_grads(fb, batch):
        losses, grads = grad_fn(unpack(fb, layout), batch)
        return losses, pack_like(grads, layout)

    q = cfg.q
    x = flat + 0.0
    zeros = jnp.zeros_like(x)
    tr, gp = zeros, zeros
    rx, sx, rt, st_ = zeros, zeros, zeros, zeros
    for r in range(rounds):
        up, mask = _eager_gates(node_prog, r, q)
        for i in range(q - 1):
            _, g = eval_grads(x, {k: v[i] for k, v in batches.items()})
            x = x - alpha * jnp.asarray(mask[i])[:, None] * g
        _, g = eval_grads(x, {k: v[q - 1] for k, v in batches.items()})
        w_r = w if topo_prog is None else topo_prog.weights_np(r)
        w_off, w_diag = compose_node_gate(
            jnp.asarray(w_r - np.diag(np.diag(w_r)), jnp.float32),
            jnp.asarray(np.diag(w_r), jnp.float32),
            jnp.asarray(up),
        )
        if engine_kind == "flat":
            if cfg.algorithm == "dsgd":
                x = (w_off @ x + w_diag[:, None] * x) - alpha * g
            else:
                tr = (w_off @ tr + w_diag[:, None] * tr) + g - gp
                x = (w_off @ x + w_diag[:, None] * x) - alpha * tr
                gp = g
        elif cfg.algorithm == "dsgd":
            x, rx, sx, _ = fused_round_ref(
                x, g, rx, sx, w_off, w_diag, jnp.float32(alpha),
                scale_chunk=chunk,
            )
        else:
            x, tr, rx, sx, rt, st_, _, _ = fused_round_gt_ref(
                x, tr, g, gp, rx, sx, rt, st_, w_off, w_diag,
                jnp.float32(alpha), scale_chunk=chunk,
            )
            gp = g
    return x


@pytest.mark.parametrize("spec", NODE_SPECS)
@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
def test_fused_faulty_rounds_match_oracle(spec, algorithm):
    n, q, chunk, rounds = 8, 3, 8, 4
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    eng, flat0 = FusedEngine.simulated(
        w, params, scale_chunk=chunk, impl="pallas", node_program=spec,
    )
    rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg, engine=eng))
    st = init_fl_state(cfg, flat0, engine=eng)
    for _ in range(rounds):
        st, m = rf(st, batches)
    assert rf._cache_size() == 1  # faults add ZERO recompiles
    assert int(st.comm["topo_round"]) == rounds
    assert 0.0 <= float(m["payload_fraction"]) <= 1.0
    if eng.node_program.heterogeneous_compute:
        assert 0.0 < float(m["compute_fraction"]) <= 1.0
    oracle = _fault_oracle(loss, params, batches, w, cfg, 0.05, rounds,
                           chunk, eng.node_program)
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(oracle),
                               atol=1e-5)


@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
def test_flat_faulty_rounds_match_oracle(algorithm):
    n, q, chunk, rounds = 8, 2, 8, 4
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    eng, flat0 = get_engine("flat").simulated(
        w, params, scale_chunk=chunk,
        node_program="stragglers:frac=0.4,rate=0.0,seed=5",
    )
    rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg, engine=eng))
    st = init_fl_state(cfg, flat0, engine=eng)
    for _ in range(rounds):
        st, m = rf(st, batches)
    assert rf._cache_size() == 1
    oracle = _fault_oracle(loss, params, batches, w, cfg, 0.05, rounds,
                           chunk, eng.node_program, engine_kind="flat")
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(oracle),
                               atol=1e-5)


def test_faults_compose_with_topology_churn():
    """Third and fourth axes together: per-round graph churn AND payload
    drops, one compiled round, both gates folded into the realized W_r."""
    n, q, chunk, rounds = 8, 2, 8, 5
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    eng, flat0 = FusedEngine.simulated(
        w, params, scale_chunk=chunk, impl="pallas",
        topology_program="edge_failure:p=0.3,seed=4",
        node_program="payload_drop:p=0.25,seed=6",
    )
    rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg, engine=eng))
    st = init_fl_state(cfg, flat0, engine=eng)
    for _ in range(rounds):
        st, m = rf(st, batches)
    assert rf._cache_size() == 1
    assert "edge_fraction" in m and "payload_fraction" in m
    oracle = _fault_oracle(loss, params, batches, w, cfg, 0.05, rounds,
                           chunk, eng.node_program,
                           topo_prog=eng.topology_program)
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(oracle),
                               atol=1e-5)


def test_faults_compose_with_bounded_staleness():
    """Fourth axis x depth-k ring: the gated W_r mixes the k-round-stale
    payload (dsgd, payload drops only -- the wire still crosses, the gate
    zeroes the mixing contribution)."""
    n, q, chunk, rounds, k = 8, 2, 8, 6, 2
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    eng, flat0 = FusedEngine.simulated(
        w, params, scale_chunk=chunk, impl="pallas",
        node_program="payload_drop:p=0.25,seed=6",
        round_schedule=f"bounded_staleness:k={k}",
    )
    rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg, engine=eng))
    st = init_fl_state(cfg, flat0, engine=eng)
    for _ in range(rounds):
        st, _ = rf(st, batches)
    assert rf._cache_size() == 1

    # k-delayed oracle with the gated W: local steps by hand, wire stage
    # via the jnp reference, mix contracting the composed W against the
    # reconstruction from k rounds back
    flat, layout = pack(params, pad_to=chunk)
    grad_fn = jax.vmap(jax.value_and_grad(loss))
    x = flat + 0.0
    zeros = jnp.zeros_like(x)
    recon, res = zeros, zeros
    past = collections.deque([zeros] * k)
    prog = eng.node_program
    for r in range(rounds):
        for i in range(q - 1):
            _, grads = grad_fn(unpack(x, layout),
                               {kk: v[i] for kk, v in batches.items()})
            x = x - 0.05 * pack_like(grads, layout)
        _, grads = grad_fn(unpack(x, layout),
                           {kk: v[q - 1] for kk, v in batches.items()})
        g = pack_like(grads, layout)
        up, _ = _eager_gates(prog, r, q)
        w_off, w_diag = compose_node_gate(
            jnp.asarray(w - np.diag(np.diag(w)), jnp.float32),
            jnp.asarray(np.diag(w), jnp.float32), jnp.asarray(up),
        )
        h, _, _, nrecon, nres = wire_stage_ref(
            x, g, recon, res, jnp.float32(0.05), scale_chunk=chunk,
        )
        x = w_off @ past[0] + w_diag[:, None] * h
        recon, res = nrecon, nres
        past.append(nrecon)
        past.popleft()
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(x),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# mid-fault checkpoint replay (Markov churn + node program manifests)
# ---------------------------------------------------------------------------


def test_mid_fault_checkpoint_replays_bit_identically():
    """Save mid-run under stateful Markov churn (topo_up mid-outage) AND
    a straggler program; the restore must replay the identical fault
    sequence bit for bit, and a restore under a DIFFERENT node program
    must be refused."""
    n, q, chunk = 8, 2, 8
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    node_spec = "stragglers:drop=1,frac=0.4,rate=0.5,seed=3"
    churn_spec = "node_churn:mean_downtime=2,p_down=0.3,seed=1"
    eng, flat0 = FusedEngine.simulated(
        w, params, scale_chunk=chunk, impl="pallas",
        topology_program=churn_spec, node_program=node_spec,
    )
    rf = jax.jit(make_fl_round(loss, None, inv_sqrt(0.05), cfg, engine=eng))
    st = init_fl_state(cfg, flat0, engine=eng)
    for _ in range(3):
        st, _ = rf(st, batches)
    assert "topo_up" in st.comm  # the Markov outage state rides in comm
    with tempfile.TemporaryDirectory() as d:
        save_fl_state(d, st, engine=eng)
        import json

        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["topology_program"] == churn_spec
        assert manifest["node_program"] == node_spec
        template = init_fl_state(cfg, flat0, engine=eng)
        back = load_fl_state(d, template, engine=eng)

        other, _ = FusedEngine.simulated(
            w, params, scale_chunk=chunk, impl="pallas",
            topology_program=churn_spec,
            node_program="payload_drop:p=0.2,seed=0",
        )
        with pytest.raises(ValueError, match="node program"):
            load_fl_state(d, template, engine=other)
    for _ in range(3):
        st, _ = rf(st, batches)
        back, _ = rf(back, batches)
    np.testing.assert_array_equal(np.asarray(st.params),
                                  np.asarray(back.params))
    np.testing.assert_array_equal(np.asarray(st.comm["topo_up"]),
                                  np.asarray(back.comm["topo_up"]))


# ---------------------------------------------------------------------------
# the staleness/churn-aware alpha controller
# ---------------------------------------------------------------------------


def test_robust_alpha_scale():
    assert robust_alpha_scale() == 1.0
    assert robust_alpha_scale(uptime=0.5) == pytest.approx(0.25)
    assert robust_alpha_scale(staleness_depth=2) == pytest.approx(0.5)
    assert robust_alpha_scale(0.8, 3) == pytest.approx(0.8 ** 2 * 2 / 5)
    with pytest.raises(ValueError, match="uptime"):
        robust_alpha_scale(uptime=1.5)
    with pytest.raises(ValueError, match="staleness"):
        robust_alpha_scale(staleness_depth=-1)
    base = inv_sqrt(0.1)
    shrunk = scaled(base, robust_alpha_scale(0.5, 0))
    for step in (1, 10, 100):
        assert float(shrunk(jnp.int32(step))) == pytest.approx(
            0.25 * float(base(jnp.int32(step)))
        )


# ---------------------------------------------------------------------------
# trainer plumbing: sugar, controller, metrics
# ---------------------------------------------------------------------------


def _toy_run(**kw):
    from repro.configs import FLRunConfig
    from repro.training.trainer import train_decentralized

    n = 8
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)

    def loss(p, batch):
        return jnp.mean((p["w"] - batch["t"]) ** 2)

    params = {"w": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)}

    def batches():
        while True:
            yield {"t": np.broadcast_to(np.asarray(target), (n, 4, 5))}

    run = FLRunConfig(algorithm="dsgd", q=2, topology="ring", n_nodes=n,
                      batch_per_node=1, alpha0=0.05, schedule="constant")
    return train_decentralized(loss, params, run, batches(), rounds=4,
                               engine="fused", scale_chunk=8, **kw)


def test_trainer_staleness_depth_sugar_and_fault_metrics():
    result = _toy_run(
        staleness_depth=2,
        node_program="stragglers:frac=0.5,rate=0.5,seed=1",
        robust_alpha=True,
    )
    assert result.engine.round_schedule.spec() == "bounded_staleness:k=2"
    assert result.engine.node_program.spec() == \
        "stragglers:drop=1,frac=0.5,rate=0.5,seed=1"
    rows = result.history.rows()
    assert all(0.0 <= r["payload_fraction"] <= 1.0 for r in rows)
    assert all(0.0 < r["compute_fraction"] <= 1.0 for r in rows)
    # depth 0 is the sequential schedule
    assert _toy_run(staleness_depth=0).engine.round_schedule.spec() == \
        "sequential"


def test_trainer_rejects_conflicting_schedule_knobs():
    with pytest.raises(ValueError, match="staleness_depth"):
        _toy_run(staleness_depth=2, round_schedule="pipelined")
