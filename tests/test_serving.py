"""Serving engine integration tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_fn(jax.random.key(0))
    return cfg, bundle, params


def test_decode_logits_match_prefill(tiny):
    """Stepping a prompt through the cached decode path must reproduce the
    full-sequence prefill logits at the last position."""
    cfg, bundle, params = tiny
    rng = np.random.default_rng(0)
    b, p = 2, 12
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, p)), jnp.int32)
    logits_prefill, _ = jax.jit(bundle.prefill_fn)(params, {"tokens": prompt})

    caches = bundle.init_decode_state_fn(b, 64)
    step = jax.jit(lambda pp, t, c: bundle.decode_fn(pp, t, c))
    logits = None
    for t in range(p):
        logits, caches = step(params, prompt[:, t], caches)
    np.testing.assert_allclose(
        np.asarray(logits_prefill, np.float32),
        np.asarray(logits, np.float32),
        atol=5e-2, rtol=5e-2,  # bf16 accumulation differences
    )
    # argmax agreement is the functional requirement
    assert (np.argmax(np.asarray(logits_prefill, np.float32), -1)
            == np.argmax(np.asarray(logits, np.float32), -1)).all()


def test_greedy_generation_deterministic(tiny):
    cfg, bundle, params = tiny
    engine = ServeEngine(bundle, params, max_seq=64, batch=2)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, size=(2, 8)).astype(np.int32)
    out1 = engine.generate(prompts, max_new_tokens=6, temperature=0.0)
    out2 = engine.generate(prompts, max_new_tokens=6, temperature=0.0)
    np.testing.assert_array_equal(out1.tokens, out2.tokens)
    assert out1.tokens.shape == (2, 14)
    assert (out1.tokens[:, :8] == prompts).all()
    assert (out1.tokens < cfg.vocab_size).all(), "sampled padded-vocab id"


def test_temperature_sampling_stays_in_vocab(tiny):
    cfg, bundle, params = tiny
    engine = ServeEngine(bundle, params, max_seq=64, batch=1)
    prompts = np.zeros((1, 4), np.int32)
    out = engine.generate(prompts, max_new_tokens=16, temperature=1.5, seed=7)
    assert (out.tokens < cfg.vocab_size).all()


def test_ssm_engine_generation():
    cfg = get_config("rwkv6-7b", smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_fn(jax.random.key(2))
    engine = ServeEngine(bundle, params, max_seq=32, batch=2)
    prompts = np.ones((2, 4), np.int32)
    out = engine.generate(prompts, max_new_tokens=4, temperature=0.0)
    assert out.tokens.shape == (2, 8)


def test_audio_engine_generation():
    cfg = get_config("whisper-medium", smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_fn(jax.random.key(3))
    engine = ServeEngine(bundle, params, max_seq=32, batch=1)
    rng = np.random.default_rng(5)
    frames = rng.normal(size=(1, cfg.encoder.seq_len, cfg.encoder.d_model)).astype(np.float32)
    out = engine.generate(np.zeros((1, 2), np.int32), max_new_tokens=4, frames=frames)
    assert out.tokens.shape == (1, 6)
