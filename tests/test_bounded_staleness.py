"""BoundedStalenessSchedule(k): depth-k staleness == a hand-written
k-delayed sequential oracle, k=1 bit-identical to the pipelined schedule,
the wire-ring comm-state contract (k payloads in flight must NOT multiply
the collective's operand bytes), and mid-ring checkpoint restores.

Single-host: the k-delayed oracle over dsgd/dsgt x k x {dense, top-k,
no-difference-coding} wires, depth-k under a dynamic topology program,
zero-recompile across faulty rounds, and depth-mismatch restore refusal.

Multi-device (subprocess, 8 forced host devices, slow): sharded
bounded_staleness:k=3 == fused over dsgd/dsgt x both wires x
{circulant, dense W}, the jaxpr proof that the ring adds ZERO extra
collectives (same ppermute count and operand bytes as depth 1), and a
mid-ring checkpoint restore that replays bit-identically.
"""

import collections
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FLConfig,
    FusedEngine,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
    pack,
    resolve_schedule,
)
from repro.core.schedules import constant, inv_sqrt
from repro.kernels.gossip.ref import wire_stage_gt_ref, wire_stage_ref
from repro.training.checkpoint import load_fl_state, save_fl_state

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _problem(n, q, seed=0):
    rng = np.random.default_rng(seed)

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {
        "w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    }
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)), jnp.float32)}
    return loss, params, batches


# ---------------------------------------------------------------------------
# the k-delayed sequential oracle
# ---------------------------------------------------------------------------


def _staleness_oracle(loss, params, batches, w, cfg, sched, rounds, chunk,
                      depth, topk=None, difference_coding=True,
                      weights_np=None):
    """Sequential-with-k-round-delay, from first principles: local steps
    by hand, the wire stage via the jnp oracle, and the mix contracting
    W_off against the reconstruction from ``depth`` rounds back (a deque
    of past reconstructions, zeros before round 0 -- the ring starts
    empty). ``weights_np(r)`` swaps in a per-round W (dynamic topology);
    the CURRENT round's graph mixes the stale payload."""
    flat, layout = pack(params, pad_to=chunk)
    grad_fn = jax.vmap(jax.value_and_grad(loss))

    from repro.core.packing import pack_like, unpack

    def eval_grads(fb, batch):
        losses, grads = grad_fn(unpack(fb, layout), batch)
        return losses, pack_like(grads, layout)

    def round_w(r):
        w_r = w if weights_np is None else weights_np(r)
        return (
            jnp.asarray(w_r - np.diag(np.diag(w_r)), jnp.float32),
            jnp.asarray(np.diag(w_r), jnp.float32),
        )

    q = cfg.q
    x = flat + 0.0
    zeros = jnp.zeros_like(x)
    recon, res = zeros, zeros
    past = collections.deque([zeros] * depth)
    if cfg.algorithm == "dsgt":
        tr, gp = zeros, zeros
        recon_t, res_t = zeros, zeros
        past_t = collections.deque([zeros] * depth)
    step = 0
    for r in range(rounds):
        for i in range(q - 1):
            step += 1
            alpha = jnp.float32(sched(jnp.int32(step)))
            _, g = eval_grads(x, {k: v[i] for k, v in batches.items()})
            x = x - alpha * g
        step += 1
        alpha = jnp.float32(sched(jnp.int32(step)))
        _, g = eval_grads(x, {k: v[q - 1] for k, v in batches.items()})
        w_off, w_self = round_w(r)
        if cfg.algorithm == "dsgd":
            h, _, _, nrecon, nres = wire_stage_ref(
                x, g, recon, res, alpha, scale_chunk=chunk, topk=topk,
                difference_coding=difference_coding,
            )
            x = w_off @ past[0] + w_self[:, None] * h  # k-DELAYED neighbors
            recon, res = nrecon, nres
            past.append(nrecon)
            past.popleft()
        else:
            (h, t_half, _, _, nrx, nsx, _, _, nrt, nst) = wire_stage_gt_ref(
                x, tr, g, gp, recon, res, recon_t, res_t, alpha,
                scale_chunk=chunk, topk=topk,
                difference_coding=difference_coding,
            )
            x = w_off @ past[0] + w_self[:, None] * h
            tr = w_off @ past_t[0] + w_self[:, None] * t_half
            recon, res, recon_t, res_t, gp = nrx, nsx, nrt, nst, g
            past.append(nrx)
            past.popleft()
            past_t.append(nrt)
            past_t.popleft()
    return x


def _run_engine(loss, batches, cfg, sched, eng, flat, rounds):
    rf = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng))
    st = init_fl_state(cfg, flat, engine=eng)
    m = None
    for _ in range(rounds):
        st, m = rf(st, batches)
    return st, m, rf


@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
@pytest.mark.parametrize("k", [2, 4])
def test_bounded_staleness_equals_k_delayed_oracle(algorithm, k):
    n, q, chunk, rounds = 8, 3, 16, 6
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=3)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    sched = inv_sqrt(0.05)

    eng, flat = FusedEngine.simulated(
        w, params, scale_chunk=chunk,
        round_schedule=f"bounded_staleness:k={k}",
    )
    st, _, rf = _run_engine(loss, batches, cfg, sched, eng, flat, rounds)
    assert rf._cache_size() == 1  # the ring rotates inside ONE compile

    oracle = _staleness_oracle(loss, params, batches, w, cfg, sched, rounds,
                               chunk, depth=k)
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(oracle),
                               atol=1e-5)

    # depth k is REAL staleness: a depth-1 pipelined run lands elsewhere
    eng1, flat1 = FusedEngine.simulated(w, params, scale_chunk=chunk,
                                        round_schedule="pipelined")
    st1, _, _ = _run_engine(loss, batches, cfg, sched, eng1, flat1, rounds)
    assert float(jnp.abs(st.params - st1.params).max()) > 1e-6


def test_bounded_staleness_topk_wire_matches_oracle():
    """The compact top-k wire rides the ring unchanged (EF absorbs the
    sparsification; the ring stores the same int8+scales encoding)."""
    n, q, chunk, rounds, k, topk = 8, 2, 16, 6, 3, 4
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=5)
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    sched = inv_sqrt(0.05)
    eng, flat = FusedEngine.simulated(
        w, params, scale_chunk=chunk, topk=topk,
        round_schedule=f"bounded_staleness:k={k}",
    )
    st, _, _ = _run_engine(loss, batches, cfg, sched, eng, flat, rounds)
    oracle = _staleness_oracle(loss, params, batches, w, cfg, sched, rounds,
                               chunk, depth=k, topk=topk)
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(oracle),
                               atol=1e-5)


def test_bounded_staleness_without_difference_coding():
    """dc=False flips the ring semantics (k stored payloads, the OLDEST
    dequantizes to the full k-stale reconstruction instead of a telescoped
    difference sum) -- same oracle, different internal path."""
    n, q, chunk, rounds, k = 8, 2, 16, 5, 2
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=7)
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    sched = constant(0.05)
    eng, flat = FusedEngine.simulated(
        w, params, scale_chunk=chunk, difference_coding=False,
        round_schedule=f"bounded_staleness:k={k}",
    )
    st, _, _ = _run_engine(loss, batches, cfg, sched, eng, flat, rounds)
    oracle = _staleness_oracle(loss, params, batches, w, cfg, sched, rounds,
                               chunk, depth=k, difference_coding=False)
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(oracle),
                               atol=1e-5)


def test_bounded_staleness_under_topology_churn():
    """Depth-k staleness composes with the dynamic-topology axis: round
    r's REALIZED graph W_r mixes the k-round-stale payload, still in one
    compiled round."""
    n, q, chunk, rounds, k = 8, 2, 8, 6, 3
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=9)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    sched = inv_sqrt(0.05)
    eng, flat = FusedEngine.simulated(
        w, params, scale_chunk=chunk,
        topology_program="edge_failure:p=0.3,seed=2",
        round_schedule=f"bounded_staleness:k={k}",
    )
    st, _, rf = _run_engine(loss, batches, cfg, sched, eng, flat, rounds)
    assert rf._cache_size() == 1
    oracle = _staleness_oracle(
        loss, params, batches, w, cfg, sched, rounds, chunk, depth=k,
        weights_np=eng.topology_program.weights_np,
    )
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(oracle),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# k=1 IS the pipelined schedule (bit-identical, same comm contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
def test_bounded_k1_bit_identical_to_pipelined(algorithm):
    n, q, chunk, rounds = 8, 2, 16, 4
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=1)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    sched = inv_sqrt(0.05)

    eng_p, flat = FusedEngine.simulated(w, params, scale_chunk=chunk,
                                        round_schedule="pipelined")
    eng_1, _ = FusedEngine.simulated(w, params, scale_chunk=chunk,
                                     round_schedule="bounded_staleness:k=1")
    # identical comm-state contract: a k=1 checkpoint IS a pipelined one
    assert eng_p.comm_keys(cfg) == eng_1.comm_keys(cfg)
    sds_p, sds_1 = eng_p.comm_state_sds(cfg), eng_1.comm_state_sds(cfg)
    assert {k: (v.shape, v.dtype) for k, v in sds_p.items()} == \
           {k: (v.shape, v.dtype) for k, v in sds_1.items()}

    st_p, _, _ = _run_engine(loss, batches, cfg, sched, eng_p, flat, rounds)
    st_1, _, _ = _run_engine(loss, batches, cfg, sched, eng_1, flat, rounds)
    np.testing.assert_array_equal(np.asarray(st_p.params),
                                  np.asarray(st_1.params))
    for key in eng_p.comm_keys(cfg):
        np.testing.assert_array_equal(np.asarray(st_p.comm[key]),
                                      np.asarray(st_1.comm[key]))


# ---------------------------------------------------------------------------
# the wire-ring contract: k payloads in flight, ONE payload on the wire
# ---------------------------------------------------------------------------


def test_ring_state_grows_but_wire_bytes_do_not():
    """The ring multiplies the CHECKPOINTED in-flight state by ~k; the
    per-round collective still moves exactly one payload -- wire_bytes
    must be identical across depths (the bench_guard invariant)."""
    n, q, chunk, rounds = 8, 2, 16, 3
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    sched = constant(0.05)

    bytes_by_k, ring_elems = {}, {}
    for spec in ("pipelined", "bounded_staleness:k=2",
                 "bounded_staleness:k=4"):
        eng, flat = FusedEngine.simulated(w, params, scale_chunk=chunk,
                                          round_schedule=spec)
        _, m, _ = _run_engine(loss, batches, cfg, sched, eng, flat, rounds)
        bytes_by_k[spec] = float(m["wire_bytes"])
        sds = eng.comm_state_sds(cfg)
        ring_elems[spec] = (int(np.prod(sds["wire_q"].shape))
                            if "wire_q" in sds else 0)
    assert len(set(bytes_by_k.values())) == 1, bytes_by_k
    # the ring itself DOES deepen (k-1 slots under difference coding:
    # recon already lags one round, so depth 1 needs NO ring at all)
    assert ring_elems["pipelined"] == 0
    assert ring_elems["bounded_staleness:k=4"] == \
        3 * ring_elems["bounded_staleness:k=2"]


def test_exact_wire_engines_reject_bounded_staleness():
    w = mixing_matrix("ring", 4)
    _, params, _ = _problem(4, 1)
    for name in ("tree", "flat"):
        with pytest.raises(ValueError, match="sequential-only"):
            get_engine(name).simulated(
                w, params, round_schedule="bounded_staleness:k=2"
            )


# ---------------------------------------------------------------------------
# mid-ring checkpoints: spec in the manifest, depth mismatch refused
# ---------------------------------------------------------------------------


def test_mid_ring_checkpoint_restores_bit_identically():
    n, q, chunk, k = 8, 2, 16, 3
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=2)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    sched = inv_sqrt(0.05)
    eng, flat = FusedEngine.simulated(
        w, params, scale_chunk=chunk,
        round_schedule=f"bounded_staleness:k={k}",
    )
    rf = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng))
    st = init_fl_state(cfg, flat, engine=eng)
    for _ in range(2):  # ring only PARTIALLY filled (2 < k)
        st, _ = rf(st, batches)
    with tempfile.TemporaryDirectory() as d:
        save_fl_state(d, st, engine=eng)
        import json

        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["round_schedule"] == f"bounded_staleness:k={k}"
        template = init_fl_state(cfg, flat, engine=eng)
        back = load_fl_state(d, template, engine=eng)

        # a k=2 engine cannot consume the 3-deep ring: refuse loudly
        eng2, _ = FusedEngine.simulated(
            w, params, scale_chunk=chunk,
            round_schedule="bounded_staleness:k=2",
        )
        with pytest.raises(ValueError, match="staleness depth"):
            load_fl_state(d, template, engine=eng2)
    for _ in range(3):
        st, _ = rf(st, batches)
        back, _ = rf(back, batches)
    np.testing.assert_array_equal(np.asarray(st.params),
                                  np.asarray(back.params))


def test_depth_spec_resolves_and_validates():
    assert resolve_schedule("bounded_staleness:k=4").depth == 4
    with pytest.raises(ValueError, match="k=-1"):
        resolve_schedule("bounded_staleness:k=-1")


# ---------------------------------------------------------------------------
# sharded: depth-3 == fused, ring adds ZERO collectives, mid-ring restore
# (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------


def _run(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


_BOUNDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (FLConfig, FusedEngine, ShardedFusedEngine,
                            flat_wire_bytes, init_fl_state, make_fl_round,
                            mixing_matrix, pack)
    from repro.core.schedules import inv_sqrt
    from repro.launch.mesh import make_test_mesh, node_axes, n_fl_nodes

    mesh = make_test_mesh((2, 2, 2))
    naxes = node_axes(mesh); n = n_fl_nodes(mesh)
    rng = np.random.default_rng(0)
    q, chunk, K = 2, 16, 3
    SPEC = "bounded_staleness:k=3"

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {"w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)), jnp.float32)}
    flat, layout = pack(params, pad_to=chunk)
    sched = inv_sqrt(0.05)
    w_er = mixing_matrix("erdos_renyi", n, p=0.7, seed=1)

    # 1. depth-3 sharded == depth-3 fused (which equals the k-delayed
    #    oracle -- tests/test_bounded_staleness.py proves that single-
    #    host) over dsgd/dsgt x {dense int8, compact top-k} x
    #    {circulant, dense W}; 6 rounds so the ring wraps twice
    def compare(algorithm, topk, w):
        cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
        sh = ShardedFusedEngine.from_mesh(
            mesh, naxes, params, scale_chunk=chunk, topk=topk,
            impl="pallas", w=w, round_schedule=SPEC)
        fe = FusedEngine(sh.dense_equivalent(), layout, scale_chunk=chunk,
                         topk=topk, impl="pallas", round_schedule=SPEC)
        rf_f = jax.jit(make_fl_round(loss, None, sched, cfg, engine=fe))
        st_f = init_fl_state(cfg, flat, engine=fe)
        with mesh:
            rf_s = jax.jit(make_fl_round(loss, None, sched, cfg, engine=sh))
            st_s = init_fl_state(
                cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
                engine=sh)
            for _ in range(6):
                st_f, m_f = rf_f(st_f, batches)
                st_s, m_s = rf_s(st_s, batches)
        err = float(jnp.abs(st_f.params - st_s.params).max())
        assert err < 1e-5, (algorithm, topk, err)
        if algorithm == "dsgt":
            terr = float(jnp.abs(st_f.tracker - st_s.tracker).max())
            assert terr < 1e-5, (algorithm, topk, terr)
        assert float(m_f["wire_bytes"]) == float(m_s["wire_bytes"])
        # the ring adds no compiles beyond the sharded engines' usual
        # init-sharding commit (sequential/pipelined lower twice too:
        # round 1 sees the eagerly-built comm layout, then steady state)
        assert rf_s._cache_size() <= 2, (algorithm, topk)
        assert rf_f._cache_size() == 1, (algorithm, topk)

    for algorithm in ("dsgd", "dsgt"):
        for topk in (None, 4):
            compare(algorithm, topk, None)
            compare(algorithm, topk, w_er)

    # 2. jaxpr: the ring must NOT multiply the wire -- the collective
    #    counts and operand bytes are IDENTICAL to the depth-1 pipelined
    #    round (one payload per direction per round; the other k-1 live
    #    in checkpointed state, never on the wire)
    def walk(jaxpr, name, found):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == name:
                found.append(eqn)
            for v in eqn.params.values():
                subs = v if isinstance(v, (list, tuple)) else [v]
                for sub in subs:
                    if hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr, name, found)
                    elif hasattr(sub, "eqns"):
                        walk(sub, name, found)
        return found

    q3 = 3
    batches3 = {"t": jnp.asarray(rng.normal(size=(q3, n, 4, 5)), jnp.float32)}
    for algorithm in ("dsgd", "dsgt"):
        cfg = FLConfig(algorithm=algorithm, q=q3, n_nodes=n)
        eng = ShardedFusedEngine.from_mesh(
            mesh, naxes, params, scale_chunk=chunk, topk=4, impl="pallas",
            round_schedule=SPEC)
        with mesh:
            rf = make_fl_round(loss, None, inv_sqrt(0.05), cfg, engine=eng)
            st = init_fl_state(
                cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
                engine=eng)
            jaxpr = jax.make_jaxpr(rf)(st, batches3)
        top = jaxpr.jaxpr.eqns
        scan_idx = [e.primitive.name for e in top].index("scan")
        pre, post = top[:scan_idx], top[scan_idx + 1:]

        def count_in(eqns, name):
            found = []
            for e in eqns:
                for v in e.params.values():
                    subs = v if isinstance(v, (list, tuple)) else [v]
                    for sub in subs:
                        if hasattr(sub, "jaxpr"):
                            walk(sub.jaxpr, name, found)
                        elif hasattr(sub, "eqns"):
                            walk(sub, name, found)
                if e.primitive.name == name:
                    found.append(e)
            return found

        wires = 2 if algorithm == "dsgt" else 1
        pp_pre = count_in(pre, "ppermute")
        assert len(pp_pre) == 3 * 2 * wires, (algorithm, len(pp_pre))
        assert len(count_in(post, "ppermute")) == 0, algorithm
        assert len(count_in(pre, "pallas_call")) == 0, algorithm
        assert len(count_in(post, "pallas_call")) == 1, algorithm
        one_dir = pp_pre[:3]
        moved = sum(int(np.prod(e.invars[0].aval.shape))
                    * e.invars[0].aval.dtype.itemsize for e in one_dir)
        # depth-1 bytes: the ring ships ONE slot, never k
        assert moved == flat_wire_bytes(layout, 1, chunk, 4), moved

    # 3. mid-ring checkpoint restore on the sharded engine: save after
    #    round 2 (ring partially filled), restore via the engine hook,
    #    continue -- bit-compatible with the uninterrupted run
    import tempfile
    from repro.training.checkpoint import load_fl_state, save_fl_state
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    eng = ShardedFusedEngine.from_mesh(
        mesh, naxes, params, scale_chunk=chunk, topk=4, impl="pallas",
        round_schedule=SPEC)
    with mesh:
        rf = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng))
        st = init_fl_state(
            cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
            engine=eng)
        for _ in range(2):
            st, _ = rf(st, batches)
        with tempfile.TemporaryDirectory() as d:
            save_fl_state(d, st, engine=eng)
            import json as _json
            manifest = _json.load(open(os.path.join(d, "manifest.json")))
            assert manifest["round_schedule"] == SPEC
            assert any(k.startswith("wire_q") for k in manifest["comm_keys"])
            template = init_fl_state(
                cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
                engine=eng)
            back = load_fl_state(d, template, engine=eng)
        for _ in range(3):
            st, _ = rf(st, batches)
            back, _ = rf(back, batches)
    err = float(jnp.abs(st.params - back.params).max())
    assert err < 1e-6, err
    print("BOUNDED-SHARDED-OK")
    """
)


@pytest.mark.slow
def test_sharded_bounded_staleness():
    out = _run(_BOUNDED_SCRIPT)
    assert "BOUNDED-SHARDED-OK" in out


# ---------------------------------------------------------------------------
# straggler convergence note (EHR cohort)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_straggler_balanced_accuracy_within_002():
    """Depth-k bounded staleness with 25% stragglers (half local steps,
    dropped payloads) must not cost more than 0.02 balanced accuracy vs
    the lockstep sequential baseline on the 20-hospital cohort at k <= 4
    (equal round budget; the full-budget frontier is
    benchmarks/straggler_ehr.py -> experiments/straggler_ehr.json)."""
    sys.path.insert(0, REPO)
    from benchmarks.straggler_ehr import run_cell

    rounds, q = 40, 10  # the committed experiment runs 80 rounds
    base = run_cell(0, 0.0, rounds, q)
    for k in (2, 4):
        cell = run_cell(k, 0.25, rounds, q)
        delta = base["bal_acc"] - cell["bal_acc"]
        assert delta <= 0.02, (k, base["bal_acc"], cell["bal_acc"])
