"""Flat-buffer gossip engine: flat == per-leaf for every backend, the fused
Pallas kernel == the jnp oracle == make_compressed_dense_gossip, and the
sharded round's HLO carries ONE collective-permute per torus direction
independent of leaf count."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (
    init_compression_state,
    init_flat_compression_state,
    make_compressed_dense_gossip,
    make_compressed_dense_gossip_per_leaf,
    make_compressed_flat_gossip,
)
from repro.core.fl import FLConfig, init_fl_state, make_fl_round
from repro.core.mixing import (
    make_dense_flat_mix,
    make_dense_gossip,
    make_dense_gossip_per_leaf,
)
from repro.core.packing import pack, unpack
from repro.core.schedules import constant
from repro.core.topology import mixing_matrix

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(n, seed, bf16=False):
    rng = np.random.default_rng(seed)
    t = {
        "a": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32),
        "b": {"c": jnp.asarray(rng.normal(size=(n, 3, 4)), jnp.float32)},
        "d": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
    }
    if bf16:
        t["e"] = jnp.asarray(rng.normal(size=(n, 6)), jnp.bfloat16)
    return t


# ---------------------------------------------------------------------------
# dense backend: flat == per-leaf
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", ["ring", "complete", "torus:4x4"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_dense_flat_matches_per_leaf(topo, seed):
    n = 16
    w = mixing_matrix(topo, n)
    tree = _tree(n, seed, bf16=True)
    out_flat = make_dense_gossip(w)(tree)
    out_leaf = make_dense_gossip_per_leaf(w)(tree)
    for a, b in zip(jax.tree_util.tree_leaves(out_flat), jax.tree_util.tree_leaves(out_leaf)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )


@pytest.mark.parametrize("seed", [0, 1])
def test_dense_flat_matches_per_leaf_bf16_wire(seed):
    n = 8
    w = mixing_matrix("ring", n)
    tree = _tree(n, seed)
    out_flat = make_dense_gossip(w, wire_dtype=jnp.bfloat16)(tree)
    out_leaf = make_dense_gossip_per_leaf(w, wire_dtype=jnp.bfloat16)(tree)
    for a, b in zip(jax.tree_util.tree_leaves(out_flat), jax.tree_util.tree_leaves(out_leaf)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_dense_flat_mix_operates_on_buffer():
    n = 8
    w = mixing_matrix("ring", n)
    tree = _tree(n, 5)
    flat, layout = pack(tree)
    mixed = make_dense_flat_mix(w)(flat)
    expect = make_dense_gossip_per_leaf(w)(tree)
    for a, b in zip(
        jax.tree_util.tree_leaves(unpack(mixed, layout)),
        jax.tree_util.tree_leaves(expect),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# compressed path: flat engine == per-leaf oracle (aligned scales),
# kernel == jnp ref == make_compressed_dense_gossip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("ef,dc", [(True, True), (True, False), (False, True)])
def test_compressed_flat_matches_per_leaf_when_scales_align(seed, ef, dc):
    """Single-leaf state that fits one scale chunk: flat per-(node,chunk)
    scales coincide with the per-leaf scales, so the paths agree exactly
    round after round."""
    n = 16
    w = mixing_matrix("torus:4x4", n)
    rng = np.random.default_rng(seed)
    tree = {"x": jnp.asarray(rng.normal(size=(n, 48)), jnp.float32)}
    g_flat = make_compressed_dense_gossip(w, error_feedback=ef, difference_coding=dc,
                                          scale_chunk=64)
    g_leaf = make_compressed_dense_gossip_per_leaf(w, error_feedback=ef,
                                                   difference_coding=dc)
    t1, t2 = tree, tree
    s1, s2 = init_compression_state(tree), init_compression_state(tree)
    for _ in range(6):
        t1, s1 = g_flat(t1, s1)
        t2, s2 = g_leaf(t2, s2)
        np.testing.assert_allclose(np.asarray(t1["x"]), np.asarray(t2["x"]), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(s1["recon"]["x"]), np.asarray(s2["recon"]["x"]), atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(s1["residual"]["x"]), np.asarray(s2["residual"]["x"]), atol=1e-6
    )


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("cfg", [
    # (n, t, chunk, ef, dc)
    (16, 256, 64, True, True),
    (8, 512, 128, True, False),
    (16, 128, 128, False, True),
    (64, 1024, 256, True, True),
    (8, 96, 32, True, True),
])
def test_fused_kernel_matches_jnp_ref(seed, cfg):
    """The Pallas kernel (interpret mode on CPU) reproduces the chunked jnp
    oracle within atol 1e-5 on every output: mixed, recon, residual,
    scales."""
    from repro.kernels.gossip.ops import gossip_mix
    from repro.kernels.gossip.ref import gossip_mix_ref

    n, t, ck, ef, dc = cfg
    rng = np.random.default_rng(seed)
    w = mixing_matrix("ring", n)
    w_self = jnp.asarray(np.diag(w), jnp.float32)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)
    scale = 10.0 ** rng.integers(-3, 3)
    x = jnp.asarray(scale * rng.normal(size=(n, t)), jnp.float32)
    recon = jnp.asarray(scale * rng.normal(size=(n, t)), jnp.float32)
    res = jnp.asarray(0.1 * scale * rng.normal(size=(n, t)), jnp.float32)
    outs_k = gossip_mix(x, recon, res, w_off, w_self, scale_chunk=ck,
                        error_feedback=ef, difference_coding=dc)
    outs_r = gossip_mix_ref(x, recon, res, w_off, w_self, scale_chunk=ck,
                            error_feedback=ef, difference_coding=dc)
    for name, a, b in zip(("mixed", "recon", "res", "scales"), outs_k, outs_r):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5 * max(scale, 1.0), err_msg=name
        )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fused_kernel_matches_compressed_dense_gossip(seed):
    """Property test against make_compressed_dense_gossip: driving the
    kernel (impl='pallas') and the default jnp engine over several rounds
    of the SAME tree state produces identical mixing within atol 1e-5."""
    n = 8
    w = mixing_matrix("ring", n)
    rng = np.random.default_rng(seed)
    tree = {
        "a": jnp.asarray(rng.normal(size=(n, 40)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 3, 7)), jnp.float32),
    }
    g_jnp = make_compressed_dense_gossip(w, scale_chunk=32)
    g_ker = make_compressed_dense_gossip(w, scale_chunk=32, impl="pallas")
    t1, t2 = tree, tree
    s1, s2 = init_compression_state(tree), init_compression_state(tree)
    for _ in range(4):
        t1, s1 = g_jnp(t1, s1)
        t2, s2 = g_ker(t2, s2)
    for a, b in zip(jax.tree_util.tree_leaves((t1, s1)), jax.tree_util.tree_leaves((t2, s2))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_compressed_flat_gossip_mean_preserving():
    """1^T W = 1^T on the flat buffer: mixing moves the node average only
    by the (vanishing) quantization drift."""
    n = 16
    w = mixing_matrix("torus:4x4", n)
    rng = np.random.default_rng(0)
    tree = {"x": jnp.asarray(rng.normal(size=(n, 100)), jnp.float32)}
    flat, layout = pack(tree, pad_to=64)
    g = make_compressed_flat_gossip(w, scale_chunk=64)
    state = init_flat_compression_state(flat)
    mean0 = np.asarray(flat).mean(0)
    for _ in range(5):
        flat, state = g(flat, state)
    drift = np.abs(np.asarray(flat).mean(0) - mean0).max()
    q_step = np.abs(np.asarray(flat)).max() / 127.0
    assert drift < 5 * q_step


def test_compressed_flat_gossip_converges_to_exact_floor():
    """Difference coding on the flat buffer reaches the exact-gossip
    consensus floor (the payload scale vanishes with consensus)."""
    n = 16
    w = mixing_matrix("torus:4x4", n)
    rng = np.random.default_rng(1)
    x0 = jnp.asarray(rng.normal(size=(n, 64)), jnp.float32)

    exact = make_dense_flat_mix(w)
    g = make_compressed_flat_gossip(w, scale_chunk=64)
    f_ex, f_df = x0, x0
    st = init_flat_compression_state(x0)
    for _ in range(120):
        f_ex = exact(f_ex)
        f_df, st = g(f_df, st)

    def dev(f):
        a = np.asarray(f)
        return float(np.linalg.norm(a - a.mean(0)))

    assert dev(f_df) < 10 * max(dev(f_ex), 1e-6)


# ---------------------------------------------------------------------------
# flat state threading through make_fl_round
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
def test_flat_fl_round_matches_tree_round(algorithm):
    n, q = 8, 3
    w = mixing_matrix("ring", n)
    rng = np.random.default_rng(0)

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {
        "w": jnp.asarray(rng.normal(size=(n, 4, 3)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    }
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 3)), jnp.float32)}
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)

    rf_tree = jax.jit(make_fl_round(loss, make_dense_gossip(w), constant(0.05), cfg))
    st_tree = init_fl_state(cfg, params)

    from repro.core.engine import FlatEngine

    flat, layout = pack(params, pad_to=8)
    engine = FlatEngine(make_dense_flat_mix(w), layout)
    rf_flat = jax.jit(make_fl_round(loss, None, constant(0.05), cfg, engine=engine))
    st_flat = init_fl_state(cfg, flat, engine=engine)

    for _ in range(3):
        st_tree, m_tree = rf_tree(st_tree, batches)
        st_flat, m_flat = rf_flat(st_flat, batches)

    back = unpack(st_flat.params, layout)
    for k in params:
        np.testing.assert_allclose(
            np.asarray(back[k]), np.asarray(st_tree.params[k]), atol=1e-5
        )
    for k in ("loss", "grad_norm_sq", "consensus_err", "local_loss"):
        np.testing.assert_allclose(
            float(m_flat[k]), float(m_tree[k]), rtol=1e-4, atol=1e-6
        )


# ---------------------------------------------------------------------------
# sharded backends: flat == per-leaf, and the compiled HLO carries ONE
# collective per direction independent of leaf count
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import (make_dense_gossip, make_mesh_gossip,
                            make_allgather_gossip, mesh_gossip_dense_equivalent,
                            mixing_matrix)
    from repro.core.mixing import (make_mesh_gossip_per_leaf,
                                   make_allgather_gossip_per_leaf)
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 2, 2))
    tree = {"w": jnp.arange(4 * 6 * 4, dtype=jnp.float32).reshape(4, 6, 4),
            "b": jnp.linspace(0, 1, 20, dtype=jnp.float32).reshape(4, 5)}
    specs = {"w": P(("pod", "data"), None, "model"), "b": P(("pod", "data"), None)}

    with mesh:
        out_mesh = jax.jit(make_mesh_gossip(mesh, ("pod", "data"), specs))(tree)
        out_mesh_pl = jax.jit(make_mesh_gossip_per_leaf(mesh, ("pod", "data"), specs))(tree)
        w_er = mixing_matrix("erdos_renyi", 4, p=0.7, seed=1)
        out_ag = jax.jit(make_allgather_gossip(mesh, ("pod", "data"), specs, w_er))(tree)
        out_ag_pl = jax.jit(make_allgather_gossip_per_leaf(mesh, ("pod", "data"), specs, w_er))(tree)

    ref_mesh = make_dense_gossip(mesh_gossip_dense_equivalent({"pod": 2, "data": 2}))(tree)
    ref_ag = make_dense_gossip(w_er)(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out_mesh[k]), np.asarray(ref_mesh[k]), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out_mesh[k]), np.asarray(out_mesh_pl[k]), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out_ag[k]), np.asarray(ref_ag[k]), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out_ag[k]), np.asarray(out_ag_pl[k]), rtol=1e-6)

    # HLO collective count: one ppermute per torus direction (the 2x2
    # (pod, data) torus has exactly 2 directions), no matter the leaf count;
    # the per-leaf reference pays one per direction PER LEAF.
    def ppermutes(compiled):
        return analyze_hlo(compiled.as_text()).collectives.get(
            "collective-permute", {}).get("count", 0)

    for nleaves in (3, 24):
        many = {f"l{i}": jnp.ones((4, 3, 5), jnp.float32) for i in range(nleaves)}
        mspecs = {f"l{i}": P(("pod", "data"), None, None) for i in range(nleaves)}
        with mesh:
            c_flat = jax.jit(make_mesh_gossip(mesh, ("pod", "data"), mspecs)).lower(many).compile()
            c_leaf = jax.jit(make_mesh_gossip_per_leaf(mesh, ("pod", "data"), mspecs)).lower(many).compile()
            c_ag = jax.jit(make_allgather_gossip(mesh, ("pod", "data"), mspecs, w_er)).lower(many).compile()
        assert ppermutes(c_flat) == 2, (nleaves, ppermutes(c_flat))
        assert ppermutes(c_leaf) == 2 * nleaves, (nleaves, ppermutes(c_leaf))
        ag = analyze_hlo(c_ag.as_text()).collectives.get("all-gather", {}).get("count", 0)
        assert ag == 1, (nleaves, ag)
    print("GOSSIP-FLAT-SHARDED-OK")
    """
)


def test_sharded_flat_gossip_and_hlo_collective_count():
    """Dry-run: flat mesh/all-gather gossip == per-leaf == dense oracle,
    and the compiled HLO has exactly one collective-permute per torus
    direction (resp. one all-gather) regardless of leaf count."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "GOSSIP-FLAT-SHARDED-OK" in proc.stdout
