"""Flat-buffer packing layer: lossless round-trips, layout invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import (
    FlatLayout,
    flat_wire_bytes,
    pack,
    pack_layout,
    pack_like,
    unpack,
)


def _mixed_tree(n, seed):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(n, 5, 3)), jnp.float32),
        "nested": {
            "b16": jnp.asarray(rng.normal(size=(n, 7)), jnp.bfloat16),
            "rank4": jnp.asarray(rng.normal(size=(n, 2, 3, 2)), jnp.float32),
        },
        "vec": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
        "f16": jnp.asarray(rng.normal(size=(n, 4)), jnp.float16),
    }


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("pad_to", [1, 8, 512])
def test_pack_unpack_roundtrip_mixed_dtypes_and_ranks(seed, pad_to):
    """fp32/bf16/fp16 leaves of rank 1-4 survive the round trip BITWISE
    (fp32 holds each losslessly)."""
    tree = _mixed_tree(6, seed)
    flat, layout = pack(tree, pad_to=pad_to)
    assert flat.shape == (6, layout.total)
    assert layout.total % pad_to == 0
    assert layout.used == sum(l.size for l in jax.tree_util.tree_leaves(tree)) // 6
    back = unpack(flat, layout)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert jnp.array_equal(a, b)


def test_layout_is_static_and_hashable():
    tree = _mixed_tree(4, 0)
    _, layout = pack(tree)
    assert isinstance(hash(layout), int)  # usable as a jit static argument
    # identical trees produce identical layouts
    _, layout2 = pack(_mixed_tree(4, 1))
    assert layout == layout2


def test_pack_layout_works_on_shape_structs():
    tree = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), _mixed_tree(4, 0)
    )
    layout = pack_layout(tree, pad_to=128)
    assert layout.n_nodes == 4 and layout.total == 128


def test_pack_padding_is_zero():
    tree = {"x": jnp.ones((3, 5), jnp.float32)}
    flat, layout = pack(tree, pad_to=8)
    assert layout.total == 8 and layout.used == 5
    assert np.asarray(flat[:, 5:]).max() == 0.0


def test_pack_like_follows_layout():
    tree = _mixed_tree(5, 3)
    flat, layout = pack(tree, pad_to=16)
    again = pack_like(tree, layout)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(again))
    # shape mismatch is rejected
    bad = dict(tree, vec=jnp.zeros((5, 2)))
    with pytest.raises(ValueError):
        pack_like(bad, layout)


def test_pack_rejects_inconsistent_node_axis():
    with pytest.raises(ValueError):
        pack({"a": jnp.zeros((4, 2)), "b": jnp.zeros((3, 2))})
    with pytest.raises(ValueError):
        pack({})


def test_unpack_rejects_wrong_buffer_shape():
    tree = {"x": jnp.ones((3, 5))}
    flat, layout = pack(tree)
    with pytest.raises(ValueError):
        unpack(flat[:, :-1], layout)


def test_roundtrip_under_jit_with_static_layout():
    tree = _mixed_tree(4, 7)
    flat, layout = pack(tree)

    @jax.jit
    def double_via_flat(t):
        f, lay = pack(t)
        return unpack(f * 2.0, lay)

    out = double_via_flat(tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32) * 2.0, np.asarray(b, np.float32),
            rtol=1e-2 if a.dtype == jnp.bfloat16 else 1e-6,
        )
    assert isinstance(layout, FlatLayout)


def test_flat_wire_bytes_accounting():
    tree = {"a": jnp.zeros((4, 1000)), "b": jnp.zeros((4, 100))}
    _, layout = pack(tree, pad_to=512)
    assert layout.total == 1536
    # int8 payload + one fp32 scale per 512-column chunk, per neighbor
    assert flat_wire_bytes(layout, degree=2, scale_chunk=512) == 2 * (1536 + 4 * 3)
    # scale_chunk=0: single per-node scale
    assert flat_wire_bytes(layout, degree=1) == 1536 + 4
