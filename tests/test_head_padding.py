"""TP head padding (§Perf optimization): exact logical-head semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.attention import layout_heads


def test_layout_heads():
    assert layout_heads(40, 16) == 48
    assert layout_heads(15, 16) == 16
    assert layout_heads(32, 16) == 32  # already divisible: no padding
    assert layout_heads(40, 0) == 40  # disabled


@pytest.mark.slow
def test_padded_heads_receive_zero_gradient():
    """Padded q heads are zero-init + output-masked: they must NEVER train,
    so the padded model IS the logical-head model."""
    cfg = dataclasses.replace(get_config("smollm-360m", smoke=True), tp_head_pad=4)
    bundle = build_model(cfg)
    params = bundle.init_fn(jax.random.key(0))
    hd = cfg.head_dim
    real = cfg.n_heads * hd
    assert params["blocks"]["attn"]["wq"]["w"].shape[-1] == layout_heads(cfg.n_heads, 4) * hd

    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 33), 0, cfg.vocab_size)}
    loss, g = jax.jit(jax.value_and_grad(bundle.loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    gwq = np.asarray(g["blocks"]["attn"]["wq"]["w"], np.float32)
    gwo = np.asarray(g["blocks"]["attn"]["wo"]["w"], np.float32)
    assert np.abs(gwq[..., real:]).max() == 0.0
    assert np.abs(gwo[:, real:, :]).max() == 0.0
    assert np.abs(gwq[..., :real]).max() > 0.0


def test_padded_decode_matches_unpadded_prefill_argmax():
    """Decode with padded layout stays finite and self-consistent."""
    cfg = dataclasses.replace(get_config("smollm-360m", smoke=True), tp_head_pad=4)
    bundle = build_model(cfg)
    params = bundle.init_fn(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    logits_pre, _ = jax.jit(bundle.prefill_fn)(params, {"tokens": prompt})
    caches = bundle.init_decode_state_fn(2, 32)
    step = jax.jit(lambda p, t, c: bundle.decode_fn(p, t, c))
    logits = None
    for t in range(8):
        logits, caches = step(params, prompt[:, t], caches)
    a = np.argmax(np.asarray(logits_pre, np.float32), -1)
    b = np.argmax(np.asarray(logits, np.float32), -1)
    np.testing.assert_array_equal(a, b)
