"""Two-axis (gossip_node, model_shard) engine suite.

Everything here runs in a SUBPROCESS with forced host devices (XLA
locks the device count at first jax init), so the whole module is
``slow``. What is proven:

* **equivalence** -- the two-axis sharded round == the single-host
  ``FusedEngine`` dense oracle at 1e-5, across model_axis x topk x
  algorithm, including the shards=1 cell (single-axis <-> two-axis
  equivalence) and a 3-axis (2, 2, 2) mesh;
* **the jaxpr contract** -- one wire-stage ``pallas_call`` per (node,
  shard) tile, gossip collectives name the NODE axes only, and one
  gossip direction's ppermute operand bytes ==
  ``flat_wire_bytes_per_shard`` to the byte (the shard_map body jaxpr
  carries LOCAL per-device shapes, so its operand sizes ARE per-shard
  bytes);
* **checkpoint geometry** -- manifests record the mesh
  (axis_names/shape/model_shards/...), a model_shards mismatch is
  refused with a migration hint, and a shards=1 two-axis checkpoint
  restores bit-exactly;
* **bf16 storage** -- the sharded round with
  ``storage_dtype=bfloat16`` tracks fp32 at bf16 resolution while the
  int8 wire bytes stay IDENTICAL;
* **heterogeneity-aware top-k** -- ``slow_uplink`` per-node k: frac=0
  is bit-identical to the homogeneous round, frac>0 matches the numpy
  byte oracle for ``wire_bytes_effective``, and engines without the
  per-node k knob refuse the program at build time.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow


def _run(script: str, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


_PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (FLConfig, FusedEngine, ShardedFusedEngine,
                            flat_wire_bytes_per_shard, init_fl_state,
                            make_fl_round, pack)
    from repro.core.schedules import constant, inv_sqrt
    from repro.launch.mesh import make_test_mesh, node_axes, n_fl_nodes

    rng = np.random.default_rng(0)
    q, chunk = 2, 16

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)
    """
)


def test_two_axis_matches_dense_oracle():
    out = _run(_PRELUDE + textwrap.dedent(
        """
        def run(mesh_shape, model_axis, algorithm="dsgd", topk=4, rounds=4):
            mesh = make_test_mesh(mesh_shape)
            na = node_axes(mesh); n = n_fl_nodes(mesh)
            params = {"w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
                      "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
            batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)),
                                        jnp.float32)}
            shards = int(mesh.shape["model"]) if model_axis else 1
            flat, layout = pack(params, pad_to=chunk, shards=shards)
            sched = inv_sqrt(0.05)
            cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
            sh = ShardedFusedEngine.from_mesh(
                mesh, na, params, scale_chunk=chunk, topk=topk, impl="jnp",
                model_axis=model_axis)
            assert sh.layout.total == layout.total
            # the single-host dense oracle on the SAME padded layout
            fe = FusedEngine(sh.dense_equivalent(), layout,
                             scale_chunk=chunk, topk=topk, impl="jnp")
            rf_f = jax.jit(make_fl_round(loss, None, sched, cfg, engine=fe))
            st_f = init_fl_state(cfg, flat, engine=fe)
            with mesh:
                rf_s = jax.jit(make_fl_round(loss, None, sched, cfg,
                                             engine=sh))
                st_s = init_fl_state(
                    cfg, jax.device_put(
                        flat, NamedSharding(mesh, sh.params_spec())),
                    engine=sh)
                for _ in range(rounds):
                    st_f, m_f = rf_f(st_f, batches)
                    st_s, m_s = rf_s(st_s, batches)
            err = float(jnp.abs(st_f.params - st_s.params).max())
            assert err < 1e-5, (mesh_shape, model_axis, algorithm, topk, err)
            if algorithm == "dsgt":
                terr = float(jnp.abs(st_f.tracker - st_s.tracker).max())
                assert terr < 1e-5, terr
            assert float(m_f["wire_bytes"]) == float(m_s["wire_bytes"])
            # sharding tiles the wire, it never grows it
            pershard = sh.wire_bytes_per_shard(cfg)
            assert abs(pershard * sh.model_shards - sh.wire_bytes(cfg)) < 1e-6
            # one compiled round: the tracing cost of five axes stays 1
            assert rf_s._cache_size() <= 2, rf_s._cache_size()

        run((4, 2), "model")                       # compact top-k wire
        run((4, 2), None)                          # shards=1 == single-axis
        run((4, 2), "model", topk=None)            # dense int8 wire
        run((2, 2, 2), "model", algorithm="dsgt")  # 3-axis mesh, tracker
        print("ORACLE-OK")
        """
    ))
    assert "ORACLE-OK" in out


def test_two_axis_jaxpr_contract():
    out = _run(_PRELUDE + textwrap.dedent(
        """
        def walk(jaxpr, name, found):
            for eqn in jaxpr.eqns:
                if eqn.primitive.name == name:
                    found.append(eqn)
                for v in eqn.params.values():
                    subs = v if isinstance(v, (list, tuple)) else [v]
                    for sub in subs:
                        if hasattr(sub, "jaxpr"):
                            walk(sub.jaxpr, name, found)
                        elif hasattr(sub, "eqns"):
                            walk(sub, name, found)
            return found

        mesh = make_test_mesh((4, 2))
        na = node_axes(mesh); n = n_fl_nodes(mesh)
        params = {"w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
        batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)),
                                    jnp.float32)}

        for topk, n_buffers in ((4, 3), (None, 2)):
            # compact bitmap wire ships vals/bits/scales per direction;
            # the dense int8 wire ships q/scales
            eng = ShardedFusedEngine.from_mesh(
                mesh, na, params, scale_chunk=chunk, topk=topk,
                impl="pallas", model_axis="model")
            cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
            flat, _ = pack(params, pad_to=chunk * eng.model_shards)
            with mesh:
                rf = make_fl_round(loss, None, constant(0.05), cfg,
                                   engine=eng)
                st = init_fl_state(cfg, jax.device_put(
                    flat, NamedSharding(mesh, eng.params_spec())),
                    engine=eng)
                jx = jax.make_jaxpr(rf)(st, batches)
            # (a) ONE fused wire-stage kernel per (node, shard) tile
            assert len(walk(jx.jaxpr, "pallas_call", [])) == 1
            # (b) gossip collectives name the NODE axes only -- the
            # model axis never appears on the wire
            for prim in ("ppermute", "all_gather"):
                for eqn in walk(jx.jaxpr, prim, []):
                    axes = eqn.params.get("axis_name", ())
                    axes = axes if isinstance(axes, (tuple, list)) else (axes,)
                    assert set(map(str, axes)) <= set(eng.node_axes), (
                        prim, axes)
            # (c) one direction's ppermute operand bytes == the
            # per-shard wire bytes, to the byte (body jaxpr shapes are
            # LOCAL per-device tiles)
            pp = walk(jx.jaxpr, "ppermute", [])
            moved = sum(
                int(np.prod(e.invars[0].aval.shape))
                * e.invars[0].aval.dtype.itemsize
                for e in pp[:n_buffers])
            expect = flat_wire_bytes_per_shard(
                eng.layout, 1, eng.scale_chunk,
                eng.topk if eng.compact_wire else None)
            assert moved == expect, (topk, moved, expect)
        print("JAXPR-OK")
        """
    ))
    assert "JAXPR-OK" in out


def test_two_axis_checkpoint_geometry(tmp_path):
    out = _run(_PRELUDE + textwrap.dedent(
        f"""
        ckpt = {str(tmp_path / "two_axis_ckpt")!r}
        """
    ) + textwrap.dedent(
        """
        import json
        from repro.training.checkpoint import load_fl_state, save_fl_state

        mesh = make_test_mesh((4, 2))
        na = node_axes(mesh); n = n_fl_nodes(mesh)
        params = {"w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
        batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)),
                                    jnp.float32)}
        cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)

        def build(model_axis):
            eng = ShardedFusedEngine.from_mesh(
                mesh, na, params, scale_chunk=chunk, topk=4, impl="jnp",
                model_axis=model_axis)
            flat, _ = pack(params, pad_to=chunk * eng.model_shards)
            with mesh:
                rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg,
                                           engine=eng))
                st = init_fl_state(cfg, jax.device_put(
                    flat, NamedSharding(mesh, eng.params_spec())),
                    engine=eng)
                st, _ = rf(st, batches)
            return eng, rf, st

        # 1. the manifest records the mesh geometry
        eng2, rf2, st2 = build("model")
        save_fl_state(ckpt, st2, engine=eng2)
        rec = json.load(open(ckpt + "/manifest.json"))["mesh"]
        assert rec["model_shards"] == 2 and rec["model_axis"] == "model"
        assert rec["axis_names"] == ["data", "model"], rec
        assert rec["node_axes"] == ["data"], rec

        # 2. a model_shards mismatch is REFUSED with a migration hint
        eng1, rf1, st1 = build(None)
        try:
            load_fl_state(ckpt, st1, engine=eng1)
            raise SystemExit("mismatched restore was not refused")
        except ValueError as e:
            assert "model_shards" in str(e) and "migrat" in str(e), e

        # 3. shards=1 two-axis checkpoints restore params/tracker
        #    bit-exactly; the replay agrees to 1e-5 (restore_comm
        #    REBUILDS mix_recon from eff_recon, so the accumulator can
        #    differ by summation-order epsilon)
        save_fl_state(ckpt, st1, engine=eng1)
        back = load_fl_state(ckpt, st1, engine=eng1)
        assert float(jnp.abs(back.params - st1.params).max()) == 0.0
        assert float(jnp.abs(back.tracker - st1.tracker).max()) == 0.0
        with mesh:
            a, _ = rf1(back, batches)
            b, _ = rf1(st1, batches)
        assert float(jnp.abs(a.params - b.params).max()) < 1e-5
        print("CKPT-OK")
        """
    ))
    assert "CKPT-OK" in out


def test_two_axis_bf16_storage():
    out = _run(_PRELUDE + textwrap.dedent(
        """
        mesh = make_test_mesh((4, 2))
        na = node_axes(mesh); n = n_fl_nodes(mesh)
        params = {"w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
        batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)),
                                    jnp.float32)}
        cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)

        def run(storage_dtype, rounds=4):
            eng = ShardedFusedEngine.from_mesh(
                mesh, na, params, scale_chunk=chunk, topk=None, impl="jnp",
                model_axis="model", storage_dtype=storage_dtype)
            flat, _ = pack(params, pad_to=chunk * eng.model_shards)
            with mesh:
                rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg,
                                           engine=eng))
                st = init_fl_state(cfg, jax.device_put(
                    flat, NamedSharding(mesh, eng.params_spec())),
                    engine=eng)
                for _ in range(rounds):
                    st, m = rf(st, batches)
            return st, m

        st32, m32 = run(None)
        st16, m16 = run(jnp.bfloat16)
        assert st16.params.dtype == jnp.bfloat16
        # bf16 carries ~8 mantissa bits: relaxed tolerance, scaled
        ref = jnp.abs(st32.params).max()
        err = float(jnp.abs(st32.params
                            - st16.params.astype(jnp.float32)).max())
        assert err < 0.05 * float(ref) + 1e-3, (err, float(ref))
        # the WIRE is unchanged: int8 + fp32 scales either way
        assert float(m32["wire_bytes"]) == float(m16["wire_bytes"])
        print("BF16-OK")
        """
    ))
    assert "BF16-OK" in out


def test_two_axis_hetero_k():
    out = _run(_PRELUDE + textwrap.dedent(
        """
        from repro.core import PayloadDropProgram, SlowUplinkProgram
        from repro.core.packing import compact_pos_dtype

        mesh = make_test_mesh((4, 2))
        na = node_axes(mesh); n = n_fl_nodes(mesh)
        topk = 8
        params = {"w": jnp.asarray(rng.normal(size=(n, 8, 8)), jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
        batches = {"t": jnp.asarray(rng.normal(size=(q, n, 8, 8)),
                                    jnp.float32)}
        cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)

        def loss8(p, batch):
            return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

        def run(prog, rounds=3):
            eng = ShardedFusedEngine.from_mesh(
                mesh, na, params, scale_chunk=chunk, topk=topk, impl="jnp",
                model_axis="model", node_program=prog)
            flat, _ = pack(params, pad_to=chunk * eng.model_shards)
            with mesh:
                rf = jax.jit(make_fl_round(loss8, None, constant(0.05), cfg,
                                           engine=eng))
                st = init_fl_state(cfg, jax.device_put(
                    flat, NamedSharding(mesh, eng.params_spec())),
                    engine=eng)
                for _ in range(rounds):
                    st, m = rf(st, batches)
            return eng, st, m

        # frac=0 is BIT-IDENTICAL to a homogeneous-k faulty baseline
        eng0, st0, m0 = run(SlowUplinkProgram(frac=0.0, k_scale=0.5))
        engb, stb, mb = run(PayloadDropProgram(p=0.0))
        assert float(jnp.abs(st0.params - stb.params).max()) == 0.0
        assert "wire_bytes_effective" in m0

        # frac>0: the effective-bytes metric matches the numpy oracle
        prog = SlowUplinkProgram(frac=0.5, k_scale=0.25, seed=3)
        eng, st, m = run(prog)
        assert np.isfinite(float(m["loss"]))
        kvec = np.where(prog._slow_mask > 0.5, round(0.25 * topk),
                        topk).astype(np.float64)
        kvec = np.clip(kvec, 1, topk)
        n_chunks = eng.layout.total // chunk
        pos_b = np.dtype(compact_pos_dtype(chunk)).itemsize
        idx = np.minimum(kvec * pos_b, chunk // 8)
        per_chunk = np.minimum(kvec + idx + 4, chunk + 4)
        deg = (np.abs(eng.dense_equivalent()) > 0).sum(1) - 1
        expect = float((deg * n_chunks * per_chunk).sum())
        got = float(m["wire_bytes_effective"])
        assert got == expect, (got, expect)
        assert got < float(m["wire_bytes"])
        print("HETEROK-OK")
        """
    ))
    assert "HETEROK-OK" in out


def test_engines_without_per_node_k_refuse_hetero_programs():
    # in-process: no mesh needed -- the refusal happens at build time
    import jax.numpy as jnp  # noqa: F401

    sys.path.insert(0, os.path.join(REPO, "src"))
    import numpy as np

    from repro.core import FLConfig, FusedEngine, SlowUplinkProgram, pack
    from repro.core.topology import mixing_matrix

    params = {"w": jnp.zeros((4, 8, 8))}
    _, layout = pack(params, pad_to=16)
    w = mixing_matrix("ring", 4)
    eng = FusedEngine(np.asarray(w), layout, scale_chunk=16, topk=4,
                      impl="jnp",
                      node_program=SlowUplinkProgram(frac=0.5))
    cfg = FLConfig(algorithm="dsgd", q=2, n_nodes=4)
    with pytest.raises(ValueError, match="per-node wire k"):
        eng.make_step_mask(cfg)
