"""Per-architecture smoke tests (task requirement (f)): reduced variant of
each family (<=2 layers, d_model<=512, <=4 experts), one forward/train step
on CPU, asserting output shapes and no NaNs; plus a decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import build_model

# ~80 s of per-arch compiles on CPU: excluded from the fast tier-1 subset
pytestmark = pytest.mark.slow

B, S = 2, 64


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(key, (B, cfg.frontend_seq, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder.seq_len, cfg.encoder.d_model))
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_config_bounds(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    full = get_config(arch, smoke=False)
    assert full.family == cfg.family
    assert full.param_count() > cfg.param_count()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_shapes_and_finiteness(arch, key):
    cfg = get_config(arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_fn(key)
    batch = _batch(cfg, key)
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss_fn))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gsq = 0.0
    for leaf in jax.tree.leaves(grads):
        arr = np.asarray(leaf, np.float32)
        assert np.isfinite(arr).all(), f"{arch}: non-finite grad"
        gsq += float((arr**2).sum())
    assert gsq > 0.0, f"{arch}: zero gradient"
    # one SGD step moves the loss
    stepped = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2 = float(jax.jit(bundle.loss_fn)(stepped, batch))
    assert np.isfinite(loss2)
    assert loss2 < float(loss), f"{arch}: SGD step did not reduce loss"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_shapes(arch, key):
    cfg = get_config(arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_fn(key)
    caches = bundle.init_decode_state_fn(B, 128)
    if cfg.family == "audio":
        from repro.models import encdec as encdec_mod

        frames = jax.random.normal(key, (B, cfg.encoder.seq_len, cfg.encoder.d_model))
        enc_out = encdec_mod.encode(params, cfg, frames)
        caches = encdec_mod.encdec_fill_cross_kv(params, cfg, enc_out, caches)
    toks = jnp.zeros((B,), jnp.int32)
    logits, caches = jax.jit(lambda p, t, c: bundle.decode_fn(p, t, c))(params, toks, caches)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, _ = jax.jit(lambda p, t, c: bundle.decode_fn(p, t, c))(params, toks, caches)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "qwen2.5-32b", "dbrx-132b"])
def test_sliding_window_decode(arch, key):
    """long_500k policy: ring-buffer cache smaller than the horizon."""
    cfg = get_config(arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_fn(key)
    caches = bundle.init_decode_state_fn(B, 32, sliding_override=True)
    toks = jnp.zeros((B,), jnp.int32)
    for _ in range(5):
        logits, caches = jax.jit(
            lambda p, t, c: bundle.decode_fn(p, t, c, sliding_override=True)
        )(params, toks, caches)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_param_count_close_to_reported():
    """Analytic param counts should land near the marketing sizes."""
    expectations = {
        "phi3-medium-14b": (13e9, 16e9),
        "tinyllama-1.1b": (1.0e9, 1.25e9),
        "qwen2.5-32b": (31e9, 36e9),
        "dbrx-132b": (125e9, 140e9),
        "rwkv6-7b": (6.5e9, 8.5e9),
        "recurrentgemma-2b": (2.0e9, 3.4e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_smaller():
    cfg = get_config("dbrx-132b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()
    cfg4 = get_config("llama4-scout-17b-a16e")
    assert cfg4.active_param_count() < 0.35 * cfg4.param_count()
