"""Layer-level unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import attn_apply, attn_decode, attn_init, init_kv_cache
from repro.models.layers import (
    apply_rope,
    chunked_softmax_xent,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    softmax_xent,
    unembed_logits,
)
from repro.models.moe import moe_apply, moe_capacity, moe_init


def test_rope_preserves_norm():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 4, 64)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)

    def dot_at(i, j):
        qi = apply_rope(q, jnp.full((1, 1), i, jnp.int32), 10000.0)
        kj = apply_rope(k, jnp.full((1, 1), j, jnp.int32), 10000.0)
        return float(jnp.sum(qi * kj))

    assert np.isclose(dot_at(5, 3), dot_at(102, 100), atol=1e-4)
    assert not np.isclose(dot_at(5, 3), dot_at(5, 4), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), d=st.sampled_from([8, 32, 128]))
def test_rmsnorm_scale_invariance(seed, d):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
    p = rmsnorm_init(d, jnp.float32)
    y1 = rmsnorm(p, x)
    y2 = rmsnorm(p, 7.3 * x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 100),
    vocab=st.sampled_from([300, 512]),
    chunk=st.sampled_from([16, 64]),
)
def test_chunked_xent_equals_full(seed, vocab, chunk):
    """The memory-saving chunked loss is EXACTLY the full softmax xent."""
    rng = np.random.default_rng(seed)
    b, s, d = 2, 48, 32
    h = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    table = jnp.asarray(rng.normal(size=(vocab + 12, d)) * 0.1, jnp.float32)  # padded vocab
    labels = jnp.asarray(rng.integers(0, vocab, size=(b, s)), jnp.int32)
    full = softmax_xent(
        unembed_logits(table, h, jnp.float32), labels, valid_vocab=vocab
    )
    chunked = chunked_softmax_xent(table, h, labels, vocab, chunk=chunk, compute_dtype=jnp.float32)
    np.testing.assert_allclose(float(full), float(chunked), rtol=2e-5)


def test_chunked_xent_masks_prefix_labels():
    rng = np.random.default_rng(0)
    b, s, d, vocab = 1, 32, 16, 64
    h = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    table = jnp.asarray(rng.normal(size=(vocab, d)) * 0.1, jnp.float32)
    labels = jnp.asarray(rng.integers(0, vocab, size=(b, s)), jnp.int32)
    masked = labels.at[:, :16].set(-1)  # VLM image-prefix masking
    l_masked = chunked_softmax_xent(table, h, masked, vocab, chunk=8, compute_dtype=jnp.float32)
    l_suffix = softmax_xent(
        unembed_logits(table, h[:, 16:], jnp.float32), labels[:, 16:], valid_vocab=vocab
    )
    np.testing.assert_allclose(float(l_masked), float(l_suffix), rtol=2e-5)


def test_decode_matches_full_attention():
    """Token-by-token decode with a KV cache reproduces full-sequence
    causal attention logits position by position."""
    rng = np.random.default_rng(2)
    d, h, kv, hd, s, b = 64, 4, 2, 16, 12, 2
    key = jax.random.key(0)
    p = attn_init(key, d, h, kv, hd, jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = attn_apply(
        p, x, pos, n_heads=h, n_kv_heads=kv, head_dim=hd, rope_theta=1e4,
        causal=True, compute_dtype=jnp.float32,
    )
    cache = init_kv_cache(b, s, kv, hd, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = attn_decode(
            p, x[:, t : t + 1], cache, n_heads=h, n_kv_heads=kv, head_dim=hd,
            rope_theta=1e4, compute_dtype=jnp.float32,
        )
        outs.append(o)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stepped), atol=2e-4)


def test_ring_buffer_decode_matches_windowed_attention():
    rng = np.random.default_rng(3)
    d, h, kv, hd, s, b, win = 32, 2, 1, 16, 20, 1, 8
    p = attn_init(jax.random.key(1), d, h, kv, hd, jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = attn_apply(
        p, x, pos, n_heads=h, n_kv_heads=kv, head_dim=hd, rope_theta=1e4,
        causal=True, window=win, compute_dtype=jnp.float32,
    )
    cache = init_kv_cache(b, win, kv, hd, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = attn_decode(
            p, x[:, t : t + 1], cache, n_heads=h, n_kv_heads=kv, head_dim=hd,
            rope_theta=1e4, ring=True, compute_dtype=jnp.float32,
        )
        outs.append(o)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stepped), atol=2e-4)


def test_moe_capacity_formula():
    assert moe_capacity(1024, 16, 4, 1.25) == 320
    assert moe_capacity(8, 16, 1, 1.0) >= 8  # floor


def test_moe_outputs_and_aux():
    rng = np.random.default_rng(4)
    d, ff, e, k = 32, 64, 4, 2
    p = moe_init(jax.random.key(2), d, ff, e, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 16, d)), jnp.float32)
    out, aux = moe_apply(p, x, n_experts=e, k=k, compute_dtype=jnp.float32)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    # balanced-router aux ~ 1.0; wildly unbalanced >> 1
    assert 0.5 < float(aux) < float(e)


def test_moe_is_permutation_equivariant_over_tokens():
    """Routing + capacity dispatch must not depend on token order when
    capacity is not binding."""
    rng = np.random.default_rng(5)
    d, ff, e, k = 16, 32, 4, 1
    p = moe_init(jax.random.key(3), d, ff, e, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 8, d)), jnp.float32)
    out1, _ = moe_apply(p, x, n_experts=e, k=k, capacity_factor=8.0, compute_dtype=jnp.float32)
    perm = np.asarray([3, 1, 7, 0, 5, 2, 6, 4])
    out2, _ = moe_apply(
        p, x[:, perm], n_experts=e, k=k, capacity_factor=8.0, compute_dtype=jnp.float32
    )
    np.testing.assert_allclose(np.asarray(out1)[:, perm], np.asarray(out2), atol=1e-5)
