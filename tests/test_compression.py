"""int8 error-feedback gossip: unbiasedness and convergence properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.compression import (
    compressed_wire_bytes,
    dequantize_int8,
    init_compression_state,
    make_compressed_dense_gossip,
    quantize_int8,
)
from repro.core.mixing import make_dense_gossip
from repro.core.topology import mixing_matrix


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
def test_quantizer_roundtrip_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(scale * rng.normal(size=(4, 64)), jnp.float32)
    q, s = quantize_int8(x)
    dq = dequantize_int8(q, s)
    assert q.dtype == jnp.int8
    # error per element <= half a quantization step
    step = np.asarray(s)[:, None]
    err = np.abs(np.asarray(dq - x)).reshape(4, -1)
    assert (err <= 0.5 * step + 1e-7).all()


def test_quantizer_handles_zeros():
    q, s = quantize_int8(jnp.zeros((3, 8)))
    assert np.asarray(dequantize_int8(q, s)).max() == 0.0


def _disagreement(tree):
    x = np.asarray(tree["x"])
    return float(np.linalg.norm(x - x.mean(0)))


def test_difference_coding_reaches_exact_floor_naive_stalls():
    """Repeated mixing of a FIXED disagreement on a fast-mixing graph:
    NAIVE full-payload int8 gossip stalls at its quantization floor
    (step ~ max|theta|/127 never shrinks -- measured 2.5e-2 on this
    setup, even WITH error feedback), while difference coding converges
    to the exact-gossip floor because payload scales vanish with
    consensus."""
    n = 16
    w = mixing_matrix("torus:4x4", n)
    rng = np.random.default_rng(0)
    x0 = {"x": jnp.asarray(rng.normal(size=(n, 32)), jnp.float32)}

    exact = make_dense_gossip(w)
    g_diff = make_compressed_dense_gossip(w, error_feedback=True)
    g_naive = make_compressed_dense_gossip(w, error_feedback=True, difference_coding=False)

    t_ex, t_df, t_nv = x0, x0, x0
    s_df = init_compression_state(x0)
    s_nv = init_compression_state(x0)
    for _ in range(120):
        t_ex = exact(t_ex)
        t_df, s_df = g_diff(t_df, s_df)
        t_nv, s_nv = g_naive(t_nv, s_nv)
    d_ex, d_df, d_nv = _disagreement(t_ex), _disagreement(t_df), _disagreement(t_nv)
    assert d_df < 10 * max(d_ex, 1e-6), (d_df, d_ex)
    assert d_nv > 100 * d_df, (d_nv, d_df)


def test_mixing_preserves_mean_with_ef():
    """EF gossip must still never move the node average (1^T W = 1^T holds
    leaf-wise because dequantized payloads are mixed with the same W)."""
    n = 8
    w = mixing_matrix("ring", n)
    g = make_compressed_dense_gossip(w, error_feedback=True)
    rng = np.random.default_rng(1)
    tree = {"x": jnp.asarray(rng.normal(size=(n, 16)), jnp.float32)}
    res = init_compression_state(tree)
    mean0 = np.asarray(tree["x"]).mean(0)
    for _ in range(5):
        tree, res = g(tree, res)
    # the mean moves only by the (bounded) quantization error of one round
    drift = np.abs(np.asarray(tree["x"]).mean(0) - mean0).max()
    q_step = np.abs(np.asarray(tree["x"])).max() / 127.0
    assert drift < 5 * q_step


def test_wire_bytes_accounting():
    tree = {"a": jnp.zeros((4, 1000)), "b": jnp.zeros((4, 10, 10))}
    assert compressed_wire_bytes(tree, degree=2) == 2 * (1000 + 4 + 100 + 4)


def test_ef_gossip_in_fl_loop_converges():
    """End-to-end: DSGD with EF-int8 gossip still drives every node to the
    consensus optimum on non-IID quadratics (4x fewer wire bytes)."""
    from repro.core import FLConfig, consensus_params, init_fl_state
    from repro.core.schedules import constant

    n, d = 8, 6
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = mixing_matrix("torus:2x4", n)
    g = make_compressed_dense_gossip(w, error_feedback=True)

    # hand-rolled DSGD round with compressed mixing (the compressed gossip
    # carries residual state, so it threads outside make_fl_round)
    alpha = 0.05
    params = {"x": jnp.zeros((n, d))}
    res = init_compression_state(params)

    @jax.jit
    def round_fn(params, res):
        mixed, res = g(params, res)
        grads = {"x": params["x"] - b}
        new = {"x": mixed["x"] - alpha * grads["x"]}
        return new, res

    exact_gossip = make_dense_gossip(w)

    @jax.jit
    def round_exact(params):
        mixed = exact_gossip(params)
        return {"x": mixed["x"] - alpha * (params["x"] - b)}

    params_ex = {"x": jnp.zeros((n, d))}
    for _ in range(600):
        params, res = round_fn(params, res)
        params_ex = round_exact(params_ex)
    xbar = np.asarray(params["x"]).mean(0)
    np.testing.assert_allclose(xbar, np.asarray(b.mean(0)), atol=2e-2)
    # constant-alpha DSGD has an inherent O(alpha*heterogeneity/gap)
    # consensus spread even with EXACT gossip; compression must not make
    # it materially worse
    spread = np.abs(np.asarray(params["x"]) - xbar).max()
    spread_ex = np.abs(
        np.asarray(params_ex["x"]) - np.asarray(params_ex["x"]).mean(0)
    ).max()
    assert spread < 2.0 * spread_ex + 1e-3, (spread, spread_ex)
