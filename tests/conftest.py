"""Suite config."""


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavyweight tests (per-arch model compiles, real training "
        "runs, model-sized multi-device subprocesses); the fast tier-1 "
        "subset runs -m 'not slow' (see ROADMAP.md). Lightweight subprocess "
        "checks (e.g. the gossip HLO collective count) stay in the fast tier "
        "so CI always asserts them.",
    )
