"""ShardedFusedEngine system tests, run in subprocesses with 8 forced
host devices (jax locks the device count at init; the rest of the suite
must see a single device).

The acceptance gate for the sharded megakernel: on a (2, 2, 2)
(pod, data, model) mesh the shard_map-native fused round must produce
results within atol 1e-5 of the dense ``FusedEngine`` oracle -- for DSGD
and DSGT, with and without top-k, over BOTH wires (circulant ppermute
and arbitrary-W all-gather) -- while the round's jaxpr carries exactly
ONE pallas_call (the wire stage; the collective moves int8 + scales
outside the kernel)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# multi-device subprocess tests (~1 min): excluded from the fast subset
pytestmark = pytest.mark.slow


def _run(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


_EQUIV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (FLConfig, FusedEngine, ShardedFusedEngine,
                            init_fl_state, make_fl_round, mixing_matrix, pack)
    from repro.core.schedules import inv_sqrt
    from repro.launch.mesh import make_test_mesh, node_axes, n_fl_nodes

    mesh = make_test_mesh((2, 2, 2))
    naxes = node_axes(mesh); n = n_fl_nodes(mesh)
    rng = np.random.default_rng(0)
    q, chunk = 2, 16

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {"w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)), jnp.float32)}
    flat, layout = pack(params, pad_to=chunk)
    sched = inv_sqrt(0.05)
    w_er = mixing_matrix("erdos_renyi", n, p=0.7, seed=1)

    def compare(algorithm, topk, w, dc=True):
        cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
        sh = ShardedFusedEngine.from_mesh(
            mesh, naxes, params, scale_chunk=chunk, topk=topk,
            impl="pallas", w=w, difference_coding=dc)
        fe = FusedEngine(sh.dense_equivalent(), layout, scale_chunk=chunk,
                         topk=topk, impl="pallas", difference_coding=dc)
        rf_f = jax.jit(make_fl_round(loss, None, sched, cfg, engine=fe))
        st_f = init_fl_state(cfg, flat, engine=fe)
        with mesh:
            rf_s = jax.jit(make_fl_round(loss, None, sched, cfg, engine=sh))
            st_s = init_fl_state(
                cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
                engine=sh)
            for _ in range(4):
                st_f, m_f = rf_f(st_f, batches)
                st_s, m_s = rf_s(st_s, batches)
        err = float(jnp.abs(st_f.params - st_s.params).max())
        assert err < 1e-5, (algorithm, topk, err)
        if algorithm == "dsgt":
            terr = float(jnp.abs(st_f.tracker - st_s.tracker).max())
            assert terr < 1e-5, (algorithm, topk, terr)
        assert float(m_f["wire_bytes"]) == float(m_s["wire_bytes"])
        return float(m_s["wire_bytes"])

    wire = {}
    for algorithm in ("dsgd", "dsgt"):
        for topk in (None, 4):
            wire[(algorithm, topk, "circulant")] = compare(algorithm, topk, None)
            wire[(algorithm, topk, "dense")] = compare(algorithm, topk, w_er)
    # without difference coding the neighbor-mix term must be REBUILT each
    # round (recon' = dq alone), not accumulated -- regression coverage
    compare("dsgd", None, None, dc=False)
    compare("dsgt", None, w_er, dc=False)
    # top-k wire strictly below the dense-int8 wire on every combination
    for algorithm in ("dsgd", "dsgt"):
        for kind in ("circulant", "dense"):
            assert wire[(algorithm, 4, kind)] < wire[(algorithm, None, kind)]
    print("SHARDED-FUSED-EQUIV-OK")
    """
)


def test_sharded_fused_matches_dense_fused():
    out = _run(_EQUIV_SCRIPT)
    assert "SHARDED-FUSED-EQUIV-OK" in out


_ONE_KERNEL_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (FLConfig, ShardedFusedEngine, init_fl_state,
                            make_fl_round, pack)
    from repro.core.schedules import constant
    from repro.launch.mesh import make_test_mesh, node_axes, n_fl_nodes

    mesh = make_test_mesh((2, 2, 2))
    naxes = node_axes(mesh); n = n_fl_nodes(mesh)
    rng = np.random.default_rng(0)
    q, chunk = 3, 16

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2)

    params = {"w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32)}
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)), jnp.float32)}
    flat, layout = pack(params, pad_to=chunk)

    def count(jaxpr, name):
        c = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == name:
                c += 1
            for v in eqn.params.values():
                subs = v if isinstance(v, (list, tuple)) else [v]
                for sub in subs:
                    if hasattr(sub, "jaxpr"):
                        c += count(sub.jaxpr, name)
                    elif hasattr(sub, "eqns"):
                        c += count(sub, name)
        return c

    for algorithm in ("dsgd", "dsgt"):
        cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
        eng = ShardedFusedEngine.from_mesh(
            mesh, naxes, params, scale_chunk=chunk, topk=4, impl="pallas")
        with mesh:
            rf = make_fl_round(loss, None, constant(0.05), cfg, engine=eng)
            st = init_fl_state(
                cfg, jax.device_put(flat, NamedSharding(mesh, P(naxes, None))),
                engine=eng)
            jaxpr = jax.make_jaxpr(rf)(st, batches)
        # ONE wire-stage kernel for the whole round -- the Q-1 local-step
        # scan and the post-wire mix contribute none, DSGT's two wires
        # share the one program
        assert count(jaxpr.jaxpr, "pallas_call") == 1, algorithm
        # topk turns on the COMPACT wire: the k int8 values, the index
        # encoding (explicit positions or the presence bitmap, whichever
        # is cheaper), and the fp32 scales each ride a ppermute (3 per
        # ring direction per wire) -- nothing masked-dense crosses
        n_pp = count(jaxpr.jaxpr, "ppermute")
        wires = 2 if algorithm == "dsgt" else 1
        assert n_pp == 3 * 2 * wires, (algorithm, n_pp)
    print("SHARDED-FUSED-ONE-KERNEL-OK")
    """
)


def test_sharded_fused_round_is_single_kernel_call():
    out = _run(_ONE_KERNEL_SCRIPT)
    assert "SHARDED-FUSED-ONE-KERNEL-OK" in out
